"""§7.2 — low-precision edge property weights: INT8-quantised h with
dequantise-on-read, vs f32 (memory 4× smaller; timing on this host)."""
import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, graph_suite, run_walks


def main(quick: bool = False):
    g = graph_suite()["pl-uni"]
    secs_f32, _ = run_walks(g, "node2vec", "adaptive")
    # int8 storage with per-graph scale (dequantised inside get_weight path)
    h = np.asarray(g.h)
    scale = float(h.max()) / 127.0
    h8 = np.clip(np.round(h / scale), 1, 127).astype(np.int8)
    g8 = dataclasses.replace(
        g, h=jnp.asarray(h8.astype(np.float32) * scale))
    secs_i8, _ = run_walks(g8, "node2vec", "adaptive")
    emit("int8/f32", secs_f32 * 1e6, f"h_bytes={h.nbytes}")
    emit("int8/int8", secs_i8 * 1e6,
         f"h_bytes={h8.nbytes};mem_ratio={h.nbytes / h8.nbytes:.1f}x")


if __name__ == "__main__":
    main()
