"""Fig. 3 — sampling-method survey on (un)weighted Node2Vec, normalised to
ITS (C-SAW), motivating the RJS/RVS choice."""
from benchmarks.common import emit, graph_suite, run_walks

METHODS = ["its", "als", "rvs_prefix", "rjs_maxreduce", "ervs", "adaptive"]


def main(quick: bool = False):
    g = graph_suite()["pl-uni"]
    for wname in (["node2vec_unweighted"] if quick
                  else ["node2vec_unweighted", "node2vec"]):
        base = None
        for m in METHODS:
            secs, _ = run_walks(g, wname, m)
            if m == "its":
                base = secs
            emit(f"fig3/{wname}/{m}", secs * 1e6,
                 f"norm_to_its={secs / base:.3f}")


if __name__ == "__main__":
    main()
