"""Shared benchmark utilities: graph suite, timed engine runs, CSV output.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (one per
measured configuration) so ``python -m benchmarks.run`` emits one stream.
Sizes are chosen to exercise the same regimes as the paper's datasets
(uniform / power-law / degree weights; skewed degree distributions) while
completing on a single CPU core.
"""
from __future__ import annotations

import time
from functools import lru_cache
from typing import Dict, Optional

import jax
import numpy as np

from repro.core import EngineConfig, WalkEngine
from repro.graphs import power_law_graph, random_graph
from repro.walks import WORKLOADS, make_workload

HEADER = "name,us_per_call,derived"


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)


@lru_cache(maxsize=None)
def graph_suite(size: str = "small"):
    """Graphs mirroring the paper's regimes (names echo its datasets)."""
    if size == "small":
        V, d = 2_000, 12
    else:
        V, d = 20_000, 16
    return {
        "rnd-uni": random_graph(V, d, weight_dist="uniform", seed=0),
        "pl-uni": power_law_graph(V, d, weight_dist="uniform", seed=1),
        "pl-deg": power_law_graph(V, d, weight_dist="degree", seed=2),
    }


@lru_cache(maxsize=None)
def pareto_graph(alpha: float, size: str = "small"):
    V, d = (2_000, 12) if size == "small" else (20_000, 16)
    return power_law_graph(V, d, weight_dist="pareto", alpha=alpha, seed=3)


def run_walks(graph, workload_name: str, method: str,
              num_queries: int = 256, steps: Optional[int] = None,
              seed: int = 0, repeats: int = 2, batch: Optional[int] = None,
              epoch_len: Optional[int] = None,
              config_kw: Optional[Dict] = None, **wl_kw):
    """Compile + time the walk engine.  Returns (best_seconds, result).

    ``batch``/``epoch_len`` expose the streaming scheduler's slot count and
    refill cadence; telemetry (``frac_rjs``) is live-step weighted, so it
    is comparable across any slot configuration.  ``config_kw`` passes
    extra ``EngineConfig`` fields (e.g. ``precomp_exec``) straight through.
    """
    wl = make_workload(workload_name, **wl_kw)
    eng = WalkEngine(graph, wl, EngineConfig(method=method, tile=128,
                                             seed=seed, **(config_kw or {})))
    starts = np.arange(num_queries) % graph.num_nodes
    steps = steps or min(wl.walk_len, 20)
    # warm-up = compile
    res = eng.run(starts, num_steps=steps, key=jax.random.key(seed),
                  batch=batch, epoch_len=epoch_len)
    best = np.inf
    for r in range(repeats):
        t0 = time.perf_counter()
        res = eng.run(starts, num_steps=steps,
                      key=jax.random.key(seed + 1 + r),
                      batch=batch, epoch_len=epoch_len)
        best = min(best, time.perf_counter() - t0)
    return best, res
