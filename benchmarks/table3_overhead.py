"""Table 3 — profiling + preprocessing overhead vs main walk time."""
import time

import jax

from benchmarks.common import emit, graph_suite, run_walks
from repro.core import profile_edge_cost_ratio
from repro.graphs import node_stats


def main(quick: bool = False):
    g = graph_suite()["pl-uni"]
    t0 = time.perf_counter()
    ratio = profile_edge_cost_ratio(g)
    t_prof = time.perf_counter() - t0
    t0 = time.perf_counter()
    st = node_stats(g)
    jax.block_until_ready(st.h_max)
    t_prep = time.perf_counter() - t0
    t_walk, _ = run_walks(g, "node2vec", "adaptive")
    emit("table3/profile", t_prof * 1e6, f"edge_cost_ratio={ratio:.2f}")
    emit("table3/preprocess", t_prep * 1e6)
    emit("table3/walk", t_walk * 1e6,
         f"overhead_pct={(100 * (t_prof + t_prep) / max(t_walk, 1e-9)):.1f}")


if __name__ == "__main__":
    main()
