"""Fig. 11 — runtime-component ablation: adaptive selection vs eRJS-only vs
eRVS-only (and FlowWalker prefix-RVS as the reference baseline)."""
from benchmarks.common import emit, graph_suite, pareto_graph, run_walks

METHODS = ["adaptive", "erjs", "ervs", "rvs_prefix"]


def main(quick: bool = False):
    cases = {"uniform": graph_suite()["pl-uni"]}
    if not quick:
        cases["pareto1.0"] = pareto_graph(1.0)
        cases["pareto2.0"] = pareto_graph(2.0)
    for cname, g in cases.items():
        for m in METHODS:
            secs, res = run_walks(g, "node2vec", m)
            emit(f"fig11/{cname}/{m}", secs * 1e6,
                 f"frac_rjs={res.frac_rjs:.2f};fallbacks={res.rjs_fallbacks}")


if __name__ == "__main__":
    main()
