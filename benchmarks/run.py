"""Benchmark aggregator — one module per paper table/figure.

``python -m benchmarks.run``          : quick suite (CI-sized)
``python -m benchmarks.run --full``   : full sizes
``python -m benchmarks.run --only t`` : run one module

Prints ``name,us_per_call,derived`` CSV rows.
"""
import argparse
import sys
import time
import traceback

from benchmarks.common import HEADER


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (fig3_survey, fig10_powerlaw,
                            fig11_runtime_ablation, fig12_kernel_ablation,
                            fig13_selection, fig14_ratio, fig15_scaling,
                            fig16_service, int8_weights, roofline, table2,
                            table3_overhead)

    modules = {
        "table2": table2,
        "fig3": fig3_survey,
        "fig10": fig10_powerlaw,
        "fig11": fig11_runtime_ablation,
        "fig12": fig12_kernel_ablation,
        "fig13": fig13_selection,
        "fig14": fig14_ratio,
        "table3": table3_overhead,
        "fig15": fig15_scaling,
        "fig16": fig16_service,
        "int8": int8_weights,
        "roofline": roofline,
    }
    if args.only:
        modules = {args.only: modules[args.only]}

    print(HEADER, flush=True)
    failures = 0
    for name, mod in modules.items():
        t0 = time.time()
        try:
            mod.main(quick=quick)
            print(f"{name}/_module_wall,{(time.time() - t0) * 1e6:.0f},ok",
                  flush=True)
        except Exception as e:
            failures += 1
            print(f"{name}/_module_wall,-1,FAIL:{type(e).__name__}:{e}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
