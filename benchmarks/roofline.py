"""Aggregate the dry-run JSON records into the §Roofline markdown table.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
Writes results/roofline.md and prints the single-pod table.
"""
import argparse
import glob
import json
import os
from typing import List


def load(dir_: str) -> List[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def table(recs: List[dict], mesh: str) -> str:
    rows = ["| arch | shape | kind | t_comp | t_mem | t_coll | dominant | "
            "useful/HLO | roofline | args/dev | temp/dev |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") == "SKIPPED":
            if mesh == "16x16":
                arch, shape, _ = r["cell"].split("__")
                rows.append(f"| {arch} | {shape} | - | - | - | - | SKIPPED | "
                            f"- | - | - | - |")
            continue
        if r.get("status") != "OK" or r.get("mesh") != mesh:
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} | "
            f"{fmt_s(r['t_collective_s'])} | {r['dominant']} | "
            f"{r['useful_flops_fraction']:.2f} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{r['argument_bytes'] / 1e9:.2f}GB | "
            f"{r['temp_bytes'] / 1e9:.2f}GB |")
    return "\n".join(rows)


def main(quick: bool = False):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args, _ = ap.parse_known_args()
    recs = load(args.dir)
    if not recs:
        print(f"roofline/no_records,0.0,dir={args.dir}")
        return
    ok = [r for r in recs if r.get("status") == "OK"]
    fail = [r for r in recs if r.get("status") == "FAIL"]
    skip = [r for r in recs if r.get("status") == "SKIPPED"]
    print(f"roofline/cells,0.0,ok={len(ok)};fail={len(fail)};"
          f"skipped={len(skip)}")
    for r in ok:
        print(f"roofline/{r['cell']},0.0,"
              f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f}")
    md = ["# Roofline (single-pod 16×16, 256 chips)\n",
          table(recs, "16x16"),
          "\n\n# Multi-pod check (2×16×16, 512 chips)\n",
          table(recs, "2x16x16")]
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write("\n".join(md))
    print("roofline/table_written,0.0,results/roofline.md")
    # optimized sweep, if present
    opt = load("results/dryrun_opt")
    if opt:
        ok_o = [r for r in opt if r.get("status") == "OK"]
        print(f"roofline/opt_cells,0.0,ok={len(ok_o)};"
              f"fail={sum(1 for r in opt if r.get('status') == 'FAIL')}")
        with open("results/roofline_opt.md", "w") as f:
            f.write("# Roofline — OPTIMIZED configuration "
                    "(single-pod 16×16)\n\n" + table(opt, "16x16"))
        print("roofline/opt_table_written,0.0,results/roofline_opt.md")


if __name__ == "__main__":
    main()
