"""Table 2 — execution time across dynamic-walk workloads × graphs × systems.

Five workloads ((un)weighted Node2Vec, (un)weighted MetaPath, 2nd-order
PageRank) on the synthetic graph suite, comparing FLEXIWALKER (adaptive)
against the baseline sampling systems (ITS/C-SAW, ALS/Skywalker,
prefix-RVS/FlowWalker, max-reduce-RJS/NextDoor).
"""
from __future__ import annotations

from benchmarks.common import emit, graph_suite, run_walks

WORKLOADS = [
    ("node2vec_unweighted", {}),
    ("node2vec", {}),
    ("metapath_unweighted", {}),
    ("metapath", {}),
    ("2ndpr", {}),
]
METHODS = ["adaptive", "its", "als", "rvs_prefix", "rjs_maxreduce"]


def main(quick: bool = False):
    graphs = graph_suite()
    if quick:
        graphs = {"pl-uni": graphs["pl-uni"]}
    rows = {}
    for wname, kw in (WORKLOADS[:2] if quick else WORKLOADS):
        for gname, g in graphs.items():
            for method in (METHODS if not quick else METHODS[:3]):
                secs, res = run_walks(g, wname, method, **kw)
                key = f"table2/{wname}/{gname}/{method}"
                emit(key, secs * 1e6, f"frac_rjs={res.frac_rjs:.2f}")
                rows[(wname, gname, method)] = secs
    # derived: geomean speedup of adaptive over best baseline
    import numpy as np
    sp = []
    for wname, kw in (WORKLOADS[:2] if quick else WORKLOADS):
        for gname in graphs:
            base = min(rows.get((wname, gname, m), np.inf)
                       for m in METHODS[1:] if (wname, gname, m) in rows)
            ours = rows.get((wname, gname, "adaptive"))
            if ours and np.isfinite(base):
                sp.append(base / ours)
    if sp:
        emit("table2/geomean_speedup_vs_best_baseline", 0.0,
             f"{np.exp(np.mean(np.log(sp))):.2f}x")


if __name__ == "__main__":
    main()
