"""Fig. 13 — sampling-method selection strategies: the Eq. 11 cost model vs
random selection vs degree-threshold selection.

The static-workload rows exercise the *extended* (three-regime) cost
model: on DeepWalk the Flexi-Compiler proves get_weight state-independent,
so ``adaptive`` routes eligible nodes to the precomputed ITS tables —
``frac_precomp`` measures how much of the traffic the third regime
actually absorbed (the baseline selectors have no precomp notion and stay
at 0)."""
from benchmarks.common import emit, graph_suite, pareto_graph, run_walks


def main(quick: bool = False):
    cases = {"pl-uni": graph_suite()["pl-uni"]}
    if not quick:
        cases["pareto1.5"] = pareto_graph(1.5)
    for cname, g in cases.items():
        for m in ["adaptive", "random", "degree"]:
            secs, res = run_walks(g, "node2vec", m)
            emit(f"fig13/{cname}/{m}", secs * 1e6,
                 f"frac_rjs={res.frac_rjs:.2f}")
    # static-weight workload: the three-regime cost model in action
    for cname, g in cases.items():
        for m in ["adaptive", "random", "degree"]:
            secs, res = run_walks(g, "deepwalk", m)
            emit(f"fig13/static-{cname}/{m}", secs * 1e6,
                 f"frac_rjs={res.frac_rjs:.2f};"
                 f"frac_precomp={res.frac_precomp:.2f}")


if __name__ == "__main__":
    main()
