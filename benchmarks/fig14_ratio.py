"""Fig. 14 — fraction of steps served by each kernel vs weight skew: the
cost model should shift from eRJS toward eRVS as α drops (more skew)."""
from benchmarks.common import emit, pareto_graph, run_walks


def main(quick: bool = False):
    alphas = [1.0, 4.0] if quick else [1.0, 1.5, 2.0, 3.0, 4.0]
    fracs = []
    for a in alphas:
        g = pareto_graph(a)
        secs, res = run_walks(g, "node2vec", "adaptive")
        fracs.append(res.frac_rjs)
        emit(f"fig14/alpha{a}", secs * 1e6, f"frac_rjs={res.frac_rjs:.3f}")
    if fracs == sorted(fracs):
        emit("fig14/monotone_rjs_fraction", 0.0, "true")


if __name__ == "__main__":
    main()
