"""Fig. 16 — walk-as-a-service sustained throughput.

Drives the continuously-batched serving loop
(:class:`repro.serving.WalkService`) through a saturating arrival trace
and reports queries/s plus the p99 completion latency at fixed slot
counts — the serving counterpart of the batch-mode scaling rows.  Two
sub-rows per slot count compare the engine's ``step_exec`` paths
(staged ``lax.scan`` vs the fused mega-step kernel) under serving load:
the results are bit-identical, so any delta is pure execution speed.

Row format: ``fig16/<graph>/<step_exec>/slots<N>`` with
``us_per_call`` = wall microseconds per completed query and ``derived``
= ``qps=<queries/s> p50=<ms> p99=<ms> occ=<peak>/<slots>``.

Two further row families cover the network front-end:

* ``fig16/transport/{direct,socket}/slots<N>`` — the same saturating
  trace driven through the in-process API vs the loopback TCP
  front-end (``WalkFrontend`` + ``WalkServiceClient``), so the delta
  is the framing + event-loop overhead per query.
* ``fig16/fairness/w3v1`` — two tenants at 3:1 DRR weights under
  sustained overload; ``derived`` reports the measured walker-step
  share against the configured 0.75 target.
"""
import time

import numpy as np

from benchmarks.common import emit, graph_suite
from repro.core import EngineConfig
from repro.launch.walk_client import WalkServiceClient
from repro.serving import (FrontendConfig, ServiceConfig, WalkFrontend,
                           WalkQuery, WalkService)

STEPS = 20


def serve_trace(graph, *, slots: int, step_exec: str, queries: int,
                seed: int = 0):
    """Saturate the service: submit everything up front, step to idle.
    Returns (wall_seconds, completed, ServiceStats)."""
    svc = WalkService(
        graph,
        ServiceConfig(slots=slots, epoch_len=5, num_steps=STEPS,
                      max_pending=queries, seed=seed),
        EngineConfig(method="its_precomp", step_exec=step_exec,
                     tile=128, seed=seed))
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, graph.num_nodes, size=queries)
    # warm-up: compile the epoch before the timed trace
    svc.submit(WalkQuery(start=int(starts[0]), program="deepwalk"))
    svc.drain()
    t0 = time.perf_counter()
    for s in starts:
        svc.submit(WalkQuery(start=int(s), program="deepwalk"))
    served = svc.drain()
    wall = time.perf_counter() - t0
    stats = svc.stats()
    assert stats.conserves(), stats
    return wall, len(served), stats


def serve_socket(graph, *, slots: int, queries: int, seed: int = 0):
    """The same saturating trace, but through the loopback TCP
    front-end: pipelined submits, polled walks, length-prefixed JSON
    frames.  Returns (wall_seconds, completed, stats-dict)."""
    svc = WalkService(
        graph,
        ServiceConfig(slots=slots, epoch_len=5, num_steps=STEPS,
                      max_pending=queries, seed=seed),
        EngineConfig(method="its_precomp", step_exec="fused",
                     tile=128, seed=seed))
    frontend = WalkFrontend(svc, FrontendConfig(client_buffer=queries))
    host, port = frontend.start()
    try:
        with WalkServiceClient(host=host, port=port) as client:
            rng = np.random.default_rng(seed)
            starts = rng.integers(0, graph.num_nodes, size=queries)
            client.walk([int(starts[0])])  # warm-up: compile the epoch
            t0 = time.perf_counter()
            walks = client.walk(starts.tolist(), poll_interval=0.001)
            wall = time.perf_counter() - t0
            stats = client.stats()
    finally:
        frontend.drain()
        frontend.stop()
    assert all(w.status == "completed" for w in walks)
    return wall, len(walks), stats


def fairness_trace(graph, *, slots: int, per_tenant: int, rounds: int,
                   seed: int = 0):
    """Two backlogged tenants at 3:1 DRR weights: run a fixed number
    of scheduler rounds and measure the walker-step split."""
    weights = {"deepwalk": 3.0, "node2vec": 1.0}
    svc = WalkService(
        graph,
        ServiceConfig(slots=slots, epoch_len=5, num_steps=STEPS,
                      max_pending=4 * per_tenant, weights=weights,
                      seed=seed),
        EngineConfig(method="its_precomp", step_exec="fused",
                     tile=128, seed=seed))
    # size the backlog so neither tenant drains mid-trace: the hot
    # tenant consumes ~3 * quantum = 3 * slots * epoch_len walker-steps
    # per round, and each query supplies STEPS of them
    need = 3 * slots * 5 * (rounds + 1)
    assert per_tenant * STEPS >= need, (per_tenant, rounds, slots)
    rng = np.random.default_rng(seed)
    for s in rng.integers(0, graph.num_nodes, size=per_tenant):
        for prog in weights:
            svc.submit(WalkQuery(start=int(s), program=prog))
    svc.step()  # warm-up: compile both tenants' epochs
    t0 = time.perf_counter()
    for _ in range(rounds):
        svc.step()
    wall = time.perf_counter() - t0
    stats = svc.stats()
    assert stats.conserves(), stats
    assert stats.pending > 0, "trace must stay overloaded to contest DRR"
    svc.drain()
    steps = {n: t["walker_steps"] for n, t in stats.per_tenant.items()}
    share = steps["deepwalk"] / max(sum(steps.values()), 1)
    return wall, share, steps


def main(quick: bool = False):
    graph = graph_suite()["pl-uni"]
    queries = 128 if quick else 1024
    slot_counts = [32, 128] if quick else [32, 128, 512]
    for step_exec in ("staged", "fused"):
        for slots in slot_counts:
            wall, done, st = serve_trace(graph, slots=slots,
                                         step_exec=step_exec,
                                         queries=queries)
            emit(f"fig16/pl-uni/{step_exec}/slots{slots}",
                 wall / max(done, 1) * 1e6,
                 f"qps={done / max(wall, 1e-9):.0f} "
                 f"p50={st.latency_p50 * 1e3:.1f}ms "
                 f"p99={st.latency_p99 * 1e3:.1f}ms "
                 f"occ={st.peak_occupancy}/{st.slots}")

    # socket vs direct: the front-end tax per query
    tslots = 32 if quick else 128
    tqueries = 64 if quick else 512
    wall, done, st = serve_trace(graph, slots=tslots, step_exec="fused",
                                 queries=tqueries)
    emit(f"fig16/transport/direct/slots{tslots}",
         wall / max(done, 1) * 1e6,
         f"qps={done / max(wall, 1e-9):.0f} "
         f"p50={st.latency_p50 * 1e3:.1f}ms "
         f"p99={st.latency_p99 * 1e3:.1f}ms")
    wall, done, sd = serve_socket(graph, slots=tslots, queries=tqueries)
    emit(f"fig16/transport/socket/slots{tslots}",
         wall / max(done, 1) * 1e6,
         f"qps={done / max(wall, 1e-9):.0f} "
         f"p50={sd['latency_p50'] * 1e3:.1f}ms "
         f"p99={sd['latency_p99'] * 1e3:.1f}ms")

    # weighted fairness: measured walker-step share vs configured 3:1
    wall, share, steps = fairness_trace(
        graph, slots=16 if quick else 32,
        per_tenant=128 if quick else 640, rounds=8 if quick else 20)
    emit("fig16/fairness/w3v1",
         wall / max(sum(steps.values()), 1) * 1e6,
         f"share={share:.3f} target=0.750 "
         f"hot={steps['deepwalk']} cold={steps['node2vec']}")


if __name__ == "__main__":
    main(quick=True)
