"""Fig. 16 — walk-as-a-service sustained throughput.

Drives the continuously-batched serving loop
(:class:`repro.serving.WalkService`) through a saturating arrival trace
and reports queries/s plus the p99 completion latency at fixed slot
counts — the serving counterpart of the batch-mode scaling rows.  Two
sub-rows per slot count compare the engine's ``step_exec`` paths
(staged ``lax.scan`` vs the fused mega-step kernel) under serving load:
the results are bit-identical, so any delta is pure execution speed.

Row format: ``fig16/<graph>/<step_exec>/slots<N>`` with
``us_per_call`` = wall microseconds per completed query and ``derived``
= ``qps=<queries/s> p50=<ms> p99=<ms> occ=<peak>/<slots>``.
"""
import time

import numpy as np

from benchmarks.common import emit, graph_suite
from repro.core import EngineConfig
from repro.serving import ServiceConfig, WalkQuery, WalkService

STEPS = 20


def serve_trace(graph, *, slots: int, step_exec: str, queries: int,
                seed: int = 0):
    """Saturate the service: submit everything up front, step to idle.
    Returns (wall_seconds, completed, ServiceStats)."""
    svc = WalkService(
        graph,
        ServiceConfig(slots=slots, epoch_len=5, num_steps=STEPS,
                      max_pending=queries, seed=seed),
        EngineConfig(method="its_precomp", step_exec=step_exec,
                     tile=128, seed=seed))
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, graph.num_nodes, size=queries)
    # warm-up: compile the epoch before the timed trace
    svc.submit(WalkQuery(start=int(starts[0]), program="deepwalk"))
    svc.drain()
    t0 = time.perf_counter()
    for s in starts:
        svc.submit(WalkQuery(start=int(s), program="deepwalk"))
    served = svc.drain()
    wall = time.perf_counter() - t0
    stats = svc.stats()
    assert stats.conserves(), stats
    return wall, len(served), stats


def main(quick: bool = False):
    graph = graph_suite()["pl-uni"]
    queries = 128 if quick else 1024
    slot_counts = [32, 128] if quick else [32, 128, 512]
    for step_exec in ("staged", "fused"):
        for slots in slot_counts:
            wall, done, st = serve_trace(graph, slots=slots,
                                         step_exec=step_exec,
                                         queries=queries)
            emit(f"fig16/pl-uni/{step_exec}/slots{slots}",
                 wall / max(done, 1) * 1e6,
                 f"qps={done / max(wall, 1e-9):.0f} "
                 f"p50={st.latency_p50 * 1e3:.1f}ms "
                 f"p99={st.latency_p99 * 1e3:.1f}ms "
                 f"occ={st.peak_occupancy}/{st.slots}")


if __name__ == "__main__":
    main(quick=True)
