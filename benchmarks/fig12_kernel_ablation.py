"""Fig. 12 — kernel-optimisation ablations.

(a) reservoir: prefix-RVS (FlowWalker) vs eRVS/EXP (exp-key, no prefix sum)
    vs eRVS/EXP+JUMP — wall time AND the RNG-draw reduction the JUMP
    technique delivers (counted exactly by the jump engine / kernel ref).
(b) rejection: max-reduce RJS (NextDoor) vs eRJS with the compiler bound —
    uniform and skewed (α=1) property weights.
(c) static regime: the precomputed samplers (``its_precomp`` O(log d)
    lookup, ``alias_precomp`` O(1) pick) and the ThunderRW-style
    ``interleaved`` pipeline vs the dynamic ``ervs``/``erjs`` kernels on a
    static-weight workload (DeepWalk) — per-live-step time, measured, with
    ``frac_precomp`` confirming the lanes really were table-served.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, graph_suite, pareto_graph, run_walks
from repro.kernels import ops, ref


def main(quick: bool = False):
    cases = {"uniform": graph_suite()["pl-uni"]}
    if not quick:
        cases["pareto1.0"] = pareto_graph(1.0)
    # (a) reservoir ablation
    for cname, g in cases.items():
        for m in ["rvs_prefix", "ervs", "ervs_jump"]:
            secs, _ = run_walks(g, "node2vec", m)
            emit(f"fig12a/{cname}/{m}", secs * 1e6)
    # RNG-draw reduction at kernel level (exact counts from the oracle)
    for deg in [512, 4096]:
        rng = np.random.default_rng(0)
        vals = rng.uniform(0.5, 5.0, deg).astype(np.float32)
        (w2d, row0, dg) = ops.align_rows(vals, np.array([0, deg]))
        N = 128
        seeds = ops.make_seeds(jax.random.key(1), N)
        _, draws, jumped = ref.ervs_select_ref(
            w2d, jnp.tile(row0, N), jnp.tile(dg, N), seeds)
        emit(f"fig12a/rng_draws/deg{deg}", 0.0,
             f"jump={float(np.mean(np.asarray(draws))):.1f};"
             f"nojump={deg};blocks_jumped="
             f"{float(np.mean(np.asarray(jumped))):.1f}")
    # (b) rejection ablation
    for cname, g in cases.items():
        for m in ["rjs_maxreduce", "erjs"]:
            secs, res = run_walks(g, "node2vec", m)
            emit(f"fig12b/{cname}/{m}", secs * 1e6,
                 f"fallbacks={res.rjs_fallbacks}")
    # (c) precomputed regimes + step interleaving, static-weight workload
    for cname, g in cases.items():
        for m in ["ervs", "erjs", "its_precomp", "alias_precomp",
                  "interleaved"]:
            secs, res = run_walks(g, "deepwalk", m)
            per_step = secs * 1e6 / max(res.live_steps, 1)
            emit(f"fig12c/{cname}/{m}", secs * 1e6,
                 f"us_per_live_step={per_step:.3f};"
                 f"frac_precomp={res.frac_precomp:.2f}")


if __name__ == "__main__":
    main()
