"""Fig. 12 — kernel-optimisation ablations.

(a) reservoir: prefix-RVS (FlowWalker) vs eRVS/EXP (exp-key, no prefix sum)
    vs eRVS/EXP+JUMP — wall time AND the RNG-draw reduction the JUMP
    technique delivers (counted exactly by the jump engine / kernel ref).
(b) rejection: max-reduce RJS (NextDoor) vs eRJS with the compiler bound —
    uniform and skewed (α=1) property weights.
(c) static regime: the precomputed samplers (``its_precomp`` O(log d)
    lookup, ``alias_precomp`` O(1) pick) and the ThunderRW-style
    ``interleaved`` pipeline vs the dynamic ``ervs``/``erjs`` kernels on a
    static-weight workload (DeepWalk) — per-live-step time, measured, with
    ``frac_precomp`` confirming the lanes really were table-served.
    The wired-kernel rows compare the engine's two ``precomp_exec`` paths
    (bit-identical; off-TPU the Pallas path runs in interpret mode, so
    its CPU number measures dispatch overhead, not the DMA win).
(d) amortized rebuild: rows/s the background drain re-bakes after an
    update_graph invalidation (the Table-3 "Preproc." cost paid
    incrementally instead of up front).
(e) structural updates: edges/s the delta-overlay path
    (``apply_updates``) absorbs vs tearing down and rebuilding the CSR +
    stats + tables from the mutated edge list per burst, plus the cost
    of the compaction cadence (``EngineConfig.compact_interval``) under
    an interleaved mutate/walk stream.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, graph_suite, pareto_graph, run_walks
from repro.core import EngineConfig, WalkEngine
from repro.kernels import ops, ref
from repro.walks import make_workload


def main(quick: bool = False):
    cases = {"uniform": graph_suite()["pl-uni"]}
    if not quick:
        cases["pareto1.0"] = pareto_graph(1.0)
    # (a) reservoir ablation
    for cname, g in cases.items():
        for m in ["rvs_prefix", "ervs", "ervs_jump"]:
            secs, _ = run_walks(g, "node2vec", m)
            emit(f"fig12a/{cname}/{m}", secs * 1e6)
    # RNG-draw reduction at kernel level (exact counts from the oracle)
    for deg in [512, 4096]:
        rng = np.random.default_rng(0)
        vals = rng.uniform(0.5, 5.0, deg).astype(np.float32)
        (w2d, row0, dg) = ops.align_rows(vals, np.array([0, deg]))
        N = 128
        seeds = ops.make_seeds(jax.random.key(1), N)
        _, draws, jumped = ref.ervs_select_ref(
            w2d, jnp.tile(row0, N), jnp.tile(dg, N), seeds)
        emit(f"fig12a/rng_draws/deg{deg}", 0.0,
             f"jump={float(np.mean(np.asarray(draws))):.1f};"
             f"nojump={deg};blocks_jumped="
             f"{float(np.mean(np.asarray(jumped))):.1f}")
    # (b) rejection ablation
    for cname, g in cases.items():
        for m in ["rjs_maxreduce", "erjs"]:
            secs, res = run_walks(g, "node2vec", m)
            emit(f"fig12b/{cname}/{m}", secs * 1e6,
                 f"fallbacks={res.rjs_fallbacks}")
    # (c) precomputed regimes + step interleaving, static-weight workload
    for cname, g in cases.items():
        for m in ["ervs", "erjs", "its_precomp", "alias_precomp",
                  "interleaved"]:
            secs, res = run_walks(g, "deepwalk", m)
            per_step = secs * 1e6 / max(res.live_steps, 1)
            emit(f"fig12c/{cname}/{m}", secs * 1e6,
                 f"us_per_live_step={per_step:.3f};"
                 f"frac_precomp={res.frac_precomp:.2f}")
    # the wired Pallas kernel path vs the jnp selector path (small batch:
    # interpret mode off-TPU executes the kernel per grid step)
    g = cases["uniform"]
    for m in ["its_precomp", "alias_precomp"]:
        for exec_path in ["jnp", "pallas"]:
            secs, res = run_walks(g, "deepwalk", m, num_queries=32, steps=8,
                                  config_kw={"precomp_exec": exec_path})
            per_step = secs * 1e6 / max(res.live_steps, 1)
            emit(f"fig12c/uniform/{m}[{exec_path}]", secs * 1e6,
                 f"us_per_live_step={per_step:.3f};"
                 f"frac_precomp={res.frac_precomp:.2f}")
    # mega-step ablation: the fused single-kernel epoch vs the staged
    # lax.scan step loop (bit-identical; off-TPU the fused path runs in
    # Pallas interpret mode, so its CPU number measures the per-lane
    # interpreter dispatch, not the on-chip fusion win it ships on TPU)
    for exec_path in ["staged", "fused"]:
        secs, res = run_walks(g, "deepwalk", "ervs", num_queries=32, steps=8,
                              config_kw={"step_exec": exec_path})
        per_step = secs * 1e6 / max(res.live_steps, 1)
        emit(f"fig12c/uniform/megastep[{exec_path}]", secs * 1e6,
             f"us_per_live_step={per_step:.3f};"
             f"live_steps={res.live_steps}")
    # (d) amortized rebuild throughput, measured at the BUDGETED cadence
    # run() actually pays: one budget-sized drain (with its full-array
    # scatter) per scheduler epoch, repeated until the queue empties
    n_rows = 64 if quick else 256
    budget = 8
    eng = WalkEngine(g, make_workload("deepwalk"),
                     EngineConfig(method="its_precomp", tile=128,
                                  rebuild_budget=budget))
    nodes = np.arange(n_rows) % g.num_nodes
    eng.update_graph(g, invalidated=nodes)  # weights unchanged: pure cost
    t0 = time.perf_counter()
    rebuilt = 0
    while len(eng.rebuild_queue):
        rebuilt += eng.drain_rebuilds(budget)
    jax.block_until_ready(eng.precomp)  # include the async table scatters
    dt = time.perf_counter() - t0
    emit("fig12d/rebuild_drain", dt * 1e6 / max(rebuilt, 1),
         f"rows={rebuilt};budget={budget};"
         f"rows_per_s={rebuilt / max(dt, 1e-9):.0f}")
    # drain write-path ablation: the legacy O(E) whole-table copy scatter
    # vs the jitted buffer-donating row scatter (rebuild_rows' default),
    # at the same budget-sized cadence.  Fresh tables per mode: "donate"
    # consumes its input buffers.
    from repro.core import precomp as precomp_mod
    wl_d = make_workload("deepwalk")
    params_d = wl_d.params()
    nodes = np.arange(n_rows) % g.num_nodes
    for mode in ["copy", "donate"]:
        tabs = precomp_mod.build_tables(g, wl_d, params_d).invalidate(nodes)
        t0 = time.perf_counter()
        for lo in range(0, n_rows, budget):
            tabs = precomp_mod.rebuild_rows(
                tabs, g, wl_d, params_d, nodes[lo:lo + budget], scatter=mode)
        jax.block_until_ready(tabs)
        dt = time.perf_counter() - t0
        emit(f"fig12d/rebuild_scatter[{mode}]", dt * 1e6 / n_rows,
             f"rows={n_rows};budget={budget};"
             f"rows_per_s={n_rows / max(dt, 1e-9):.0f}")
    # batched drains (EngineConfig.rebuild_interval): every 4th epoch
    # re-bakes a 4×budget batch — same amortized rate, 1/4 the drain calls
    eng4 = WalkEngine(g, make_workload("deepwalk"),
                      EngineConfig(method="its_precomp", tile=128,
                                   rebuild_budget=budget,
                                   rebuild_interval=4))
    eng4.update_graph(g, invalidated=nodes)
    t0 = time.perf_counter()
    rebuilt = 0
    while len(eng4.rebuild_queue):
        rebuilt += eng4.drain_rebuilds(budget * 4)
    jax.block_until_ready(eng4.precomp)
    dt = time.perf_counter() - t0
    emit("fig12d/rebuild_drain[interval=4]", dt * 1e6 / max(rebuilt, 1),
         f"rows={rebuilt};batch={budget * 4};"
         f"rows_per_s={rebuilt / max(dt, 1e-9):.0f}")
    # (e) structural updates through the delta overlay: the absorb rate
    # of apply_updates (merged view + patched stats + spliced tables +
    # queued row repairs) vs the teardown baseline that re-sorts the
    # edge list and rebuilds CSR, stats, and EVERY table row per burst
    from repro.graphs import from_edges, node_stats
    V = g.num_nodes
    burst, n_bursts = 64, (4 if quick else 16)
    rng = np.random.default_rng(7)
    bursts = [(rng.integers(0, V, burst), rng.integers(0, V, burst),
               rng.uniform(0.5, 1.5, burst).astype(np.float32))
              for _ in range(n_bursts)]
    eng_e = WalkEngine(g, make_workload("deepwalk"),
                       EngineConfig(method="its_precomp", tile=128,
                                    rebuild_budget=budget))
    t0 = time.perf_counter()
    applied = 0
    for ins in bursts:
        rep = eng_e.apply_updates(inserts=ins)
        applied += rep.inserted + rep.reweighted
    jax.block_until_ready((eng_e.stats.h_sum, eng_e.precomp.cdf))
    dt = time.perf_counter() - t0
    emit("fig12e/apply_updates[overlay]", dt * 1e6 / max(applied, 1),
         f"edges={applied};bursts={n_bursts};"
         f"edges_per_s={applied / max(dt, 1e-9):.0f}")
    indptr_e = np.asarray(g.indptr, np.int64)
    src_e = np.repeat(np.arange(V), np.diff(indptr_e))
    dst_e = np.asarray(g.indices, np.int64).copy()
    h_e = np.asarray(g.h).copy()
    t0 = time.perf_counter()
    for ins in bursts:
        src_e = np.concatenate([src_e, ins[0]])
        dst_e = np.concatenate([dst_e, ins[1]])
        h_e = np.concatenate([h_e, ins[2]])
        g_full = from_edges(src_e, dst_e, V, h=h_e)
        stats_full = node_stats(g_full)
        tabs_full = precomp_mod.build_tables(g_full, wl_d, params_d)
    jax.block_until_ready((stats_full.h_sum, tabs_full.cdf))
    dt = time.perf_counter() - t0
    emit("fig12e/apply_updates[full_rebuild]", dt * 1e6 / max(applied, 1),
         f"edges={applied};bursts={n_bursts};"
         f"edges_per_s={applied / max(dt, 1e-9):.0f}")
    # splice-path ablation at paper scale (V≈50k power law): the
    # O(touched) splice — tables kept in the overlay layout
    # (grow_tables), incremental device sync of dirty spans only,
    # pow2-bucketed jitted stats patch — vs the seed's per-burst O(E)
    # path: full host overlay concat, whole-array device uploads, the
    # splice_tables re-layout gather over every edge, and an eagerly-
    # executed stats patch that recompiles per distinct touched-set
    # shape.  Both sides get the same warmup bursts so the steady-state
    # absorb rate is measured, not first-burst compilation.  Skipped in
    # quick mode (graph build dominates).
    if not quick:
        from repro.graphs import power_law_graph
        from repro.graphs.delta import GraphDelta
        g50 = power_law_graph(50_000, 12, seed=3)
        V50 = g50.num_nodes
        rng = np.random.default_rng(11)

        def mk50():
            return (rng.integers(0, V50, burst),
                    rng.integers(0, V50, burst),
                    rng.uniform(0.5, 1.5, burst).astype(np.float32))

        warm50 = [mk50() for _ in range(3)]
        bursts50 = [mk50() for _ in range(16)]
        eng50 = WalkEngine(g50, make_workload("deepwalk"),
                           EngineConfig(method="its_precomp", tile=128,
                                        rebuild_budget=budget))
        for ins in warm50:
            eng50.apply_updates(inserts=ins)
        jax.block_until_ready((eng50.stats.h_sum, eng50.precomp.cdf,
                               eng50.graph.indices))
        t0 = time.perf_counter()
        applied = 0
        for ins in bursts50:
            rep = eng50.apply_updates(inserts=ins)
            applied += rep.inserted + rep.reweighted
        jax.block_until_ready((eng50.stats.h_sum, eng50.precomp.cdf,
                               eng50.graph.indices))
        dt = time.perf_counter() - t0
        new_rate = applied / max(dt, 1e-9)
        emit("fig12e/overlay_splice[v50k]", dt * 1e6 / max(applied, 1),
             f"edges={applied};E={int(g50.num_edges)};"
             f"edges_per_s={new_rate:.0f}")

        # faithful seed reproduction: same GraphDelta host merge, then
        # the per-burst O(E) work the old apply_updates paid
        def seed_patch_stats(d, stats, nodes):
            import dataclasses as dc
            nodes = np.unique(np.atleast_1d(np.asarray(nodes, np.int64)))
            num_labels = int(stats.label_count.shape[1])
            rows = [d.row(int(v)) for v in nodes]
            degs = np.array([r[0].size for r in rows], np.int64)
            T, total = int(nodes.size), int(degs.sum())
            h_all = (np.concatenate([r[1] for r in rows])
                     if total else np.zeros(0, np.float32))
            lab_all = (np.concatenate([r[2] for r in rows])
                       if total else np.zeros(0, np.int32))
            seg = jnp.asarray(np.repeat(np.arange(T), degs), jnp.int32)
            h_j = jnp.asarray(h_all)
            deg_j = jnp.asarray(degs, jnp.int32)
            h_min = jax.ops.segment_min(h_j, seg, num_segments=T)
            h_max = jax.ops.segment_max(h_j, seg, num_segments=T)
            h_sum = jax.ops.segment_sum(h_j, seg, num_segments=T)
            h_mean = h_sum / jnp.maximum(deg_j, 1).astype(jnp.float32)
            h_min = jnp.where(deg_j > 0, h_min, 0.0)
            h_max = jnp.where(deg_j > 0, h_max, 0.0)
            lbl_seg = seg * num_labels + jnp.clip(
                jnp.asarray(lab_all), 0, num_labels - 1)
            label_count = jax.ops.segment_sum(
                jnp.ones((total,), jnp.int32), lbl_seg,
                num_segments=T * num_labels).reshape(T, num_labels)
            idx = jnp.asarray(nodes, jnp.int32)
            return dc.replace(
                stats, h_min=stats.h_min.at[idx].set(h_min),
                h_max=stats.h_max.at[idx].set(h_max),
                h_sum=stats.h_sum.at[idx].set(h_sum),
                h_mean=stats.h_mean.at[idx].set(h_mean),
                degree=stats.degree.at[idx].set(deg_j),
                label_count=stats.label_count.at[idx].set(label_count))

        def seed_burst(d, tabs, stats, ins, starts, degs):
            old_starts, old_degs = starts.copy(), degs.copy()
            rep = d.apply(ins, None)
            starts, degs = (a.copy() for a in d.layout())
            ih, hh, lh = d._host_full()  # full host overlay concat
            dev = (jnp.asarray(ih), jnp.asarray(hh), jnp.asarray(lh),
                   jnp.asarray(starts), jnp.asarray(degs))
            tabs = precomp_mod.splice_tables(
                tabs, old_starts, old_degs, starts, degs,
                int(ih.shape[0])).invalidate(rep.touched)
            stats = seed_patch_stats(d, stats, rep.touched)
            jax.block_until_ready(dev + (tabs.cdf, stats.h_sum))
            return tabs, stats, starts, degs, rep

        d2 = GraphDelta(g50)
        tabs50 = precomp_mod.build_tables(g50, wl_d, params_d)
        stats50 = node_stats(g50)
        starts, degs = (a.copy() for a in d2.layout())
        for ins in warm50:
            tabs50, stats50, starts, degs, _ = seed_burst(
                d2, tabs50, stats50, ins, starts, degs)
        t0 = time.perf_counter()
        applied = 0
        for ins in bursts50:
            tabs50, stats50, starts, degs, rep = seed_burst(
                d2, tabs50, stats50, ins, starts, degs)
            applied += rep.inserted + rep.reweighted
        dt = time.perf_counter() - t0
        old_rate = applied / max(dt, 1e-9)
        emit("fig12e/legacy_splice[v50k]", dt * 1e6 / max(applied, 1),
             f"edges={applied};edges_per_s={old_rate:.0f};"
             f"absorb_speedup={new_rate / max(old_rate, 1e-9):.1f}x")
    # compaction-cadence sweep: mutate/walk rounds with the overlay
    # folded back every K engine epochs (0 = never during the stream).
    # apply_updates no longer refreshes the jitted epoch (the graph and
    # tables are jit arguments), so the per-round number prices the
    # O(touched) splice + the walk + (at the cadence) the O(E) fold.
    rounds = bursts[:min(n_bursts, 6)]
    starts = np.arange(64, dtype=np.int32) % V
    for k in [0, 2, 8]:
        eng_k = WalkEngine(g, make_workload("deepwalk"),
                           EngineConfig(method="its_precomp", tile=128,
                                        rebuild_budget=budget,
                                        compact_interval=k))
        t0 = time.perf_counter()
        for i, ins in enumerate(rounds):
            eng_k.apply_updates(inserts=ins)
            eng_k.run(starts, num_steps=4, key=jax.random.key(i))
        compacted_in_stream = not eng_k.overlay_active
        if eng_k.overlay_active:
            eng_k.compact()
        jax.block_until_ready(eng_k.precomp.cdf)
        dt = time.perf_counter() - t0
        emit(f"fig12e/compact_interval[{k}]", dt * 1e6 / len(rounds),
             f"rounds={len(rounds)};"
             f"compacted_in_stream={int(compacted_in_stream)}")


if __name__ == "__main__":
    main()
