"""Fig. 10 — robustness across power-law property-weight skews (Pareto α ∈
[1, 4]) and degree-based weights, vs NextDoor (max-reduce RJS) and
FlowWalker (prefix RVS)."""
from benchmarks.common import emit, graph_suite, pareto_graph, run_walks

METHODS = ["adaptive", "rjs_maxreduce", "rvs_prefix"]


def main(quick: bool = False):
    alphas = [1.0, 2.0] if quick else [1.0, 1.5, 2.0, 3.0, 4.0]
    for a in alphas:
        g = pareto_graph(a)
        for m in METHODS:
            secs, res = run_walks(g, "node2vec", m)
            emit(f"fig10/alpha{a}/{m}", secs * 1e6,
                 f"frac_rjs={res.frac_rjs:.2f}")
    g = graph_suite()["pl-deg"]  # degree-based weights
    for m in METHODS:
        secs, res = run_walks(g, "node2vec", m)
        emit(f"fig10/degree-weights/{m}", secs * 1e6,
             f"frac_rjs={res.frac_rjs:.2f}")


if __name__ == "__main__":
    main()
