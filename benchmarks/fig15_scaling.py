"""Fig. 15 — multi-device scalability of the walk engine.

Queries are hash-partitioned over devices (the paper's §6.6 scheme) with
the graph replicated per device; walks run under shard_map.  This host has
ONE physical core, so the subprocess forces N host devices and we report
the *work-distribution* quality (per-device query counts and the sharded
engine's consistency), plus wall time (flat on 1 core; linear on real
hardware — noted in the derived column).

Two rows per device count:

* ``fig15/devices{n}``       — ``walk_batch`` on a pre-sharded batch (the
  fully-occupied, no-host-scheduling path);
* ``fig15/sched_devices{n}`` — the *sharded streaming scheduler*
  (``run(devices=n)``, docs/scaling.md): slot pool at half the query
  count, so every device takes mid-walk refills from the host queue.
  ``ident`` reports whether its paths matched the single-device
  scheduler bit-for-bit (the topology-invariance guarantee).
"""
import json
import os
import subprocess
import sys

from benchmarks.common import emit

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={NDEV}"
import time, json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.graphs import power_law_graph
from repro.walks import node2vec
from repro.core import WalkEngine, EngineConfig

n_dev = len(jax.devices())
g = power_law_graph(2000, 12, weight_dist="uniform", seed=1)
eng = WalkEngine(g, node2vec(), EngineConfig(method="ervs", tile=128))
Q = 512
starts = np.arange(Q, dtype=np.int32)
# hash-partition queries over devices (paper §6.6)
dev_of = starts % n_dev
order = np.argsort(dev_of, kind="stable")
starts_p = starts[order]
mesh = jax.make_mesh((n_dev,), ("data",))
sh = NamedSharding(mesh, P("data"))
sharded_starts = jax.device_put(jnp.asarray(starts_p), sh)
key = jax.random.key(0)
path, _ = eng.walk_batch(sharded_starts, key, 10)
jax.block_until_ready(path)
t0 = time.perf_counter()
path, _ = eng.walk_batch(sharded_starts, key, 10)
jax.block_until_ready(path)
dt = time.perf_counter() - t0
counts = np.bincount(dev_of, minlength=n_dev).tolist()
ok = bool((np.asarray(path) >= 0).all())

# sharded streaming scheduler: half-size slot pool forces host refills
devs = n_dev if n_dev > 1 else None
res = eng.run(starts, num_steps=10, key=key, batch=Q // 2, epoch_len=4,
              devices=devs)  # warm (compile)
t0 = time.perf_counter()
res = eng.run(starts, num_steps=10, key=key, batch=Q // 2, epoch_len=4,
              devices=devs)
sched_dt = time.perf_counter() - t0
ref = eng.run(starts, num_steps=10, key=key, batch=Q // 2, epoch_len=4)
ident = bool((res.paths == ref.paths).all())
sched_counts = ([d["queries"] for d in res.per_device]
                if res.per_device else [Q])
print(json.dumps({"n_dev": n_dev, "secs": dt, "counts": counts, "ok": ok,
                  "sched_secs": sched_dt, "sched_counts": sched_counts,
                  "ident": ident}))
"""


def main(quick: bool = False):
    for n in ([1, 4] if quick else [1, 2, 4, 8]):
        out = subprocess.run(
            [sys.executable, "-c", _CHILD.replace("{NDEV}", str(n))],
            capture_output=True, text=True,
            env={**os.environ, "PYTHONPATH": "src"})
        line = out.stdout.strip().splitlines()[-1] if out.stdout else "{}"
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            emit(f"fig15/devices{n}", -1, "FAIL:" + out.stderr[-200:])
            continue
        balance = (min(rec["counts"]) / max(rec["counts"])
                   if max(rec["counts"]) else 0)
        emit(f"fig15/devices{n}", rec["secs"] * 1e6,
             f"ok={rec['ok']};balance={balance:.2f};1-core-host")
        sbal = (min(rec["sched_counts"]) / max(rec["sched_counts"])
                if max(rec["sched_counts"]) else 0)
        emit(f"fig15/sched_devices{n}", rec["sched_secs"] * 1e6,
             f"ident={rec['ident']};balance={sbal:.2f};1-core-host")


if __name__ == "__main__":
    main()
