"""Graph substrate: CSR graphs, synthetic generators, per-node preprocessing.

The walk engine consumes :class:`CSRGraph` (a JAX pytree).  The generated
``preprocess()`` of Flexi-Compiler (paper Fig. 9d) materialises per-node
min/max/sum/mean of the edge property weight ``h`` — here implemented once as
:func:`repro.graphs.csr.node_stats` (segment reductions over CSR rows).
"""
from repro.graphs.csr import (
    CSRGraph,
    NodeStats,
    from_edges,
    node_stats,
    has_edge,
    neighbor_slice,
)
from repro.graphs.delta import (
    GraphDelta,
    OverlayGraph,
    UpdateReport,
    host_row_layout,
)
from repro.graphs.generators import (
    random_graph,
    power_law_graph,
    ring_of_cliques,
    attach_weights,
)

__all__ = [
    "CSRGraph",
    "NodeStats",
    "from_edges",
    "node_stats",
    "has_edge",
    "neighbor_slice",
    "GraphDelta",
    "OverlayGraph",
    "UpdateReport",
    "host_row_layout",
    "random_graph",
    "power_law_graph",
    "ring_of_cliques",
    "attach_weights",
]
