"""Synthetic graph generators mirroring the paper's evaluation setup.

The paper evaluates on SNAP/LAW graphs with three property-weight regimes
(§6.2):  uniform reals from [1, 5), Pareto power-law (α ∈ [1, 4]) and
degree-based weights.  These generators reproduce the regimes on synthetic
graphs so the full benchmark suite runs offline on any host.
"""
from __future__ import annotations

from typing import Literal, Optional

import numpy as np

from repro.graphs.csr import CSRGraph, from_edges

WeightDist = Literal["uniform", "pareto", "degree", "ones"]


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def attach_weights(
    src: np.ndarray,
    dst: np.ndarray,
    num_nodes: int,
    weight_dist: WeightDist = "uniform",
    alpha: float = 2.0,
    num_labels: int = 5,
    seed: int = 0,
) -> CSRGraph:
    """Attach property weights h and labels to an edge list (paper §6.1/§6.2).

    uniform: h ~ U[1, 5)          (paper's default for unweighted datasets)
    pareto:  h ~ 1 + Pareto(α)    (paper Fig. 10; lower α = more skew)
    degree:  h = deg(dst)         (paper "degree-based" distribution)
    ones:    h = 1                (unweighted workloads)
    """
    rng = _rng(seed + 1)
    E = src.shape[0]
    if weight_dist == "uniform":
        h = rng.uniform(1.0, 5.0, size=E).astype(np.float32)
    elif weight_dist == "pareto":
        h = (1.0 + rng.pareto(alpha, size=E)).astype(np.float32)
    elif weight_dist == "degree":
        deg = np.bincount(src, minlength=num_nodes)
        h = np.maximum(deg[dst], 1).astype(np.float32)
    elif weight_dist == "ones":
        h = np.ones(E, dtype=np.float32)
    else:
        raise ValueError(f"unknown weight_dist: {weight_dist}")
    labels = rng.integers(0, num_labels, size=E).astype(np.int32)
    return from_edges(src, dst, num_nodes, h=h, labels=labels)


def random_graph(
    num_nodes: int,
    avg_degree: int,
    weight_dist: WeightDist = "uniform",
    alpha: float = 2.0,
    num_labels: int = 5,
    seed: int = 0,
    symmetric: bool = True,
) -> CSRGraph:
    """Erdős–Rényi-ish random graph with ≥1 out-edge per node.

    ``symmetric=True`` adds reverse edges so dist(v',u)==1 cases actually
    occur (Node2Vec's return/in-out dynamics need them).
    """
    rng = _rng(seed)
    E = num_nodes * avg_degree
    src = rng.integers(0, num_nodes, size=E)
    dst = rng.integers(0, num_nodes, size=E)
    # guarantee every node has at least one out-edge (self-avoiding ring)
    ring_src = np.arange(num_nodes)
    ring_dst = (ring_src + 1) % num_nodes
    src = np.concatenate([src, ring_src])
    dst = np.concatenate([dst, ring_dst])
    if symmetric:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    keep = src != dst
    src, dst = src[keep], dst[keep]
    # dedupe
    key = src.astype(np.int64) * num_nodes + dst
    _, uniq = np.unique(key, return_index=True)
    src, dst = src[uniq], dst[uniq]
    return attach_weights(src, dst, num_nodes, weight_dist, alpha, num_labels, seed)


def power_law_graph(
    num_nodes: int,
    avg_degree: int,
    degree_alpha: float = 2.0,
    weight_dist: WeightDist = "uniform",
    alpha: float = 2.0,
    num_labels: int = 5,
    seed: int = 0,
) -> CSRGraph:
    """Preferential-attachment-flavoured graph: degree sequence ~ Zipf.

    Mimics the skewed-degree structure of the paper's web/social graphs
    (EU, SK, TW) where per-node degree varies over orders of magnitude —
    the regime where per-node kernel selection matters most.
    """
    rng = _rng(seed)
    # Zipf-distributed target out-degrees, clipped.
    raw = rng.zipf(degree_alpha, size=num_nodes).astype(np.int64)
    deg = np.clip(raw, 1, max(4, num_nodes // 4))
    scale = (avg_degree * num_nodes) / max(int(deg.sum()), 1)
    deg = np.maximum((deg * scale).astype(np.int64), 1)
    src = np.repeat(np.arange(num_nodes), deg)
    # preferential destinations: sample proportional to degree sequence
    p = deg / deg.sum()
    dst = rng.choice(num_nodes, size=src.shape[0], p=p)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    ring = np.arange(num_nodes)
    src = np.concatenate([src, ring])
    dst = np.concatenate([dst, (ring + 1) % num_nodes])
    key = src.astype(np.int64) * num_nodes + dst
    _, uniq = np.unique(key, return_index=True)
    src, dst = src[uniq], dst[uniq]
    return attach_weights(src, dst, num_nodes, weight_dist, alpha, num_labels, seed)


def ring_of_cliques(
    num_cliques: int,
    clique_size: int,
    weight_dist: WeightDist = "uniform",
    seed: int = 0,
) -> CSRGraph:
    """Deterministic structured graph for exact-distribution tests."""
    src_l, dst_l = [], []
    n = num_cliques * clique_size
    for c in range(num_cliques):
        base = c * clique_size
        for i in range(clique_size):
            for j in range(clique_size):
                if i != j:
                    src_l.append(base + i)
                    dst_l.append(base + j)
        nxt = ((c + 1) % num_cliques) * clique_size
        src_l.append(base)
        dst_l.append(nxt)
        src_l.append(nxt)
        dst_l.append(base)
    src = np.asarray(src_l)
    dst = np.asarray(dst_l)
    return attach_weights(src, dst, n, weight_dist, seed=seed)
