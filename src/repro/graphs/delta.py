"""Delta-overlay CSR: structural edge mutations without an engine rebuild.

``CSRGraph`` is immutable and contiguous — exactly what the samplers,
precomp tables and the fused kernels want, and exactly what makes edge
*insertions and deletions* expensive: a single new edge shifts every
downstream row offset, so the naive path is a full ``from_edges`` +
engine rebuild.  This module provides the middle ground the ROADMAP's
"structural dynamism at traffic rate" item asks for:

* :class:`GraphDelta` — a host-side ledger over a base ``CSRGraph``.
  Each structural edit (:meth:`GraphDelta.apply`) re-materialises only
  the *touched* rows: deletions tombstone edges out, insertions merge in
  sorted-by-destination (upsert semantics — inserting an existing edge
  re-weights it), and every touched row ends up an exact copy of the row
  a fresh ``from_edges`` of the mutated edge list would build.
* :class:`OverlayGraph` — the device view: the base edge arrays with a
  bump-allocated *patch region* appended, plus explicit per-node
  ``row_start`` / ``row_deg`` arrays.  Untouched rows keep pointing at
  their (bit-identical) base slices; touched rows point into the patch.
  It satisfies the same row-accessor protocol as ``CSRGraph``
  (``row_starts`` / ``row_degs`` / ``degrees`` / ``num_edges``), so
  every jnp sampling path — weight eval, reservoir/rejection tiles, the
  precomp selectors, ``has_edge`` — runs on it unchanged.
* :meth:`GraphDelta.compact` — splice the overlay back into a fresh
  contiguous ``CSRGraph``, bitwise equal to ``from_edges`` of the
  mutated edge list (an O(E) gather, no weight re-evaluation).

Stable patch layout (O(touched) applies)
----------------------------------------
The patch region is a host-side bump allocator with *stable* per-row
placements: a touched row gets a power-of-two span and keeps it across
subsequent edits until its degree outgrows the span (then it moves to a
fresh span and the old one becomes dead space, reclaimed at
:meth:`compact`).  Stability is load-bearing twice over:

* ``PrecompTables`` stay in the overlay layout between compactions
  (``WalkEngine.apply_updates`` grows them with
  :func:`repro.core.precomp.grow_tables` instead of the O(E)
  ``splice_tables`` gather).  A rebuilt row's table values live at its
  overlay offsets — if rows relocated on every apply those values would
  silently go stale.
* :meth:`materialize` syncs the device view *incrementally*: only the
  spans of rows dirtied since the last call are scattered (one
  pow2-padded ``.at[].set`` per edge array), so per-apply device work is
  O(touched edges), not O(E).  A full upload happens only when the patch
  capacity itself grows — capacities are powers of two, so O(log) times
  per compaction cycle, and the device array *shapes* seen by the jitted
  epoch form O(log K) buckets across a K-burst mutation storm.

Dead space between spans (and span slack beyond a row's live degree) is
never observed: every consumer masks gathers by ``row_deg`` — the tile
loops mask ``offs < deg``, ITS/alias selection clips to ``deg - 1``,
``has_edge`` searches ``[start, start + deg)``, and the compaction
gather walks only live spans.

Determinism contract (pinned by tests/test_structural.py)
---------------------------------------------------------
Per-edge RNG draws are keyed by the edge's *offset within its row*, so
bit-identity with a fresh-built engine needs exactly two properties, both
guaranteed here: untouched rows keep their base offsets and values, and
touched rows present the same sorted-by-destination merged order a fresh
``from_edges`` build produces.  Compaction moves rows without reordering
within them, so it never changes a sampled path either.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.csr import CSRGraph, NodeStats


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OverlayGraph:
    """Device view of a base CSR + patch region (see module docstring).

    ``indices``/``h``/``labels`` hold the base edge arrays with the
    patch region (re-materialised touched rows, power-of-two padded)
    appended; ``row_start``/``row_deg`` say where each node's row lives.
    Rows are sorted by destination within the row, like ``CSRGraph``.
    """

    indices: jax.Array  # [E_base + patch] int32
    h: jax.Array  # [E_base + patch] float32
    labels: jax.Array  # [E_base + patch] int32
    row_start: jax.Array  # [V] int32 — offset of each node's row
    row_deg: jax.Array  # [V] int32 — live degree of each node

    @property
    def num_nodes(self) -> int:
        return self.row_start.shape[0]

    @property
    def num_edges(self) -> int:
        # total edge-array length (base + patch capacity) — the clip
        # bound for padded gathers, like CSRGraph.num_edges
        return self.indices.shape[0]

    def degrees(self) -> jax.Array:
        return self.row_deg

    def max_degree(self) -> int:
        return int(jnp.max(self.row_deg))

    def row_starts(self, v: jax.Array) -> jax.Array:
        return self.row_start[v]

    def row_degs(self, v: jax.Array) -> jax.Array:
        return self.row_deg[v]


def host_row_layout(graph) -> Tuple[np.ndarray, np.ndarray]:
    """Host (row starts, row degrees) of a ``CSRGraph`` OR an
    :class:`OverlayGraph` — the layout helper the rebuild/splice paths
    use so they never assume contiguity."""
    if isinstance(graph, OverlayGraph):
        return (np.asarray(graph.row_start, np.int64),
                np.asarray(graph.row_deg, np.int64))
    indptr = np.asarray(graph.indptr, np.int64)
    return indptr[:-1], np.diff(indptr)


def _norm_inserts(inserts):
    """Normalise ``inserts`` to (src, dst, h, labels) int64/int64/f32/i32.

    Accepted: None, or a (src, dst, h) / (src, dst, h, labels) tuple of
    equal-length array-likes."""
    if inserts is None:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.float32), np.zeros(0, np.int32))
    if not isinstance(inserts, (tuple, list)) or len(inserts) not in (3, 4):
        raise ValueError(
            "inserts must be a (src, dst, h) or (src, dst, h, labels) "
            f"tuple of equal-length arrays, got {type(inserts).__name__} "
            f"of length {len(inserts) if hasattr(inserts, '__len__') else '?'}")
    src = np.atleast_1d(np.asarray(inserts[0], np.int64))
    dst = np.atleast_1d(np.asarray(inserts[1], np.int64))
    h = np.atleast_1d(np.asarray(inserts[2], np.float32))
    lab = (np.atleast_1d(np.asarray(inserts[3], np.int32))
           if len(inserts) == 4 else np.zeros(src.shape[0], np.int32))
    if not (src.shape == dst.shape == h.shape == lab.shape):
        raise ValueError(
            f"inserts arrays must agree in length, got "
            f"{src.shape[0]}/{dst.shape[0]}/{h.shape[0]}/{lab.shape[0]}")
    return src, dst, h, lab


def _norm_deletes(deletes):
    """Normalise ``deletes`` to (src, dst) int64 arrays."""
    if deletes is None:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    if not isinstance(deletes, (tuple, list)) or len(deletes) != 2:
        raise ValueError(
            "deletes must be a (src, dst) tuple of equal-length arrays")
    src = np.atleast_1d(np.asarray(deletes[0], np.int64))
    dst = np.atleast_1d(np.asarray(deletes[1], np.int64))
    if src.shape != dst.shape:
        raise ValueError(
            f"deletes arrays must agree in length, got "
            f"{src.shape[0]}/{dst.shape[0]}")
    return src, dst


@dataclasses.dataclass
class UpdateReport:
    """What one :meth:`GraphDelta.apply` batch did."""

    touched: Tuple[int, ...]  # rows re-materialised by this batch
    inserted: int  # genuinely new edges
    reweighted: int  # upserts of existing edges (weight/label change)
    deleted: int  # tombstoned edges (delete of a missing edge is a no-op)


@jax.jit
def _dev_scatter(dst, idx, vals):
    return dst.at[idx].set(vals)


def _pow2_scatter(dst: jax.Array, idx: np.ndarray, vals: np.ndarray):
    """Scatter host (idx, vals) into device array ``dst``, padding both to
    the next power of two by repeating the last entry — duplicate writes
    of an identical value, so the result is exact while the jit cache
    stays O(log E) across arbitrary touched-set sizes."""
    n = int(idx.shape[0])
    m = 1 << max(n - 1, 0).bit_length()
    if m != n:
        idx = np.concatenate([idx, np.full(m - n, idx[-1], idx.dtype)])
        vals = np.concatenate([vals, np.full(m - n, vals[-1], vals.dtype)])
    return _dev_scatter(dst, jnp.asarray(idx, jnp.int32),
                        jnp.asarray(vals, dst.dtype))


class GraphDelta:
    """Host-side structural-mutation ledger over a base ``CSRGraph``.

    Deliberately not a pytree: like :class:`~repro.core.precomp.
    RebuildQueue` it never enters a traced computation — it owns the
    host copies of the base arrays, one merged (dst, h, label) row per
    *touched* node, and the stable bump-allocated patch layout (module
    docstring), and mints :class:`OverlayGraph` device views /
    compacted ``CSRGraph`` s on demand.
    """

    def __init__(self, base: CSRGraph):
        self.base_indptr = np.asarray(base.indptr, np.int64)
        self.base_indices = np.asarray(base.indices, np.int32)
        self.base_h = np.asarray(base.h, np.float32)
        self.base_labels = np.asarray(base.labels, np.int32)
        self.num_nodes = int(self.base_indptr.shape[0] - 1)
        #: node -> merged (dst, h, label) row arrays, sorted by dst
        self.rows: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        # persistent overlay layout — row v lives at
        # [_row_start[v], _row_start[v] + _row_deg[v])
        self._row_start = self.base_indptr[:-1].copy()
        self._row_deg = np.diff(self.base_indptr)
        #: node -> (patch-local offset, allocated pow2 span)
        self._palloc: Dict[int, Tuple[int, int]] = {}
        self._pend = 0  # bump pointer into the patch region
        self._cap = 0  # patch capacity (power of two, grows only)
        self._pindices = np.zeros(0, np.int32)
        self._ph = np.zeros(0, np.float32)
        self._plabels = np.zeros(0, np.int32)
        self._dirty: set = set()  # rows to sync on next materialize()
        self._dev: Optional[OverlayGraph] = None  # cached device view

    def __len__(self) -> int:
        return len(self.rows)

    def row(self, v: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The merged (dst, h, label) arrays of node ``v``'s row."""
        got = self.rows.get(v)
        if got is not None:
            return got
        s, e = int(self.base_indptr[v]), int(self.base_indptr[v + 1])
        return (self.base_indices[s:e], self.base_h[s:e],
                self.base_labels[s:e])

    # --------------------------------------------------------------- edits
    def apply(self, inserts=None, deletes=None) -> UpdateReport:
        """Apply one batch of structural edits.

        ``inserts`` is a ``(src, dst, h)`` or ``(src, dst, h, labels)``
        tuple of arrays; ``deletes`` is ``(src, dst)``.  Deletions apply
        before insertions within a batch; inserting an edge that already
        exists is an *upsert* (re-weight); deleting a missing edge is a
        no-op; duplicate inserts of the same (src, dst) — last wins.
        Endpoints must name existing nodes (the overlay never grows V).
        """
        i_src, i_dst, i_h, i_lab = _norm_inserts(inserts)
        d_src, d_dst = _norm_deletes(deletes)
        for name, arr in (("insert src", i_src), ("insert dst", i_dst),
                          ("delete src", d_src), ("delete dst", d_dst)):
            if arr.size and (arr.min() < 0 or arr.max() >= self.num_nodes):
                raise ValueError(
                    f"{name} out of range [0, {self.num_nodes}): "
                    f"structural updates cannot add nodes")
        touched = np.union1d(i_src, d_src).astype(np.int64)
        if touched.size == 0:
            return UpdateReport(touched=(), inserted=0, reweighted=0,
                                deleted=0)
        inserted = reweighted = deleted = 0
        for v in touched.tolist():
            dst, h, lab = (a.copy() for a in self.row(v))
            dd = d_dst[d_src == v]
            if dd.size:
                keep = ~np.isin(dst, dd)
                deleted += int(dst.size - keep.sum())
                dst, h, lab = dst[keep], h[keep], lab[keep]
            sel = i_src == v
            if sel.any():
                # last-wins dedup of this batch's inserts into row v
                vd, vh, vl = i_dst[sel], i_h[sel], i_lab[sel]
                _, last = np.unique(vd[::-1], return_index=True)
                pick = vd.size - 1 - last  # last occurrence of each dst
                vd, vh, vl = vd[pick], vh[pick], vl[pick]
                old = np.isin(vd, dst)
                reweighted += int(old.sum())
                inserted += int(vd.size - old.sum())
                keep = ~np.isin(dst, vd)  # upsert: new payload wins
                dst = np.concatenate([dst[keep], vd.astype(np.int32)])
                h = np.concatenate([h[keep], vh])
                lab = np.concatenate([lab[keep], vl])
                order = np.argsort(dst, kind="stable")
                dst, h, lab = dst[order], h[order], lab[order]
            self.rows[v] = (np.ascontiguousarray(dst, np.int32),
                            np.ascontiguousarray(h, np.float32),
                            np.ascontiguousarray(lab, np.int32))
            self._place(v)
        return UpdateReport(touched=tuple(int(v) for v in touched),
                            inserted=inserted, reweighted=reweighted,
                            deleted=deleted)

    # --------------------------------------------------------- host layout
    def _place(self, v: int) -> None:
        """Write row ``v``'s merged arrays into its stable patch span,
        bump-allocating a fresh pow2 span only when the degree outgrows
        the current one — O(row degree), amortized O(1) reallocations."""
        dst, hh, ll = self.rows[v]
        deg = int(dst.size)
        E0 = int(self.base_indices.shape[0])
        alloc = self._palloc.get(v)
        if deg > 0 and (alloc is None or deg > alloc[1]):
            span = 1 << max(deg - 1, 0).bit_length()
            off = self._pend
            self._pend += span
            if self._pend > self._cap:
                self._grow(self._pend)
            alloc = (off, span)
            self._palloc[v] = alloc
        if alloc is not None:
            self._row_start[v] = E0 + alloc[0]
            off = alloc[0]
            self._pindices[off:off + deg] = dst
            self._ph[off:off + deg] = hh
            self._plabels[off:off + deg] = ll
        # deg == 0 with no alloc: row_start keeps its old value — never
        # dereferenced, every consumer masks by row_deg
        self._row_deg[v] = deg
        self._dirty.add(v)

    def _grow(self, need: int) -> None:
        """Grow the patch region to a pow2 capacity ≥ ``need``, keeping
        every existing span at its offset.  Invalidates the cached device
        view (the next materialize() is a full upload) — pow2 growth
        makes that O(log) full uploads per compaction cycle, and bounds
        the distinct device shapes the jitted epoch ever sees."""
        cap = max(16, 1 << max(need - 1, 0).bit_length())
        for name in ("_pindices", "_ph", "_plabels"):
            old = getattr(self, name)
            new = np.zeros(cap, old.dtype)
            new[:old.shape[0]] = old
            setattr(self, name, new)
        self._cap = cap
        self._dev = None

    def layout(self) -> Tuple[np.ndarray, np.ndarray]:
        """Host (row starts, row degrees) of the current overlay.

        These are the ledger's live arrays — treat as read-only."""
        return self._row_start, self._row_deg

    def materialize(self) -> OverlayGraph:
        """The device :class:`OverlayGraph` of the current ledger state.

        Incremental: rows dirtied since the last call are scattered into
        the cached device view span-by-span (O(touched edges)); the full
        O(E) upload happens only on first build or after a capacity
        growth."""
        if self._dev is None:
            self._dev = OverlayGraph(
                indices=jnp.asarray(
                    np.concatenate([self.base_indices, self._pindices])),
                h=jnp.asarray(np.concatenate([self.base_h, self._ph])),
                labels=jnp.asarray(
                    np.concatenate([self.base_labels, self._plabels])),
                row_start=jnp.asarray(self._row_start, jnp.int32),
                row_deg=jnp.asarray(self._row_deg, jnp.int32),
            )
            self._dirty.clear()
            return self._dev
        if self._dirty:
            E0 = int(self.base_indices.shape[0])
            vs = np.fromiter(self._dirty, np.int64, len(self._dirty))
            vs.sort()
            spans = [(int(self._row_start[v]), int(self._row_deg[v]))
                     for v in vs.tolist()]
            eidx = np.concatenate(
                [np.arange(s, s + d, dtype=np.int64) for s, d in spans]
                or [np.zeros(0, np.int64)])
            dev = self._dev
            if eidx.size:
                pl = eidx - E0  # dirty rows always live in the patch
                dev = dataclasses.replace(
                    dev,
                    indices=_pow2_scatter(dev.indices, eidx,
                                          self._pindices[pl]),
                    h=_pow2_scatter(dev.h, eidx, self._ph[pl]),
                    labels=_pow2_scatter(dev.labels, eidx,
                                         self._plabels[pl]),
                )
            dev = dataclasses.replace(
                dev,
                row_start=_pow2_scatter(dev.row_start, vs,
                                        self._row_start[vs]),
                row_deg=_pow2_scatter(dev.row_deg, vs, self._row_deg[vs]),
            )
            self._dev = dev
            self._dirty.clear()
        return self._dev

    def _host_full(self):
        """(indices, h, labels) full host overlay arrays (base + patch)."""
        return (np.concatenate([self.base_indices, self._pindices]),
                np.concatenate([self.base_h, self._ph]),
                np.concatenate([self.base_labels, self._plabels]))

    def _gather_order(self):
        """(gather index into the overlay arrays, new indptr) placing
        every live edge contiguously in row order — the ``from_edges``
        layout of the mutated edge list."""
        row_start, row_deg = self._row_start, self._row_deg
        V = self.num_nodes
        indptr = np.zeros(V + 1, np.int64)
        np.cumsum(row_deg, out=indptr[1:])
        E = int(indptr[-1])
        src = np.repeat(np.arange(V, dtype=np.int64), row_deg)
        within = np.arange(E, dtype=np.int64) - np.repeat(indptr[:-1],
                                                          row_deg)
        return row_start[src] + within, indptr

    def edge_list(self):
        """The mutated edge multiset as (src, dst, h, labels) host arrays
        in row order — feed to ``from_edges`` for an oracle rebuild."""
        indices, h, labels = self._host_full()
        gather, indptr = self._gather_order()
        src = np.repeat(np.arange(self.num_nodes, dtype=np.int64),
                        self._row_deg)
        return src, indices[gather], h[gather], labels[gather]

    def compact(self) -> CSRGraph:
        """Splice the overlay into a fresh contiguous ``CSRGraph`` —
        bitwise equal to ``from_edges`` of :meth:`edge_list` (same row
        order, same within-row order), via one O(E) gather."""
        indices, h, labels = self._host_full()
        gather, indptr = self._gather_order()
        return CSRGraph(
            indptr=jnp.asarray(indptr, jnp.int32),
            indices=jnp.asarray(indices[gather]),
            h=jnp.asarray(h[gather]),
            labels=jnp.asarray(labels[gather]),
        )

    # ---------------------------------------------------------- node stats
    def patch_stats(self, stats: NodeStats, nodes) -> NodeStats:
        """Recompute ``node_stats`` for just the listed (touched) rows and
        scatter them into ``stats``.

        Uses the SAME segment reductions over the same within-row edge
        order as :func:`repro.graphs.node_stats`, so the patched stats are
        bitwise equal to a full recompute on the equivalently mutated
        graph — load-bearing, because stats feed the compiler's bound
        estimators and therefore the sampled path bits.

        The device work runs through one jitted core with pow2-padded
        row/edge counts (padding lands in dummy segments scattered to a
        throwaway row), so a K-burst mutation storm compiles O(log K)
        variants instead of one per distinct touched-set size."""
        nodes = np.unique(np.atleast_1d(np.asarray(nodes, np.int64)))
        if nodes.size == 0:
            return stats
        num_labels = int(stats.label_count.shape[1])
        rows = [self.row(int(v)) for v in nodes]
        degs = np.array([r[0].size for r in rows], np.int64)
        T, total = int(nodes.size), int(degs.sum())
        # pow2 pad; Tp > T always, so segment Tp-1 is free for pad edges
        Tp = 1 << max(T, 1).bit_length()
        totalp = max(1 << max(total - 1, 0).bit_length(), 1)
        idx = np.full(Tp, self.num_nodes, np.int32)  # → throwaway row V
        idx[:T] = nodes
        degs_p = np.zeros(Tp, np.int32)
        degs_p[:T] = degs
        seg = np.full(totalp, Tp - 1, np.int32)
        seg[:total] = np.repeat(np.arange(T), degs)
        h_all = np.zeros(totalp, np.float32)
        lab_all = np.zeros(totalp, np.int32)
        if total:
            h_all[:total] = np.concatenate([r[1] for r in rows])
            lab_all[:total] = np.concatenate([r[2] for r in rows])
        return _patch_stats_core(stats, jnp.asarray(idx), jnp.asarray(seg),
                                 jnp.asarray(h_all), jnp.asarray(lab_all),
                                 jnp.asarray(degs_p),
                                 num_labels=num_labels)


@functools.partial(jax.jit, static_argnames=("num_labels",))
def _patch_stats_core(stats: NodeStats, idx, seg, h, labels, degs, *,
                      num_labels: int) -> NodeStats:
    """Jitted segment reductions + scatter behind :meth:`patch_stats`.

    ``idx``/``degs`` are [Tp] (touched nodes, padded with the
    out-of-range index V), ``seg``/``h``/``labels`` are [totalp] (their
    edges, padded into segment Tp-1, which is always a pad segment).
    Each stats array grows a throwaway row, absorbs the scatter (pad
    entries land in the extra row), then drops it — so pad values never
    touch a real node and real segments reduce bit-identically to the
    unpadded computation."""
    Tp = int(degs.shape[0])
    h_min = jax.ops.segment_min(h, seg, num_segments=Tp)
    h_max = jax.ops.segment_max(h, seg, num_segments=Tp)
    h_sum = jax.ops.segment_sum(h, seg, num_segments=Tp)
    safe_deg = jnp.maximum(degs, 1)
    h_mean = h_sum / safe_deg.astype(jnp.float32)
    h_min = jnp.where(degs > 0, h_min, 0.0)
    h_max = jnp.where(degs > 0, h_max, 0.0)
    lbl_seg = seg * num_labels + jnp.clip(labels, 0, num_labels - 1)
    label_count = jax.ops.segment_sum(
        jnp.ones(h.shape, jnp.int32), lbl_seg,
        num_segments=Tp * num_labels).reshape(Tp, num_labels)

    def scat(dst, vals):
        pad = jnp.zeros((1,) + dst.shape[1:], dst.dtype)
        grown = jnp.concatenate([dst, pad])
        return grown.at[idx].set(vals.astype(dst.dtype))[:dst.shape[0]]

    return NodeStats(
        h_min=scat(stats.h_min, h_min),
        h_max=scat(stats.h_max, h_max),
        h_sum=scat(stats.h_sum, h_sum),
        h_mean=scat(stats.h_mean, h_mean),
        degree=scat(stats.degree, degs),
        label_count=scat(stats.label_count, label_count),
    )
