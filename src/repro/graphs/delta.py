"""Delta-overlay CSR: structural edge mutations without an engine rebuild.

``CSRGraph`` is immutable and contiguous — exactly what the samplers,
precomp tables and the fused kernels want, and exactly what makes edge
*insertions and deletions* expensive: a single new edge shifts every
downstream row offset, so the naive path is a full ``from_edges`` +
engine rebuild.  This module provides the middle ground the ROADMAP's
"structural dynamism at traffic rate" item asks for:

* :class:`GraphDelta` — a host-side ledger over a base ``CSRGraph``.
  Each structural edit (:meth:`GraphDelta.apply`) re-materialises only
  the *touched* rows: deletions tombstone edges out, insertions merge in
  sorted-by-destination (upsert semantics — inserting an existing edge
  re-weights it), and every touched row ends up an exact copy of the row
  a fresh ``from_edges`` of the mutated edge list would build.
* :class:`OverlayGraph` — the device view: the base edge arrays with a
  bump-allocated *patch region* appended, plus explicit per-node
  ``row_start`` / ``row_deg`` arrays.  Untouched rows keep pointing at
  their (bit-identical) base slices; touched rows point into the patch.
  It satisfies the same row-accessor protocol as ``CSRGraph``
  (``row_starts`` / ``row_degs`` / ``degrees`` / ``num_edges``), so
  every jnp sampling path — weight eval, reservoir/rejection tiles, the
  precomp selectors, ``has_edge`` — runs on it unchanged.
* :meth:`GraphDelta.compact` — splice the overlay back into a fresh
  contiguous ``CSRGraph``, bitwise equal to ``from_edges`` of the
  mutated edge list (an O(E) gather, no weight re-evaluation).

Determinism contract (pinned by tests/test_structural.py)
---------------------------------------------------------
Per-edge RNG draws are keyed by the edge's *offset within its row*, so
bit-identity with a fresh-built engine needs exactly two properties, both
guaranteed here: untouched rows keep their base offsets and values, and
touched rows present the same sorted-by-destination merged order a fresh
``from_edges`` build produces.  Compaction moves rows without reordering
within them, so it never changes a sampled path either.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.csr import CSRGraph, NodeStats


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OverlayGraph:
    """Device view of a base CSR + patch region (see module docstring).

    ``indices``/``h``/``labels`` hold the base edge arrays with the
    patch region (re-materialised touched rows, power-of-two padded)
    appended; ``row_start``/``row_deg`` say where each node's row lives.
    Rows are sorted by destination within the row, like ``CSRGraph``.
    """

    indices: jax.Array  # [E_base + patch] int32
    h: jax.Array  # [E_base + patch] float32
    labels: jax.Array  # [E_base + patch] int32
    row_start: jax.Array  # [V] int32 — offset of each node's row
    row_deg: jax.Array  # [V] int32 — live degree of each node

    @property
    def num_nodes(self) -> int:
        return self.row_start.shape[0]

    @property
    def num_edges(self) -> int:
        # total edge-array length (base + patch capacity) — the clip
        # bound for padded gathers, like CSRGraph.num_edges
        return self.indices.shape[0]

    def degrees(self) -> jax.Array:
        return self.row_deg

    def max_degree(self) -> int:
        return int(jnp.max(self.row_deg))

    def row_starts(self, v: jax.Array) -> jax.Array:
        return self.row_start[v]

    def row_degs(self, v: jax.Array) -> jax.Array:
        return self.row_deg[v]


def host_row_layout(graph) -> Tuple[np.ndarray, np.ndarray]:
    """Host (row starts, row degrees) of a ``CSRGraph`` OR an
    :class:`OverlayGraph` — the layout helper the rebuild/splice paths
    use so they never assume contiguity."""
    if isinstance(graph, OverlayGraph):
        return (np.asarray(graph.row_start, np.int64),
                np.asarray(graph.row_deg, np.int64))
    indptr = np.asarray(graph.indptr, np.int64)
    return indptr[:-1], np.diff(indptr)


def _norm_inserts(inserts):
    """Normalise ``inserts`` to (src, dst, h, labels) int64/int64/f32/i32.

    Accepted: None, or a (src, dst, h) / (src, dst, h, labels) tuple of
    equal-length array-likes."""
    if inserts is None:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.float32), np.zeros(0, np.int32))
    if not isinstance(inserts, (tuple, list)) or len(inserts) not in (3, 4):
        raise ValueError(
            "inserts must be a (src, dst, h) or (src, dst, h, labels) "
            f"tuple of equal-length arrays, got {type(inserts).__name__} "
            f"of length {len(inserts) if hasattr(inserts, '__len__') else '?'}")
    src = np.atleast_1d(np.asarray(inserts[0], np.int64))
    dst = np.atleast_1d(np.asarray(inserts[1], np.int64))
    h = np.atleast_1d(np.asarray(inserts[2], np.float32))
    lab = (np.atleast_1d(np.asarray(inserts[3], np.int32))
           if len(inserts) == 4 else np.zeros(src.shape[0], np.int32))
    if not (src.shape == dst.shape == h.shape == lab.shape):
        raise ValueError(
            f"inserts arrays must agree in length, got "
            f"{src.shape[0]}/{dst.shape[0]}/{h.shape[0]}/{lab.shape[0]}")
    return src, dst, h, lab


def _norm_deletes(deletes):
    """Normalise ``deletes`` to (src, dst) int64 arrays."""
    if deletes is None:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    if not isinstance(deletes, (tuple, list)) or len(deletes) != 2:
        raise ValueError(
            "deletes must be a (src, dst) tuple of equal-length arrays")
    src = np.atleast_1d(np.asarray(deletes[0], np.int64))
    dst = np.atleast_1d(np.asarray(deletes[1], np.int64))
    if src.shape != dst.shape:
        raise ValueError(
            f"deletes arrays must agree in length, got "
            f"{src.shape[0]}/{dst.shape[0]}")
    return src, dst


@dataclasses.dataclass
class UpdateReport:
    """What one :meth:`GraphDelta.apply` batch did."""

    touched: Tuple[int, ...]  # rows re-materialised by this batch
    inserted: int  # genuinely new edges
    reweighted: int  # upserts of existing edges (weight/label change)
    deleted: int  # tombstoned edges (delete of a missing edge is a no-op)


class GraphDelta:
    """Host-side structural-mutation ledger over a base ``CSRGraph``.

    Deliberately not a pytree: like :class:`~repro.core.precomp.
    RebuildQueue` it never enters a traced computation — it owns the
    host copies of the base arrays plus one merged (dst, h, label) row
    per *touched* node, and mints :class:`OverlayGraph` device views /
    compacted ``CSRGraph`` s on demand.
    """

    def __init__(self, base: CSRGraph):
        self.base_indptr = np.asarray(base.indptr, np.int64)
        self.base_indices = np.asarray(base.indices, np.int32)
        self.base_h = np.asarray(base.h, np.float32)
        self.base_labels = np.asarray(base.labels, np.int32)
        self.num_nodes = int(self.base_indptr.shape[0] - 1)
        #: node -> merged (dst, h, label) row arrays, sorted by dst
        self.rows: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        self._host: Optional[tuple] = None  # cached _host_overlay()

    def __len__(self) -> int:
        return len(self.rows)

    def row(self, v: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The merged (dst, h, label) arrays of node ``v``'s row."""
        got = self.rows.get(v)
        if got is not None:
            return got
        s, e = int(self.base_indptr[v]), int(self.base_indptr[v + 1])
        return (self.base_indices[s:e], self.base_h[s:e],
                self.base_labels[s:e])

    # --------------------------------------------------------------- edits
    def apply(self, inserts=None, deletes=None) -> UpdateReport:
        """Apply one batch of structural edits.

        ``inserts`` is a ``(src, dst, h)`` or ``(src, dst, h, labels)``
        tuple of arrays; ``deletes`` is ``(src, dst)``.  Deletions apply
        before insertions within a batch; inserting an edge that already
        exists is an *upsert* (re-weight); deleting a missing edge is a
        no-op; duplicate inserts of the same (src, dst) — last wins.
        Endpoints must name existing nodes (the overlay never grows V).
        """
        i_src, i_dst, i_h, i_lab = _norm_inserts(inserts)
        d_src, d_dst = _norm_deletes(deletes)
        for name, arr in (("insert src", i_src), ("insert dst", i_dst),
                          ("delete src", d_src), ("delete dst", d_dst)):
            if arr.size and (arr.min() < 0 or arr.max() >= self.num_nodes):
                raise ValueError(
                    f"{name} out of range [0, {self.num_nodes}): "
                    f"structural updates cannot add nodes")
        touched = np.union1d(i_src, d_src).astype(np.int64)
        if touched.size == 0:
            return UpdateReport(touched=(), inserted=0, reweighted=0,
                                deleted=0)
        inserted = reweighted = deleted = 0
        for v in touched.tolist():
            dst, h, lab = (a.copy() for a in self.row(v))
            dd = d_dst[d_src == v]
            if dd.size:
                keep = ~np.isin(dst, dd)
                deleted += int(dst.size - keep.sum())
                dst, h, lab = dst[keep], h[keep], lab[keep]
            sel = i_src == v
            if sel.any():
                # last-wins dedup of this batch's inserts into row v
                vd, vh, vl = i_dst[sel], i_h[sel], i_lab[sel]
                _, last = np.unique(vd[::-1], return_index=True)
                pick = vd.size - 1 - last  # last occurrence of each dst
                vd, vh, vl = vd[pick], vh[pick], vl[pick]
                old = np.isin(vd, dst)
                reweighted += int(old.sum())
                inserted += int(vd.size - old.sum())
                keep = ~np.isin(dst, vd)  # upsert: new payload wins
                dst = np.concatenate([dst[keep], vd.astype(np.int32)])
                h = np.concatenate([h[keep], vh])
                lab = np.concatenate([lab[keep], vl])
                order = np.argsort(dst, kind="stable")
                dst, h, lab = dst[order], h[order], lab[order]
            self.rows[v] = (np.ascontiguousarray(dst, np.int32),
                            np.ascontiguousarray(h, np.float32),
                            np.ascontiguousarray(lab, np.int32))
        self._host = None
        return UpdateReport(touched=tuple(int(v) for v in touched),
                            inserted=inserted, reweighted=reweighted,
                            deleted=deleted)

    # --------------------------------------------------------- host layout
    def _host_overlay(self):
        """(indices, h, labels, row_start, row_deg) host arrays of the
        overlay: base arrays + pow2-padded patch of the touched rows."""
        if self._host is not None:
            return self._host
        E0 = int(self.base_indices.shape[0])
        row_start = self.base_indptr[:-1].copy()
        row_deg = np.diff(self.base_indptr)
        touched = sorted(self.rows)
        parts = [self.rows[v] for v in touched]
        patch_len = int(sum(p[0].size for p in parts))
        cap = max(1, 1 << max(patch_len - 1, 0).bit_length())
        indices = np.zeros(E0 + cap, np.int32)
        h = np.zeros(E0 + cap, np.float32)
        labels = np.zeros(E0 + cap, np.int32)
        indices[:E0] = self.base_indices
        h[:E0] = self.base_h
        labels[:E0] = self.base_labels
        off = E0
        for v, (dst, hh, ll) in zip(touched, parts):
            row_start[v] = off
            row_deg[v] = dst.size
            indices[off:off + dst.size] = dst
            h[off:off + dst.size] = hh
            labels[off:off + dst.size] = ll
            off += dst.size
        self._host = (indices, h, labels, row_start, row_deg)
        return self._host

    def layout(self) -> Tuple[np.ndarray, np.ndarray]:
        """Host (row starts, row degrees) of the current overlay."""
        _, _, _, row_start, row_deg = self._host_overlay()
        return row_start, row_deg

    def materialize(self) -> OverlayGraph:
        """The device :class:`OverlayGraph` of the current ledger state."""
        indices, h, labels, row_start, row_deg = self._host_overlay()
        return OverlayGraph(
            indices=jnp.asarray(indices),
            h=jnp.asarray(h),
            labels=jnp.asarray(labels),
            row_start=jnp.asarray(row_start, jnp.int32),
            row_deg=jnp.asarray(row_deg, jnp.int32),
        )

    def _gather_order(self):
        """(gather index into the overlay arrays, new indptr) placing
        every live edge contiguously in row order — the ``from_edges``
        layout of the mutated edge list."""
        _, _, _, row_start, row_deg = self._host_overlay()
        V = self.num_nodes
        indptr = np.zeros(V + 1, np.int64)
        np.cumsum(row_deg, out=indptr[1:])
        E = int(indptr[-1])
        src = np.repeat(np.arange(V, dtype=np.int64), row_deg)
        within = np.arange(E, dtype=np.int64) - np.repeat(indptr[:-1],
                                                          row_deg)
        return row_start[src] + within, indptr

    def edge_list(self):
        """The mutated edge multiset as (src, dst, h, labels) host arrays
        in row order — feed to ``from_edges`` for an oracle rebuild."""
        indices, h, labels, _, row_deg = self._host_overlay()
        gather, indptr = self._gather_order()
        src = np.repeat(np.arange(self.num_nodes, dtype=np.int64), row_deg)
        return src, indices[gather], h[gather], labels[gather]

    def compact(self) -> CSRGraph:
        """Splice the overlay into a fresh contiguous ``CSRGraph`` —
        bitwise equal to ``from_edges`` of :meth:`edge_list` (same row
        order, same within-row order), via one O(E) gather."""
        indices, h, labels, _, _ = self._host_overlay()
        gather, indptr = self._gather_order()
        return CSRGraph(
            indptr=jnp.asarray(indptr, jnp.int32),
            indices=jnp.asarray(indices[gather]),
            h=jnp.asarray(h[gather]),
            labels=jnp.asarray(labels[gather]),
        )

    # ---------------------------------------------------------- node stats
    def patch_stats(self, stats: NodeStats, nodes) -> NodeStats:
        """Recompute ``node_stats`` for just the listed (touched) rows and
        scatter them into ``stats``.

        Uses the SAME segment reductions over the same within-row edge
        order as :func:`repro.graphs.node_stats`, so the patched stats are
        bitwise equal to a full recompute on the equivalently mutated
        graph — load-bearing, because stats feed the compiler's bound
        estimators and therefore the sampled path bits."""
        nodes = np.unique(np.atleast_1d(np.asarray(nodes, np.int64)))
        if nodes.size == 0:
            return stats
        num_labels = int(stats.label_count.shape[1])
        rows = [self.row(int(v)) for v in nodes]
        degs = np.array([r[0].size for r in rows], np.int64)
        T, total = int(nodes.size), int(degs.sum())
        h_all = (np.concatenate([r[1] for r in rows])
                 if total else np.zeros(0, np.float32))
        lab_all = (np.concatenate([r[2] for r in rows])
                   if total else np.zeros(0, np.int32))
        seg = jnp.asarray(np.repeat(np.arange(T), degs), jnp.int32)
        h_j = jnp.asarray(h_all)
        deg_j = jnp.asarray(degs, jnp.int32)
        h_min = jax.ops.segment_min(h_j, seg, num_segments=T)
        h_max = jax.ops.segment_max(h_j, seg, num_segments=T)
        h_sum = jax.ops.segment_sum(h_j, seg, num_segments=T)
        safe_deg = jnp.maximum(deg_j, 1)
        h_mean = h_sum / safe_deg.astype(jnp.float32)
        h_min = jnp.where(deg_j > 0, h_min, 0.0)
        h_max = jnp.where(deg_j > 0, h_max, 0.0)
        lbl_seg = seg * num_labels + jnp.clip(jnp.asarray(lab_all), 0,
                                              num_labels - 1)
        label_count = jax.ops.segment_sum(
            jnp.ones((total,), jnp.int32), lbl_seg,
            num_segments=T * num_labels).reshape(T, num_labels)
        idx = jnp.asarray(nodes, jnp.int32)
        return NodeStats(
            h_min=stats.h_min.at[idx].set(h_min),
            h_max=stats.h_max.at[idx].set(h_max),
            h_sum=stats.h_sum.at[idx].set(h_sum),
            h_mean=stats.h_mean.at[idx].set(h_mean),
            degree=stats.degree.at[idx].set(deg_j),
            label_count=stats.label_count.at[idx].set(label_count),
        )
