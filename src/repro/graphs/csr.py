"""CSR graph representation (a JAX pytree) and per-node preprocessing.

Design notes
------------
* ``indices`` is sorted within each row — this makes ``dist(v', u)`` (the
  Node2Vec/2nd-PR "is u a neighbour of the previous node" test) a fixed-depth
  binary search (:func:`has_edge`), vectorisable with ``vmap``.
* ``node_stats`` is the JAX equivalent of the code Flexi-Compiler *generates*
  for ``preprocess()`` (paper Fig. 9d): per-node h_MAX / h_MIN / h_SUM /
  h_MEAN pointers, computed with segment reductions.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Directed graph in CSR form.  All fields are device arrays.

    indptr:  [V+1] int32 — row offsets.
    indices: [E] int32   — destination of each edge, sorted within a row.
    h:       [E] float32 — edge *property* weights (the dataset's weights).
    labels:  [E] int32   — edge labels (MetaPath); zeros when unlabeled.
    """

    indptr: jax.Array
    indices: jax.Array
    h: jax.Array
    labels: jax.Array

    @property
    def num_nodes(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def num_edges(self) -> int:
        return self.indices.shape[0]

    def degrees(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    def max_degree(self) -> int:
        return int(jnp.max(self.degrees()))

    # Row-accessor protocol shared with graphs.delta.OverlayGraph: every
    # sampling path reads rows through these two (never indptr directly),
    # so a delta-overlay graph — whose rows are NOT contiguous — runs the
    # same kernels unchanged.
    def row_starts(self, v: jax.Array) -> jax.Array:
        """Edge-array offset of each node's row (``v`` may be batched)."""
        return self.indptr[v]

    def row_degs(self, v: jax.Array) -> jax.Array:
        """Degree of each node's row (``v`` may be batched)."""
        return self.indptr[v + 1] - self.indptr[v]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NodeStats:
    """Per-node statistics of the edge property weight h.

    This is the materialisation of Flexi-Compiler's generated
    ``preprocess()``: the h_MAX / h_SUM (and friends) pointers of Fig. 9d.
    """

    h_min: jax.Array  # [V] float32
    h_max: jax.Array  # [V] float32
    h_sum: jax.Array  # [V] float32
    h_mean: jax.Array  # [V] float32
    degree: jax.Array  # [V] int32
    label_count: jax.Array  # [V, L] int32 — #edges per label per node (MetaPath)


def from_edges(
    src: np.ndarray,
    dst: np.ndarray,
    num_nodes: int,
    h: Optional[np.ndarray] = None,
    labels: Optional[np.ndarray] = None,
) -> CSRGraph:
    """Build a CSRGraph from an edge list (host-side, numpy)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if h is None:
        h = np.ones(src.shape[0], dtype=np.float32)
    if labels is None:
        labels = np.zeros(src.shape[0], dtype=np.int32)
    # Sort by (src, dst) so rows are contiguous and sorted.
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    h, labels = np.asarray(h, np.float32)[order], np.asarray(labels, np.int32)[order]
    counts = np.bincount(src, minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, dtype=np.int32)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(dst, jnp.int32),
        h=jnp.asarray(h, jnp.float32),
        labels=jnp.asarray(labels, jnp.int32),
    )


def node_stats(graph: CSRGraph, num_labels: int = 8) -> NodeStats:
    """Segment min/max/sum/mean of h per node + per-label edge counts.

    Pure JAX (jit-able); this is the one-time preprocessing whose cost the
    paper reports in Table 3 ("Preproc.").
    """
    V = graph.num_nodes
    E = graph.num_edges
    deg = graph.degrees()
    # segment id of each edge = its source row.
    seg = jnp.repeat(jnp.arange(V, dtype=jnp.int32), deg, total_repeat_length=E)
    h_min = jax.ops.segment_min(graph.h, seg, num_segments=V)
    h_max = jax.ops.segment_max(graph.h, seg, num_segments=V)
    h_sum = jax.ops.segment_sum(graph.h, seg, num_segments=V)
    # Degenerate rows (deg == 0): segment_min/max give +inf/-inf; clamp to 0.
    safe_deg = jnp.maximum(deg, 1)
    h_mean = h_sum / safe_deg.astype(jnp.float32)
    h_min = jnp.where(deg > 0, h_min, 0.0)
    h_max = jnp.where(deg > 0, h_max, 0.0)
    lbl_seg = seg * num_labels + jnp.clip(graph.labels, 0, num_labels - 1)
    label_count = jax.ops.segment_sum(
        jnp.ones((E,), jnp.int32), lbl_seg, num_segments=V * num_labels
    ).reshape(V, num_labels)
    return NodeStats(
        h_min=h_min,
        h_max=h_max,
        h_sum=h_sum,
        h_mean=h_mean,
        degree=deg,
        label_count=label_count,
    )


def neighbor_slice(graph: CSRGraph, v: jax.Array, width: int):
    """Gather a fixed-width window of v's adjacency (padded).

    Returns (nbr_idx, nbr_h, nbr_labels, mask) each of shape [width].
    Out-of-row lanes are masked (idx = -1, h = 0).
    """
    start = graph.row_starts(v)
    deg = graph.row_degs(v)
    offs = jnp.arange(width, dtype=jnp.int32)
    mask = offs < deg
    pos = jnp.clip(start + offs, 0, graph.num_edges - 1)
    nbr = jnp.where(mask, graph.indices[pos], -1)
    hh = jnp.where(mask, graph.h[pos], 0.0)
    ll = jnp.where(mask, graph.labels[pos], -1)
    return nbr, hh, ll, mask


@partial(jax.jit, static_argnames=())
def has_edge(graph: CSRGraph, v: jax.Array, u: jax.Array) -> jax.Array:
    """True iff edge (v, u) exists.  Fixed-depth binary search on the sorted
    row ``indices[indptr[v]:indptr[v+1]]`` — vectorise with vmap over (v, u).

    Handles v == -1 (no previous node yet) by returning False.
    """
    valid = v >= 0
    vs = jnp.maximum(v, 0)
    lo = graph.row_starts(vs)
    end = lo + graph.row_degs(vs)
    hi = end

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) // 2
        mid_val = graph.indices[jnp.clip(mid, 0, graph.num_edges - 1)]
        go_right = jnp.logical_and(mid_val < u, lo < hi)
        new_lo = jnp.where(go_right, mid + 1, lo)
        new_hi = jnp.where(jnp.logical_or(go_right, lo >= hi), hi, mid)
        return (new_lo, new_hi)

    # ceil(log2(E)) iterations always suffice; use 32 for safety at int32.
    lo, hi = jax.lax.fori_loop(0, 32, body, (lo, hi))
    found = jnp.logical_and(lo < end,
                            graph.indices[jnp.clip(lo, 0, graph.num_edges - 1)] == u)
    return jnp.logical_and(valid, found)


def dist_code(graph: CSRGraph, v_prev: jax.Array, u: jax.Array) -> jax.Array:
    """Node2Vec's dist(v', u) ∈ {0, 1, 2}: 0 if u == v', 1 if (v'→u) ∈ E,
    else 2.  v' == -1 (first step) returns 1 ("stay neutral"), matching the
    usual first-step semantics of Node2Vec implementations.
    """
    is_prev = u == v_prev
    connected = has_edge(graph, v_prev, u)
    d = jnp.where(is_prev, 0, jnp.where(connected, 1, 2))
    return jnp.where(v_prev < 0, 1, d).astype(jnp.int32)
