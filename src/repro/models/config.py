"""Unified model configuration covering all assigned architecture families.

One config dataclass describes dense GQA (llama-family), qk-norm GQA
(qwen3), MoE (DeepSeek-V3-style routed+shared experts), RG-LRU hybrids
(recurrentgemma/griffin), Mamba2 SSD, and the early-fusion VLM / EnCodec
audio backbones (whose modality frontends are stubs per the assignment —
``input_specs`` provides token ids / precomputed embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    # attention (0 heads for attention-free archs)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    local_window: int = 0  # >0: sliding-window attention
    # dense FFN
    d_ff: int = 0
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    shared_experts: int = 0
    num_dense_layers: int = 0  # dense lead-in layers (DeepSeek/Kimi style)
    capacity_factor: float = 1.25
    router: str = "topk"  # "topk" | "sampled" (eRVS Gumbel-top-k router)
    # hybrid (RG-LRU): repeating pattern of block kinds
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rec","rec","attn")
    lru_width: int = 0
    conv_width: int = 4
    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    d_inner: int = 0
    # embeddings / head
    tie_embeddings: bool = False
    # minicpm-style depth scaling of residual branches
    scale_depth: float = 0.0
    # numerics
    dtype: str = "bfloat16"
    # training
    max_seq_len: int = 4096

    # ----------------------------------------------------------- derived
    @property
    def attn_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True for sub-quadratic decode state (SSM / hybrid local-attn)."""
        return self.family in ("ssm", "hybrid")

    def layer_kinds(self) -> Tuple[str, ...]:
        """Kind of every layer, in order."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                kinds.append("mamba")
            elif self.family == "hybrid" and self.block_pattern:
                kinds.append(self.block_pattern[i % len(self.block_pattern)])
            elif self.num_experts > 0 and i >= self.num_dense_layers:
                kinds.append("moe")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        n = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for kind in self.layer_kinds():
            n += self._layer_params(kind)
        n += self.d_model  # final norm
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (= param_count for non-MoE)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for kind in self.layer_kinds():
            n += self._layer_params(kind, active_only=True)
        n += self.d_model
        return n

    def _layer_params(self, kind: str, active_only: bool = False) -> int:
        D = self.d_model
        n = 2 * D  # two rms norms
        if kind == "attn" or (kind == "moe"):
            qkvo = D * self.attn_dim * 2 + D * self.kv_dim * 2
            if self.qk_norm:
                qkvo += 2 * self.head_dim
            n += qkvo
        if kind == "attn":
            n += 3 * D * self.d_ff
        elif kind == "moe":
            e = self.experts_per_token if active_only else self.num_experts
            n += 3 * D * self.moe_d_ff * (e + self.shared_experts)
            n += D * self.num_experts  # router
        elif kind == "rec":
            W = self.lru_width
            n += 2 * D * W + W * D  # in (x,gate) + out
            n += self.conv_width * W + 3 * W  # conv + lru gates/Lambda
            n += 3 * D * self.d_ff  # the block's MLP
        elif kind == "mamba":
            din = self.d_inner
            H = din // self.ssm_head_dim
            N = self.ssm_state
            n += D * (2 * din + 2 * self.ssm_groups * N + H)  # in_proj
            n += self.conv_width * (din + 2 * self.ssm_groups * N)
            n += 2 * H + din  # A_log, D, norm
            n += din * D  # out_proj
        return n
