"""Model building blocks, pure JAX, one namespace per block kind.

Every block ships ``init_*`` (params as a flat dict of named leaves — names
drive sharding, see distributed/sharding.LEAF_LOGICAL) and ``*_fwd`` for
the train/prefill path plus a ``*_decode`` single-token path where the
block carries state (KV cache / RG-LRU hidden / SSD state / conv tails).

Numerics: params and activations bf16 (configurable), norms/softmax/router
in fp32.  Attention is chunked (flash-style online softmax, causal block
skipping, optional sliding window) — [S, S] score matrices are never
materialised, which is what makes the 32k-prefill dry-run cells fit.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard, tp_down_proj
from repro.models.config import ModelConfig

Params = Dict[str, jax.Array]
F32 = jnp.float32


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, F32) * scale).astype(dtype)


# ------------------------------------------------------------------ norms
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(F32))
    return out.astype(x.dtype)


# ------------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    ang = positions[..., :, None].astype(F32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(F32), x2.astype(F32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention
def init_attention(key, cfg: ModelConfig) -> Params:
    D, A, KV = cfg.d_model, cfg.attn_dim, cfg.kv_dim
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (D, A), D ** -0.5, dt),
        "wk": _init(ks[1], (D, KV), D ** -0.5, dt),
        "wv": _init(ks[2], (D, KV), D ** -0.5, dt),
        "wo": _init(ks[3], (A, D), A ** -0.5, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), F32)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), F32)
    return p


def _qkv(p: Params, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    B, S, _ = x.shape
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, G, hd)
    v = (x @ p["wv"]).reshape(B, S, G, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _blk_mask(qpos, kpos, window: int):
    mask = qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    return mask


def _needed(q_lo, k_lo, q_chunk, kv_chunk, window: int):
    needed = k_lo <= q_lo + q_chunk - 1
    if window > 0:
        needed &= k_lo + kv_chunk > q_lo - window
    return needed


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, window: int = 0,
                    q_chunk: int = 1024, kv_chunk: int = 1024) -> jax.Array:
    """Causal chunked attention, flash-style, with a hand-derived VJP.

    q: [B, S, H, d]; k, v: [B, S, G, d] (GQA: H = G·rep).  [S, S] scores are
    never materialised in either pass: the forward carries the online
    softmax (m, l, acc) over kv blocks; the custom backward *recomputes*
    p per block from the saved logsumexp instead of letting scan-autodiff
    stack O(S²/chunk) residuals (which compiled to >200 GB/device temps on
    the 32k cells — see EXPERIMENTS.md §Perf iteration log).  Causal block
    skipping and the sliding-window left cut are lax.cond per block in both
    passes.
    """
    out, _ = _flash_fwd_impl(q, k, v, window, q_chunk, kv_chunk)
    return out


def _flash_fwd_impl(q, k, v, window, q_chunk, kv_chunk):
    B, S, H, d = q.shape
    G = k.shape[2]
    rep = H // G
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    nq = S // q_chunk
    nk = S // kv_chunk
    scale = d ** -0.5
    q5 = q.reshape(B, S, G, rep, d)

    def one_q_chunk(qi):
        q_lo = qi * q_chunk
        qc = jax.lax.dynamic_slice_in_dim(q5, q_lo, q_chunk, axis=1)
        qpos = q_lo + jnp.arange(q_chunk)

        def body(carry, ki):
            k_lo = ki * kv_chunk

            def compute(carry):
                m, l, acc = carry
                kc = jax.lax.dynamic_slice_in_dim(k, k_lo, kv_chunk, axis=1)
                vc = jax.lax.dynamic_slice_in_dim(v, k_lo, kv_chunk, axis=1)
                kpos = k_lo + jnp.arange(kv_chunk)
                s = jnp.einsum("bqgrd,bkgd->bgrqk", qc.astype(F32),
                               kc.astype(F32)) * scale
                mask = _blk_mask(qpos, kpos, window)
                s = jnp.where(mask[None, None, None], s, -jnp.inf)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
                p_ = jnp.exp(s - m_safe[..., None])
                p_ = jnp.where(jnp.isneginf(s), 0.0, p_)
                corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
                l_new = l * corr + jnp.sum(p_, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bgrqk,bkgd->bgrqd", p_, vc.astype(F32))
                return m_new, l_new, acc_new

            out = jax.lax.cond(_needed(q_lo, k_lo, q_chunk, kv_chunk, window),
                               compute, lambda c: c, carry)
            return out, None

        m0 = jnp.full((B, G, rep, q_chunk), -jnp.inf, F32)
        l0 = jnp.zeros((B, G, rep, q_chunk), F32)
        a0 = jnp.zeros((B, G, rep, q_chunk, d), F32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nk))
        out_c = acc / jnp.maximum(l[..., None], 1e-20)
        lse_c = m + jnp.log(jnp.maximum(l, 1e-20))  # [B,G,rep,qc]
        return out_c, lse_c

    outs, lses = jax.lax.map(one_q_chunk, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 3)  # [B,G,rep,nq,qc,d]
    out = out.reshape(B, G, rep, S, d)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, S, H, d)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, G, rep, S)
    lse = jnp.transpose(lse, (0, 3, 1, 2))  # [B,S,G,rep]
    return out.astype(q.dtype), lse


def _flash_fwd(q, k, v, window, q_chunk, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, window, q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(window, q_chunk, kv_chunk, res, g):
    q, k, v, out, lse = res
    B, S, H, d = q.shape
    G = k.shape[2]
    rep = H // G
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    nq = S // q_chunk
    nk = S // kv_chunk
    scale = d ** -0.5
    q5 = q.reshape(B, S, G, rep, d)
    g5 = g.reshape(B, S, G, rep, d)
    o5 = out.reshape(B, S, G, rep, d)
    # delta_i = Σ_d g_i·o_i  (rowwise)
    delta = jnp.sum(g5.astype(F32) * o5.astype(F32), axis=-1)  # [B,S,G,rep]

    def per_q_chunk(carry, qi):
        dk_acc, dv_acc = carry  # f32 [B,S,G,d]
        q_lo = qi * q_chunk
        qc = jax.lax.dynamic_slice_in_dim(q5, q_lo, q_chunk, 1).astype(F32)
        gc = jax.lax.dynamic_slice_in_dim(g5, q_lo, q_chunk, 1).astype(F32)
        lsec = jax.lax.dynamic_slice_in_dim(lse, q_lo, q_chunk, 1)
        dltc = jax.lax.dynamic_slice_in_dim(delta, q_lo, q_chunk, 1)
        # [B,qc,G,rep] → [B,G,rep,qc]
        lsec = jnp.transpose(lsec, (0, 2, 3, 1))
        dltc = jnp.transpose(dltc, (0, 2, 3, 1))
        qpos = q_lo + jnp.arange(q_chunk)

        def per_kv(carry, ki):
            dq_c, dk_acc, dv_acc = carry
            k_lo = ki * kv_chunk

            def compute(carry):
                dq_c, dk_acc, dv_acc = carry
                kc = jax.lax.dynamic_slice_in_dim(k, k_lo, kv_chunk, 1).astype(F32)
                vc = jax.lax.dynamic_slice_in_dim(v, k_lo, kv_chunk, 1).astype(F32)
                kpos = k_lo + jnp.arange(kv_chunk)
                s = jnp.einsum("bqgrd,bkgd->bgrqk", qc, kc) * scale
                mask = _blk_mask(qpos, kpos, window)
                s = jnp.where(mask[None, None, None], s, -jnp.inf)
                p = jnp.exp(s - lsec[..., None])  # recomputed probabilities
                p = jnp.where(jnp.isneginf(s), 0.0, p)
                dv_blk = jnp.einsum("bgrqk,bqgrd->bkgd", p, gc)
                dp = jnp.einsum("bqgrd,bkgd->bgrqk", gc, vc)
                ds = p * (dp - dltc[..., None]) * scale
                dq_blk = jnp.einsum("bgrqk,bkgd->bqgrd", ds, kc)
                dk_blk = jnp.einsum("bgrqk,bqgrd->bkgd", ds, qc)
                dk_acc = jax.lax.dynamic_update_slice_in_dim(
                    dk_acc, jax.lax.dynamic_slice_in_dim(
                        dk_acc, k_lo, kv_chunk, 1) + dk_blk, k_lo, 1)
                dv_acc = jax.lax.dynamic_update_slice_in_dim(
                    dv_acc, jax.lax.dynamic_slice_in_dim(
                        dv_acc, k_lo, kv_chunk, 1) + dv_blk, k_lo, 1)
                return dq_c + dq_blk, dk_acc, dv_acc

            out = jax.lax.cond(_needed(q_lo, k_lo, q_chunk, kv_chunk, window),
                               compute, lambda c: c, carry)
            return out, None

        dq0 = jnp.zeros((B, q_chunk, G, rep, d), F32)
        (dq_c, dk_acc, dv_acc), _ = jax.lax.scan(
            per_kv, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_c

    dk0 = jnp.zeros((B, S, G, d), F32)
    dv0 = jnp.zeros((B, S, G, d), F32)
    (dk, dv), dqs = jax.lax.scan(per_q_chunk, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, S, G, rep, d)
    return (dq.reshape(B, S, H, d).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def attention_fwd(p: Params, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, window: int = 0) -> jax.Array:
    B, S, D = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    qc = 1024 if S >= 1024 else S
    # custom_vjp requires positional args
    out = flash_attention(q, k, v, window, qc, qc)
    out = out.reshape(B, S, cfg.attn_dim)
    return tp_down_proj(out, p["wo"])


def attention_decode(p: Params, cfg: ModelConfig, x: jax.Array,
                     cache: Dict[str, jax.Array], index: jax.Array,
                     window: int = 0) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, 1, D]; cache: {"k","v": [B, Smax, G, hd]}; index: current pos.

    For windowed layers the cache is a rolling buffer of size ``window``.
    """
    B, _, D = x.shape
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    rep = H // G
    pos = jnp.full((B, 1), index, jnp.int32)
    q, k_new, v_new = _qkv(p, cfg, x, pos)
    Smax = cache["k"].shape[1]
    slot = index % Smax if window > 0 else index
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    kpos = jnp.arange(Smax)
    if window > 0:  # rolling buffer: entry i holds position index - ((slot - i) mod Smax)
        age = (slot - kpos) % Smax
        valid = age <= jnp.minimum(index, window - 1)
    else:
        valid = kpos <= index
    # bf16 operands + fp32 accumulation: converting the cache to f32 for
    # the einsum makes XLA materialise a full fp32 copy of the 32k cache
    # EVERY step (2× full-cache traffic per layer — dominated the decode
    # roofline; §Perf iteration C1).  preferred_element_type keeps the
    # cache read at bf16 while the MXU accumulates in fp32.
    s = jnp.einsum("bqgrd,bkgd->bgrqk",
                   q.reshape(B, 1, G, rep, hd), k,
                   preferred_element_type=F32) * hd ** -0.5
    s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w.astype(k.dtype), v,
                     preferred_element_type=F32)
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    return out @ p["wo"], {"k": k, "v": v}


def attention_decode_stacked(p: Params, cfg: ModelConfig, x: jax.Array,
                             k_stack: jax.Array, v_stack: jax.Array,
                             r: int, index: jax.Array, window: int = 0):
    """Decode with the layer-stacked KV buffers updated IN PLACE.

    The new token's K/V is written into the stacked [L, B, S, G, hd]
    buffer with a tiny dynamic-update-slice (aliased on donated caches),
    then the layer's slice is read once for the attention math — no
    per-layer full-slice copy (the lax.scan ys path pays 2 of those per
    layer per token; §Perf iteration C2).
    """
    B, _, D = x.shape
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    rep = H // G
    pos = jnp.full((B, 1), index, jnp.int32)
    q, k_new, v_new = _qkv(p, cfg, x, pos)
    Smax = k_stack.shape[2]
    slot = index % Smax if window > 0 else index
    zero = jnp.int32(0)
    k_stack = jax.lax.dynamic_update_slice(
        k_stack, k_new[None], (jnp.int32(r), zero, slot, zero, zero))
    v_stack = jax.lax.dynamic_update_slice(
        v_stack, v_new[None], (jnp.int32(r), zero, slot, zero, zero))
    k = jax.lax.index_in_dim(k_stack, r, 0, keepdims=False)
    v = jax.lax.index_in_dim(v_stack, r, 0, keepdims=False)
    kpos = jnp.arange(Smax)
    if window > 0:
        age = (slot - kpos) % Smax
        valid = age <= jnp.minimum(index, window - 1)
    else:
        valid = kpos <= index
    s = jnp.einsum("bqgrd,bkgd->bgrqk", q.reshape(B, 1, G, rep, hd), k,
                   preferred_element_type=F32) * hd ** -0.5
    s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w.astype(k.dtype), v,
                     preferred_element_type=F32)
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    return out @ p["wo"], k_stack, v_stack


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  window: int = 0) -> Dict[str, jax.Array]:
    size = min(window, max_len) if window > 0 else max_len
    shape = (batch, size, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, _dtype(cfg)), "v": jnp.zeros(shape, _dtype(cfg))}


# ------------------------------------------------------------------- mlp
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    return {
        "wi": _init(ks[0], (D, F), D ** -0.5, dt),
        "wg": _init(ks[1], (D, F), D ** -0.5, dt),
        "wd": _init(ks[2], (F, D), F ** -0.5, dt),
    }


def mlp_fwd(p: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu((x @ p["wg"]).astype(F32)) * (x @ p["wi"]).astype(F32)
    h = shard(h.astype(x.dtype), "batch", "seq", "mlp")
    return tp_down_proj(h, p["wd"])


# ------------------------------------------------------------------- moe
def init_moe(key, cfg: ModelConfig) -> Params:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (D, E), D ** -0.5, F32),
        "we_i": _init(ks[1], (E, D, F), D ** -0.5, dt),
        "we_g": _init(ks[2], (E, D, F), D ** -0.5, dt),
        "we_d": _init(ks[3], (E, F, D), F ** -0.5, dt),
    }
    if cfg.shared_experts > 0:
        sh = init_mlp(ks[4], cfg, d_ff=cfg.moe_d_ff * cfg.shared_experts)
        p.update({"ws_i": sh["wi"], "ws_g": sh["wg"], "ws_d": sh["wd"]})
    return p


def _capacity(cfg: ModelConfig, tokens_per_row: int) -> int:
    c = int(math.ceil(tokens_per_row * cfg.experts_per_token
                      * cfg.capacity_factor / cfg.num_experts))
    return max(4, ((c + 3) // 4) * 4)


MOE_CHUNK = 512  # sequence chunk for the einsum dispatch


def moe_fwd(p: Params, cfg: ModelConfig, x: jax.Array,
            rng: Optional[jax.Array] = None,
            router_override: Optional[str] = None) -> jax.Array:
    """Token-choice top-k MoE with CHUNKED EINSUM dispatch/combine.

    The earlier sort+gather/scatter dispatch compiled to giant fp32
    [T·k, D] gather buffers (bf16 scatter-add gets promoted) and an
    expert-replicating all-gather on the combine leg — together the
    dominant memory term of the MoE train cells (§Perf iteration A4).
    This formulation builds a one-hot dispatch tensor per 512-token
    sequence chunk and runs dispatch/combine as einsums:

      buf[e,c,d]  = Σ_s  D[s,e,c]·x[s,d]          (dispatch)
      y[s,d]      = Σ_ec D[s,e,c]·g[s,e]·out[e,c,d]  (combine)

    MXU-friendly, dtype-controlled (bf16 wire), and GSPMD partitions the
    (batch × expert) einsums with clean all-to-alls.  ~25% matmul FLOPs
    overhead at the assigned shapes (C_chunk·E / (k·D) ≪ 1) bought ~4×
    off the memory term.  Capacity is per chunk (≈ paper-standard token
    dropping at cf=1.25).

    router_override="sampled" uses the eRVS/Gumbel-top-k stochastic router
    (the paper's exponential-key mechanism as an exploration router).
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    Sc = min(MOE_CHUNK, S)
    nc = (S + Sc - 1) // Sc
    Cc = _capacity(cfg, Sc)
    router = router_override or cfg.router
    x = shard(x, "batch", "seq", None)
    logits = jnp.einsum("bsd,de->bse", x.astype(F32), p["router"].astype(F32))
    if router == "sampled":
        assert rng is not None, "sampled router needs rng"
        # Gumbel-top-k == Efraimidis–Espirakis exponential keys on softmax
        g = -jnp.log(-jnp.log(jax.random.uniform(
            rng, logits.shape, F32, minval=1e-12)))
        sel_scores = logits + g
    else:
        sel_scores = logits
    probs = jax.nn.softmax(logits, axis=-1)
    _, eidx = jax.lax.top_k(sel_scores, k)  # [B, S, k]
    gates = jnp.take_along_axis(probs, eidx, axis=-1)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    dt = x.dtype
    earange = jnp.arange(E, dtype=eidx.dtype)
    carange = jnp.arange(Cc, dtype=jnp.int32)

    def one_chunk(ci):
        xc = jax.lax.dynamic_slice_in_dim(x, ci * Sc, Sc, axis=1)
        ec = jax.lax.dynamic_slice_in_dim(eidx, ci * Sc, Sc, axis=1)
        gc = jax.lax.dynamic_slice_in_dim(gates, ci * Sc, Sc, axis=1)
        onehot = (ec[..., None] == earange).astype(jnp.int32)  # [B,Sc,k,E]
        flat = onehot.reshape(B, Sc * k, E)
        pos = jnp.cumsum(flat, axis=1) - flat  # rank within expert
        keep = pos < Cc
        slot = ((pos[..., None] == carange) & keep[..., None]
                & (flat[..., None] > 0))  # [B, Sc·k, E, Cc]
        # token-level dispatch: sum each token's k slots
        disp = slot.reshape(B, Sc, k, E, Cc).sum(2).astype(dt)  # [B,Sc,E,Cc]
        gate_e = jnp.einsum("bske,bsk->bse", onehot.astype(F32),
                            gc).astype(dt)
        buf = jnp.einsum("bsec,bsd->becd", disp, xc)
        buf = shard(buf, "batch", "experts", None, None)
        h_g = jnp.einsum("becd,edf->becf", buf, p["we_g"])
        h_i = jnp.einsum("becd,edf->becf", buf, p["we_i"])
        h = (jax.nn.silu(h_g.astype(F32)) * h_i.astype(F32)).astype(dt)
        h = shard(h, "batch", "experts", None, "mlp")
        out_e = jnp.einsum("becf,efd->becd", h, p["we_d"])
        out_e = shard(out_e, "batch", "experts", None, None)
        y_c = jnp.einsum("bsec,bse,becd->bsd", disp, gate_e, out_e)
        return shard(y_c, "batch", None, None)

    if nc == 1:
        y = one_chunk(0)
    else:
        # Python-unrolled chunk loop: under lax.scan the backward emits a
        # full expert-weight-gradient all-reduce PER CHUNK (observed ×8
        # wire/memory blowup); unrolled, the chunk gradients sum locally
        # and reduce once per layer.
        ys = [one_chunk(ci) for ci in range(nc)]
        y = jnp.concatenate(ys, axis=1)[:, :S]
    if cfg.shared_experts > 0:
        y = y + mlp_fwd({"wi": p["ws_i"], "wg": p["ws_g"], "wd": p["ws_d"]}, x)
    return y


# ---------------------------------------------------------------- RG-LRU
def init_rec(key, cfg: ModelConfig) -> Params:
    D, W, K = cfg.d_model, cfg.lru_width, cfg.conv_width
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    # Λ init so a = exp(-8·softplus(Λ)·σ(r)) spans ~(0.9, 0.999) (Griffin)
    lam = jax.random.uniform(ks[3], (W,), F32, 0.0, 1.0)
    return {
        "rg_in": _init(ks[0], (D, W), D ** -0.5, dt),
        "rg_gate": _init(ks[1], (D, W), D ** -0.5, dt),
        "rg_out": _init(ks[2], (W, D), W ** -0.5, dt),
        "rg_conv": _init(ks[4], (K, W), K ** -0.5, dt),
        "rg_a": jnp.log(jnp.exp((lam * 0.65 + 0.35)) - 1.0),  # softplus^-1
        "rg_input_gate": _init(ks[5], (W,), 1.0, F32),
        "rg_a_gate": _init(ks[5], (W,), 1.0, F32),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array,
                           state: Optional[jax.Array] = None):
    """x: [B, S, W]; w: [K, W].  Returns (y, new_state [B, K-1, W])."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return y, xp[:, -(K - 1):]


def _rg_lru(x: jax.Array, p: Params, h0: Optional[jax.Array] = None):
    """x: [B, S, W] → (y, h_last).  a_t = exp(-8·softplus(Λ)·σ(x·w_r))."""
    xf = x.astype(F32)
    r = jax.nn.sigmoid(xf * p["rg_a_gate"])
    i = jax.nn.sigmoid(xf * p["rg_input_gate"])
    log_a = -8.0 * jax.nn.softplus(p["rg_a"]) * r  # [B, S, W]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    if h0 is not None:
        # fold the carried state in as a virtual step 0
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        gated = jnp.concatenate([h0[:, None].astype(F32), gated], axis=1)

    def combine(l, r_):
        a1, b1 = l
        a2, b2 = r_
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        hh = hh[:, 1:]
    return hh.astype(x.dtype), hh[:, -1]


def rec_fwd(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    gate = jax.nn.gelu((x @ p["rg_gate"]).astype(F32)).astype(x.dtype)
    h = x @ p["rg_in"]
    h, _ = _causal_depthwise_conv(h, p["rg_conv"])
    h, _ = _rg_lru(h, p)
    return (h * gate) @ p["rg_out"]


def rec_decode(p: Params, cfg: ModelConfig, x: jax.Array, state):
    """x: [B, 1, D]; state = {"h": [B, W], "conv": [B, K-1, W]}."""
    gate = jax.nn.gelu((x @ p["rg_gate"]).astype(F32)).astype(x.dtype)
    h = x @ p["rg_in"]
    h, conv_state = _causal_depthwise_conv(h, p["rg_conv"], state["conv"])
    h, h_last = _rg_lru(h, p, h0=state["h"])
    y = (h * gate) @ p["rg_out"]
    return y, {"h": h_last.astype(x.dtype), "conv": conv_state}


def init_rec_state(cfg: ModelConfig, batch: int):
    return {
        "h": jnp.zeros((batch, cfg.lru_width), _dtype(cfg)),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), _dtype(cfg)),
    }


# ---------------------------------------------------------------- Mamba2
def init_mamba(key, cfg: ModelConfig) -> Params:
    D, din, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = din // cfg.ssm_head_dim
    G, K = cfg.ssm_groups, cfg.conv_width
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    in_dim = 2 * din + 2 * G * N + H  # [z, x, B, C, dt]
    return {
        "m_in": _init(ks[0], (D, in_dim), D ** -0.5, dt),
        "m_conv": _init(ks[1], (K, din + 2 * G * N), K ** -0.5, dt),
        "m_alog": jnp.log(jnp.arange(1, H + 1, dtype=F32)),
        "m_d": jnp.ones((H,), F32),
        "m_dtbias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, F32))),  # dt≈0.01
        "m_norm": jnp.zeros((din,), F32),
        "m_out": _init(ks[2], (din, D), din ** -0.5, dt),
    }


def _ssd_chunk_scan(xh, dth, A, Bm, Cm, chunk: int):
    """Chunked SSD (state-space duality) scan.

    xh: [b, s, h, p]; dth: [b, s, h]; A: [h]; Bm, Cm: [b, s, n] (1 group).
    Sequential lax.scan over chunks keeps live memory to one chunk — the
    [l, l] intra-chunk matrices exist per chunk only.
    Returns (y [b, s, h, p], final_state [b, h, p, n]).
    """
    b, s, h, p_ = xh.shape
    n = Bm.shape[-1]
    l = min(chunk, s)
    pad = (-s) % l
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dth = jnp.pad(dth, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // l
    xc = xh.reshape(b, nc, l, h, p_).swapaxes(0, 1)
    dtc = dth.reshape(b, nc, l, h).swapaxes(0, 1)
    Bc = Bm.reshape(b, nc, l, n).swapaxes(0, 1)
    Cc = Cm.reshape(b, nc, l, n).swapaxes(0, 1)
    tril = jnp.tril(jnp.ones((l, l), bool))

    def body(state, inp):  # state: [b, h, p, n]
        xk, dk, bk, ck = inp
        dA = dk.astype(F32) * A  # [b, l, h] (negative)
        dA_cs = jnp.cumsum(dA, axis=1)
        # contribution of the carried state
        y0 = jnp.einsum("bln,bhpn->blhp", ck.astype(F32), state) \
            * jnp.exp(dA_cs)[..., None]
        # intra-chunk (masked decay matrix)
        diff = dA_cs[:, :, None, :] - dA_cs[:, None, :, :]  # [b, i, j, h]
        L = jnp.where(tril[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bin,bjn->bij", ck.astype(F32), bk.astype(F32))
        M = scores[..., None] * L  # [b, i, j, h]
        y1 = jnp.einsum("bijh,bjh,bjhp->bihp", M, dk.astype(F32),
                        xk.astype(F32))
        y = y0 + y1
        # state update
        decay_to_end = jnp.exp(dA_cs[:, -1:, :] - dA_cs)  # [b, l, h]
        state = state * jnp.exp(dA_cs[:, -1, :])[:, :, None, None] \
            + jnp.einsum("blh,blhp,bln->bhpn",
                         decay_to_end * dk.astype(F32), xk.astype(F32),
                         bk.astype(F32))
        return state, y

    state0 = jnp.zeros((b, h, p_, n), F32)
    state, ys = jax.lax.scan(body, state0, (xc, dtc, Bc, Cc))
    y = ys.swapaxes(0, 1).reshape(b, nc * l, h, p_)[:, :s]
    return y, state


def _mamba_split(p: Params, cfg: ModelConfig, x: jax.Array):
    din, N, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    H = din // cfg.ssm_head_dim
    zxbcdt = x @ p["m_in"]
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * G * N], axis=-1)
    return z, xbc, dt, (din, N, G, H)


def mamba_fwd(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    B, S, D = x.shape
    z, xbc, dt, (din, N, G, H) = _mamba_split(p, cfg, x)
    xbc, _ = _causal_depthwise_conv(xbc, p["m_conv"])
    xbc = jax.nn.silu(xbc.astype(F32)).astype(x.dtype)
    xin, Bm, Cm = jnp.split(xbc, [din, din + G * N], axis=-1)
    ph = cfg.ssm_head_dim
    xh = xin.reshape(B, S, H, ph)
    dth = jax.nn.softplus(dt.astype(F32) + p["m_dtbias"])  # [B,S,H]
    A = -jnp.exp(p["m_alog"])
    y, _ = _ssd_chunk_scan(xh, dth, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xh.astype(F32) * p["m_d"][:, None]
    y = y.reshape(B, S, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype), p["m_norm"])
    return y @ p["m_out"]


def mamba_decode(p: Params, cfg: ModelConfig, x: jax.Array, state):
    """x: [B, 1, D]; state = {"ssm": [B,H,P,N] f32, "conv": [B,K-1,din+2GN]}."""
    B = x.shape[0]
    z, xbc, dt, (din, N, G, H) = _mamba_split(p, cfg, x)
    xbc, conv_state = _causal_depthwise_conv(xbc, p["m_conv"], state["conv"])
    xbc = jax.nn.silu(xbc.astype(F32)).astype(x.dtype)
    xin, Bm, Cm = jnp.split(xbc, [din, din + G * N], axis=-1)
    ph = cfg.ssm_head_dim
    xh = xin.reshape(B, H, ph).astype(F32)
    dth = jax.nn.softplus(dt.astype(F32) + p["m_dtbias"])[:, 0]  # [B,H]
    A = -jnp.exp(p["m_alog"])
    dA = jnp.exp(dth * A)  # [B,H]
    ssm = state["ssm"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dth, xh, Bm[:, 0].astype(F32))
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(F32), ssm)
    y = y + xh * p["m_d"][:, None]
    y = y.reshape(B, 1, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype), p["m_norm"])
    return y @ p["m_out"], {"ssm": ssm, "conv": conv_state}


def init_mamba_state(cfg: ModelConfig, batch: int):
    din, N, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    H = din // cfg.ssm_head_dim
    return {
        "ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, N), F32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, din + 2 * G * N),
                          _dtype(cfg)),
    }
