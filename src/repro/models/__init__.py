from repro.models.config import ModelConfig
from repro.models.model import (
    decode_step,
    forward,
    forward_hidden,
    init_cache,
    init_params,
    prefill,
    segment_plan,
)

__all__ = [
    "forward_hidden",
    "ModelConfig",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "prefill",
    "segment_plan",
]
