"""Decoder assembly: segments of scanned layers, forward + decode paths.

The layer stack is organised as *segments*: maximal runs of an identical
layer-group, scanned with ``lax.scan`` over stacked parameters.  This keeps
the lowered HLO size O(#segment kinds), not O(#layers) — essential for
compiling 61-layer MoEs on a 512-device mesh (the dry-run would otherwise
produce gigabyte HLO).  Heterogeneous patterns (recurrentgemma's
rec/rec/attn) scan over whole *groups*; the remainder layers form a tail
segment.

  dense GQA       : [ (attn,) × L ]
  MoE w/ lead-in  : [ (attn,) × n_dense, (moe,) × (L - n_dense) ]
  hybrid (griffin): [ (rec, rec, attn) × L//3, (rec,) × L%3 ]
  mamba2          : [ (mamba,) × L ]
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.config import ModelConfig

F32 = jnp.float32


# ----------------------------------------------------------------- plan
def segment_plan(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    kinds = cfg.layer_kinds()
    if cfg.family == "hybrid" and cfg.block_pattern:
        g = len(cfg.block_pattern)
        full = cfg.num_layers // g
        plan = [(tuple(cfg.block_pattern), full)]
        rem = cfg.num_layers % g
        if rem:
            plan.append((tuple(cfg.block_pattern[:rem]), 1))
        return plan
    # group identical consecutive kinds
    plan: List[Tuple[Tuple[str, ...], int]] = []
    for kind in kinds:
        if plan and plan[-1][0] == (kind,):
            plan[-1] = ((kind,), plan[-1][1] + 1)
        else:
            plan.append(((kind,), 1))
    return plan


# ----------------------------------------------------------------- init
def _init_block(key, kind: str, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), F32)}
    if kind == "attn":
        p["attn"] = L.init_attention(ks[0], cfg)
        p["mlp"] = L.init_mlp(ks[1], cfg)
        p["norm2"] = jnp.zeros((cfg.d_model,), F32)
    elif kind == "moe":
        p["attn"] = L.init_attention(ks[0], cfg)
        p["moe"] = L.init_moe(ks[1], cfg)
        p["norm2"] = jnp.zeros((cfg.d_model,), F32)
    elif kind == "rec":
        p["rec"] = L.init_rec(ks[0], cfg)
        p["mlp"] = L.init_mlp(ks[1], cfg)
        p["norm2"] = jnp.zeros((cfg.d_model,), F32)
    elif kind == "mamba":
        p["mamba"] = L.init_mamba(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, len(segment_plan(cfg)) + 2)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model), F32)
                  * cfg.d_model ** -0.5).astype(dt),
        "final_norm": jnp.zeros((cfg.d_model,), F32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size), F32)
            * cfg.d_model ** -0.5).astype(dt)
    segs = []
    for si, (kinds, reps) in enumerate(segment_plan(cfg)):
        seg_keys = jax.random.split(keys[2 + si], reps)

        def init_group(k):
            gks = jax.random.split(k, len(kinds))
            return {f"b{j}_{kind}": _init_block(gks[j], kind, cfg)
                    for j, kind in enumerate(kinds)}

        segs.append(jax.vmap(init_group)(seg_keys))
    params["segments"] = segs
    return params


# ------------------------------------------------------------- blocks
def _res_scale(cfg: ModelConfig) -> float:
    if cfg.scale_depth > 0:
        return cfg.scale_depth / (cfg.num_layers ** 0.5)
    return 1.0


def _apply_block(kind: str, p, cfg: ModelConfig, x, positions):
    s = _res_scale(cfg)
    if kind in ("attn", "moe"):
        window = cfg.local_window if cfg.family == "hybrid" else 0
        h = L.attention_fwd(p["attn"], cfg, L.rms_norm(x, p["norm1"]),
                            positions, window=window)
        x = x + s * h
        h2 = L.rms_norm(x, p["norm2"])
        if kind == "moe":
            h2 = L.moe_fwd(p["moe"], cfg, h2)
        else:
            h2 = L.mlp_fwd(p["mlp"], h2)
        x = x + s * h2
    elif kind == "rec":
        x = x + s * L.rec_fwd(p["rec"], cfg, L.rms_norm(x, p["norm1"]))
        x = x + s * L.mlp_fwd(p["mlp"], L.rms_norm(x, p["norm2"]))
    elif kind == "mamba":
        x = x + s * L.mamba_fwd(p["mamba"], cfg, L.rms_norm(x, p["norm1"]))
    else:
        raise ValueError(kind)
    return shard(x, "batch", "seq", "embed")


def _apply_block_decode(kind: str, p, cfg: ModelConfig, x, cache, index):
    s = _res_scale(cfg)
    if kind in ("attn", "moe"):
        window = cfg.local_window if cfg.family == "hybrid" else 0
        h, kv = L.attention_decode(p["attn"], cfg, L.rms_norm(x, p["norm1"]),
                                   cache["kv"], index, window=window)
        x = x + s * h
        h2 = L.rms_norm(x, p["norm2"])
        if kind == "moe":
            h2 = L.moe_fwd(p["moe"], cfg, h2)
        else:
            h2 = L.mlp_fwd(p["mlp"], h2)
        x = x + s * h2
        return x, {"kv": kv}
    if kind == "rec":
        h, st = L.rec_decode(p["rec"], cfg, L.rms_norm(x, p["norm1"]),
                             cache["rec"])
        x = x + s * h
        x = x + s * L.mlp_fwd(p["mlp"], L.rms_norm(x, p["norm2"]))
        return x, {"rec": st}
    if kind == "mamba":
        h, st = L.mamba_decode(p["mamba"], cfg, L.rms_norm(x, p["norm1"]),
                               cache["ssm"])
        return x + s * h, {"ssm": st}
    raise ValueError(kind)


# ------------------------------------------------------------- forward
def forward_hidden(params, cfg: ModelConfig, tokens: jax.Array,
                   remat: bool = True,
                   embeddings: Optional[jax.Array] = None):
    """tokens [B, S] → (final-norm hidden [B, S, D], head [D, V]).

    Callers that only need the loss use the hidden states with the chunked
    vocab-sharded cross-entropy (train.step.loss_fn) — full [B, S, V] fp32
    logits are never materialised during training.
    """
    B, S = tokens.shape[:2]
    if embeddings is None:
        x = params["embed"][tokens]
        x = shard(x, "batch", "seq", None)
    else:
        x = embeddings
    x = x.astype(jnp.dtype(cfg.dtype))
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    for (kinds, reps), seg in zip(segment_plan(cfg), params["segments"]):

        def body(x, p_layer):
            for j, kind in enumerate(kinds):
                x = _apply_block(kind, p_layer[f"b{j}_{kind}"], cfg, x,
                                 positions)
            return x, None

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, seg)

    x = L.rms_norm(x, params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return x, head


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            remat: bool = True,
            embeddings: Optional[jax.Array] = None) -> jax.Array:
    """tokens [B, S] int32 → logits [B, S, V] (fp32, vocab-sharded).

    ``embeddings`` (optional [B, S, D]) bypasses the token embedding — the
    stub modality frontends of the VLM/audio archs inject precomputed
    patch/frame embeddings here (assignment: frontends are stubs).
    """
    x, head = forward_hidden(params, cfg, tokens, remat=remat,
                             embeddings=embeddings)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(F32), head.astype(F32))
    return shard(logits, "batch", "seq", "vocab")


# -------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked per-segment decode caches (scan-compatible pytrees)."""
    caches = []
    for kinds, reps in segment_plan(cfg):
        def one_group(_):
            g = {}
            for j, kind in enumerate(kinds):
                if kind in ("attn", "moe"):
                    window = cfg.local_window if cfg.family == "hybrid" else 0
                    g[f"b{j}_{kind}"] = {"kv": L.init_kv_cache(
                        cfg, batch, max_len, window=window)}
                elif kind == "rec":
                    g[f"b{j}_{kind}"] = {"rec": L.init_rec_state(cfg, batch)}
                elif kind == "mamba":
                    g[f"b{j}_{kind}"] = {"ssm": L.init_mamba_state(cfg, batch)}
            return g

        caches.append(jax.vmap(one_group)(jnp.arange(reps)))
    return caches


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, caches,
                index: jax.Array,
                embeddings: Optional[jax.Array] = None,
                unroll: bool = False):
    """tokens [B, 1] + caches + position index → (logits [B, V], caches').

    ``unroll=True`` runs the layer loop in Python instead of lax.scan:
    the scan's ys-restacking copies each layer's FULL cache slice per
    step (2 cache copies/layer/token); unrolled, the cache update is a
    plain dynamic-update-slice on a donated buffer that XLA aliases
    in place (§Perf iteration C2).  HLO grows O(L) — fine for decode.
    """
    B = tokens.shape[0]
    x = params["embed"][tokens] if embeddings is None else embeddings
    x = x.astype(jnp.dtype(cfg.dtype))
    x = shard(x, "batch", None, "embed")
    new_caches = []
    for (kinds, reps), seg, cache in zip(segment_plan(cfg),
                                         params["segments"], caches):

        def body(x, scanned):
            p_layer, c_layer = scanned
            new_c = {}
            for j, kind in enumerate(kinds):
                key = f"b{j}_{kind}"
                x, new_c[key] = _apply_block_decode(
                    kind, p_layer[key], cfg, x, c_layer[key], index)
            return x, new_c

        if unroll:
            # container-level copy so the caller's cache pytree is not
            # mutated; leaves are replaced functionally below
            upd = jax.tree_util.tree_map(lambda a: a, cache)
            s = _res_scale(cfg)
            for r in range(reps):
                p_layer = jax.tree.map(lambda a: a[r], seg)
                for j, kind in enumerate(kinds):
                    key = f"b{j}_{kind}"
                    p_blk = p_layer[key]
                    if kind in ("attn", "moe"):
                        window = cfg.local_window if cfg.family == "hybrid" \
                            else 0
                        h, ks, vs = L.attention_decode_stacked(
                            p_blk["attn"], cfg,
                            L.rms_norm(x, p_blk["norm1"]),
                            upd[key]["kv"]["k"], upd[key]["kv"]["v"],
                            r, index, window=window)
                        upd[key]["kv"]["k"] = ks
                        upd[key]["kv"]["v"] = vs
                        x = x + s * h
                        h2 = L.rms_norm(x, p_blk["norm2"])
                        h2 = L.moe_fwd(p_blk["moe"], cfg, h2) \
                            if kind == "moe" else L.mlp_fwd(p_blk["mlp"], h2)
                        x = x + s * h2
                    else:
                        c_layer = jax.tree.map(lambda a: a[r], upd[key])
                        x, new_c = _apply_block_decode(
                            kind, p_blk, cfg, x, c_layer, index)
                        upd[key] = jax.tree.map(
                            lambda full, n: full.at[r].set(n),
                            upd[key], new_c)
        else:
            x, upd = jax.lax.scan(body, x, (seg, cache))
        new_caches.append(upd)

    x = L.rms_norm(x, params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x.astype(F32), head.astype(F32))[:, 0]
    return shard(logits, "batch", "vocab"), new_caches


def prefill(params, cfg: ModelConfig, tokens: jax.Array,
            embeddings: Optional[jax.Array] = None,
            last_only: bool = False) -> jax.Array:
    """Prefill forward (no cache write-back — benchmark/roofline path).

    Production serving would fuse cache population; for the dry-run cells
    the compute/memory/collective profile of prefill is what matters.
    ``last_only`` computes logits for the final position only — serving
    needs just the next-token distribution, which deletes the [B, S, V]
    head matmul and its collectives (§Perf iteration B).
    """
    if not last_only:
        return forward(params, cfg, tokens, remat=False,
                       embeddings=embeddings)
    x, head = forward_hidden(params, cfg, tokens, remat=False,
                             embeddings=embeddings)
    x_last = x[:, -1]
    logits = jnp.einsum("bd,dv->bv", x_last.astype(F32), head.astype(F32))
    return shard(logits, "batch", "vocab")
