"""eRVS — enhanced reservoir sampling (paper §3.2, Alg. 1 + Fig. 4).

Two statistically equivalent implementations:

* :func:`ervs_step` — the EXP optimisation: Efraimidis–Spirakis exponential
  keys, arg-max selection.  No prefix sum over the weights (the baseline
  FlowWalker kernel needs one) — a single streaming pass.
  We use the *log-domain* key ln(u)/w̃ (monotone in u^{1/w̃}); the float key
  of the paper underflows fp32 for small w̃, the log form does not.
* :func:`ervs_jump_step` — adds the A-ExpJ *jump* technique [9, 16]: per
  lane, a threshold T drawn once replaces per-neighbour RNG; random numbers
  are only drawn when the cumulative weight crosses T.  Statistically
  identical; the point is the RNG/transcendental reduction, which the Pallas
  kernel exploits at block granularity (see kernels/ervs_kernel.py).

Both scan the neighbour list in [W, tile] blocks with a fori_loop, so memory
traffic is one streaming pass over each walker's row — the paper's "roughly
halves the costly memory accesses" claim vs prefix-sum RVS.

Engine integration: registered as the ``ervs`` / ``ervs_jump`` samplers
(``samplers.ERVSSampler`` / ``ERVSJumpSampler``); both honour the runtime
partition mask, so either can serve as the reservoir half of a
``PartitionedSampler``.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ctxutil import degrees_of as degrees_of_cached, eval_weights, tile_ctx
from repro.core.types import Workload
from repro.graphs.csr import CSRGraph

NEG_INF = jnp.float32(-jnp.inf)


def _log_keys(u: jax.Array, w: jax.Array) -> jax.Array:
    """ln(key) = ln(u)/w̃ for w̃>0 else -inf.  u ∈ (0,1)."""
    safe_w = jnp.where(w > 0, w, 1.0)
    lk = jnp.log(u) / safe_w
    return jnp.where(w > 0, lk, NEG_INF)


@partial(jax.jit, static_argnames=("workload", "params", "tile", "max_tiles"))
def ervs_step(
    graph: CSRGraph,
    workload: Workload,
    params,
    cur: jax.Array,
    prev: jax.Array,
    step: jax.Array,
    rng: jax.Array,  # [W, 2] per-walker keys
    tile: int = 256,
    max_tiles: Optional[int] = None,
    active: Optional[jax.Array] = None,
    wstate=None,
) -> jax.Array:
    """One eRVS step for a batch of walkers.  Returns next nodes [W] (or -1).

    ``active`` masks walkers this kernel should process (runtime partition);
    inactive walkers return -2 (untouched sentinel for the engine to merge).
    ``wstate`` is the per-walker program state fed to ``get_weight``
    (WalkProgram contract); ``None`` for stateless programs.
    """
    W = cur.shape[0]
    if active is None:
        active = jnp.ones((W,), bool)
    # dynamic trip count: tiles needed by the *active* partition only — when
    # the cost model sends every high-degree walker to eRJS, the eRVS pass
    # shrinks accordingly (fori_loop with a traced bound lowers to while).
    deg_act = jnp.where(active, degrees_of_cached(graph, cur), 0)
    needed = (jnp.max(deg_act) + tile - 1) // tile
    if max_tiles is not None:
        needed = jnp.minimum(needed, max_tiles)

    def body(t, carry):
        best_lk, best_nbr = carry
        ctx, mask = tile_ctx(graph, workload, cur, prev, step,
                             jnp.full((W,), t * tile, jnp.int32), tile)
        w = eval_weights(workload, params, ctx, mask, wstate)
        # counter-based per-(walker, tile) uniforms — the "jumping RNG" idiom:
        # no sequential stream to advance, so tiles are independent.
        u = _tile_uniforms(rng, t, (W, tile))
        lk = jnp.where(mask & active[:, None], _log_keys(u, w), NEG_INF)
        tile_best = jnp.argmax(lk, axis=1)
        tile_lk = jnp.take_along_axis(lk, tile_best[:, None], axis=1)[:, 0]
        tile_nbr = jnp.take_along_axis(ctx.nbr, tile_best[:, None], axis=1)[:, 0]
        upd = tile_lk > best_lk
        return (jnp.where(upd, tile_lk, best_lk), jnp.where(upd, tile_nbr, best_nbr))

    init = (jnp.full((W,), NEG_INF), jnp.full((W,), -1, jnp.int32))
    best_lk, best_nbr = jax.lax.fori_loop(0, needed, body, init)
    return jnp.where(active, best_nbr, -2)


@partial(jax.jit, static_argnames=("workload", "params", "tile", "max_tiles"))
def ervs_jump_step(
    graph: CSRGraph,
    workload: Workload,
    params,
    cur: jax.Array,
    prev: jax.Array,
    step: jax.Array,
    rng: jax.Array,
    tile: int = 256,
    max_tiles: Optional[int] = None,
    active: Optional[jax.Array] = None,
    wstate=None,
) -> Tuple[jax.Array, jax.Array]:
    """A-ExpJ (jump) variant.  Returns (next_nodes [W], rng_draws [W]).

    Each *lane* l ∈ [0, tile) owns the strided neighbour subsequence
    {l, l+tile, l+2·tile, …} of its walker, runs sequential A-ExpJ on it
    (carry: local log-key max, threshold, cumulative weight), and the final
    reduction arg-maxes over lanes — exactly the paper's per-thread local
    max + cross-thread reduction (Fig. 4b), with threads → vector lanes.

    rng_draws counts actual draws (consumed only at threshold crossings);
    on SIMD hardware the arithmetic cost of a masked lane is not saved, but
    the Pallas kernel skips whole *blocks* — this function is the semantic
    oracle and the statistics source (Fig. 12a JUMP ablation).
    """
    W = cur.shape[0]
    if active is None:
        active = jnp.ones((W,), bool)
    deg_act = jnp.where(active, degrees_of_cached(graph, cur), 0)
    needed = (jnp.max(deg_act) + tile - 1) // tile
    if max_tiles is not None:
        needed = jnp.minimum(needed, max_tiles)

    def body(t, carry):
        lk_max, nbr_best, thresh, cumw, draws = carry
        ctx, mask = tile_ctx(graph, workload, cur, prev, step,
                             jnp.full((W,), t * tile, jnp.int32), tile)
        w = eval_weights(workload, params, ctx, mask, wstate)  # [W, tile]
        w = jnp.where(active[:, None], w, 0.0)
        is_first = lk_max == NEG_INF  # lane not initialised yet
        # --- initialisation: first item of each lane draws a plain key ---
        u0 = _tile_uniforms(rng, 2 * t, (W, tile))
        init_lk = _log_keys(u0, w)
        # --- jump: does this item cross the lane threshold? ---
        crossed = (cumw + w >= thresh) & (w > 0) & mask
        # conditional key on crossing: u2 ~ U(t_w, 1), t_w = exp(w·lk_max)
        t_w = jnp.exp(jnp.clip(w * lk_max, -80.0, 0.0))
        u2 = t_w + u0 * (1.0 - t_w)
        cross_lk = _log_keys(jnp.clip(u2, 1e-38, 1.0), w)
        new_key = jnp.where(is_first, init_lk, cross_lk)
        take = (is_first & (w > 0) & mask) | crossed
        # new threshold after an update: T = ln(u')/lk_new, cumw resets
        u1 = _tile_uniforms(rng, 2 * t + 1, (W, tile))
        lk_new = jnp.where(take, new_key, lk_max)
        new_thresh_val = jnp.log(u1) / jnp.where(lk_new < 0, lk_new, -1e-30)
        thresh = jnp.where(take, new_thresh_val, thresh)
        cumw = jnp.where(take, 0.0, cumw + jnp.where(mask, w, 0.0))
        nbr_best = jnp.where(take, ctx.nbr, nbr_best)
        # dtype pinned: under JAX_ENABLE_X64 an unpinned int32 sum promotes
        # to int64 and breaks the fori_loop carry contract
        draws = draws + jnp.sum(take, axis=1, dtype=jnp.int32) * 2
        return (lk_new, nbr_best, thresh, cumw, draws)

    init = (
        jnp.full((W, tile), NEG_INF),
        jnp.full((W, tile), -1, jnp.int32),
        jnp.zeros((W, tile), jnp.float32),  # thresh: first item always "crosses" via is_first
        jnp.zeros((W, tile), jnp.float32),
        jnp.zeros((W,), jnp.int32),
    )
    lk, nbr, _, _, draws = jax.lax.fori_loop(0, needed, body, init)
    lane = jnp.argmax(lk, axis=1)
    best = jnp.take_along_axis(nbr, lane[:, None], axis=1)[:, 0]
    best = jnp.where(jnp.max(lk, axis=1) > NEG_INF, best, -1)
    return jnp.where(active, best, -2), draws


def _tile_uniforms(rng: jax.Array, t, shape) -> jax.Array:
    """Counter-based uniforms for (walker-batch, tile t): fold t into the key.

    rng is [W, 2] (one key per walker); we fold the tile counter so that any
    tile's randomness is addressable without advancing a stream — this is
    what makes block-level jumps actually free in the Pallas kernel.
    """
    W, tile = shape
    base = jax.vmap(lambda k: jax.random.fold_in(k, t))(rng)
    u = jax.vmap(lambda k: jax.random.uniform(
        k, (tile,), dtype=jnp.float32, minval=1e-12, maxval=1.0))(base)
    return u
