"""Flexi-Runtime — the walk engine (paper §4.1, §5.2, §5.3, Fig. 8).

The engine is sampler-agnostic: ``EngineConfig.method`` resolves through
the :mod:`repro.core.samplers` registry to a :class:`~repro.core.samplers.
Sampler` object, and the jitted step loop simply calls
``sampler.select(ctx, state, rng, active=live)`` — there is no per-method
dispatch here.  The paper's runtime adaptation (per-node eRJS/eRVS choice
via the Eq. 11 cost model, with the §7.1 fallback) lives in
``PartitionedSampler``; registering a new strategy by name makes it
runnable end-to-end with no engine edits.

Step loop: the carry is a :class:`~repro.core.types.WalkerState` pytree
(cur/prev/step/alive/rng per slot) advanced by ``lax.scan``.  Each step
folds the walker's step counter into its per-query stream key, masks the
live lanes (alive ∧ degree>0 ∧ step<L), and records
:class:`~repro.core.types.StepStats` telemetry over live lanes only.

Scheduling (§5.3): the GPU global-atomic work queue becomes a *streaming
epoch scheduler* — ``run`` keeps a fixed number of walker slots, executes
the jitted epoch (``epoch_len`` scan steps), and between epochs refills
slots whose walker finished (walked L steps or dead-ended) from a
host-side queue of pending queries.  Empty slots stay ``alive=False``:
they are masked out of every kernel and never touch paths or telemetry,
so query counts that don't divide the slot count cannot skew ``frac_rjs``.
Queries are degree-sorted host-side (degree-similar co-scheduling) so the
dynamic tile-trip bound in eRVS actually bites.  Because random streams
are keyed per query (not per slot), results are bit-identical for any
slot count / epoch length.

Multi-device (docs/scaling.md): ``run(..., devices=N)`` shards the slot
pool over a 1D ``"walkers"`` mesh — each device owns a contiguous block
of slots, the single host-side queue refills them *round-robin across
devices* so no device starves while another queues work, and the jitted
epoch runs as one GSPMD program with the graph replicated.  Telemetry
stays exact: ``StepStats`` counters are integer sums over live lanes, a
cross-device reduction with no ordering freedom, so ``frac_rjs`` /
``frac_precomp`` are identical to the single-device run — as are the
paths, because RNG streams are per query (topology invariance is the
batch-invariance contract, extended).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flexi_compiler as fc
from repro.core import precomp as precomp_mod
from repro.core.cost_model import CostModel
from repro.core.ctxutil import degrees_of
from repro.core.samplers import (PRECOMP_EXEC_CHOICES, SamplerContext,
                                 available_samplers, get_sampler,
                                 resolve_precomp_exec)
from repro.core.types import (EdgeCtx, StepStats, WalkerState, WalkProgram,
                              Workload, from_workload)
from repro.distributed import sharding as shd
from repro.graphs.csr import CSRGraph
from repro.graphs import node_stats
from repro.graphs.delta import GraphDelta, UpdateReport, host_row_layout
# DMA block size of the mega-step kernel (kernels/ref.py is jnp-only —
# importing the constant never loads the Pallas modules)
from repro.kernels.ref import TILE as KERNEL_TILE

# Snapshot of the built-in registry (kept for CLI choices / legacy imports);
# the registry itself is the source of truth and accepts custom samplers.
METHODS = available_samplers()

DEFAULT_EPOCH_LEN = 16

# Step execution paths (EngineConfig.step_exec): "staged" = the lax.scan
# step loop below; "fused" = the kernels/megastep_kernel.py mega-step (one
# Pallas kernel per epoch, no XLA round-trips between DMA / weight eval /
# regime pick / hooks); "auto" = fused on TPU when the (sampler × program)
# cell is provably fusable, staged everywhere else.  Both paths consume
# the same counter-based Threefry streams and are bit-identical — the
# knob is throughput only, and non-fusable cells silently keep the staged
# scan (WalkEngine.step_exec_resolved reports the decision).
STEP_EXEC_CHOICES = ("auto", "fused", "staged")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    method: str = "adaptive"
    tile: int = 256
    rjs_trials: int = 8
    rjs_max_rounds: int = 16
    cost_model: CostModel = dataclasses.field(default_factory=CostModel)
    seed: int = 0
    # "degree" selection strategy threshold (Fig. 13 baseline)
    degree_threshold: int = 1024
    # degree at which PartitionedSampler's reservoir side switches from
    # plain eRVS to the A-ExpJ jump variant (per-node reservoir choice:
    # the jump bookkeeping only pays for itself on long rows)
    jump_threshold: int = 1024
    # scan steps per scheduler epoch.  None → one full-walk epoch when
    # every query has a slot (nothing to refill, no host syncs mid-walk),
    # else min(walk length, 16).  Slots are refilled from the host queue
    # only at epoch boundaries, so smaller epochs reclaim dead lanes
    # sooner at the cost of more host syncs.
    epoch_len: Optional[int] = None
    # execution path for precomputed-table draws: "pallas" = the
    # kernels/precomp_kernel.py DMA kernels (interpret mode off-TPU),
    # "jnp" = the core/precomp.py selectors, "auto" = pallas on TPU, jnp
    # elsewhere.  Bit-identical either way — this knob is throughput only.
    precomp_exec: str = "auto"
    # stale precomp rows re-baked per scheduler epoch (amortized background
    # rebuild after update_graph invalidations); 0 disables draining, so
    # stale rows keep the dynamic fallback until drain_rebuilds() is
    # called explicitly.
    rebuild_budget: int = 8
    # drain the rebuild queue only every K-th scheduler epoch, with a
    # K×-sized batch (same amortized rate, fewer host round-trips — each
    # drain is one jitted scatter regardless of row count).  1 = drain
    # every epoch (the original cadence).  Like the epoch cadence itself,
    # this only matters while the queue is non-empty (see run()'s batch-
    # invariance note).
    rebuild_interval: int = 1
    # fold the structural delta overlay (apply_updates) back into a
    # contiguous CSR every K-th engine epoch, on the engine-absolute
    # epoch clock (so the cadence is a property of the engine's
    # timeline, not of any one run's loop).  0 = never compact
    # automatically; call WalkEngine.compact() explicitly.
    compact_interval: int = 0
    # step execution path: see STEP_EXEC_CHOICES above.  Bit-identical
    # either way; "fused" on a non-fusable (sampler × program) cell keeps
    # the staged scan rather than erroring.
    step_exec: str = "auto"

    def __post_init__(self):
        if self.method not in available_samplers():
            raise ValueError(
                f"method {self.method!r} does not name a registered "
                f"sampler; known samplers: "
                f"{', '.join(available_samplers())}")
        if self.precomp_exec not in PRECOMP_EXEC_CHOICES:
            raise ValueError(
                f"precomp_exec {self.precomp_exec!r} does not name a "
                f"table-draw execution path; valid choices: "
                f"{', '.join(PRECOMP_EXEC_CHOICES)}")
        if self.rebuild_budget < 0:
            raise ValueError(
                f"rebuild_budget must be >= 0 (stale table rows re-baked "
                f"per scheduler epoch; 0 disables background rebuilds), "
                f"got {self.rebuild_budget}")
        if self.rebuild_interval < 1:
            raise ValueError(
                f"rebuild_interval must be >= 1 (drain the rebuild queue "
                f"every K-th scheduler epoch), got {self.rebuild_interval}")
        if self.compact_interval < 0:
            raise ValueError(
                f"compact_interval must be >= 0 (fold the structural "
                f"overlay into a fresh CSR every K-th engine epoch; 0 "
                f"keeps compaction explicit-only), "
                f"got {self.compact_interval}")
        if self.step_exec not in STEP_EXEC_CHOICES:
            raise ValueError(
                f"step_exec {self.step_exec!r} does not name a step "
                f"execution path; valid choices: "
                f"{', '.join(STEP_EXEC_CHOICES)}")


@dataclasses.dataclass
class WalkResult:
    paths: np.ndarray  # [Q, L+1] int32; -1 marks termination
    frac_rjs: float  # fraction of live steps served by eRJS (Fig. 14)
    rjs_fallbacks: int
    steps: int
    live_steps: int = 0  # total live walker-steps (the frac_rjs denominator)
    # fraction of live steps served from precomputed ITS/alias tables
    # (nonzero only for static-provable workloads in the precomp regime)
    frac_precomp: float = 0.0
    # fraction of live steps that hit a stale (invalidated) table row and
    # fell back to the dynamic path — transient: drops to 0 once the
    # rebuild queue has re-baked every invalidated row
    frac_stale: float = 0.0
    # stale table rows re-baked by this run's per-epoch queue drains
    rebuilt_rows: int = 0
    # per-device work distribution for sharded runs (run(..., devices=N)):
    # one dict per device — {"device", "slots", "queries", "emitted_steps"}.
    # None for single-device runs.  Aggregate telemetry above is already
    # the exact cross-device reduction; this is the balance diagnostic.
    per_device: Optional[list] = None


@dataclasses.dataclass
class EpochReport:
    """What one scheduler epoch did — the epoch-boundary view a driver
    (``WalkEngine.run`` or ``repro.serving.WalkService``) schedules
    against."""

    #: query ids whose walkers finished this epoch (walked ``num_steps``,
    #: dead-ended, or stopped via ``should_stop``) — their slots are free
    completed: np.ndarray
    #: steps each completed query actually walked (aligned with
    #: ``completed``; < num_steps for dead ends / early stops)
    steps_taken: np.ndarray
    #: slots occupied while the epoch ran
    occupied: int
    #: this epoch's integer telemetry sums (``StepStats.host_totals`` keys)
    stats: dict

    @property
    def walker_steps(self) -> int:
        """Live walker-steps this epoch actually served (the ``live``
        telemetry sum) — the work unit the serving loop's deficit-round-
        robin fairness scheduler charges against a tenant's credit.  Pad
        slots, finished walkers and dead lanes never count, so an epoch
        over a mostly-empty pool is cheap in deficit terms exactly like
        it is cheap in arithmetic."""
        return int(self.stats.get("live", 0))


class EpochScheduler:
    """Host-side driver of one engine's jitted epoch — the streaming
    scheduler of §5.3 as a reusable object.

    ``WalkEngine.run`` is a thin loop over this class (admit everything,
    step until drained); ``repro.serving.WalkService`` drives the same
    object as a long-lived serving loop, admitting queries from concurrent
    clients at epoch boundaries.  Because both paths share the slot pool,
    refill scatter, path harvest and telemetry accumulation — and random
    streams are keyed per *query id* (``fold_in(key, qid)``), never per
    slot or epoch — a query's served path is bit-identical no matter which
    driver ran it or when it was admitted (the scheduler contract
    documented on ``run``).

    Epoch-boundary hooks
    --------------------
    * :meth:`free_slots` — slots available for admission (round-robin
      across devices under a mesh).
    * :meth:`admit` — install queries into free slots without retrace:
      a refilled slot gets ``step=0``, ``prev=-1``, ``alive=True``, the
      query's own stream key, and a fresh ``init_walker_state(qid)``.
    * :meth:`run_epoch` — drain the engine's rebuild queue on its
      cadence, execute one jitted epoch, harvest emitted path entries,
      and report which queries completed.
    * :meth:`kill` — clear lanes' alive bits host-side (the serving
      loop's deadline enforcement: the walker emits nothing further and
      stops counting toward telemetry, exactly like a ``should_stop``
      verdict folding into the alive mask).

    Query ids are caller-assigned: they pick the RNG stream
    (``fold_in(key, qid)``) and index into :attr:`paths`, which grows on
    demand (``run`` sizes it exactly; the serving loop admits unbounded
    streams).
    """

    def __init__(self, engine: "WalkEngine", num_steps: int, key,
                 slots: int, epoch_len: int, mesh=None, n_dev: int = 1,
                 capacity: int = 0, track_tables: bool = False):
        self.engine = engine
        self.num_steps = int(num_steps)
        self.key = key
        self.W = int(slots)
        self.T = int(epoch_len)
        self.mesh = mesh
        self.n_dev = int(n_dev)
        #: serve every epoch from this pinned view of the precomp tables,
        #: NOT from engine.precomp — background drains repair the engine's
        #: copy without flipping any row's regime mid-run (the batch-
        #: invariance contract of run(); drains become visible to the
        #: next scheduler, or immediately with track_tables=True, the
        #: serving loop's epoch-granular mode)
        # pins tables + graph/stats/pad views and records the engine
        # mutation epoch; a clock bump (weight or structural mutation)
        # forces a re-pin — the old views index a dead row layout /
        # stale payloads (see run_epoch)
        self.adopt_tables()
        self.track_tables = bool(track_tables)
        # slots per device (device d owns [d·spd, (d+1)·spd))
        self.spd = self.W // self.n_dev
        #: [Q, num_steps+1] harvested paths, -1 past termination; row q
        #: belongs to query id q (grown on demand for streaming drivers)
        self.paths = np.full((int(capacity), self.num_steps + 1), -1,
                             np.int32)
        #: query id each slot serves (-1 = free)
        self.slot_query = np.full(self.W, -1, np.int64)
        #: accumulated StepStats.host_totals over every epoch run so far
        self.totals = {"live": 0, "rjs_served": 0, "fallbacks": 0,
                       "precomp_served": 0, "stale_served": 0}
        self.rebuilt_rows = 0
        self.epoch_idx = 0
        self.dev_queries = np.zeros(self.n_dev, np.int64)
        self.dev_steps = np.zeros(self.n_dev, np.int64)
        kd_shape = jax.random.key_data(key).shape
        state = WalkerState(
            cur=jnp.zeros((self.W,), jnp.int32),
            prev=jnp.full((self.W,), -1, jnp.int32),
            step=jnp.full((self.W,), self.num_steps, jnp.int32),
            alive=jnp.zeros((self.W,), bool),
            rng=jnp.zeros((self.W,) + kd_shape, jnp.uint32),
            carry=engine.sampler.init_carry(engine.sampler_ctx, self.W),
            # program-owned per-walker state: placeholder rows until a
            # refill installs the query's own init_walker_state(q)
            wstate=engine.workload.init_wstate_batch(
                jnp.zeros((self.W,), jnp.int32)),
        )
        if mesh is not None:
            state = shd.shard_walker_state(state, self.W, mesh)
        self.state = state

    # ------------------------------------------------------------- queries
    @property
    def busy(self) -> bool:
        """Whether any slot still serves a query."""
        return bool((self.slot_query >= 0).any())

    @property
    def occupancy(self) -> int:
        """Slots currently serving a query (never exceeds ``W``)."""
        return int((self.slot_query >= 0).sum())

    def in_flight(self) -> np.ndarray:
        """Query ids currently occupying slots."""
        return self.slot_query[self.slot_query >= 0].copy()

    def free_slots(self) -> np.ndarray:
        """Admittable slot indices.  Under a mesh they come round-robin
        across devices (every device's first free slot before any
        device's second), so one busy device cannot leave another starved
        while queries queue."""
        free = np.nonzero(self.slot_query < 0)[0]
        if self.mesh is not None and free.size:
            free = free[np.argsort((free % self.spd) * self.n_dev
                                   + free // self.spd, kind="stable")]
        return free

    def _ensure_capacity(self, n: int) -> None:
        if n <= self.paths.shape[0]:
            return
        cap = max(n, 2 * self.paths.shape[0], 64)
        grown = np.full((cap, self.num_steps + 1), -1, np.int32)
        grown[:self.paths.shape[0]] = self.paths
        self.paths = grown

    def admit(self, query_ids, starts) -> int:
        """Install queries into free slots (epoch-boundary refill).

        ``query_ids`` pick the RNG streams and path rows; the caller must
        not exceed ``free_slots()``.  Returns how many were admitted.
        """
        qs = np.asarray(query_ids, np.int64).reshape(-1)
        if qs.size == 0:
            return 0
        starts = np.asarray(starts, np.int32).reshape(-1)
        free = self.free_slots()
        if qs.size > free.size:
            raise ValueError(
                f"admit() got {qs.size} queries but only {free.size} "
                f"slots are free; consult free_slots() first")
        self._ensure_capacity(int(qs.max()) + 1)
        self.paths[qs, 0] = starts
        take = free[:qs.size]
        self.slot_query[take] = qs
        if self.mesh is not None:
            np.add.at(self.dev_queries, take // self.spd, 1)
        idx = jnp.asarray(take, jnp.int32)
        qkeys = WalkerState.stream_key_data(
            self.key, jnp.asarray(qs, jnp.int32))
        state = self.state
        self.state = WalkerState(
            cur=state.cur.at[idx].set(jnp.asarray(starts)),
            prev=state.prev.at[idx].set(-1),
            step=state.step.at[idx].set(0),
            alive=state.alive.at[idx].set(True),
            rng=state.rng.at[idx].set(qkeys),
            # sampler carry survives refills untouched: samplers validate
            # it per lane (a prefetch tile is tagged with its node, so a
            # new occupant simply misses)
            carry=state.carry,
            # program state is reset per QUERY (like the RNG stream), so
            # results stay placement-invariant
            wstate=jax.tree_util.tree_map(
                lambda leaf, new: leaf.at[idx].set(new),
                state.wstate,
                self.engine.workload.init_wstate_batch(
                    jnp.asarray(qs, jnp.int32))),
        )
        if self.mesh is not None:
            # re-assert the walker layout: the scatter above may leave
            # the refilled leaves with a gathered sharding
            self.state = shd.shard_walker_state(self.state, self.W,
                                                self.mesh)
        return int(qs.size)

    def kill(self, query_ids) -> np.ndarray:
        """Retire the lanes serving ``query_ids`` NOW (the serving loop's
        deadline enforcement).  Clears their ``alive`` bits — like a
        ``should_stop`` verdict, the walker emits nothing further and
        stops counting toward telemetry — and frees their slots for the
        next admission.  Harvested path prefixes stay in :attr:`paths`.
        Returns the query ids actually found in flight."""
        qs = np.asarray(query_ids, np.int64).reshape(-1)
        if qs.size == 0:
            return qs
        idx_np = np.nonzero(np.isin(self.slot_query, qs))[0]
        if idx_np.size == 0:
            return self.slot_query[idx_np]  # empty
        killed = self.slot_query[idx_np].copy()
        idx = jnp.asarray(idx_np, jnp.int32)
        self.state = dataclasses.replace(
            self.state, alive=self.state.alive.at[idx].set(False))
        if self.mesh is not None:
            self.state = shd.shard_walker_state(self.state, self.W,
                                                self.mesh)
        self.slot_query[idx_np] = -1
        return killed

    # ------------------------------------------------------- table pinning
    def adopt_tables(self) -> None:
        """Re-pin this scheduler's serving view on the engine's current
        precomp tables — plus the graph/stats/pad views the jitted epoch
        now takes as arguments — and record the engine mutation epoch the
        view reflects.  Called automatically when a graph mutation bumps
        the engine's mutation clock, and every epoch under
        ``track_tables=True``; call it directly to make a just-drained
        repair visible mid-run."""
        eng = self.engine
        self.tables = eng.precomp
        self.graph_view = eng.graph
        self.stats_view = eng.stats
        self.pad_view = eng.pad
        self.max_tiles_view = eng.max_tiles
        self._mutation_seen = eng.mutation_clock

    def reset_sampler_carry(self) -> None:
        """Re-initialise the sampler-owned cross-step carry (e.g. the
        interleaved sampler's prefetch tile, which caches edge payloads
        gathered from the pre-mutation graph).  Bit-neutral while the
        graph is unchanged — a cold tile re-gathers the same values — and
        required after a weight or structural mutation so in-flight
        walkers read post-mutation payloads, exactly like a fresh
        engine's walkers would."""
        eng = self.engine
        self.state = dataclasses.replace(
            self.state,
            carry=eng.sampler.init_carry(eng.sampler_ctx, self.W))
        if self.mesh is not None:
            self.state = shd.shard_walker_state(self.state, self.W,
                                                self.mesh)

    # -------------------------------------------------------------- epochs
    def run_epoch(self) -> EpochReport:
        """Compact / drain on the engine-absolute cadences, execute one
        jitted epoch (``T`` scan steps) against the pinned table view,
        harvest emitted path entries, and report completions."""
        eng = self.engine
        cfg = eng.config
        # scheduled overlay compaction (config.compact_interval), keyed —
        # like the drain cadence below — to the ENGINE-absolute epoch
        # clock, so when the overlay folds back into a contiguous CSR is
        # a property of the engine's timeline, not of which run happens
        # to be looping.  compact() bumps the mutation clock, so the
        # re-pin below picks up the re-laid tables in the same epoch.
        if (eng.overlay_active and cfg.compact_interval
                and eng.epoch_clock % cfg.compact_interval == 0):
            eng.compact()
        # Pinned-table contract: a graph mutation (apply_updates /
        # update_graph / compact) bumped the engine's mutation clock —
        # the pinned view indexes a dead row layout (structural) or
        # pre-mutation payloads cached in the sampler carry (weights),
        # so re-pin and reset the carry.  Absent mutations the view
        # stays fixed for the scheduler's whole life: background drains
        # repair engine-side only, which is what makes paths invariant
        # to the epoch cadence even while a rebuild is in flight.
        if eng.mutation_clock != self._mutation_seen:
            self.adopt_tables()
            self.reset_sampler_carry()
        # amortized background rebuild: re-bake a budgeted few stale
        # table rows while the walkers run (host work between jitted
        # epochs; the tables are an epoch *argument*, so no retrace).
        # cfg.rebuild_interval batches the drains: every K-th engine
        # epoch re-bakes a K×budget batch — same amortized rate, one
        # jitted scatter per drain instead of K.  scatter="copy": the
        # pinned view may alias the drained buffers, and donating them
        # would invalidate the view mid-run (explicit drain_rebuilds()
        # calls keep the donating fast path).
        if (eng.precomp is not None and cfg.rebuild_budget
                and len(eng.rebuild_queue)
                and eng.epoch_clock % cfg.rebuild_interval == 0):
            self.rebuilt_rows += eng.drain_rebuilds(
                cfg.rebuild_budget * cfg.rebuild_interval, scatter="copy")
        # serving-loop mode: adopt the engine's tables every epoch, AFTER
        # the drain, so repairs become visible at epoch granularity (the
        # piecewise-deterministic serving contract — see WalkService)
        if self.track_tables:
            self.adopt_tables()
        self.epoch_idx += 1
        eng.epoch_clock += 1
        # Serve against the PINNED graph/stats/table views (re-pinned
        # above on any mutation-clock bump) — run_epoch_fn resolves
        # fused-vs-staged per epoch, so a mutation mid-serve flips the
        # path the moment the engine's streams change.  Sharded runs keep
        # the staged scan: the mega-step kernel is one Pallas program
        # over the whole lane pool, and mixing it with a GSPMD-
        # partitioned epoch would change nothing but plumbing — both
        # paths are bit-identical, so this is purely an exec choice.
        step0 = np.asarray(self.state.step)
        self.state, emitted, stats = eng.run_epoch_fn(
            self.state, self.tables, self.graph_view, self.stats_view,
            epoch_len=self.T, num_steps=self.num_steps,
            pad=self.pad_view, max_tiles=self.max_tiles_view,
            fused=(self.mesh is None))
        emitted = np.asarray(emitted)  # [T, W]
        step1 = np.asarray(self.state.step)
        alive1 = np.asarray(self.state.alive)
        occupied = np.nonzero(self.slot_query >= 0)[0]
        taken = step1[occupied] - step0[occupied]
        s0 = step0[occupied]
        if s0.size and (s0 == s0[0]).all():
            # homogeneous epoch (incl. the full-batch single-epoch
            # case): one vectorized write; the -1s emitted after a
            # lane stops are exactly the termination padding.
            base = int(s0[0])
            width = min(self.T, self.num_steps - base)
            self.paths[self.slot_query[occupied],
                       base + 1:base + 1 + width] = \
                emitted[:width, occupied].T
        else:
            for t in range(int(taken.max(initial=0))):
                sel = occupied[taken > t]
                self.paths[self.slot_query[sel],
                           step0[sel] + 1 + t] = emitted[t, sel]
        ep = stats.host_totals()
        for k in self.totals:
            self.totals[k] += ep[k]
        if self.mesh is not None:
            self.dev_steps += (emitted >= 0).sum(axis=0) \
                .reshape(self.n_dev, self.spd).sum(axis=1)
        done = occupied[(~alive1[occupied])
                        | (step1[occupied] >= self.num_steps)]
        completed = self.slot_query[done].copy()
        steps_taken = step1[done].copy()
        self.slot_query[done] = -1
        return EpochReport(completed=completed, steps_taken=steps_taken,
                           occupied=int(occupied.size), stats=ep)


class WalkEngine:
    """End-to-end dynamic walk executor for one (graph, walk program).

    ``workload`` is a :class:`~repro.core.types.WalkProgram` — or the
    deprecated :class:`~repro.core.types.Workload` / any duck-typed legacy
    object, which is adapted via :func:`~repro.core.types.from_workload`
    with bit-identical results.
    """

    def __init__(self, graph: CSRGraph, workload: WalkProgram,
                 config: Optional[EngineConfig] = None):
        self.graph = graph
        if not isinstance(workload, WalkProgram):
            workload = from_workload(workload)  # duck-typed legacy object
        self.workload = workload
        self.config = config or EngineConfig()
        try:
            self.sampler = get_sampler(self.config.method)
        except KeyError:
            raise ValueError(
                f"method must name a registered sampler; "
                f"have {available_samplers()}") from None
        self.stats = node_stats(graph, num_labels=max(workload.num_labels, 1))
        self.compiled = fc.analyze(workload)
        self.max_degree = int(graph.max_degree())
        self.pad = max(1 << (self.max_degree - 1).bit_length(), self.config.tile)
        self.max_tiles = math.ceil(self.pad / self.config.tile)
        # Mega-step plan: can (sampler × program) run as ONE fused Pallas
        # kernel per epoch?  Needs the Flexi-Compiler's fusability proof
        # (fuse_report), a sampler-declared fused regime, and kernel tile
        # geometry; "rejection" additionally needs the compiled bound to
        # be node-local so it can be baked into a per-node table.
        self.fuse = fc.fuse_report(workload)
        will_precomp = (self.sampler.caps.needs_precomp
                        and fc.is_static(workload))
        self._fused_kind = self._plan_fused_kind(will_precomp)
        # Precomputed-regime tables (C-SAW-style): built once iff the
        # sampler asked for them (caps.needs_precomp) AND the Flexi-
        # Compiler proves get_weight state-independent.  Dynamic workloads
        # leave this None and precomp-capable samplers degrade to eRVS.
        self.precomp = None
        if will_precomp:
            # the tile-aligned kernel streams are only materialised when
            # a resolved execution path will actually DMA them — the
            # per-draw Pallas kernels or the fused mega-step table regime
            aligned = (resolve_precomp_exec(
                self.config.precomp_exec) == "pallas"
                or (self._fused_kind or "").startswith("precomp"))
            self.precomp = precomp_mod.build_tables(
                graph, workload, compiled_params(workload), aligned=aligned)
        # stale rows queued by update_graph, drained a budgeted few per
        # scheduler epoch (config.rebuild_budget) / via drain_rebuilds()
        self.rebuild_queue = precomp_mod.RebuildQueue()
        # structural delta overlay (apply_updates): None while the graph
        # is a contiguous CSR; a GraphDelta while edits are pending, with
        # self.graph the matching OverlayGraph until compact() folds it
        self.delta: Optional[GraphDelta] = None
        # engine-absolute epoch counter: every scheduler epoch ever run
        # against this engine advances it, so rebuild/compaction cadences
        # are properties of the engine's timeline, not of any one run's
        # loop-local index
        self.epoch_clock = 0
        # bumped by every graph mutation (update_graph / apply_updates /
        # compact); schedulers compare it against the value their pinned
        # table view was taken at and re-pin on mismatch
        self.mutation_clock = 0
        self.sampler_ctx = SamplerContext(
            graph=graph, workload=workload, params=compiled_params(workload),
            compiled=self.compiled, stats=self.stats, config=self.config,
            pad=self.pad, max_tiles=self.max_tiles, precomp=self.precomp)
        # trace-time side-effect counters: incremented by a Python
        # statement inside the traced epoch bodies, so they count actual
        # XLA compilations, not calls — the retrace-bound regression
        # (tests/test_structural.py) pins mutation bursts to O(log K)
        self.staged_traces = 0
        self.fused_traces = 0
        # Both epochs are jitted ONCE per engine: everything a mutation
        # changes (graph, stats, tables, edge streams) enters as a
        # runtime argument, so a mutation retraces only when an argument
        # SHAPE (or the graph's pytree type) changes — and the overlay's
        # pow2 patch capacity + the sticky pow2 pad bucket those shapes.
        self._epoch_fn = jax.jit(
            self._make_epoch(),
            static_argnames=("epoch_len", "num_steps", "pad", "max_tiles"))
        self._fused_epoch_fn = (self._build_fused_epoch()
                                if self._fused_kind else None)
        self._fused_streams = None
        self._refresh_fused_streams()

    # ------------------------------------------------------ fused planning
    @property
    def step_exec_resolved(self) -> str:
        """The step execution path this engine actually runs for
        single-device epochs: "fused" or "staged" (sharded epochs always
        run staged — see run()).  Reservoir/rejection regimes keep the
        fused kernel while a structural overlay is active; precomp
        regimes stand down to the staged scan until compact() re-attaches
        the aligned table streams."""
        return ("fused" if self._fused_epoch_fn is not None
                and self._fused_streams is not None else "staged")

    def _plan_fused_kind(self, will_precomp: bool):
        """Resolve ``config.step_exec`` against the fusability analysis:
        the mega-step regime to run, or None → staged scan."""
        cfg = self.config
        if cfg.step_exec == "staged":
            return None
        if cfg.step_exec == "auto" and jax.default_backend() != "tpu":
            # interpret-mode fused epochs are a test vehicle, not a win;
            # opt in explicitly with step_exec="fused"
            return None
        if not self.fuse.fusable:
            return None
        kind = self.sampler.fused_kind(usable=self.compiled.usable,
                                       has_precomp=will_precomp)
        if kind is None:
            return None
        if kind == "rejection" and not self.fuse.bound_node_local:
            # the kernel reads a per-NODE bound table; a bound that also
            # depends on prev/step/wstate cannot be baked.  Never downgrade
            # to the reservoir regime (different telemetry) — stay staged.
            return None
        tile = cfg.tile
        if tile < 2 or tile % 2 or KERNEL_TILE % tile:
            return None  # kernel DMA geometry (see megastep_kernel)
        return kind

    def _bake_bmax(self) -> jnp.ndarray:
        """Per-node rejection bound table for the fused kernel.  Sound
        because the plan requires ``fuse.bound_node_local``: the compiled
        bound provably ignores prev/step/wstate, so evaluating it at a
        placeholder walker context gives every walker's bound at v."""
        V = int(self.graph.num_nodes)
        nodes = jnp.arange(V, dtype=jnp.int32)
        ws = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l[None], (V,) + l.shape),
            self.workload.wstate_template())
        bi = fc.BoundInputs(
            h_min=self.stats.h_min, h_max=self.stats.h_max,
            h_mean=self.stats.h_mean,
            deg_cur=jnp.asarray(self.graph.degrees(), jnp.int32),
            deg_prev=jnp.zeros((V,), jnp.int32),
            cur=nodes, prev=jnp.full((V,), -1, jnp.int32),
            step=jnp.zeros((V,), jnp.int32), wstate=ws)
        _, bmax = jax.vmap(self.compiled.bound_fn)(bi)
        return bmax

    def _build_fused_epoch(self):
        # deferred so staged-only engines never load the Pallas modules
        from repro.kernels import megastep_kernel
        cfg = self.config
        inner = megastep_kernel.make_streamed_epoch(
            self.workload, compiled_params(self.workload),
            kind=self._fused_kind, tile=cfg.tile,
            rjs_trials=cfg.rjs_trials, rjs_max_rounds=cfg.rjs_max_rounds)
        engine = self

        def epoch(state, precomp, streams, epoch_len: int, num_steps: int,
                  max_tiles: int):
            engine.fused_traces += 1  # trace-time only (see __init__)
            return inner(state, precomp, streams, epoch_len, num_steps,
                         max_tiles)

        return jax.jit(
            epoch, static_argnames=("epoch_len", "num_steps", "max_tiles"))

    def _refresh_fused_streams(self) -> None:
        """(Re)build the host-side aligned edge streams the fused
        mega-step consumes, or set them to None when the fused path must
        stand down for the current graph.

        The streams are jit *arguments* (make_streamed_epoch), so a
        mutation re-aligns the touched layout host-side and the kernel
        retraces only when the pow2-bucketed stream shapes change.
        Reservoir/rejection regimes rebuild them for overlay graphs too
        (the kernel body reads per-node deg/row0 streams and never
        assumes contiguity); precomp regimes need the aligned *table*
        streams, which exist only in the compacted layout (grow_tables
        drops them), so they wait for compact()."""
        if self._fused_kind is None:
            self._fused_streams = None
            return
        if self.overlay_active and self._fused_kind.startswith("precomp"):
            self._fused_streams = None
            return
        from repro.kernels import megastep_kernel
        bmax = self._bake_bmax() if self._fused_kind == "rejection" else None
        self._fused_streams = megastep_kernel.fused_streams(
            self.graph, self.workload, bmax=bmax,
            bucket_rows=self.overlay_active)

    # ------------------------------------------------------------ epoch fn
    def _make_epoch(self):
        """Build the jitted epoch: ``epoch_len`` scan steps over WalkerState.

        ``epoch(state, precomp, graph, stats, ...)`` — everything a graph
        mutation changes enters as a runtime *argument* (PrecompTables,
        CSRGraph/OverlayGraph and NodeStats are registered pytrees), not
        a closed-over constant: between-epoch rebuild drains swap in
        re-baked rows with no retrace, and a structural/weight mutation
        swaps in the new graph view the same way.  ``pad``/``max_tiles``
        ride along as *static* args.  The epoch is jitted once per
        engine, so a K-burst mutation storm retraces only once per
        distinct (graph pytree type, array-shape bucket, pad) combination
        — O(log K) with the overlay's pow2 patch capacity and the sticky
        pow2 pad.  Returns ``(state', emitted [T, W], StepStats of
        [T]-arrays)`` where ``emitted[t, s]`` is the node slot ``s`` moved
        to at scan step t (-1 when it did not step).  Lanes past
        ``num_steps`` are masked, so an epoch may safely overshoot a
        walker's remaining budget.
        """
        sampler = self.sampler
        base_ctx = self.sampler_ctx
        program = self.workload
        params = self.sampler_ctx.params
        engine = self

        def transition_ctx(graph, state: WalkerState, nxt, deg_cur
                           ) -> EdgeCtx:
            """Per-walker EdgeCtx of the transition just taken (the
            WalkProgram hook contract documented on WalkProgram): nbr =
            node moved to, cur/prev/step = pre-move view; per-edge payload
            fields are placeholders (h=1, label=-1, dist=-1)."""
            W = state.cur.shape[0]
            return EdgeCtx(
                h=jnp.ones((W,), jnp.float32),
                label=jnp.full((W,), -1, jnp.int32),
                dist=jnp.full((W,), -1, jnp.int32),
                nbr=nxt,
                deg_cur=deg_cur,
                deg_prev=degrees_of(graph, state.prev),
                cur=state.cur, prev=state.prev, step=state.step,
            )

        def step(state: WalkerState, ctx, num_steps: int
                 ) -> Tuple[WalkerState, jax.Array, StepStats]:
            deg = degrees_of(ctx.graph, state.cur)
            wants = state.alive & (state.step < num_steps)
            live = wants & (deg > 0)
            rng = state.stream_keys()
            sel = sampler.select(ctx, state, rng, active=live)
            nxt = jnp.where(live, sel.next_nodes, -1)
            stepped = live & (nxt >= 0)
            # ---- WalkProgram hooks: state transition + early termination.
            # Both see the transition ctx; on_step only commits on lanes
            # that moved, and a True should_stop folds into the alive mask
            # so the walker emits nothing further, stops counting toward
            # telemetry, and frees its slot at the next epoch boundary.
            new_wstate = state.wstate
            stop = jnp.zeros_like(stepped)
            if program.has_hooks:
                tctx = transition_ctx(ctx.graph, state, nxt, deg)
                if program.on_step is not None:
                    cand = jax.vmap(program.on_step, in_axes=(0, None, 0))(
                        tctx, params, state.wstate)
                    new_wstate = jax.tree_util.tree_map(
                        lambda n, o: jnp.where(
                            stepped.reshape((-1,) + (1,) * (n.ndim - 1)),
                            n, o),
                        cand, state.wstate)
                if program.should_stop is not None:
                    verdict = jax.vmap(program.should_stop,
                                       in_axes=(0, None, 0))(
                        tctx, params, new_wstate)
                    stop = stepped & verdict
            new_state = WalkerState(
                cur=jnp.where(stepped, nxt, state.cur),
                prev=jnp.where(stepped, state.cur, state.prev),
                step=state.step + stepped.astype(jnp.int32),
                # a lane that wanted to step but could not has dead-ended;
                # a lane whose program said stop is equally finished
                alive=state.alive & ~(wants & ~stepped) & ~stop,
                rng=state.rng,
                # sampler-owned cross-step state (e.g. interleaved's
                # prefetch tile) threads through the scan untouched
                carry=sel.carry if sel.carry is not None else state.carry,
                wstate=new_wstate,
            )
            stats = StepStats(live=jnp.sum(live.astype(jnp.int32)),
                              rjs_served=sel.rjs_served,
                              fallbacks=sel.fallbacks,
                              precomp_served=sel.precomp_served,
                              stale_served=sel.stale_served)
            return new_state, jnp.where(stepped, nxt, -1), stats

        def epoch(state: WalkerState, precomp, graph, stats,
                  epoch_len: int, num_steps: int, pad: int,
                  max_tiles: int):
            engine.staged_traces += 1  # trace-time only (see __init__)
            ctx = dataclasses.replace(base_ctx, precomp=precomp,
                                      graph=graph, stats=stats, pad=pad,
                                      max_tiles=max_tiles)

            def body(carry, _):
                new_state, emitted, stats_t = step(carry, ctx, num_steps)
                return new_state, (emitted, stats_t)

            state, (emitted, step_stats) = jax.lax.scan(
                body, state, None, length=epoch_len)
            return state, emitted, step_stats

        return epoch

    def run_epoch_fn(self, state, tables, graph, stats, *, epoch_len: int,
                     num_steps: int, pad: int, max_tiles: int,
                     fused: bool = True):
        """Execute one jitted epoch against explicit graph/stats/table
        views — the single entry point both drivers (EpochScheduler and
        walk_batch) call, so the fused-vs-staged pick lives in one place.
        Runs the fused mega-step when the engine has one AND its edge
        streams exist for the current graph (see _refresh_fused_streams);
        ``fused=False`` forces the staged scan (sharded epochs).  Both
        paths are bit-identical."""
        if (fused and self._fused_epoch_fn is not None
                and self._fused_streams is not None):
            return self._fused_epoch_fn(
                state, tables, self._fused_streams, epoch_len=epoch_len,
                num_steps=num_steps, max_tiles=max_tiles)
        return self._epoch_fn(state, tables, graph, stats,
                              epoch_len=epoch_len, num_steps=num_steps,
                              pad=pad, max_tiles=max_tiles)

    # ------------------------------------------------------------ frontend
    def run(self, starts, num_steps: Optional[int] = None,
            key: Optional[jax.Array] = None, batch: Optional[int] = None,
            epoch_len: Optional[int] = None,
            devices: Optional[int] = None) -> WalkResult:
        """Run all queries through the streaming epoch scheduler (§5.3).

        ``batch`` fixes the walker-slot count (default: all queries at
        once); pending queries stream into slots as walkers finish.
        ``devices`` shards the slot pool over a 1D walker mesh of that
        many local devices (default 1; see docs/scaling.md).

        Scheduler contract (established in PR 1, relied on by tests)
        ------------------------------------------------------------
        * **Refill**: slots are refilled from the host-side queue only at
          epoch boundaries.  A refilled slot gets ``step=0``, ``prev=-1``,
          ``alive=True`` and the *query's own* stream key; whatever the
          previous occupant left in the slot is dead residue that the live
          mask hides (see ``WalkerState`` invariants).
        * **Batch invariance**: random streams are keyed per *query*
          (``fold_in(run_key, query_id)``), never per slot, epoch or
          device, so paths and telemetry are bit-identical for ANY
          ``batch`` / ``epoch_len`` / ``devices`` choice — including query
          counts that do not divide the slot count.  This holds even
          while a rebuild is in flight: every epoch serves from the
          table view pinned when the run's scheduler was created, and
          background drains repair the *engine's* tables — on the
          engine-absolute epoch clock — without touching the pinned
          view.  Which steps see a stale row therefore depends only on
          the queue state when the run started, never on the epoch
          cadence.  Repairs become visible to the next run (or
          immediately via an explicit ``drain_rebuilds()`` between
          runs); the serving loop opts into epoch-granular visibility
          instead with ``scheduler(track_tables=True)``.
        * **Telemetry**: ``frac_rjs`` / ``frac_precomp`` are weighted by
          *live* walker-steps only; empty slots, finished walkers and tail
          epochs can never dilute them.  Under sharding the counters are
          integer sums over the (sharded) live lanes — exact regardless of
          device count.
        * Queries are served in start-degree order (degree-similar
          co-scheduling) — per-query results are placement-independent, so
          this only affects which queries share an epoch, not any output.
        * **Sharded refill**: each device owns ``W // devices`` contiguous
          slots; free slots are handed to the queue round-robin *across
          devices* (all devices' slot 0 before anyone's slot 1), so a
          device never idles while the queue is non-empty and another
          device hoards free slots.  The pool is padded up to a multiple
          of ``devices``; pad slots are ordinary empty slots
          (``alive=False``) that refills may later occupy.
        """
        num_steps = self.workload.walk_len if num_steps is None else num_steps
        if num_steps <= 0:
            raise ValueError(f"num_steps must be positive, got {num_steps}")
        if batch is not None and batch <= 0:
            raise ValueError(f"batch must be positive, got {batch}")
        if epoch_len is not None and epoch_len <= 0:
            raise ValueError(f"epoch_len must be positive, got {epoch_len}")
        if devices is not None and devices <= 0:
            raise ValueError(f"devices must be positive, got {devices}")
        n_dev = int(devices or 1)
        key = key if key is not None else jax.random.key(self.config.seed)
        starts = np.asarray(starts, np.int32)
        Q = starts.shape[0]
        if Q == 0:
            return WalkResult(paths=np.full((0, num_steps + 1), -1,
                                            np.int32),
                              frac_rjs=0.0, rjs_fallbacks=0,
                              steps=num_steps)
        W = int(min(batch or Q, Q))
        mesh = None
        if n_dev > 1:
            mesh = shd.walker_mesh(n_dev)
            local = {d.id for d in jax.local_devices()}
            if not all(d.id in local for d in mesh.devices.flat):
                # Host-side refills write directly into the sharded state;
                # multi-host meshes need the pre-staged refill buffers
                # described in docs/scaling.md instead.
                raise NotImplementedError(
                    "run(devices=N) requires a fully-addressable "
                    "(single-process) mesh; see docs/scaling.md")
            W = -(-W // n_dev) * n_dev  # pad: every device owns W/n slots
        # With a slot per query there is nothing to refill: run one full
        # epoch (no host syncs inside the walk, like the pre-streaming
        # engine).  Otherwise default to short epochs so dead/finished
        # slots are reclaimed promptly.
        T = int(epoch_len or self.config.epoch_len
                or (num_steps if W >= Q
                    else min(num_steps, DEFAULT_EPOCH_LEN)))
        T = max(1, min(T, num_steps))

        sched = EpochScheduler(self, num_steps=num_steps, key=key,
                               slots=W, epoch_len=T, mesh=mesh,
                               n_dev=n_dev, capacity=Q)
        # degree-similar co-scheduling: serve queries in start-degree order
        # so co-resident slots share a tight eRVS tile-trip bound.
        deg_np = np.asarray(self.graph.degrees())
        queue = deque(np.argsort(deg_np[starts], kind="stable").tolist())

        while queue or sched.busy:
            free = sched.free_slots()
            if queue and free.size:
                take = min(free.size, len(queue))
                qs = np.asarray([queue.popleft() for _ in range(take)])
                sched.admit(qs, starts[qs])
            sched.run_epoch()

        per_device = None
        if mesh is not None:
            per_device = [
                {"device": d, "slots": sched.spd,
                 "queries": int(sched.dev_queries[d]),
                 "emitted_steps": int(sched.dev_steps[d])}
                for d in range(n_dev)]
        live_total = sched.totals["live"]
        return WalkResult(paths=sched.paths,
                          frac_rjs=sched.totals["rjs_served"]
                          / max(live_total, 1),
                          rjs_fallbacks=sched.totals["fallbacks"],
                          steps=num_steps,
                          live_steps=live_total,
                          frac_precomp=sched.totals["precomp_served"]
                          / max(live_total, 1),
                          frac_stale=sched.totals["stale_served"]
                          / max(live_total, 1),
                          rebuilt_rows=sched.rebuilt_rows,
                          per_device=per_device)

    def scheduler(self, num_steps: Optional[int] = None,
                  key: Optional[jax.Array] = None, slots: int = 64,
                  epoch_len: Optional[int] = None,
                  capacity: int = 0,
                  track_tables: bool = False,
                  devices: Optional[int] = None) -> EpochScheduler:
        """Epoch-boundary admission hook: a long-lived
        :class:`EpochScheduler` over this engine's jitted epoch.

        This is what ``run`` itself drives to completion, exposed so a
        serving loop (``repro.serving.WalkService``) can admit queries
        from concurrent clients at epoch boundaries, stream completions
        back per epoch, and kill lanes past their deadline — all without
        retrace, and with the same per-query-stream bit-identity
        guarantee as a batch ``run``.

        ``track_tables=True`` re-adopts the engine's precomp tables every
        epoch (after the background drain) instead of serving the whole
        scheduler life from the view pinned at construction — the serving
        loop's mode: repairs become visible at epoch granularity, at the
        cost of the cross-run drain-schedule invariance a pinned view
        gives a batch ``run``.

        ``devices`` shards the scheduler's slot pool over a 1D walker
        mesh exactly like ``run(devices=N)``: the pool is padded up to a
        multiple of the device count, free slots are handed out round-
        robin across devices, and — because streams are keyed per query,
        never per slot or device — admitted queries produce bit-identical
        paths and telemetry for any device count.
        """
        num_steps = self.workload.walk_len if num_steps is None else num_steps
        if num_steps <= 0:
            raise ValueError(f"num_steps must be positive, got {num_steps}")
        if slots <= 0:
            raise ValueError(f"slots must be positive, got {slots}")
        if devices is not None and devices <= 0:
            raise ValueError(f"devices must be positive, got {devices}")
        n_dev = int(devices or 1)
        key = key if key is not None else jax.random.key(self.config.seed)
        T = int(epoch_len or self.config.epoch_len
                or min(num_steps, DEFAULT_EPOCH_LEN))
        T = max(1, min(T, num_steps))
        slots = int(slots)
        mesh = None
        if n_dev > 1:
            mesh = shd.walker_mesh(n_dev)
            local = {d.id for d in jax.local_devices()}
            if not all(d.id in local for d in mesh.devices.flat):
                # Same constraint as run(devices=N): host-side refills
                # write directly into the sharded state.
                raise NotImplementedError(
                    "scheduler(devices=N) requires a fully-addressable "
                    "(single-process) mesh; see docs/scaling.md")
            slots = -(-slots // n_dev) * n_dev
        return EpochScheduler(self, num_steps=num_steps, key=key,
                              slots=slots, epoch_len=T, mesh=mesh,
                              n_dev=n_dev, capacity=capacity,
                              track_tables=track_tables)

    def walk_batch(self, starts, key: jax.Array, num_steps: int,
                   devices: Optional[int] = None
                   ) -> Tuple[jax.Array, StepStats]:
        """One fully-occupied jitted batch, no host scheduling: returns
        (paths [W, num_steps] on device, per-step StepStats).  This is the
        entry point for sharded/multi-device runs (walker i's stream is
        fold_in(key, i), so lanes are independent of device placement).

        Pass ``devices=N`` to place the batch on a 1D walker mesh here
        (``N`` must divide the batch; walker i keeps stream
        ``fold_in(key, i)``, so outputs are bit-identical to ``devices=1``)
        — or pre-shard ``starts`` yourself with an arbitrary
        ``NamedSharding`` and leave ``devices`` unset."""
        if devices is not None and devices <= 0:
            raise ValueError(f"devices must be positive, got {devices}")
        starts = jnp.asarray(starts, jnp.int32)
        state = WalkerState.create(
            starts, key,
            # walker i serves query i here, so its program state — like
            # its RNG stream — is keyed by i (run()/walk_batch parity)
            wstate=self.workload.init_wstate_batch(
                jnp.arange(starts.shape[0], dtype=jnp.int32)))
        state = dataclasses.replace(
            state, carry=self.sampler.init_carry(self.sampler_ctx,
                                                 starts.shape[0]))
        if devices is not None and devices > 1:
            W = int(starts.shape[0])
            if W % devices:
                raise ValueError(
                    f"devices={devices} must divide the batch ({W}); pad "
                    f"the batch or use run(), which pads its slot pool")
            state = shd.shard_walker_state(state, W, shd.walker_mesh(devices))
        _, emitted, stats = self.run_epoch_fn(
            state, self.precomp, self.graph, self.stats,
            epoch_len=num_steps, num_steps=num_steps, pad=self.pad,
            max_tiles=self.max_tiles,
            fused=(devices is None or devices <= 1))
        return emitted.T, stats

    # -------------------------------------------------------- graph updates
    @property
    def overlay_active(self) -> bool:
        """Whether structural edits are pending in the delta overlay (the
        engine is serving an :class:`~repro.graphs.delta.OverlayGraph`;
        :meth:`compact` folds it back into a contiguous CSR)."""
        return self.delta is not None

    def _refresh_epoch_fns(self) -> None:
        """Refresh the sampler context around the current
        graph/stats/tables/pad and bump the mutation clock so live
        schedulers re-pin their serving views (EpochScheduler.run_epoch).

        The jitted epochs themselves are NOT rebuilt: they were jitted
        once in ``__init__`` with graph/stats/tables/streams as runtime
        arguments, so a mutation costs a retrace only when an argument
        shape changes — and the overlay's pow2 patch capacity plus the
        sticky pow2 pad (``_set_pad(floor=...)``) bucket those shapes to
        O(log K) variants across a K-burst mutation storm."""
        self.sampler_ctx = dataclasses.replace(
            self.sampler_ctx, graph=self.graph, stats=self.stats,
            precomp=self.precomp, pad=self.pad, max_tiles=self.max_tiles)
        self.mutation_clock += 1

    def _set_pad(self, max_degree: int, *, floor: int = 0) -> None:
        # identical to the __init__ formula — the fuzzer's fresh-build
        # oracle relies on pad/max_tiles (and hence the eRVS tile-trip
        # bound and ITS search depth) matching a from-scratch engine.
        # ``floor`` keeps the pad monotone across overlay applies (sticky
        # pow2 bucketing, so a mutation burst reuses the jitted epoch
        # instead of flapping between pad shapes); oversizing is
        # bit-neutral — ITS search iterations past convergence are no-ops,
        # eRVS tile trips are clamped by live degrees, and padded-row
        # weight baselines mask the extra lanes.  compact() calls with
        # the default floor, restoring the exact fresh-build formula.
        self.max_degree = int(max_degree)
        self.pad = max(1 << (self.max_degree - 1).bit_length(),
                       self.config.tile, int(floor))
        self.max_tiles = math.ceil(self.pad / self.config.tile)

    def update_graph(self, graph: CSRGraph, invalidated=()) -> None:
        """Swap in a graph whose *edge weights* (``h``) were mutated.

        The topology (indptr/indices) must be unchanged — this is the
        weight-only fast path the precomp regime's invalidation bitmap
        exists for; it never creates a delta overlay.  For structural
        changes (edge inserts/deletes) use :meth:`apply_updates`.
        ``invalidated`` lists the nodes whose rows changed: their
        precomputed ITS/alias rows are marked stale (one bitmap write
        now, no synchronous table rebuild) and every sampler's dynamic
        path — which those lanes fall back to — reads the *new* weights
        immediately.  Rows NOT listed keep serving from their
        (still-correct) tables.

        The stale rows also enter the engine's rebuild queue: subsequent
        ``run`` calls re-bake ``config.rebuild_budget`` of them per
        scheduler epoch (or call :meth:`drain_rebuilds` to repair them
        synchronously), flipping their validity bits back — the dynamic
        fallback is transient, not permanent.

        Node stats (the compiler's preprocess() output) are recomputed so
        bound/sum estimators track the new weights.  The jitted epochs
        are NOT rebuilt — the new graph/stats enter as epoch arguments
        with unchanged shapes, so a weight mutation costs no retrace.
        """
        if self.delta is not None:
            raise ValueError(
                "update_graph cannot swap graphs while a structural "
                "overlay is active; fold the pending edits with "
                "WalkEngine.compact() first, or route the change through "
                "WalkEngine.apply_updates(inserts=...) — inserting an "
                "existing edge re-weights it in place")
        if (graph.indptr.shape != self.graph.indptr.shape
                or graph.indices.shape != self.graph.indices.shape):
            raise ValueError(
                "update_graph requires unchanged topology (same "
                "indptr/indices shapes) — it is the weight-only fast "
                "path.  For structural changes use WalkEngine."
                "apply_updates(inserts=..., deletes=...), which overlays "
                "the edits under live traffic and repairs only the "
                "touched precomp rows")
        self.graph = graph
        self.stats = node_stats(graph,
                                num_labels=max(self.workload.num_labels, 1))
        if self.precomp is not None and len(np.atleast_1d(invalidated)):
            self.precomp = self.precomp.invalidate(invalidated)
            self.rebuild_queue.push(invalidated)
        self._refresh_epoch_fns()
        # the fused kernel's edge streams carry the mutated weights (and
        # the rejection kind the node-stat-derived bound table), so the
        # weight mutation re-aligns them host-side; same shapes → the
        # jitted fused epoch is reused without retrace
        self._refresh_fused_streams()

    def apply_updates(self, inserts=None, deletes=None) -> UpdateReport:
        """Apply structural edits — edge inserts and deletes — under live
        traffic, without rebuilding the engine.

        ``inserts`` is ``(src, dst, h)`` or ``(src, dst, h, labels)``
        (array-likes; inserting an existing edge re-weights it in place),
        ``deletes`` is ``(src, dst)``; deletes are applied before inserts
        within one call.  Node ids must already exist — structural
        updates never add nodes.

        The edits land in a :class:`~repro.graphs.delta.GraphDelta`
        overlay: untouched rows keep their base CSR offsets (and hence
        their per-offset RNG draws and still-valid precomp rows)
        bit-for-bit, while each touched row is re-materialised into a
        *stable* patch span, sorted by destination exactly like a fresh
        ``from_edges`` build.  The whole apply is O(touched), not O(E):
        the device overlay syncs only the dirty spans, the per-edge
        precomp tables stay in the overlay layout — valid rows are
        already addressed through the overlay's ``row_starts``, so
        :func:`~repro.core.precomp.grow_tables` merely tracks the patch
        capacity (amortized pow2 growth) while the touched rows are
        invalidated and queued for the amortized background rebuild —
        and node stats are patched for the touched rows only
        (bit-identical to a full recompute).  The one-shot O(E)
        re-layout back to the contiguous order is deferred to
        :meth:`compact` (or ``config.compact_interval``).

        A no-op edit set (nothing touched) is bit-neutral: no overlay is
        created, the mutation clock does not bump, and live schedulers
        keep their pinned views and prefetch carries.

        Reservoir/rejection fused engines keep the mega-step kernel
        while the overlay is active (the edge streams are re-aligned to
        the overlay layout, bit-identically); precomp-regime fused
        engines stand down to the staged scan until :meth:`compact`
        re-attaches the aligned table streams (``step_exec_resolved``
        reports the decision either way).
        """
        if self.delta is None:
            delta = GraphDelta(self.graph)
        else:
            delta = self.delta
        rep = delta.apply(inserts, deletes)
        if not rep.touched:
            return rep
        self.delta = delta
        self.graph = delta.materialize()
        self.stats = delta.patch_stats(self.stats, rep.touched)
        _, new_degs = delta.layout()
        # sticky pow2 pad: monotone while the overlay is active, so a
        # burst of applies reuses the jitted epoch; compact() restores
        # the exact fresh-build formula
        self._set_pad(new_degs.max(initial=0), floor=self.pad)
        if self.precomp is not None:
            self.precomp = precomp_mod.grow_tables(
                self.precomp, self.graph.num_edges).invalidate(rep.touched)
            self.rebuild_queue.push(rep.touched)
        self._refresh_epoch_fns()
        self._refresh_fused_streams()
        return rep

    def compact(self) -> int:
        """Fold the delta overlay back into a contiguous CSR (bitwise
        equal to ``from_edges`` of the mutated edge list) with one O(E)
        gather, re-laying the precomp tables from the overlay layout
        onto the new row layout — valid rows keep their values, pending
        stale rows stay queued — and restoring the fused mega-step
        (and aligned table streams) if the engine had one.  This is the
        deferred O(E) half of the apply/compact split; node stats are
        *not* recomputed — the per-row patches applied by
        :meth:`apply_updates` are bitwise equal to a fresh
        ``node_stats(graph)`` (pinned by the mutation fuzzer), so the
        carried stats are already exact.
        Returns the number of overlay rows folded (0 = no overlay)."""
        if self.delta is None:
            return 0
        folded = len(self.delta)
        old_starts, old_degs = host_row_layout(self.graph)
        graph = self.delta.compact()
        self.delta = None
        self.graph = graph
        self._set_pad(graph.max_degree())
        if self.precomp is not None:
            new_starts, new_degs = host_row_layout(graph)
            self.precomp = precomp_mod.splice_tables(
                self.precomp, old_starts, old_degs, new_starts, new_degs,
                graph.num_edges)
            # the overlay dropped the tile-aligned kernel streams; re-
            # attach them iff a resolved execution path will DMA them
            if (resolve_precomp_exec(self.config.precomp_exec) == "pallas"
                    or (self._fused_kind or "").startswith("precomp")):
                self.precomp = self.precomp.with_aligned(graph.indptr)
        self._refresh_epoch_fns()
        self._refresh_fused_streams()
        return folded

    def drain_rebuilds(self, max_rows: Optional[int] = None, *,
                       scatter: str = "donate") -> int:
        """Re-bake up to ``max_rows`` queued stale table rows right now
        (all of them when None) and flip their validity bits back.
        Returns how many rows were rebuilt.  ``run`` calls this with
        ``config.rebuild_budget`` once per scheduler epoch — the
        amortized background path, with ``scatter="copy"`` so pinned
        table views stay readable; direct calls keep the donating
        in-place scatter."""
        if self.precomp is None or not len(self.rebuild_queue):
            return 0
        self.precomp, done = self.rebuild_queue.drain(
            self.precomp, self.graph, self.workload,
            self.sampler_ctx.params, budget=max_rows, scatter=scatter)
        self.sampler_ctx = dataclasses.replace(
            self.sampler_ctx, precomp=self.precomp)
        return len(done)


def compiled_params(workload: Workload):
    # params are pure-Python hyperparameters, baked in at trace time
    return workload.params()


# ----------------------------------------------------- exact distributions
def exact_probs(graph: CSRGraph, workload: Workload, params,
                v: int, prev: int, step: int, pad: int,
                wstate=None) -> np.ndarray:
    """Ground-truth transition distribution for tests/benchmarks.

    ``wstate`` is ONE walker's program state (unbatched pytree, e.g. the
    exact visited set of the walker whose next-step distribution is being
    checked); ``None`` for stateless programs.
    """
    from repro.core.baselines import padded_weights

    ws = None
    if wstate is not None:
        ws = jax.tree_util.tree_map(lambda l: jnp.asarray(l)[None], wstate)
    w, nbr, mask = padded_weights(
        graph, workload, params,
        jnp.asarray([v], jnp.int32), jnp.asarray([prev], jnp.int32),
        jnp.asarray([step], jnp.int32), pad, ws)
    w = np.asarray(w[0])
    total = w.sum()
    p = w / total if total > 0 else w
    return p, np.asarray(nbr[0])
