"""Flexi-Runtime — the walk engine (paper §4.1, §5.2, §5.3, Fig. 8).

Per step, for every live walker:

  1. evaluate the compiler-synthesized estimators (bound of max w̃, Σw̃ est),
  2. run the Eq. 11 cost model to pick eRJS vs eRVS *per node*,
  3. execute the two kernels on their partitions (the TPU analogue of the
     paper's warp-ballot regrouping — see DESIGN.md §3.2),
  4. eRJS walkers unresolved after R_max rounds fall back into the eRVS
     partition (the §7.1 soundness fallback doubling as straggler control).

Scheduling (§5.3): the GPU global-atomic work queue becomes an *epoch
scheduler* — fixed-size walker batches run a jitted step; finished walkers
are refilled from the host-side queue between epochs.  Degree-similar
queries are co-scheduled (host-side sort) so the dynamic tile-trip bound in
eRVS actually bites.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flexi_compiler as fc
from repro.core.baselines import als_step, its_step, rjs_maxreduce_step, rvs_prefix_step
from repro.core.cost_model import CostModel
from repro.core.ctxutil import degrees_of
from repro.core.erjs import erjs_step
from repro.core.ervs import ervs_jump_step, ervs_step
from repro.core.types import Workload
from repro.graphs.csr import CSRGraph
from repro.graphs import node_stats

METHODS = ("adaptive", "ervs", "ervs_jump", "erjs", "its", "als",
           "rvs_prefix", "rjs_maxreduce", "random", "degree")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    method: str = "adaptive"
    tile: int = 256
    rjs_trials: int = 8
    rjs_max_rounds: int = 16
    cost_model: CostModel = dataclasses.field(default_factory=CostModel)
    seed: int = 0
    # "degree" selection strategy threshold (Fig. 13 baseline)
    degree_threshold: int = 1024
    collect_stats: bool = True


@dataclasses.dataclass
class WalkResult:
    paths: np.ndarray  # [Q, L+1] int32; -1 marks termination
    frac_rjs: float  # fraction of live steps served by eRJS (Fig. 14)
    rjs_fallbacks: int
    steps: int


class WalkEngine:
    """End-to-end dynamic random walk executor for one (graph, workload)."""

    def __init__(self, graph: CSRGraph, workload: Workload,
                 config: Optional[EngineConfig] = None):
        self.graph = graph
        self.workload = workload
        self.config = config or EngineConfig()
        if self.config.method not in METHODS:
            raise ValueError(f"method must be one of {METHODS}")
        self.stats = node_stats(graph, num_labels=max(workload.num_labels, 1))
        self.compiled = fc.analyze(workload)
        self.max_degree = int(graph.max_degree())
        self.pad = max(1 << (self.max_degree - 1).bit_length(), self.config.tile)
        self.max_tiles = math.ceil(self.pad / self.config.tile)
        self._step_fn = self._build_step()

    # ------------------------------------------------------------- step fn
    def _build_step(self):
        cfg = self.config
        graph, workload, stats = self.graph, self.workload, self.stats
        compiled = self.compiled
        usable = compiled.usable and cfg.method in ("adaptive", "erjs", "random", "degree")

        def bound_inputs(cur, prev, step):
            vs = jnp.maximum(cur, 0)
            return fc.BoundInputs(
                h_min=stats.h_min[vs], h_max=stats.h_max[vs],
                h_mean=stats.h_mean[vs],
                deg_cur=degrees_of(graph, cur), deg_prev=degrees_of(graph, prev),
                cur=cur, prev=prev, step=step,
            )

        def step_fn(cur, prev, step, alive, rng, step_idx):
            """One walk step for the whole batch; returns (next, telemetry)."""
            W = cur.shape[0]
            # per-step rng: fold the step counter (counter-based streams)
            rng_s = jax.vmap(lambda k: jax.random.fold_in(k, step_idx))(rng)
            deg = degrees_of(graph, cur)
            live = alive & (deg > 0)

            frac_rjs = jnp.float32(0.0)
            fallbacks = jnp.int32(0)

            if cfg.method in ("ervs", "ervs_jump"):
                if cfg.method == "ervs_jump":
                    nxt, _ = ervs_jump_step(graph, workload, compiled_params(workload),
                                            cur, prev, step, rng_s, tile=cfg.tile,
                                            max_tiles=self.max_tiles, active=live)
                else:
                    nxt = ervs_step(graph, workload, compiled_params(workload),
                                    cur, prev, step, rng_s, tile=cfg.tile,
                                    max_tiles=self.max_tiles, active=live)
            elif cfg.method == "its":
                nxt = its_step(graph, workload, compiled_params(workload),
                               cur, prev, step, rng_s, pad=self.pad)
                nxt = jnp.where(live, nxt, -2)
            elif cfg.method == "als":
                nxt = als_step(graph, workload, compiled_params(workload),
                               cur, prev, step, rng_s, pad=self.pad)
                nxt = jnp.where(live, nxt, -2)
            elif cfg.method == "rvs_prefix":
                nxt = rvs_prefix_step(graph, workload, compiled_params(workload),
                                      cur, prev, step, rng_s, pad=self.pad)
                nxt = jnp.where(live, nxt, -2)
            elif cfg.method == "rjs_maxreduce":
                nxt = rjs_maxreduce_step(graph, workload, compiled_params(workload),
                                         cur, prev, step, rng_s, pad=self.pad,
                                         trials_per_round=cfg.rjs_trials,
                                         max_rounds=4 * cfg.rjs_max_rounds)
                nxt = jnp.where(live, nxt, -2)
            else:
                # ---------------- adaptive / erjs / random / degree ----------
                if usable:
                    bi = bound_inputs(cur, prev, step)
                    _, bmax = jax.vmap(compiled.bound_fn)(bi)
                    ssum = jax.vmap(compiled.sum_fn)(bi)
                else:
                    bmax = jnp.zeros((W,), jnp.float32)
                    ssum = jnp.zeros((W,), jnp.float32)
                if cfg.method == "adaptive":
                    want_rjs = cfg.cost_model.prefer_rjs(bmax, ssum, deg) if usable \
                        else jnp.zeros((W,), bool)
                elif cfg.method == "erjs":
                    want_rjs = jnp.ones((W,), bool) if usable else jnp.zeros((W,), bool)
                elif cfg.method == "random":
                    coin = jax.vmap(lambda k: jax.random.bernoulli(
                        jax.random.fold_in(k, 777)))(rng_s)
                    want_rjs = coin & (bmax > 0)
                else:  # degree-based (Fig. 13): RJS for high degree
                    want_rjs = (deg >= cfg.degree_threshold) & (bmax > 0)
                want_rjs = want_rjs & live
                nxt_rjs, fb, _ = erjs_step(
                    graph, workload, compiled_params(workload), cur, prev, step,
                    rng_s, bound=bmax, trials_per_round=cfg.rjs_trials,
                    max_rounds=cfg.rjs_max_rounds, active=want_rjs)
                rvs_active = live & ((~want_rjs) | fb)
                nxt_rvs = ervs_step(graph, workload, compiled_params(workload),
                                    cur, prev, step, rng_s, tile=cfg.tile,
                                    max_tiles=self.max_tiles, active=rvs_active)
                nxt = jnp.where(rvs_active, nxt_rvs,
                                jnp.where(want_rjs, nxt_rjs, -1))
                n_live = jnp.maximum(jnp.sum(live.astype(jnp.int32)), 1)
                frac_rjs = jnp.sum((want_rjs & ~fb).astype(jnp.int32)) / n_live
                fallbacks = jnp.sum(fb.astype(jnp.int32))

            nxt = jnp.where(live, nxt, -1)
            return nxt, frac_rjs, fallbacks

        def scan_steps(starts, key, num_steps):
            W = starts.shape[0]
            rng = jax.random.split(key, W)
            init = (starts.astype(jnp.int32), jnp.full((W,), -1, jnp.int32),
                    jnp.zeros((W,), jnp.int32), jnp.ones((W,), bool))

            def body(carry, step_idx):
                cur, prev, step, alive = carry
                nxt, frj, fb = step_fn(cur, prev, step, alive, rng, step_idx)
                new_alive = alive & (nxt >= 0)
                new_cur = jnp.where(new_alive, nxt, cur)
                new_prev = jnp.where(new_alive, cur, prev)
                return ((new_cur, new_prev, step + 1, new_alive),
                        (jnp.where(new_alive, nxt, -1), frj, fb))

            (_, _, _, _), (path, frjs, fbs) = jax.lax.scan(
                body, init, jnp.arange(num_steps, dtype=jnp.int32))
            return path.T, frjs, fbs  # [W, L]

        return jax.jit(scan_steps, static_argnames=("num_steps",))

    # ------------------------------------------------------------ frontend
    def run(self, starts, num_steps: Optional[int] = None,
            key: Optional[jax.Array] = None, batch: Optional[int] = None
            ) -> WalkResult:
        """Run walks for all queries with epoch scheduling (§5.3)."""
        num_steps = num_steps or self.workload.walk_len
        key = key if key is not None else jax.random.key(self.config.seed)
        starts = np.asarray(starts, np.int32)
        Q = starts.shape[0]
        batch = batch or Q
        # degree-similar co-scheduling: sort queries by start degree so each
        # batch has a tight max-degree (dynamic eRVS trip bound bites).
        deg_np = np.asarray(self.graph.degrees())
        order = np.argsort(deg_np[starts], kind="stable")
        paths = np.full((Q, num_steps + 1), -1, np.int32)
        paths[:, 0] = starts
        frac, fb_total, chunks = 0.0, 0, 0
        for lo in range(0, Q, batch):
            sel = order[lo:lo + batch]
            sub = starts[sel]
            if sub.shape[0] < batch:  # pad the tail epoch
                padded = np.concatenate([sub, np.zeros(batch - sub.shape[0], np.int32)])
            else:
                padded = sub
            k = jax.random.fold_in(key, lo)
            path, frjs, fbs = self._step_fn(jnp.asarray(padded), k, num_steps)
            path = np.asarray(path)[: sub.shape[0]]
            paths[sel, 1:] = path
            frac += float(np.mean(np.asarray(frjs)))
            fb_total += int(np.sum(np.asarray(fbs)))
            chunks += 1
        return WalkResult(paths=paths, frac_rjs=frac / max(chunks, 1),
                          rjs_fallbacks=fb_total, steps=num_steps)


def compiled_params(workload: Workload):
    # params are pure-Python hyperparameters, baked in at trace time
    return workload.params()


# ----------------------------------------------------- exact distributions
def exact_probs(graph: CSRGraph, workload: Workload, params,
                v: int, prev: int, step: int, pad: int) -> np.ndarray:
    """Ground-truth transition distribution for tests/benchmarks."""
    from repro.core.baselines import padded_weights

    w, nbr, mask = padded_weights(
        graph, workload, params,
        jnp.asarray([v], jnp.int32), jnp.asarray([prev], jnp.int32),
        jnp.asarray([step], jnp.int32), pad)
    w = np.asarray(w[0])
    total = w.sum()
    p = w / total if total > 0 else w
    return p, np.asarray(nbr[0])
