"""Baseline sampling methods (paper §2.2, Fig. 2) — the comparison systems.

These are faithful JAX ports of the *algorithms* used by the published
baselines, with their characteristic costs preserved:

* ALS  (alias sampling, Skywalker):   O(d) sequential table build per step,
  then O(1) draws.  The build is the sequential two-stack Vose algorithm —
  its serial dependence is the cost the paper's Fig. 3 exposes.
* ITS  (inverse transform, C-SAW):    prefix sum + binary search.
* RVS  (prefix-sum reservoir, FlowWalker): prefix sum + per-neighbour
  uniform + parallel last-accept reduction.
* RJS  (max-reduce rejection, NextDoor): full-row max reduction, then
  rejection trials — the max reduction is what eRJS eliminates.

All operate on one [W, D] padded block (D = padded max degree of the batch);
that padding is itself representative of how the GPU baselines bucket work.
Each is registered with the sampler registry via ``samplers.
PaddedRowSampler`` (see :data:`BASELINE_STEP_FNS`); none supports runtime
partitioning — the full-row pass is exactly the cost they exist to expose.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ctxutil import degrees_of, tile_ctx, eval_weights
from repro.core.erjs import erjs_step
from repro.core.types import Workload
from repro.graphs.csr import CSRGraph


def padded_weights(
    graph: CSRGraph, workload: Workload, params,
    cur, prev, step, pad: int, wstate=None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-row transition weights, padded to [W, pad].  Returns (w, nbr, mask).

    ``wstate`` is the per-walker program state ([W]-leading leaves;
    ``None`` for stateless programs)."""
    ctx, mask = tile_ctx(graph, workload, cur, prev, step,
                         jnp.zeros_like(cur), pad)
    w = eval_weights(workload, params, ctx, mask, wstate)
    return w, ctx.nbr, mask


# ---------------------------------------------------------------- ITS (C-SAW)
@partial(jax.jit, static_argnames=("workload", "params", "pad"))
def its_step(graph, workload: Workload, params, cur, prev, step, rng, pad: int,
             wstate=None):
    w, nbr, mask = padded_weights(graph, workload, params, cur, prev, step,
                                  pad, wstate)
    csum = jnp.cumsum(w, axis=1)
    total = csum[:, -1]
    u = jax.vmap(lambda k: jax.random.uniform(
        k, (), dtype=jnp.float32))(rng)
    r = u * total
    # first index with csum > r  (strictly: right bisect)
    sel = jnp.sum((csum <= r[:, None]).astype(jnp.int32), axis=1)
    sel = jnp.minimum(sel, pad - 1)
    out = jnp.take_along_axis(nbr, sel[:, None], axis=1)[:, 0]
    return jnp.where(total > 0, out, -1)


# ----------------------------------------------------- prefix-RVS (FlowWalker)
@partial(jax.jit, static_argnames=("workload", "params", "pad"))
def rvs_prefix_step(graph, workload: Workload, params, cur, prev, step, rng,
                    pad: int, wstate=None):
    """FlowWalker's parallel reservoir: accept_i iff u_i < w_i / W_i, where
    W_i is the inclusive prefix sum; the *last* accepting index wins (this is
    the parallelisation of sequential reservoir sampling the paper describes
    in §2.2 — prefix sum + per-neighbour RNG + max-index reduction)."""
    w, nbr, mask = padded_weights(graph, workload, params, cur, prev, step,
                                  pad, wstate)
    W_i = jnp.cumsum(w, axis=1)
    u = jax.vmap(lambda k: jax.random.uniform(
        k, (pad,), dtype=jnp.float32, minval=1e-12))(rng)
    ok = (u * W_i < w) & mask & (w > 0)
    idx = jnp.arange(pad, dtype=jnp.int32)[None, :]
    last = jnp.max(jnp.where(ok, idx, -1), axis=1)
    out = jnp.take_along_axis(nbr, jnp.maximum(last, 0)[:, None], axis=1)[:, 0]
    return jnp.where(last >= 0, out, -1)


# ------------------------------------------------------ max-reduce RJS (NextDoor)
@partial(jax.jit, static_argnames=("workload", "params", "pad", "trials_per_round", "max_rounds"))
def rjs_maxreduce_step(graph, workload: Workload, params, cur, prev, step, rng,
                       pad: int, trials_per_round: int = 8, max_rounds: int = 64,
                       wstate=None):
    """NextDoor-style: pay a full-row pass for the exact max, then trials.
    The full pass is the cost eRJS's bound estimation removes."""
    w, _, _ = padded_weights(graph, workload, params, cur, prev, step, pad,
                             wstate)
    exact_max = jnp.max(w, axis=1)
    nxt, fb, _ = erjs_step(graph, workload, params, cur, prev, step, rng,
                           bound=exact_max, trials_per_round=trials_per_round,
                           max_rounds=max_rounds, wstate=wstate)
    # exact max ⇒ acceptance ≥ 1/d; fall back to ITS on the (rare) unresolved
    its = its_step(graph, workload, params, cur, prev, step, rng, pad,
                   wstate=wstate)
    return jnp.where(fb, its, nxt)


# ---------------------------------------------------------------- ALS (Skywalker)
@partial(jax.jit, static_argnames=("workload", "params", "pad"))
def als_step(graph, workload: Workload, params, cur, prev, step, rng, pad: int,
             wstate=None):
    """Alias sampling with per-step table (re)construction (Skywalker
    extended to dynamic walks): Vose two-stack build — O(d) with a *serial*
    dependence chain, which is exactly the per-step overhead Fig. 3 exposes.

    The build runs the textbook Vose algorithm with explicit stacks inside a
    fori_loop (each iteration finalises one "small" entry, so ``pad``
    iterations always suffice); padded lanes never enter the stacks.
    """
    w, nbr, mask = padded_weights(graph, workload, params, cur, prev, step,
                                  pad, wstate)
    deg = degrees_of(graph, cur)
    total = jnp.sum(w, axis=1)

    def build_one(w_row, deg_row, total_row):
        lane = jnp.arange(pad, dtype=jnp.int32)
        valid = lane < deg_row
        n = jnp.maximum(deg_row, 1).astype(jnp.float32)
        q = jnp.where(valid, w_row * n / jnp.maximum(total_row, 1e-30), 1.0)
        is_small = (q < 1.0) & valid
        is_large = (q >= 1.0) & valid
        # initial stacks: valid lanes of each class, compacted to the front.
        small_stack = jnp.sort(jnp.where(is_small, lane, pad))
        large_stack = jnp.sort(jnp.where(is_large, lane, pad))
        small_top = jnp.sum(is_small.astype(jnp.int32))
        large_top = jnp.sum(is_large.astype(jnp.int32))
        alias0 = lane
        prob0 = jnp.ones((pad,), jnp.float32)

        def body(_, st):
            q, alias, prob, s_stk, s_top, l_stk, l_top = st
            can = (s_top > 0) & (l_top > 0)
            s = s_stk[jnp.clip(s_top - 1, 0, pad - 1)]
            l = l_stk[jnp.clip(l_top - 1, 0, pad - 1)]
            # finalise small s against large l
            prob = jnp.where(can, prob.at[s].set(q[s]), prob)
            alias = jnp.where(can, alias.at[s].set(l), alias)
            new_ql = q[l] - (1.0 - q[s])
            q = jnp.where(can, q.at[l].set(new_ql), q)
            s_top = s_top - can.astype(jnp.int32)
            # l demoted to small when its residual drops below 1
            demote = can & (new_ql < 1.0)
            l_top = l_top - demote.astype(jnp.int32)
            s_stk = jnp.where(demote, s_stk.at[jnp.clip(s_top, 0, pad - 1)].set(l), s_stk)
            s_top = s_top + demote.astype(jnp.int32)
            return (q, alias, prob, s_stk, s_top, l_stk, l_top)

        st = (q, alias0, prob0, small_stack, small_top, large_stack, large_top)
        _, alias, prob, _, _, _, _ = jax.lax.fori_loop(0, pad, body, st)
        return alias, prob

    alias, prob = jax.vmap(build_one)(w, deg, total)
    # draw: 2 uniforms → (column, accept-or-alias)
    k1 = jax.vmap(lambda k: jax.random.uniform(
        k, (2,), dtype=jnp.float32))(rng)
    col = jnp.minimum((k1[:, 0] * deg.astype(jnp.float32)).astype(jnp.int32),
                      jnp.maximum(deg - 1, 0))
    p_col = jnp.take_along_axis(prob, col[:, None], axis=1)[:, 0]
    a_col = jnp.take_along_axis(alias, col[:, None], axis=1)[:, 0]
    sel = jnp.where(k1[:, 1] < p_col, col, a_col)
    out = jnp.take_along_axis(nbr, sel[:, None], axis=1)[:, 0]
    return jnp.where(total > 0, out, -1)


# Baseline step functions by registry name (samplers.py wraps each in a
# PaddedRowSampler; benchmarks may call them directly on padded blocks).
BASELINE_STEP_FNS = {
    "its": its_step,
    "als": als_step,
    "rvs_prefix": rvs_prefix_step,
    "rjs_maxreduce": rjs_maxreduce_step,
}
