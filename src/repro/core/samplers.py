"""Sampler protocol + registry — the extensibility layer of Flexi-Runtime.

Every sampling strategy the engine can run is a :class:`Sampler` object
registered by name.  The engine (`core/runtime.py`) never dispatches on
method strings: it resolves ``EngineConfig.method`` through this registry
and calls ``sampler.select(ctx, state, rng, active=live)`` once per step.
Adding a strategy therefore means registering one object here — no engine
edits; the C-SAW-style precomputed regimes (``its_precomp`` /
``alias_precomp``) and the ThunderRW-style step-interleaved pipeline
(``interleaved``) below landed exactly that way.

Architecture:

* :class:`Sampler`        — the protocol: ``select`` + capability metadata
  (:class:`SamplerCaps`: needs the compiler bound, needs full-row padding,
  supports masked partitions).
* :class:`SamplerContext` — everything static a sampler may need: graph,
  workload + params, Flexi-Compiler output, node stats, engine config,
  padding geometry; plus the bound/sum estimator evaluation helper.
* :class:`PartitionedSampler` — the paper's runtime adaptation (§4.1,
  §5.2) expressed generically: a *selector policy* splits the live lanes
  into a rejection partition and a reservoir partition, any registered
  rejection/reservoir pair executes them, and rejection lanes unresolved
  after R_max rounds fall back to the reservoir side (§7.1 soundness
  fallback).  ``adaptive`` (Eq. 11 cost model), ``erjs`` (all-rejection),
  ``random`` and ``degree`` (Fig. 13 baseline selectors) are all just
  ``PartitionedSampler`` instances with different policies.
* precomputed regime — :class:`ITSPrecompSampler` /
  :class:`AliasPrecompSampler` serve static-provable workloads from the
  baked tables of ``core/precomp.py`` (per-node invalidation bitmap gates
  every read); :class:`InterleavedSampler` pipelines the next step's
  neighbour gather behind the current move/update via the sampler-owned
  ``WalkerState.carry``.
* registry — :func:`register_sampler` / :func:`get_sampler` /
  :func:`available_samplers` (sorted).  ``runtime.METHODS`` is a snapshot
  of the registry keys taken at import; the registry itself is the source
  of truth and accepts user strategies at any time.

Sampler convention: ``select`` returns next nodes for the *active* lanes
(-1 = dead end); inactive lanes are unspecified — the engine masks them.
Telemetry (lanes served by rejection, fallback count) counts active lanes
only, so padded/dead walkers can never skew Fig. 14-style statistics.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import flexi_compiler as fc
from repro.core import precomp as precomp_mod
from repro.core.baselines import BASELINE_STEP_FNS
from repro.core.ctxutil import degrees_of, eval_weights, tile_ctx
from repro.core.erjs import erjs_step
from repro.core.ervs import (NEG_INF, _log_keys, _tile_uniforms,
                             ervs_jump_step, ervs_step)
from repro.core.types import EdgeCtx, WalkerState
from repro.graphs.csr import dist_code


# ---------------------------------------------------------------- metadata
@dataclasses.dataclass(frozen=True)
class SamplerCaps:
    """Capability metadata the engine/scheduler can reason about."""

    needs_bound: bool = False  # evaluates the Flexi-Compiler estimators
    needs_padded_row: bool = False  # materialises [W, pad] weight rows
    supports_partition: bool = False  # honours an ``active`` lane mask
    # wants precomputed ITS/alias tables: the engine runs the is_static
    # analysis and builds core/precomp.py tables when it holds (the sampler
    # must still degrade gracefully when ctx.precomp is None).
    needs_precomp: bool = False


@dataclasses.dataclass(frozen=True)
class Estimates:
    """Per-walker Flexi-Compiler estimates (zeros when not usable)."""

    bound_max: jax.Array  # [W] upper bound of max_i w̃ (Eqs. 5–8)
    sum_est: jax.Array  # [W] estimate of Σ_i w̃ (Eq. 12)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Selection:
    """Result of one ``select`` call for a walker batch."""

    next_nodes: jax.Array  # [W] int32; -1 = dead end; inactive lanes junk
    rjs_served: jax.Array  # [] int32 — active lanes served by rejection
    fallbacks: jax.Array  # [] int32 — active lanes that hit §7.1 fallback
    # active lanes served from precomputed ITS/alias tables
    precomp_served: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.int32(0))
    # active lanes that hit a stale (invalidated) table row and took the
    # dynamic path while the row awaits its background rebuild
    stale_served: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.int32(0))
    # sampler-owned cross-step state; the engine stores it in
    # WalkerState.carry for the next step (None = carry nothing)
    carry: Any = None


@dataclasses.dataclass(frozen=True)
class SamplerContext:
    """Static per-engine inputs shared by every sampler.

    Built once by ``WalkEngine``; samplers close over it inside the jitted
    epoch, so all fields are trace-time constants.
    """

    graph: Any  # CSRGraph
    workload: Any  # Workload
    params: Any  # workload.params() (static hyperparameters)
    compiled: fc.CompiledWorkload
    stats: Any  # node_stats output (h_min/h_max/h_mean per node)
    config: Any  # EngineConfig (avoid circular import with runtime)
    pad: int  # padded max degree (power of two ≥ tile)
    max_tiles: int  # ceil(pad / tile)
    # precomputed ITS/alias tables (core/precomp.py) — present only when
    # the workload is is_static-provable AND the sampler asked for them
    # (caps.needs_precomp); None otherwise.
    precomp: Optional[precomp_mod.PrecompTables] = None

    def bound_inputs(self, state: WalkerState) -> fc.BoundInputs:
        vs = jnp.maximum(state.cur, 0)
        return fc.BoundInputs(
            h_min=self.stats.h_min[vs], h_max=self.stats.h_max[vs],
            h_mean=self.stats.h_mean[vs],
            deg_cur=degrees_of(self.graph, state.cur),
            deg_prev=degrees_of(self.graph, state.prev),
            cur=state.cur, prev=state.prev, step=state.step,
            # program-owned per-walker state: a concrete runtime input to
            # the synthesized estimators, like cur/prev/step
            wstate=state.wstate,
        )

    def estimates(self, state: WalkerState) -> Estimates:
        W = state.cur.shape[0]
        if not self.compiled.usable:
            z = jnp.zeros((W,), jnp.float32)
            return Estimates(bound_max=z, sum_est=z)
        bi = self.bound_inputs(state)
        _, bmax = jax.vmap(self.compiled.bound_fn)(bi)
        ssum = jax.vmap(self.compiled.sum_fn)(bi)
        return Estimates(bound_max=bmax, sum_est=ssum)


# ---------------------------------------------------------------- protocol
class Sampler(abc.ABC):
    """One sampling strategy: pick the next node for a batch of walkers."""

    name: str
    caps: SamplerCaps = SamplerCaps()

    @abc.abstractmethod
    def select(self, ctx: SamplerContext, state: WalkerState,
               rng: jax.Array, *, active: jax.Array) -> Selection:
        """Sample next nodes for lanes where ``active`` is True.

        ``rng`` is a [W] array of per-walker, per-step PRNG keys (the
        engine folds the walker's step counter into its stream key, so a
        query's randomness is independent of slot/epoch placement).
        """

    def init_carry(self, ctx: SamplerContext, num_slots: int) -> Any:
        """Initial value of the sampler's cross-step carry
        (``WalkerState.carry``).  Samplers that pipeline across steps (the
        ``interleaved`` gather-move-update pipeline) override this; the
        default carries nothing.

        Sharding contract: every array leaf of the carry must either have
        the walker-slot dim leading (``shape[0] == num_slots``) or be
        slot-free (a scalar/replicated table).  The sharded scheduler
        (docs/scaling.md) partitions exactly the leaves whose dim 0 is the
        slot dim, so a carry laid out any other way would be silently
        replicated — per-lane state must ride the ``"walkers"`` axis to
        stay on the device that owns its lane."""
        return None

    def fused_kind(self, *, usable: bool, has_precomp: bool
                   ) -> Optional[str]:
        """Which mega-step regime (``kernels/megastep_kernel.FUSED_KINDS``)
        replicates this sampler bit-for-bit, or ``None`` if the strategy
        has no fused equivalent and the engine must stay on the staged
        scan.  ``usable`` = the Flexi-Compiler synthesized estimators for
        the workload; ``has_precomp`` = baked tables exist for this run.
        The default is honest: unknown strategies are never fused."""
        return None


# ---------------------------------------------------------------- registry
_REGISTRY: Dict[str, Sampler] = {}


def register_sampler(sampler: Sampler, *, overwrite: bool = False) -> Sampler:
    """Register a strategy under ``sampler.name``.  Returns it (chainable)."""
    name = sampler.name
    if not name or not isinstance(name, str):
        raise ValueError("sampler.name must be a non-empty string")
    if name in _REGISTRY and not overwrite:
        existing = _REGISTRY[name]
        raise ValueError(
            f"sampler {name!r} already registered by "
            f"{type(existing).__name__} (pass overwrite=True to replace); "
            f"registered samplers: {', '.join(available_samplers())}")
    _REGISTRY[name] = sampler
    return sampler


def get_sampler(name: str) -> Sampler:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown sampler {name!r}; registered: "
                       f"{available_samplers()}") from None


def available_samplers() -> Tuple[str, ...]:
    """Registered strategy names, **sorted** — deterministic regardless of
    import/registration order (CLI choices, error messages and docs tables
    all render the same list)."""
    return tuple(sorted(_REGISTRY))


# ------------------------------------------------------------- reservoirs
class ERVSSampler(Sampler):
    """eRVS — streaming exponential-key reservoir (paper §3.2, Alg. 1)."""

    name = "ervs"
    caps = SamplerCaps(supports_partition=True)

    def select(self, ctx, state, rng, *, active):
        nxt = ervs_step(ctx.graph, ctx.workload, ctx.params,
                        state.cur, state.prev, state.step, rng,
                        tile=ctx.config.tile, max_tiles=ctx.max_tiles,
                        active=active, wstate=state.wstate)
        zero = jnp.int32(0)
        return Selection(next_nodes=nxt, rjs_served=zero, fallbacks=zero)

    def fused_kind(self, *, usable, has_precomp):
        return "reservoir"


class ERVSJumpSampler(Sampler):
    """eRVS + A-ExpJ jumps — RNG draws only at threshold crossings."""

    name = "ervs_jump"
    caps = SamplerCaps(supports_partition=True)

    def select(self, ctx, state, rng, *, active):
        nxt, _ = ervs_jump_step(ctx.graph, ctx.workload, ctx.params,
                                state.cur, state.prev, state.step, rng,
                                tile=ctx.config.tile, max_tiles=ctx.max_tiles,
                                active=active, wstate=state.wstate)
        zero = jnp.int32(0)
        return Selection(next_nodes=nxt, rjs_served=zero, fallbacks=zero)


# ---------------------------------------------------------- rejection side
class RejectionComponent(abc.ABC):
    """The rejection half of a :class:`PartitionedSampler` pair."""

    @abc.abstractmethod
    def propose(self, ctx: SamplerContext, state: WalkerState,
                rng: jax.Array, bound: jax.Array, active: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
        """Return (next_nodes [W], needs_fallback [W] bool)."""


class ERJSRejection(RejectionComponent):
    """eRJS — bound-based rejection trials (paper §3.3, Eqs. 5–8)."""

    def propose(self, ctx, state, rng, bound, active):
        nxt, fb, _ = erjs_step(
            ctx.graph, ctx.workload, ctx.params,
            state.cur, state.prev, state.step, rng, bound=bound,
            trials_per_round=ctx.config.rjs_trials,
            max_rounds=ctx.config.rjs_max_rounds, active=active,
            wstate=state.wstate)
        return nxt, fb


# -------------------------------------------------------- selector policies
# A policy maps (ctx, state, est, deg, active, rng) -> bool [W]: which of
# the active lanes should go to the rejection partition this step.
SelectorPolicy = Callable[..., jax.Array]


def cost_model_policy(ctx, state, est, deg, active, rng):
    """Eq. 11: rejection wins when ratio·max-bound < Σ-estimate."""
    return ctx.config.cost_model.prefer_rjs(est.bound_max, est.sum_est, deg)


def always_policy(ctx, state, est, deg, active, rng):
    """All-rejection (the pure ``erjs`` method); needs a usable bound."""
    W = deg.shape[0]
    if not ctx.compiled.usable:
        return jnp.zeros((W,), bool)
    return jnp.ones((W,), bool)


def random_policy(ctx, state, est, deg, active, rng):
    """Coin-flip selection (Fig. 13 baseline)."""
    coin = jax.vmap(lambda k: jax.random.bernoulli(
        jax.random.fold_in(k, 777)))(rng)
    return coin & (est.bound_max > 0)


def degree_policy(ctx, state, est, deg, active, rng):
    """Degree-threshold selection (Fig. 13 baseline): rejection for hubs."""
    return (deg >= ctx.config.degree_threshold) & (est.bound_max > 0)


SELECTOR_POLICIES: Dict[str, SelectorPolicy] = {
    "cost_model": cost_model_policy,
    "always": always_policy,
    "random": random_policy,
    "degree": degree_policy,
}


class PartitionedSampler(Sampler):
    """Runtime adaptation: policy-split lanes, compose any (rejection,
    reservoir) pair, fall back rejection→reservoir (§7.1) — and, when the
    workload is static-provable, a third *precomputed* partition served
    straight from the baked ITS tables (C-SAW's regime; O(log d) per step).

    Per-node regime order is precomp > rejection > reservoir: lanes whose
    row is eligible (valid table + ``CostModel.prefer_precomp``) never
    reach the Eq. 11 split.  The reservoir side itself can be a per-degree
    pair (``reservoir_hi``): hub lanes (degree ≥ config.jump_threshold) run
    the A-ExpJ jump reservoir, whose RNG-draw saving only amortises on long
    rows, while everyone else streams plain eRVS.

    This is the generic form of the engine's former hand-written adaptive
    path; ``adaptive``/``erjs``/``random``/``degree`` are four instances.
    """

    def __init__(self, name: str, policy: SelectorPolicy,
                 rejection: Optional[RejectionComponent] = None,
                 reservoir: Optional[Sampler] = None, *,
                 precomp_regime: bool = False,
                 reservoir_hi: Optional[Sampler] = None):
        self.name = name
        self.policy = policy
        self.rejection = rejection or ERJSRejection()
        self.reservoir = reservoir or ERVSSampler()
        self.reservoir_hi = reservoir_hi
        self.precomp_regime = precomp_regime
        self.caps = SamplerCaps(needs_bound=True, supports_partition=True,
                                needs_precomp=precomp_regime)
        for res in filter(None, [self.reservoir, self.reservoir_hi]):
            if not res.caps.supports_partition:
                raise ValueError(
                    f"reservoir {res.name!r} cannot run on a "
                    f"partition (caps.supports_partition=False)")

    def _reservoir_select(self, ctx, state, rng, deg, active):
        """Reservoir partition, optionally split by degree (hubs take the
        jump variant — the ROADMAP's per-node reservoir choice)."""
        if self.reservoir_hi is None:
            return self.reservoir.select(ctx, state, rng, active=active).next_nodes
        hi = active & (deg >= ctx.config.jump_threshold)
        lo = active & ~hi
        r_lo = self.reservoir.select(ctx, state, rng, active=lo)
        r_hi = self.reservoir_hi.select(ctx, state, rng, active=hi)
        return jnp.where(hi, r_hi.next_nodes, r_lo.next_nodes)

    def select(self, ctx, state, rng, *, active):
        deg = degrees_of(ctx.graph, state.cur)
        est = ctx.estimates(state)
        # --- third regime: static rows served from the baked tables ------
        if self.precomp_regime and ctx.precomp is not None:
            # routing discounts by the transient stale fraction: as the
            # rebuild queue backs up, fewer lanes are sent to bounce off
            # invalid rows (see CostModel.prefer_precomp)
            prefer = ctx.config.cost_model.prefer_precomp(
                deg, frac_stale=ctx.precomp.frac_stale())
            valid = ctx.precomp.row_valid(state.cur)
            want_pre = active & valid & prefer
            stale_pre = active & ~valid & prefer
            nxt_pre = precomp_table_select(ctx, state, rng, want_pre,
                                           kind="its")
        else:
            want_pre = jnp.zeros_like(active)
            stale_pre = jnp.zeros_like(active)
            nxt_pre = jnp.full_like(state.cur, -1)
        rest = active & ~want_pre
        # --- Eq. 11 split on the remaining lanes -------------------------
        want_rjs = self.policy(ctx, state, est, deg, rest, rng) & rest
        nxt_rjs, fb = self.rejection.propose(ctx, state, rng,
                                             est.bound_max, want_rjs)
        # reservoir partition = lanes the policy kept + rejection fallbacks
        res_active = rest & ((~want_rjs) | fb)
        nxt_res = self._reservoir_select(ctx, state, rng, deg, res_active)
        nxt = jnp.where(res_active, nxt_res,
                        jnp.where(want_rjs, nxt_rjs, -1))
        nxt = jnp.where(want_pre, nxt_pre, nxt)
        # served = the regime actually produced a transition; lanes that
        # were infeasible (zero bound / all-zero weights) emit no node and
        # must not count toward Fig. 14-style coverage statistics.  A lane
        # that bounced off a stale table row counts ONLY as stale — never
        # also under the dynamic regime that absorbed it — so the regime
        # fractions partition the live lanes (telemetry mass conservation,
        # pinned by the conformance suite).
        return Selection(
            next_nodes=nxt,
            rjs_served=jnp.sum(
                (want_rjs & ~fb & (nxt_rjs >= 0)
                 & ~stale_pre).astype(jnp.int32)),
            fallbacks=jnp.sum(fb.astype(jnp.int32)),
            precomp_served=jnp.sum(
                (want_pre & (nxt_pre >= 0)).astype(jnp.int32)),
            stale_served=jnp.sum(
                (stale_pre & (nxt >= 0)).astype(jnp.int32)),
        )

    def fused_kind(self, *, usable, has_precomp):
        # Only the pure all-rejection composition ("erjs": always_policy
        # over the stock eRJS/eRVS pair, no degree split, no precomp
        # partition) has a mega-step replica.  With a usable bound every
        # lane runs rejection (§7.1 fallback included); without one,
        # always_policy routes every lane to the eRVS side — exactly the
        # kernel's reservoir regime.  Any custom policy/component keeps
        # the staged scan.
        structural = (self.policy is always_policy
                      and type(self.rejection) is ERJSRejection
                      and type(self.reservoir) is ERVSSampler
                      and self.reservoir_hi is None
                      and not self.precomp_regime)
        if not structural:
            return None
        return "rejection" if usable else "reservoir"


# ------------------------------------------------------- padded baselines
class PaddedRowSampler(Sampler):
    """Adapter for the §2.2 baselines (ITS / ALS / prefix-RVS / max-reduce
    RJS): they materialise one [W, pad] weight row per step — the padding
    cost the enhanced kernels avoid is part of what they measure."""

    caps = SamplerCaps(needs_padded_row=True)

    def __init__(self, name: str, step_fn: Callable, **extra_of_cfg):
        self.name = name
        self._step_fn = step_fn
        # kwargs derived from the engine config at call time, e.g.
        # trials_per_round=lambda cfg: cfg.rjs_trials
        self._extra_of_cfg = extra_of_cfg

    def select(self, ctx, state, rng, *, active):
        extra = {k: f(ctx.config) for k, f in self._extra_of_cfg.items()}
        nxt = self._step_fn(ctx.graph, ctx.workload, ctx.params,
                            state.cur, state.prev, state.step, rng,
                            pad=ctx.pad, wstate=state.wstate, **extra)
        zero = jnp.int32(0)
        return Selection(next_nodes=jnp.where(active, nxt, -1),
                         rjs_served=zero, fallbacks=zero)


# ------------------------------------------------------ precomputed regime
# Execution paths for table draws (EngineConfig.precomp_exec): the Pallas
# DMA kernels of kernels/precomp_kernel.py, or the jnp selectors of
# core/precomp.py.  Both consume the same counter-based Threefry
# (key, counter, salt) triples, so the choice never changes an output bit.
PRECOMP_EXEC_CHOICES = ("auto", "jnp", "pallas")


def resolve_precomp_exec(choice: str) -> str:
    """``auto`` → the Pallas kernels on TPU, the jnp selectors (which are
    also the interpret-mode oracles) everywhere else."""
    if choice == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return choice


def precomp_table_select(ctx: SamplerContext, state: WalkerState,
                         rng: jax.Array, active: jax.Array, *,
                         kind: str) -> jax.Array:
    """Next nodes for the ``active`` lanes straight from the baked tables
    (``kind``: "its" binary search or "alias" pick), via whichever
    execution path ``EngineConfig.precomp_exec`` resolves to.

    The "pallas" path DMAs the tile-aligned streams
    (``PrecompTables.cdf2d``/``prob2d``/``alias2d``; interpret mode when
    not on TPU) and falls back to the jnp selectors for hand-built tables
    that carry no aligned layout — a fallback with no observable effect,
    since the paths are bit-identical by construction (pinned by
    tests/test_kernels.py).
    """
    tables = ctx.precomp
    graph = ctx.graph
    exec_path = resolve_precomp_exec(ctx.config.precomp_exec)
    if exec_path == "pallas" and tables.arow0 is not None:
        # arow0 alone does not prove the per-kind value streams exist —
        # a partially-stripped table (e.g. mid-overlay) must fail loudly
        # at trace time, never DMA a missing stream into a silent wrong
        # draw.  with_aligned()/compact() re-attach the full set; or set
        # precomp_exec="jnp" to skip the kernels.
        needed = ("cdf2d",) if kind == "its" else ("prob2d", "alias2d")
        missing = [f for f in needed if getattr(tables, f) is None]
        if missing:
            raise RuntimeError(
                f"precomp_exec resolved to 'pallas' for kind={kind!r} but "
                f"the aligned table stream(s) {missing} are absent "
                f"(arow0 is attached). Re-attach via "
                f"PrecompTables.with_aligned(indptr) / engine.compact(), "
                f"or run with precomp_exec='jnp'.")
        # deferred so jnp-only engines never load the Pallas modules
        from repro.kernels import ops as kernel_ops
        from repro.kernels import precomp_kernel
        vs = jnp.maximum(state.cur, 0)
        deg = degrees_of(graph, state.cur)
        seeds = precomp_mod.threefry_seeds(rng)
        totals = tables.total[vs]
        row0 = tables.arow0[vs]
        interpret = precomp_kernel.default_interpret()
        if kind == "its":
            off = kernel_ops.its_search(tables.cdf2d, row0, deg, totals,
                                        seeds, interpret=interpret)
        else:
            off = kernel_ops.alias_pick(tables.prob2d, tables.alias2d, row0,
                                        deg, totals, seeds,
                                        interpret=interpret)
        start = graph.row_starts(vs)
        nxt = graph.indices[jnp.clip(start + jnp.maximum(off, 0), 0,
                                     graph.num_edges - 1)]
        return jnp.where(active & (off >= 0), nxt, -1)
    if kind == "its":
        return precomp_mod.its_select(
            graph, tables, state.cur, rng, active=active,
            depth=precomp_mod.search_depth(ctx.pad))
    return precomp_mod.alias_select(graph, tables, state.cur, rng,
                                    active=active)


class _PrecompBase(Sampler):
    """Shared shell of the C-SAW-style precomputed samplers.

    When the engine proved the workload static, ``ctx.precomp`` holds the
    baked tables and ``select`` is a pure table lookup (Pallas kernel or
    jnp selector per ``EngineConfig.precomp_exec`` — bit-identical); lanes
    whose row was invalidated (mutated weights) take the dynamic eRVS path
    over the live graph *transiently*, counted in ``stale_served``, until
    the engine's rebuild queue re-bakes the row.  Entire runs on workloads
    that are NOT static-provable fall back to eRVS for good (not "stale" —
    there is nothing to rebuild), so the method is always sound, never
    silently stale.
    """

    caps = SamplerCaps(supports_partition=True, needs_precomp=True)
    kind = "its"  # which table family select() draws from

    def __init__(self):
        self._fallback = ERVSSampler()

    def select(self, ctx, state, rng, *, active):
        zero = jnp.int32(0)
        if ctx.precomp is None:  # workload not static-provable
            dyn = self._fallback.select(ctx, state, rng, active=active)
            return Selection(next_nodes=dyn.next_nodes, rjs_served=zero,
                             fallbacks=zero)
        ok = active & ctx.precomp.row_valid(state.cur)
        nxt_pre = precomp_table_select(ctx, state, rng, ok, kind=self.kind)
        stale = active & ~ok
        dyn = self._fallback.select(ctx, state, rng, active=stale)
        nxt = jnp.where(ok, nxt_pre,
                        jnp.where(stale, dyn.next_nodes, -1))
        # like precomp_served, stale_served counts lanes whose (fallback)
        # draw actually produced a transition — dead-ends stay uncounted
        return Selection(
            next_nodes=nxt, rjs_served=zero, fallbacks=zero,
            precomp_served=jnp.sum((ok & (nxt_pre >= 0)).astype(jnp.int32)),
            stale_served=jnp.sum(
                (stale & (dyn.next_nodes >= 0)).astype(jnp.int32)))

    def fused_kind(self, *, usable, has_precomp):
        # With baked tables the kernel serves the table regime (stale rows
        # take its in-kernel reservoir fallback); without them the sampler
        # is eRVS for good, which the reservoir regime replicates.
        return f"precomp_{self.kind}" if has_precomp else "reservoir"


class ITSPrecompSampler(_PrecompBase):
    """``its_precomp`` — O(log d) binary search of the baked per-row CDF."""

    name = "its_precomp"
    kind = "its"


class AliasPrecompSampler(_PrecompBase):
    """``alias_precomp`` — O(1) draw from the baked Vose alias tables."""

    name = "alias_precomp"
    kind = "alias"


# -------------------------------------------------- step-interleaved eRVS
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PrefetchTile:
    """The ``interleaved`` sampler's cross-step carry: the first neighbour
    tile of the node each lane is *about to* occupy, gathered at the end of
    the previous step so the HBM fetch overlaps the move/update.

    All leaves lead with the walker-slot dim (the ``init_carry`` sharding
    contract), so under ``run(devices=N)`` each device carries only its own
    lanes' tiles — the prefetch never crosses the mesh: a lane's tile is
    gathered, stored and consumed on the device that owns the lane."""

    node: jax.Array  # [W] int32 — node the tile was gathered for (-1 none)
    nbr: jax.Array  # [W, tile] int32
    h: jax.Array  # [W, tile] float32
    label: jax.Array  # [W, tile] int32


class InterleavedSampler(Sampler):
    """``interleaved`` — ThunderRW-style gather-move-update pipeline.

    Identical *distribution and bit pattern* to plain eRVS (same per-tile
    counter-based uniforms, same log-key argmax), but restructured as a
    software pipeline across the engine's fused ``lax.scan`` steps: after
    selecting step t's transition, the first neighbour tile of the chosen
    node is gathered immediately (the step-t+1 *gather* overlapping the
    step-t *move/update* in the same scan body), carried in
    ``WalkerState.carry``, and consumed next step without touching HBM.

    Correctness never depends on the prefetch hitting: the carry records
    which node each tile was gathered for, and lanes whose current node
    differs (first step, scheduler refill, dead-end residue) re-fetch
    inline — a tile gathered for node v is valid for *any* lane now at v
    because graph data is immutable within a run.  Hit lanes point their
    correction-gather indices at row 0, so on hardware the prefetch
    genuinely removes the cold row fetch from the critical path.
    """

    name = "interleaved"
    caps = SamplerCaps(supports_partition=True)

    def init_carry(self, ctx, num_slots):
        tile = ctx.config.tile
        return PrefetchTile(
            node=jnp.full((num_slots,), -1, jnp.int32),
            nbr=jnp.full((num_slots, tile), -1, jnp.int32),
            h=jnp.zeros((num_slots, tile), jnp.float32),
            label=jnp.zeros((num_slots, tile), jnp.int32),
        )

    def _gather_tile0(self, ctx, node, *, cheap_lanes=None):
        """(nbr, h, label, mask) of rows ``node`` for offsets [0, tile) —
        the same values ``ctxutil.tile_ctx`` would produce.  Lanes in
        ``cheap_lanes`` read position 0 instead (their data comes from the
        prefetch; the degenerate index keeps the gather cache-hot)."""
        graph, wl = ctx.graph, ctx.workload
        tile = ctx.config.tile
        deg = degrees_of(graph, node)
        start = graph.row_starts(jnp.maximum(node, 0))
        offs = jnp.arange(tile, dtype=jnp.int32)[None, :]
        mask = (offs < deg[:, None]) & (node >= 0)[:, None]
        pos = jnp.clip(start[:, None] + offs, 0, graph.num_edges - 1)
        if cheap_lanes is not None:
            pos = jnp.where(cheap_lanes[:, None], 0, pos)
        nbr = jnp.where(mask, graph.indices[pos], -1)
        if wl.weighted:
            h = jnp.where(mask, graph.h[pos], 0.0)
        else:
            h = jnp.where(mask, 1.0, 0.0)
        if wl.needs_labels:
            label = jnp.where(mask, graph.labels[pos], -1)
        else:
            label = jnp.zeros_like(nbr)
        return nbr, h, label, mask

    def select(self, ctx, state, rng, *, active):
        graph, wl = ctx.graph, ctx.workload
        tile = ctx.config.tile
        W = state.cur.shape[0]
        cur, prev, step = state.cur, state.prev, state.step
        deg_cur = degrees_of(graph, cur)
        deg_prev = degrees_of(graph, prev)
        pf: Optional[PrefetchTile] = state.carry
        # ---- tile 0: consume the prefetch, correction-gather the misses --
        hit = (jnp.zeros((W,), bool) if pf is None
               else (pf.node == cur) & (pf.node >= 0))
        nbr_f, h_f, label_f, mask0 = self._gather_tile0(
            ctx, cur, cheap_lanes=hit if pf is not None else None)
        if pf is not None:
            nbr0 = jnp.where(hit[:, None], pf.nbr, nbr_f)
            h0 = jnp.where(hit[:, None], pf.h, h_f)
            label0 = jnp.where(hit[:, None], pf.label, label_f)
        else:
            nbr0, h0, label0 = nbr_f, h_f, label_f
        if wl.needs_dist:
            dist0 = jax.vmap(lambda p, us: jax.vmap(
                lambda u: dist_code(graph, p, jnp.maximum(u, 0)))(us)
            )(prev, nbr0)
        else:
            dist0 = jnp.ones_like(nbr0)
        ctx0 = EdgeCtx(
            h=h0, label=label0, dist=dist0, nbr=nbr0,
            deg_cur=jnp.broadcast_to(deg_cur[:, None], (W, tile)),
            deg_prev=jnp.broadcast_to(deg_prev[:, None], (W, tile)),
            cur=jnp.broadcast_to(cur[:, None], (W, tile)),
            prev=jnp.broadcast_to(prev[:, None], (W, tile)),
            step=jnp.broadcast_to(step[:, None], (W, tile)),
        )
        w0 = eval_weights(wl, ctx.params, ctx0, mask0, state.wstate)
        u0 = _tile_uniforms(rng, 0, (W, tile))
        lk0 = jnp.where(mask0 & active[:, None], _log_keys(u0, w0), NEG_INF)
        b0 = jnp.argmax(lk0, axis=1)
        best_lk = jnp.take_along_axis(lk0, b0[:, None], axis=1)[:, 0]
        best_nbr = jnp.take_along_axis(nbr0, b0[:, None], axis=1)[:, 0]
        best_nbr = jnp.where(best_lk > NEG_INF, best_nbr, -1)
        # ---- remaining tiles: plain eRVS streaming (same math/counters) --
        deg_act = jnp.where(active, deg_cur, 0)
        # the one cross-lane op in this sampler: a max over (possibly
        # device-sharded) lanes, which GSPMD lowers to an all-reduce — an
        # order-free reduction, so the trip count (and every bit of the
        # output) matches the single-device run.
        needed = (jnp.max(deg_act) + tile - 1) // tile
        needed = jnp.minimum(needed, ctx.max_tiles)

        def body(t, carry):
            best_lk, best_nbr = carry
            tctx, tmask = tile_ctx(graph, wl, cur, prev, step,
                                   jnp.full((W,), t * tile, jnp.int32), tile)
            w = eval_weights(wl, ctx.params, tctx, tmask, state.wstate)
            u = _tile_uniforms(rng, t, (W, tile))
            lk = jnp.where(tmask & active[:, None], _log_keys(u, w), NEG_INF)
            tb = jnp.argmax(lk, axis=1)
            tile_lk = jnp.take_along_axis(lk, tb[:, None], axis=1)[:, 0]
            tile_nbr = jnp.take_along_axis(tctx.nbr, tb[:, None], axis=1)[:, 0]
            upd = tile_lk > best_lk
            return (jnp.where(upd, tile_lk, best_lk),
                    jnp.where(upd, tile_nbr, best_nbr))

        best_lk, best_nbr = jax.lax.fori_loop(1, needed, body,
                                              (best_lk, best_nbr))
        nxt = jnp.where(active, best_nbr, -1)
        # ---- prefetch for step t+1: gather the chosen node's first tile --
        nxt_node = jnp.where(active & (nxt >= 0), nxt, -1)
        pn_nbr, pn_h, pn_label, _ = self._gather_tile0(ctx, nxt_node)
        carry = PrefetchTile(node=nxt_node, nbr=pn_nbr, h=pn_h,
                             label=pn_label)
        zero = jnp.int32(0)
        return Selection(next_nodes=nxt, rjs_served=zero, fallbacks=zero,
                         carry=carry)


# --------------------------------------------------------------- built-ins
# NOTE: runtime.METHODS snapshots available_samplers() at import — a sorted
# tuple, so registration order here carries no external meaning.
register_sampler(PartitionedSampler("adaptive", cost_model_policy,
                                    precomp_regime=True,
                                    reservoir_hi=ERVSJumpSampler()))
register_sampler(ERVSSampler())
register_sampler(ERVSJumpSampler())
register_sampler(PartitionedSampler("erjs", always_policy))
_BASELINE_CFG_KW = {
    "rjs_maxreduce": dict(trials_per_round=lambda cfg: cfg.rjs_trials,
                          max_rounds=lambda cfg: 4 * cfg.rjs_max_rounds),
}
for _name, _fn in BASELINE_STEP_FNS.items():
    register_sampler(PaddedRowSampler(_name, _fn,
                                      **_BASELINE_CFG_KW.get(_name, {})))
register_sampler(PartitionedSampler("random", random_policy))
register_sampler(PartitionedSampler("degree", degree_policy))
register_sampler(ITSPrecompSampler())
register_sampler(AliasPrecompSampler())
register_sampler(InterleavedSampler())
