"""Sampler protocol + registry — the extensibility layer of Flexi-Runtime.

Every sampling strategy the engine can run is a :class:`Sampler` object
registered by name.  The engine (`core/runtime.py`) never dispatches on
method strings: it resolves ``EngineConfig.method`` through this registry
and calls ``sampler.select(ctx, state, rng, active=live)`` once per step.
Adding a strategy (C-SAW-style pre-computed ITS/alias regimes, ThunderRW
step interleaving, …) therefore means registering one object here — no
engine edits.

Architecture:

* :class:`Sampler`        — the protocol: ``select`` + capability metadata
  (:class:`SamplerCaps`: needs the compiler bound, needs full-row padding,
  supports masked partitions).
* :class:`SamplerContext` — everything static a sampler may need: graph,
  workload + params, Flexi-Compiler output, node stats, engine config,
  padding geometry; plus the bound/sum estimator evaluation helper.
* :class:`PartitionedSampler` — the paper's runtime adaptation (§4.1,
  §5.2) expressed generically: a *selector policy* splits the live lanes
  into a rejection partition and a reservoir partition, any registered
  rejection/reservoir pair executes them, and rejection lanes unresolved
  after R_max rounds fall back to the reservoir side (§7.1 soundness
  fallback).  ``adaptive`` (Eq. 11 cost model), ``erjs`` (all-rejection),
  ``random`` and ``degree`` (Fig. 13 baseline selectors) are all just
  ``PartitionedSampler`` instances with different policies.
* registry — :func:`register_sampler` / :func:`get_sampler` /
  :func:`available_samplers`.  ``runtime.METHODS`` is a snapshot of the
  registry keys taken at import; the registry itself is the source of
  truth and accepts user strategies at any time.

Sampler convention: ``select`` returns next nodes for the *active* lanes
(-1 = dead end); inactive lanes are unspecified — the engine masks them.
Telemetry (lanes served by rejection, fallback count) counts active lanes
only, so padded/dead walkers can never skew Fig. 14-style statistics.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import flexi_compiler as fc
from repro.core.baselines import BASELINE_STEP_FNS
from repro.core.ctxutil import degrees_of
from repro.core.erjs import erjs_step
from repro.core.ervs import ervs_jump_step, ervs_step
from repro.core.types import WalkerState


# ---------------------------------------------------------------- metadata
@dataclasses.dataclass(frozen=True)
class SamplerCaps:
    """Capability metadata the engine/scheduler can reason about."""

    needs_bound: bool = False  # evaluates the Flexi-Compiler estimators
    needs_padded_row: bool = False  # materialises [W, pad] weight rows
    supports_partition: bool = False  # honours an ``active`` lane mask


@dataclasses.dataclass(frozen=True)
class Estimates:
    """Per-walker Flexi-Compiler estimates (zeros when not usable)."""

    bound_max: jax.Array  # [W] upper bound of max_i w̃ (Eqs. 5–8)
    sum_est: jax.Array  # [W] estimate of Σ_i w̃ (Eq. 12)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Selection:
    """Result of one ``select`` call for a walker batch."""

    next_nodes: jax.Array  # [W] int32; -1 = dead end; inactive lanes junk
    rjs_served: jax.Array  # [] int32 — active lanes served by rejection
    fallbacks: jax.Array  # [] int32 — active lanes that hit §7.1 fallback


@dataclasses.dataclass(frozen=True)
class SamplerContext:
    """Static per-engine inputs shared by every sampler.

    Built once by ``WalkEngine``; samplers close over it inside the jitted
    epoch, so all fields are trace-time constants.
    """

    graph: Any  # CSRGraph
    workload: Any  # Workload
    params: Any  # workload.params() (static hyperparameters)
    compiled: fc.CompiledWorkload
    stats: Any  # node_stats output (h_min/h_max/h_mean per node)
    config: Any  # EngineConfig (avoid circular import with runtime)
    pad: int  # padded max degree (power of two ≥ tile)
    max_tiles: int  # ceil(pad / tile)

    def bound_inputs(self, state: WalkerState) -> fc.BoundInputs:
        vs = jnp.maximum(state.cur, 0)
        return fc.BoundInputs(
            h_min=self.stats.h_min[vs], h_max=self.stats.h_max[vs],
            h_mean=self.stats.h_mean[vs],
            deg_cur=degrees_of(self.graph, state.cur),
            deg_prev=degrees_of(self.graph, state.prev),
            cur=state.cur, prev=state.prev, step=state.step,
        )

    def estimates(self, state: WalkerState) -> Estimates:
        W = state.cur.shape[0]
        if not self.compiled.usable:
            z = jnp.zeros((W,), jnp.float32)
            return Estimates(bound_max=z, sum_est=z)
        bi = self.bound_inputs(state)
        _, bmax = jax.vmap(self.compiled.bound_fn)(bi)
        ssum = jax.vmap(self.compiled.sum_fn)(bi)
        return Estimates(bound_max=bmax, sum_est=ssum)


# ---------------------------------------------------------------- protocol
class Sampler(abc.ABC):
    """One sampling strategy: pick the next node for a batch of walkers."""

    name: str
    caps: SamplerCaps = SamplerCaps()

    @abc.abstractmethod
    def select(self, ctx: SamplerContext, state: WalkerState,
               rng: jax.Array, *, active: jax.Array) -> Selection:
        """Sample next nodes for lanes where ``active`` is True.

        ``rng`` is a [W] array of per-walker, per-step PRNG keys (the
        engine folds the walker's step counter into its stream key, so a
        query's randomness is independent of slot/epoch placement).
        """


# ---------------------------------------------------------------- registry
_REGISTRY: Dict[str, Sampler] = {}


def register_sampler(sampler: Sampler, *, overwrite: bool = False) -> Sampler:
    """Register a strategy under ``sampler.name``.  Returns it (chainable)."""
    name = sampler.name
    if not name or not isinstance(name, str):
        raise ValueError("sampler.name must be a non-empty string")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"sampler {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[name] = sampler
    return sampler


def get_sampler(name: str) -> Sampler:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown sampler {name!r}; registered: "
                       f"{available_samplers()}") from None


def available_samplers() -> Tuple[str, ...]:
    """Registry keys in registration order (built-ins first)."""
    return tuple(_REGISTRY)


# ------------------------------------------------------------- reservoirs
class ERVSSampler(Sampler):
    """eRVS — streaming exponential-key reservoir (paper §3.2, Alg. 1)."""

    name = "ervs"
    caps = SamplerCaps(supports_partition=True)

    def select(self, ctx, state, rng, *, active):
        nxt = ervs_step(ctx.graph, ctx.workload, ctx.params,
                        state.cur, state.prev, state.step, rng,
                        tile=ctx.config.tile, max_tiles=ctx.max_tiles,
                        active=active)
        zero = jnp.int32(0)
        return Selection(next_nodes=nxt, rjs_served=zero, fallbacks=zero)


class ERVSJumpSampler(Sampler):
    """eRVS + A-ExpJ jumps — RNG draws only at threshold crossings."""

    name = "ervs_jump"
    caps = SamplerCaps(supports_partition=True)

    def select(self, ctx, state, rng, *, active):
        nxt, _ = ervs_jump_step(ctx.graph, ctx.workload, ctx.params,
                                state.cur, state.prev, state.step, rng,
                                tile=ctx.config.tile, max_tiles=ctx.max_tiles,
                                active=active)
        zero = jnp.int32(0)
        return Selection(next_nodes=nxt, rjs_served=zero, fallbacks=zero)


# ---------------------------------------------------------- rejection side
class RejectionComponent(abc.ABC):
    """The rejection half of a :class:`PartitionedSampler` pair."""

    @abc.abstractmethod
    def propose(self, ctx: SamplerContext, state: WalkerState,
                rng: jax.Array, bound: jax.Array, active: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
        """Return (next_nodes [W], needs_fallback [W] bool)."""


class ERJSRejection(RejectionComponent):
    """eRJS — bound-based rejection trials (paper §3.3, Eqs. 5–8)."""

    def propose(self, ctx, state, rng, bound, active):
        nxt, fb, _ = erjs_step(
            ctx.graph, ctx.workload, ctx.params,
            state.cur, state.prev, state.step, rng, bound=bound,
            trials_per_round=ctx.config.rjs_trials,
            max_rounds=ctx.config.rjs_max_rounds, active=active)
        return nxt, fb


# -------------------------------------------------------- selector policies
# A policy maps (ctx, state, est, deg, active, rng) -> bool [W]: which of
# the active lanes should go to the rejection partition this step.
SelectorPolicy = Callable[..., jax.Array]


def cost_model_policy(ctx, state, est, deg, active, rng):
    """Eq. 11: rejection wins when ratio·max-bound < Σ-estimate."""
    return ctx.config.cost_model.prefer_rjs(est.bound_max, est.sum_est, deg)


def always_policy(ctx, state, est, deg, active, rng):
    """All-rejection (the pure ``erjs`` method); needs a usable bound."""
    W = deg.shape[0]
    if not ctx.compiled.usable:
        return jnp.zeros((W,), bool)
    return jnp.ones((W,), bool)


def random_policy(ctx, state, est, deg, active, rng):
    """Coin-flip selection (Fig. 13 baseline)."""
    coin = jax.vmap(lambda k: jax.random.bernoulli(
        jax.random.fold_in(k, 777)))(rng)
    return coin & (est.bound_max > 0)


def degree_policy(ctx, state, est, deg, active, rng):
    """Degree-threshold selection (Fig. 13 baseline): rejection for hubs."""
    return (deg >= ctx.config.degree_threshold) & (est.bound_max > 0)


SELECTOR_POLICIES: Dict[str, SelectorPolicy] = {
    "cost_model": cost_model_policy,
    "always": always_policy,
    "random": random_policy,
    "degree": degree_policy,
}


class PartitionedSampler(Sampler):
    """Two-way runtime adaptation: policy-split lanes, compose any
    (rejection, reservoir) pair, fall back rejection→reservoir (§7.1).

    This is the generic form of the engine's former hand-written adaptive
    path; ``adaptive``/``erjs``/``random``/``degree`` are four instances.
    """

    caps = SamplerCaps(needs_bound=True, supports_partition=True)

    def __init__(self, name: str, policy: SelectorPolicy,
                 rejection: Optional[RejectionComponent] = None,
                 reservoir: Optional[Sampler] = None):
        self.name = name
        self.policy = policy
        self.rejection = rejection or ERJSRejection()
        self.reservoir = reservoir or ERVSSampler()
        if not self.reservoir.caps.supports_partition:
            raise ValueError(
                f"reservoir {self.reservoir.name!r} cannot run on a "
                f"partition (caps.supports_partition=False)")

    def select(self, ctx, state, rng, *, active):
        deg = degrees_of(ctx.graph, state.cur)
        est = ctx.estimates(state)
        want_rjs = self.policy(ctx, state, est, deg, active, rng) & active
        nxt_rjs, fb = self.rejection.propose(ctx, state, rng,
                                             est.bound_max, want_rjs)
        # reservoir partition = lanes the policy kept + rejection fallbacks
        res_active = active & ((~want_rjs) | fb)
        res = self.reservoir.select(ctx, state, rng, active=res_active)
        nxt = jnp.where(res_active, res.next_nodes,
                        jnp.where(want_rjs, nxt_rjs, -1))
        # served = rejection actually produced a transition; lanes that
        # were infeasible (zero bound / all-zero weights) emit no node and
        # must not count toward Fig. 14's rejection coverage.
        return Selection(
            next_nodes=nxt,
            rjs_served=jnp.sum(
                (want_rjs & ~fb & (nxt_rjs >= 0)).astype(jnp.int32)),
            fallbacks=jnp.sum(fb.astype(jnp.int32)),
        )


# ------------------------------------------------------- padded baselines
class PaddedRowSampler(Sampler):
    """Adapter for the §2.2 baselines (ITS / ALS / prefix-RVS / max-reduce
    RJS): they materialise one [W, pad] weight row per step — the padding
    cost the enhanced kernels avoid is part of what they measure."""

    caps = SamplerCaps(needs_padded_row=True)

    def __init__(self, name: str, step_fn: Callable, **extra_of_cfg):
        self.name = name
        self._step_fn = step_fn
        # kwargs derived from the engine config at call time, e.g.
        # trials_per_round=lambda cfg: cfg.rjs_trials
        self._extra_of_cfg = extra_of_cfg

    def select(self, ctx, state, rng, *, active):
        extra = {k: f(ctx.config) for k, f in self._extra_of_cfg.items()}
        nxt = self._step_fn(ctx.graph, ctx.workload, ctx.params,
                            state.cur, state.prev, state.step, rng,
                            pad=ctx.pad, **extra)
        zero = jnp.int32(0)
        return Selection(next_nodes=jnp.where(active, nxt, -1),
                         rjs_served=zero, fallbacks=zero)


# --------------------------------------------------------------- built-ins
# Registration order defines the legacy METHODS tuple ordering.
register_sampler(PartitionedSampler("adaptive", cost_model_policy))
register_sampler(ERVSSampler())
register_sampler(ERVSJumpSampler())
register_sampler(PartitionedSampler("erjs", always_policy))
_BASELINE_CFG_KW = {
    "rjs_maxreduce": dict(trials_per_round=lambda cfg: cfg.rjs_trials,
                          max_rounds=lambda cfg: 4 * cfg.rjs_max_rounds),
}
for _name, _fn in BASELINE_STEP_FNS.items():
    register_sampler(PaddedRowSampler(_name, _fn,
                                      **_BASELINE_CFG_KW.get(_name, {})))
register_sampler(PartitionedSampler("random", random_policy))
register_sampler(PartitionedSampler("degree", degree_policy))
