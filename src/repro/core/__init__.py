"""FlexiWalker core — the paper's contribution as composable JAX modules.

Flexi-Kernel  : ervs.py / erjs.py (+ Pallas TPU variants in repro.kernels)
Flexi-Runtime : runtime.py (WalkerState scan + streaming epoch scheduler),
                samplers.py (Sampler protocol + registry, runtime
                adaptation as PartitionedSampler), cost_model.py
Flexi-Compiler: flexi_compiler.py (jaxpr abstract interpretation)
Baselines     : baselines.py (ALS / ITS / prefix-RVS / max-reduce RJS)
"""
from repro.core.cost_model import CostModel, profile_edge_cost_ratio
from repro.core.flexi_compiler import (
    FALLBACK,
    PER_KERNEL,
    PER_STEP,
    BoundInputs,
    CompiledWorkload,
    analyze,
    is_static,
)
from repro.core.precomp import (PrecompTables, RebuildQueue, build_tables,
                                rebuild_rows)
from repro.core.samplers import (
    PartitionedSampler,
    Sampler,
    SamplerCaps,
    SamplerContext,
    Selection,
    available_samplers,
    get_sampler,
    register_sampler,
)
from repro.core.runtime import (METHODS, EngineConfig, EpochReport,
                                EpochScheduler, WalkEngine, WalkResult,
                                exact_probs)
from repro.core.types import (EdgeCtx, StepStats, WalkerState, WalkProgram,
                              Workload, from_workload)

__all__ = [
    "CostModel", "profile_edge_cost_ratio", "FALLBACK", "PER_KERNEL",
    "PER_STEP", "BoundInputs", "CompiledWorkload", "analyze", "is_static",
    "PrecompTables", "RebuildQueue", "build_tables", "rebuild_rows",
    "EngineConfig", "EpochReport", "EpochScheduler",
    "METHODS", "WalkEngine", "WalkResult", "exact_probs", "EdgeCtx",
    "StepStats", "WalkerState", "WalkProgram", "Workload", "from_workload",
    "Sampler", "SamplerCaps",
    "SamplerContext", "Selection", "PartitionedSampler",
    "available_samplers", "get_sampler", "register_sampler",
]
