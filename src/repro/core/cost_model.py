"""Flexi-Runtime's first-order cost model (paper §4.1, Eqs. 9–12).

  Cost_RVS     = EdgeCost_RVS · degree                           (Eq. 9)
  Cost_RJS     = EdgeCost_RJS · degree · max_i(w̃_i) / Σ_i w̃_i    (Eq. 10)
  Cost_precomp = EdgeCost_probe · log₂(degree)        (ITS; alias is O(1))

Preferring eRJS over eRVS for the current node therefore reduces to

  (EdgeCost_RJS / EdgeCost_RVS) · max_i(w̃_i) < Σ_i w̃_i           (Eq. 11)

with max replaced by its Flexi-Compiler upper bound and Σ by the Eq. 12
estimate (both supplied per-walker by the engine).  EdgeCost ratio is a
profiled scalar (§5.1): random-gather cost vs streaming cost per edge.

The third (precomputed) regime exists only for nodes whose transition
distribution is a graph constant (``flexi_compiler.is_static`` + a valid
row in ``precomp.PrecompTables``).  There a draw is a pure table lookup —
no weight evaluation, no RNG retries — so its cost is O(log d) probes (ITS)
against the O(d) streaming pass of Eq. 9; ``prefer_precomp`` is that
comparison.  Eligible nodes route precomp > rejection > reservoir: the
Eq. 11 split only runs on lanes the precomp regime declined.

``prefer_rjs``/``prefer_precomp`` are consumed by ``PartitionedSampler``
in ``samplers.py`` — the composition that makes it the paper's
``adaptive`` method (the Fig. 13 ``random``/``degree`` selectors are
alternative policies over the same estimates).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.csr import CSRGraph

DEFAULT_EDGE_COST_RATIO = 4.0  # HBM random gather ≈ 4× streaming, per edge


@dataclasses.dataclass(frozen=True)
class CostModel:
    """edge_cost_ratio = EdgeCost_RJS / EdgeCost_RVS (profiled)."""

    edge_cost_ratio: float = DEFAULT_EDGE_COST_RATIO
    # eRJS trial bookkeeping has a fixed per-walker overhead; nodes whose
    # degree is below this never benefit from rejection (one RVS tile pass
    # is already minimal).  First-order constant, profiled with the ratio.
    min_rjs_degree: int = 8
    # Cost_precomp = lookup_cost_ratio · log2(d): cost of one random CDF
    # probe relative to one streaming edge read.  Both are single HBM
    # touches, but the probe does no weight evaluation, hence ≈ 1.
    lookup_cost_ratio: float = 1.0
    # below this degree a single reservoir tile pass is already minimal
    # and the table gather locality does not pay for itself.
    min_precomp_degree: int = 4
    # a lane routed to the precomp regime that lands on a stale row pays
    # the dynamic O(d) path PLUS the wasted eligibility check/probe setup
    # — slightly worse than having gone dynamic directly.  Used to
    # discount `prefer_precomp` by the transient stale fraction while the
    # rebuild queue drains.
    stale_penalty: float = 1.25

    def prefer_rjs(
        self,
        bound_max: jax.Array,  # [W] upper bound of max_i w̃ (compiler)
        sum_est: jax.Array,  # [W] estimate of Σ_i w̃      (compiler, Eq. 12)
        degree: jax.Array,  # [W]
    ) -> jax.Array:
        """Vectorised Eq. 11 decision per walker."""
        ok = self.edge_cost_ratio * bound_max < sum_est
        return ok & (degree >= self.min_rjs_degree) & (bound_max > 0)

    def prefer_precomp(self, degree: jax.Array,
                       frac_stale=0.0) -> jax.Array:
        """Vectorised third-regime decision per walker.

        Cost_precomp = lookup_ratio · log₂(d) probes vs Cost_RVS = d
        streamed edges (Eq. 9).  Eligibility (static workload + valid
        table row) is checked by the caller — this is only the cost side.

        ``frac_stale`` is the fraction of table rows currently awaiting a
        background rebuild (``PrecompTables.frac_stale()``), used as the
        *a-priori* probability that a lane sent to this regime bounces off
        a stale row and pays the dynamic path plus the wasted eligibility
        work (``stale_penalty·d``).  The expected cost interpolates: at
        ``frac_stale = 0`` this is the pure table cost, and as the queue
        backs up the regime prices itself out until rows are repaired.
        Deliberately a prior, not the per-lane bitmap (the sampler still
        applies ``row_valid`` per lane afterwards): during a heavy
        transient this conservatively keeps marginal lanes off the regime
        even when their own row is valid — a bounded, short-lived trade
        the per-epoch drain erases by driving ``frac_stale`` back to 0.
        """
        d = jnp.maximum(degree, 1).astype(jnp.float32)
        cost_pre = self.lookup_cost_ratio * jnp.log2(d + 1.0)
        exp_cost = ((1.0 - frac_stale) * cost_pre
                    + frac_stale * self.stale_penalty * d)
        return (exp_cost < d) & (degree >= self.min_precomp_degree)


def profile_edge_cost_ratio(
    graph: CSRGraph,
    sample_nodes: int = 256,
    neighbors_per_node: int = 64,
    repeats: int = 3,
    seed: int = 0,
) -> float:
    """§5.1 profiling kernels: measure per-edge cost of the two access
    patterns on a fixed slice of the graph — a *random gather* microkernel
    (eRJS's pattern) vs a *streaming window* microkernel (eRVS's pattern).

    Runs on whatever backend hosts the arrays, so hardware effects (cache,
    gather throughput) are captured, exactly as the paper intends.
    """
    V, E = graph.num_nodes, graph.num_edges
    rng = np.random.default_rng(seed)
    nodes = jnp.asarray(rng.integers(0, V, size=sample_nodes), jnp.int32)
    starts = graph.indptr[nodes]
    degs = jnp.maximum(graph.indptr[nodes + 1] - starts, 1)

    offs = jnp.arange(neighbors_per_node, dtype=jnp.int32)

    @jax.jit
    def stream_kernel(h):
        pos = jnp.clip(starts[:, None] + jnp.minimum(offs[None, :], degs[:, None] - 1),
                       0, E - 1)
        return jnp.sum(h[pos])

    rand_pos = jnp.asarray(
        rng.integers(0, E, size=(sample_nodes, neighbors_per_node)), jnp.int32)

    @jax.jit
    def gather_kernel(h):
        return jnp.sum(h[rand_pos])

    def timed(fn) -> float:
        fn(graph.h).block_until_ready()  # compile + warm
        best = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn(graph.h).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return best

    t_stream = timed(stream_kernel)
    t_gather = timed(gather_kernel)
    ratio = float(t_gather / max(t_stream, 1e-9))
    # clamp to a sane band — a mis-profiled ratio must not wreck selection
    return float(np.clip(ratio, 1.0, 64.0))
