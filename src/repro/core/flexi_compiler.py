"""Flexi-Compiler (paper §4.2) — compile-time analysis of user walk logic.

The paper statically analyses the user's CUDA ``get_weight`` with
Clang/LLVM (AST + IR dataflow) and *generates source* for three artefacts:

  preprocess()        — per-node max/sum pointers for indexed arrays (h_MAX…)
  get_weight_max()    — a cheap upper bound of max_u w̃(v, u)   (feeds eRJS)
  get_weight_sum()    — an estimate of Σ_u w̃(v, u) via Eq. 12  (feeds Eq. 11)

JAX adaptation: user workloads are jax-traceable, so "the IR" is the jaxpr.
We run two abstract interpretations over it:

1. **Interval arithmetic** (the max helper): every value carries
   [lo, hi] endpoints — *runtime* scalars, so the synthesized bound function
   is itself jittable and evaluated per walker per step.  Per-edge fields
   (h, label, dist, nbr) enter as intervals (h's from the preprocessed
   per-node stats — the generated ``preprocess()``); node/step fields enter
   exact (lo == hi) because the runtime knows v, v', step.  The output's
   ``hi`` IS ``get_weight_max()``.  For factorable code like Node2Vec this
   reproduces the paper's max(w)·max(h) bound exactly; for non-factorable
   code it stays sound where the paper's pattern-matching would bail.

2. **Provenance/taint** (the flag allocator): each interval's *endpoints*
   carry the set of runtime-varying inputs they depend on.  Output taint ⊆ ∅
   ⇒ PER_KERNEL (one bound for the whole launch, e.g. unweighted Node2Vec);
   anything node/step-dependent ⇒ PER_STEP — the paper's exact flag lattice.

3. **Soundness fallback** (§7.1): any primitive outside the abstract domain
   (data-dependent loops, scatter, sort, PRNG…) ⇒ FALLBACK: the engine runs
   eRVS-only, and a warning names the offending primitive.

The sum helper implements Eq. 12 by *enumeration*: evaluate get_weight over
the small declared domains (dist ∈ {0,1,2}, label ∈ [0, L)) with h replaced
by its per-node mean, and average.  (The paper averages unique branch return
values; domain-uniform averaging is equivalent for Node2Vec and strictly
more accurate for MetaPath — recorded as a deviation in DESIGN.md.)
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, FrozenSet, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.extend import core as jcore

from repro.core.types import EDGE_FIELDS, NODE_FIELDS, EdgeCtx, Workload

# ---------------------------------------------------------------- intervals


@dataclasses.dataclass(frozen=True)
class IVal:
    """Abstract value: closed interval [lo, hi] with provenance.

    lo/hi are jnp scalars or arrays (runtime values — the synthesized bound
    function is traced through this interpreter).  ``exact`` is static:
    lo is hi *by construction*.  ``taint`` is the set of runtime-varying
    input fields the endpoints depend on (drives PER_KERNEL vs PER_STEP).
    """

    lo: Any
    hi: Any
    exact: bool
    taint: FrozenSet[str] = frozenset()

    @staticmethod
    def point(x, taint: FrozenSet[str] = frozenset()) -> "IVal":
        return IVal(x, x, True, taint)


class Unsupported(Exception):
    pass


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BoundInputs:
    """Per-walker runtime scalars available to the synthesized estimators.

    h_min/h_max/h_mean are the per-node preprocessed stats (the generated
    preprocess() of Fig. 9d); the rest are the walker's concrete state.
    """

    h_min: jax.Array
    h_max: jax.Array
    h_mean: jax.Array
    deg_cur: jax.Array
    deg_prev: jax.Array
    cur: jax.Array
    prev: jax.Array
    step: jax.Array
    # per-walker WalkProgram state (a pytree; None for stateless programs).
    # Like cur/prev/step it is CONCRETE at bound-evaluation time — the
    # runtime knows each walker's state — so its leaves enter the abstract
    # interpreter as exact points, tainted "wstate" (any dependence makes
    # the bound PER_STEP and disqualifies the static/precomp regime).
    wstate: Any = None


PER_KERNEL = "PER_KERNEL"
PER_STEP = "PER_STEP"
FALLBACK = "FALLBACK"


@dataclasses.dataclass
class CompiledWorkload:
    """The output of Flexi-Compiler for one workload."""

    workload: Workload
    flag: str
    warnings: List[str]
    # bound_fn(bi: BoundInputs) -> (lo, hi) of w̃ over the node's edges
    bound_fn: Optional[Callable[[BoundInputs], Tuple[jax.Array, jax.Array]]]
    # sum_fn(bi: BoundInputs) -> estimate of Σ_u w̃(v, u)      (Eq. 12)
    sum_fn: Optional[Callable[[BoundInputs], jax.Array]]

    @property
    def usable(self) -> bool:
        return self.flag != FALLBACK


# ------------------------------------------------------------ interpreter


def _ctx_field_order() -> List[str]:
    probe = EdgeCtx(**{f: f for f in EDGE_FIELDS + NODE_FIELDS})
    leaves, _ = jax.tree_util.tree_flatten(probe)
    return list(leaves)


def _input_ivals(bi: BoundInputs, workload: Workload) -> Dict[str, IVal]:
    """Abstract values for each EdgeCtx field (§4.2 dependency classes)."""
    f32 = lambda x: jnp.asarray(x, jnp.float32)
    i32 = lambda x: jnp.asarray(x, jnp.int32)
    if workload.weighted:
        h = IVal(f32(bi.h_min), f32(bi.h_max), False, frozenset({"h"}))
    else:
        h = IVal.point(f32(1.0))
    L = max(workload.num_labels, 1)
    return {
        "h": h,
        "label": IVal(i32(0), i32(L - 1), False),
        "dist": IVal(i32(0), i32(2), False),
        "nbr": IVal(i32(0), i32(np.iinfo(np.int32).max - 1), False),
        "deg_cur": IVal.point(i32(bi.deg_cur), frozenset({"deg_cur"})),
        "deg_prev": IVal.point(i32(bi.deg_prev), frozenset({"deg_prev"})),
        "cur": IVal.point(i32(bi.cur), frozenset({"cur"})),
        "prev": IVal.point(i32(bi.prev), frozenset({"prev"})),
        "step": IVal.point(i32(bi.step), frozenset({"step"})),
    }


def _hull(vals: List[IVal], extra_taint: FrozenSet[str] = frozenset()) -> IVal:
    lo = vals[0].lo
    hi = vals[0].hi
    for v in vals[1:]:
        lo = jnp.minimum(lo, v.lo)
        hi = jnp.maximum(hi, v.hi)
    taint = frozenset().union(*[v.taint for v in vals]) | extra_taint
    return IVal(lo, hi, False, taint)


def _mul(a: IVal, b: IVal) -> IVal:
    t = a.taint | b.taint
    if a.exact and b.exact:
        return IVal.point(a.lo * b.lo, t)
    c = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi]
    lo = jnp.minimum(jnp.minimum(c[0], c[1]), jnp.minimum(c[2], c[3]))
    hi = jnp.maximum(jnp.maximum(c[0], c[1]), jnp.maximum(c[2], c[3]))
    return IVal(lo, hi, False, t)


def _div(a: IVal, b: IVal) -> IVal:
    t = a.taint | b.taint
    if a.exact and b.exact:
        return IVal.point(a.lo / b.lo, t)
    if not b.exact:
        # Dividing by an uncertain quantity that may straddle zero cannot be
        # bounded statically — the paper's compiler has the same limitation
        # and falls back (§7.1).
        raise Unsupported("interval division by non-exact divisor")
    d = b.lo
    lo = jnp.minimum(a.lo / d, a.hi / d)
    hi = jnp.maximum(a.lo / d, a.hi / d)
    return IVal(lo, hi, False, t)


def _monotone(fn, a: IVal) -> IVal:
    if a.exact:
        return IVal.point(fn(a.lo), a.taint)
    return IVal(fn(a.lo), fn(a.hi), False, a.taint)


def _cmp(kind: str, a: IVal, b: IVal) -> IVal:
    t = a.taint | b.taint
    ops = {
        "lt": (lambda x, y: x < y),
        "le": (lambda x, y: x <= y),
        "gt": (lambda x, y: x > y),
        "ge": (lambda x, y: x >= y),
        "eq": (lambda x, y: x == y),
        "ne": (lambda x, y: x != y),
    }
    if a.exact and b.exact:
        return IVal.point(ops[kind](a.lo, b.lo), t)
    false = jnp.asarray(False)
    true = jnp.asarray(True)
    if kind in ("lt", "le"):
        strict = kind == "lt"
        certainly = (a.hi < b.lo) if strict else (a.hi <= b.lo)
        possibly = (a.lo < b.hi) if strict else (a.lo <= b.hi)
        return IVal(certainly, possibly, False, t)
    if kind in ("gt", "ge"):
        flipped = "lt" if kind == "gt" else "le"
        return _cmp(flipped, b, a)
    if kind == "eq":
        certainly = (a.lo == a.hi) & (b.lo == b.hi) & (a.lo == b.lo)
        possibly = (a.lo <= b.hi) & (b.lo <= a.hi)
        return IVal(certainly, possibly, False, t)
    if kind == "ne":
        e = _cmp("eq", a, b)
        return IVal(~e.hi, ~e.lo, False, t)
    raise Unsupported(kind)


def _select_n(pred: IVal, *cases: IVal) -> IVal:
    if pred.exact:
        lo = jax.lax.select_n(pred.lo, *[c.lo for c in cases])
        hi = jax.lax.select_n(pred.lo, *[c.hi for c in cases])
        taint = pred.taint.union(*[c.taint for c in cases])
        return IVal(lo, hi, all(c.exact for c in cases), taint)
    if len(cases) == 2:
        # refine with the predicate's own bool interval:
        # pred.lo == certainly-true, pred.hi == possibly-true
        c0, c1 = cases
        hull = _hull([c0, c1], pred.taint)
        lo = jnp.where(pred.lo, c1.lo, jnp.where(~pred.hi, c0.lo, hull.lo))
        hi = jnp.where(pred.lo, c1.hi, jnp.where(~pred.hi, c0.hi, hull.hi))
        return IVal(lo, hi, False, hull.taint)
    return _hull(list(cases), pred.taint)


def _integer_pow(a: IVal, n: int) -> IVal:
    if a.exact:
        return IVal.point(a.lo**n, a.taint)
    if n % 2 == 1:
        return IVal(a.lo**n, a.hi**n, False, a.taint)
    c_lo, c_hi = a.lo**n, a.hi**n
    straddles = (a.lo <= 0) & (a.hi >= 0)
    lo = jnp.where(straddles, jnp.zeros_like(c_lo), jnp.minimum(c_lo, c_hi))
    return IVal(lo, jnp.maximum(c_lo, c_hi), False, a.taint)


_MONOTONE_UNARY = {
    "exp": jnp.exp,
    "log": jnp.log,
    "sqrt": jnp.sqrt,
    "tanh": jnp.tanh,
    "logistic": jax.nn.sigmoid,
    "floor": jnp.floor,
    "ceil": jnp.ceil,
    "round_nearest_even": jnp.round,
    "sign": jnp.sign,
    "erf": jax.scipy.special.erf,
    "log1p": jnp.log1p,
    "expm1": jnp.expm1,
}

_PASSTHROUGH = {"stop_gradient", "copy"}
# shape-changing but value-preserving: apply the primitive to both endpoints
_SHAPE_OPS = {"squeeze", "expand_dims", "transpose", "rev"}


def _eval_eqn(eqn, read: Callable[[Any], IVal]) -> List[IVal]:
    prim = eqn.primitive.name
    ins = [read(v) for v in eqn.invars]
    p = eqn.params

    if prim == "add":
        a, b = ins
        ex = a.exact and b.exact
        return [IVal(a.lo + b.lo, a.hi + b.hi, ex, a.taint | b.taint)]
    if prim == "sub":
        a, b = ins
        ex = a.exact and b.exact
        return [IVal(a.lo - b.hi, a.hi - b.lo, ex, a.taint | b.taint)]
    if prim == "mul":
        return [_mul(*ins)]
    if prim == "div":
        return [_div(*ins)]
    if prim == "neg":
        (a,) = ins
        return [IVal(-a.hi, -a.lo, a.exact, a.taint)]
    if prim == "abs":
        (a,) = ins
        if a.exact:
            return [IVal.point(jnp.abs(a.lo), a.taint)]
        straddles = (a.lo <= 0) & (a.hi >= 0)
        lo = jnp.where(straddles, jnp.zeros_like(a.lo), jnp.minimum(jnp.abs(a.lo), jnp.abs(a.hi)))
        hi = jnp.maximum(jnp.abs(a.lo), jnp.abs(a.hi))
        return [IVal(lo, hi, False, a.taint)]
    if prim == "max":
        a, b = ins
        return [IVal(jnp.maximum(a.lo, b.lo), jnp.maximum(a.hi, b.hi),
                     a.exact and b.exact, a.taint | b.taint)]
    if prim == "min":
        a, b = ins
        return [IVal(jnp.minimum(a.lo, b.lo), jnp.minimum(a.hi, b.hi),
                     a.exact and b.exact, a.taint | b.taint)]
    if prim in _MONOTONE_UNARY:
        return [_monotone(_MONOTONE_UNARY[prim], ins[0])]
    if prim == "integer_pow":
        return [_integer_pow(ins[0], p["y"])]
    if prim == "pow":
        a, b = ins
        if a.exact and b.exact:
            return [IVal.point(a.lo**b.lo, a.taint | b.taint)]
        if b.exact:  # monotone in base for base ≥ 0 (walk weights are)
            return [IVal(ins[0].lo ** b.lo, ins[0].hi ** b.lo, False,
                         a.taint | b.taint)]
        raise Unsupported("pow with non-exact exponent")
    if prim in ("lt", "le", "gt", "ge", "eq", "ne"):
        return [_cmp(prim, *ins)]
    if prim == "and":
        a, b = ins
        return [IVal(a.lo & b.lo, a.hi & b.hi, a.exact and b.exact, a.taint | b.taint)]
    if prim == "or":
        a, b = ins
        return [IVal(a.lo | b.lo, a.hi | b.hi, a.exact and b.exact, a.taint | b.taint)]
    if prim == "not":
        (a,) = ins
        return [IVal(~a.hi, ~a.lo, a.exact, a.taint)]
    if prim == "xor":
        a, b = ins
        if a.exact and b.exact:
            return [IVal.point(a.lo ^ b.lo, a.taint | b.taint)]
        return [IVal(jnp.asarray(False), jnp.asarray(True), False, a.taint | b.taint)]
    if prim == "select_n":
        return [_select_n(ins[0], *ins[1:])]
    if prim == "convert_element_type":
        (a,) = ins
        to = p["new_dtype"]
        return [IVal(jnp.asarray(a.lo, to), jnp.asarray(a.hi, to), a.exact, a.taint)]
    if prim in _PASSTHROUGH:
        (a,) = ins
        return [a]
    if prim in _SHAPE_OPS:
        (a,) = ins
        bind = lambda x: eqn.primitive.bind(x, **p)
        return [IVal(bind(a.lo), bind(a.hi), a.exact, a.taint)]
    if prim == "reshape" or prim == "broadcast_in_dim":
        (a,) = ins
        shape = p.get("new_sizes", p.get("shape"))
        dims = p.get("dimensions", p.get("broadcast_dimensions"))
        if prim == "reshape":
            f = lambda x: jax.lax.reshape(x, shape, dims)
        else:
            f = lambda x: jax.lax.broadcast_in_dim(x, shape, dims)
        return [IVal(f(a.lo), f(a.hi), a.exact, a.taint)]
    if prim == "rem":
        a, b = ins
        if a.exact and b.exact:
            return [IVal.point(jax.lax.rem(a.lo, b.lo), a.taint | b.taint)]
        if b.exact:
            # lhs nonneg assumed (walk steps / labels); result ∈ [0, |b|-1]
            one = jnp.ones_like(b.lo)
            return [IVal(jnp.zeros_like(b.lo), jnp.abs(b.lo) - one, False,
                         a.taint | b.taint)]
        raise Unsupported("rem by non-exact divisor")
    if prim == "clamp":
        lo_b, x, hi_b = ins
        if not (lo_b.exact and hi_b.exact):
            raise Unsupported("clamp with non-exact bounds")
        f = lambda v: jnp.clip(v, lo_b.lo, hi_b.lo)
        return [IVal(f(x.lo), f(x.hi), x.exact, x.taint | lo_b.taint | hi_b.taint)]
    if prim in ("gather", "dynamic_slice"):
        op = ins[0]
        idxs = ins[1:]
        if all(i.exact for i in idxs):
            bind = lambda o: eqn.primitive.bind(o, *[i.lo for i in idxs], **p)
            taint = op.taint.union(*[i.taint for i in idxs]) if idxs else op.taint
            return [IVal(bind(op.lo), bind(op.hi), op.exact, taint)]
        # uncertain index ⇒ hull over the whole operand
        taint = op.taint.union(*[i.taint for i in idxs])
        shape = eqn.outvars[0].aval.shape
        lo = jnp.broadcast_to(jnp.min(op.lo), shape)
        hi = jnp.broadcast_to(jnp.max(op.hi), shape)
        return [IVal(lo, hi, False, taint)]
    if prim == "reduce_min":
        (a,) = ins
        f = lambda x: jnp.min(x, axis=tuple(p["axes"]))
        return [IVal(f(a.lo), f(a.hi), a.exact, a.taint)]
    if prim == "reduce_max":
        (a,) = ins
        f = lambda x: jnp.max(x, axis=tuple(p["axes"]))
        return [IVal(f(a.lo), f(a.hi), a.exact, a.taint)]
    if prim == "reduce_sum":
        (a,) = ins
        f = lambda x: jnp.sum(x, axis=tuple(p["axes"]))
        return [IVal(f(a.lo), f(a.hi), a.exact, a.taint)]
    if prim == "reduce_or":
        (a,) = ins
        f = lambda x: jnp.any(x, axis=tuple(p["axes"]))
        return [IVal(f(a.lo), f(a.hi), a.exact, a.taint)]
    if prim == "reduce_and":
        (a,) = ins
        f = lambda x: jnp.all(x, axis=tuple(p["axes"]))
        return [IVal(f(a.lo), f(a.hi), a.exact, a.taint)]
    if prim in ("jit", "pjit", "closed_call", "custom_jvp_call", "custom_vjp_call",
                "custom_jvp_call_jaxpr", "remat", "checkpoint"):
        sub = p.get("jaxpr", p.get("call_jaxpr"))
        if sub is None:
            raise Unsupported(prim)
        closed = sub if isinstance(sub, jcore.ClosedJaxpr) else jcore.ClosedJaxpr(sub, [])
        return _interpret(closed, ins)
    raise Unsupported(prim)


def _interpret(closed: jcore.ClosedJaxpr, in_ivals: List[IVal]) -> List[IVal]:
    jaxpr = closed.jaxpr
    if len(in_ivals) != len(jaxpr.invars):
        # zip would silently truncate; fail loudly instead (typically a
        # wstate pytree whose structure differs from the trace template)
        raise Unsupported(
            f"input arity mismatch: {len(in_ivals)} abstract inputs for "
            f"{len(jaxpr.invars)} jaxpr inputs (wstate missing or "
            f"mis-structured?)")
    env: Dict[Any, IVal] = {}

    def read(v) -> IVal:
        if isinstance(v, jcore.Literal):
            return IVal.point(jnp.asarray(v.val))
        return env[v]

    for var, val in zip(jaxpr.constvars, closed.consts):
        env[var] = IVal.point(jnp.asarray(val))
    for var, val in zip(jaxpr.invars, in_ivals):
        env[var] = val
    for eqn in jaxpr.eqns:
        outs = _eval_eqn(eqn, read)
        for var, val in zip(eqn.outvars, outs):
            env[var] = val
    return [read(v) for v in jaxpr.outvars]


# ------------------------------------------------------------- public API


def _wstate_ivals(wstate) -> List[IVal]:
    """Abstract values for the program's per-walker state leaves.

    ``wstate`` is concrete at bound-evaluation time (the runtime holds
    every walker's state, like ``cur``/``prev``/``step``), so each leaf
    enters as an exact point — tainted ``"wstate"`` so dependence shows up
    in the flag lattice and the static-regime proof.  Array leaves indexed
    by per-edge fields (e.g. a visited set gathered at ``ctx.nbr``) flow
    through the existing uncertain-index gather rule: the hull over the
    leaf's actual values, which stays both sound and tight.
    """
    return [IVal.point(jnp.asarray(leaf), frozenset({"wstate"}))
            for leaf in jax.tree_util.tree_leaves(wstate)]


def analyze(workload: Workload, max_enum_labels: int = 8) -> CompiledWorkload:
    """Run Flexi-Compiler on a walk program.  Never raises: analysis
    failure returns flag=FALLBACK (the paper's eRVS-only safe mode) with
    warnings.  Accepts both :class:`~repro.core.types.WalkProgram` and the
    deprecated :class:`~repro.core.types.Workload` (whose ``edge_weight``
    drops the empty ``wstate`` — identical jaxpr, identical analysis).
    """
    params = workload.params()
    warnings: List[str] = []
    order = _ctx_field_order()

    template = EdgeCtx(
        h=jnp.float32(1.0), label=jnp.int32(0), dist=jnp.int32(1),
        nbr=jnp.int32(0), deg_cur=jnp.int32(1), deg_prev=jnp.int32(1),
        cur=jnp.int32(0), prev=jnp.int32(0), step=jnp.int32(0),
    )
    try:
        template_ws = workload.wstate_template()
        closed = jax.make_jaxpr(
            lambda c, ws: workload.edge_weight(c, params, ws)
        )(template, template_ws)
    except Exception as e:  # untraceable user code
        return CompiledWorkload(workload, FALLBACK,
                                [f"get_weight not traceable: {e!r}"], None, None)

    # --- probe the abstract interpreter once to decide flag/fallback -----
    probe_bi = BoundInputs(*(jnp.float32(1.0),) * 3, *(jnp.int32(1),) * 5,
                           wstate=template_ws)

    def bound_fn(bi: BoundInputs) -> Tuple[jax.Array, jax.Array]:
        field_ivals = _input_ivals(bi, workload)
        ins = [field_ivals[name] for name in order] + _wstate_ivals(bi.wstate)
        (out,) = _interpret(closed, ins)
        return (jnp.maximum(out.lo, 0.0).astype(jnp.float32),
                jnp.maximum(out.hi, 0.0).astype(jnp.float32))

    try:
        field_ivals = _input_ivals(probe_bi, workload)
        (probe_out,) = _interpret(
            closed, [field_ivals[n] for n in order]
            + _wstate_ivals(template_ws))
    except Unsupported as e:
        return CompiledWorkload(
            workload, FALLBACK,
            [f"unsupported primitive in get_weight: {e} — eRVS-only mode"],
            None, None)

    flag = PER_STEP if probe_out.taint else PER_KERNEL

    # --- sum estimator (Eq. 12): enumerate small domains, average --------
    L = min(max(workload.num_labels, 1), max_enum_labels)
    dists = (0, 1, 2) if workload.needs_dist else (1,)
    labels = tuple(range(L)) if workload.needs_labels else (0,)

    def sum_fn(bi: BoundInputs) -> jax.Array:
        h_val = bi.h_mean if workload.weighted else jnp.float32(1.0)
        acc = jnp.float32(0.0)
        cnt = 0
        for d, l in itertools.product(dists, labels):
            ctx = EdgeCtx(
                h=jnp.asarray(h_val, jnp.float32), label=jnp.int32(l),
                dist=jnp.int32(d), nbr=jnp.int32(0),
                deg_cur=jnp.asarray(bi.deg_cur, jnp.int32),
                deg_prev=jnp.asarray(bi.deg_prev, jnp.int32),
                cur=jnp.asarray(bi.cur, jnp.int32),
                prev=jnp.asarray(bi.prev, jnp.int32),
                step=jnp.asarray(bi.step, jnp.int32),
            )
            # the walker's actual state feeds the estimate (an Eq. 12-style
            # average, not a bound — exactness is not required here)
            acc = acc + jnp.maximum(
                workload.edge_weight(ctx, params, bi.wstate), 0.0)
            cnt += 1
        mean_w = acc / cnt
        return mean_w * jnp.maximum(bi.deg_cur, 0).astype(jnp.float32)

    return CompiledWorkload(workload, flag, warnings, bound_fn, sum_fn)


# ------------------------------------------------- static-regime analysis

# Inputs that vary with *walk state* (they change every step / every
# walker): the state-class EdgeCtx fields plus the program's own per-walker
# ``wstate``.  A get_weight whose output provably ignores all of them
# depends only on (edge data, current node) — so the transition
# distribution of a node is a constant of the graph and per-node ITS/alias
# tables can be built ONCE (the precomp regime of core/precomp.py; C-SAW's
# static case).
STATE_FIELDS = frozenset({"dist", "prev", "deg_prev", "step", "wstate"})


def static_taint(workload: Workload) -> Optional[FrozenSet[str]]:
    """Dependence set of ``get_weight``'s output over ALL EdgeCtx fields.

    Runs the provenance half of the abstract interpreter with every field
    entered as an *exact probe point tainted by its own name* (unlike the
    bound analysis, which only taints runtime-varying inputs).  Exact points
    keep every primitive inside the abstract domain, so this succeeds for
    any traceable get_weight; the value endpoints are meaningless, only the
    propagated taint is read.  Returns None when the workload cannot be
    traced or hits an unsupported primitive (conservative: treat as
    state-dependent).
    """
    params = workload.params()
    template = EdgeCtx(
        h=jnp.float32(1.0), label=jnp.int32(0), dist=jnp.int32(1),
        nbr=jnp.int32(0), deg_cur=jnp.int32(1), deg_prev=jnp.int32(1),
        cur=jnp.int32(0), prev=jnp.int32(0), step=jnp.int32(0),
    )
    try:
        template_ws = workload.wstate_template()
        closed = jax.make_jaxpr(
            lambda c, ws: workload.edge_weight(c, params, ws)
        )(template, template_ws)
    except Exception:
        return None
    probe = {
        "h": jnp.float32(1.0), "label": jnp.int32(0), "dist": jnp.int32(1),
        "nbr": jnp.int32(0), "deg_cur": jnp.int32(1),
        "deg_prev": jnp.int32(1), "cur": jnp.int32(0),
        "prev": jnp.int32(0), "step": jnp.int32(0),
    }
    ins = [IVal.point(probe[name], frozenset({name}))
           for name in _ctx_field_order()] + _wstate_ivals(template_ws)
    try:
        (out,) = _interpret(closed, ins)
    except Unsupported:
        return None
    return out.taint


def is_static(workload: Workload) -> bool:
    """True iff ``get_weight`` provably ignores the walk state.

    This is the gate of the precomp regime: a static workload's per-node
    transition distribution never changes, so ``core/precomp.py`` may bake
    it into ITS/alias tables at engine construction and samplers reduce to
    an O(log d) binary search / O(1) alias pick per step.
    """
    taint = static_taint(workload)
    return taint is not None and not (taint & STATE_FIELDS)


# ------------------------------------------------------- fusable analysis

# EdgeCtx fields the mega-step kernel cannot materialise per candidate
# edge: ``dist`` needs a binary search of prev's row per neighbour and
# ``label`` an extra gather stream — both stay on the staged path.  The
# kernel's tile builder substitutes the same neutral placeholders the
# transition-ctx contract documents (dist=1 in weight tiles, label=0), so
# a weight whose output provably ignores both fields evaluates to the
# SAME value in-kernel as staged — that proof is this gate.
FUSE_EDGE_EXCLUDED = frozenset({"dist", "label"})

# For the rejection regime the kernel wants the compiled upper bound as a
# per-NODE array baked before launch (one ``bound_fn`` eval per node, at
# placeholder deg_prev/prev/step/wstate).  Sound iff the bound provably
# ignores everything that is not node-local.
FUSE_BOUND_STATE = frozenset(
    {"dist", "label", "deg_prev", "prev", "step", "wstate"})


@dataclasses.dataclass(frozen=True)
class FuseReport:
    """Whether a walk program can be staged into the mega-step kernel.

    ``weight_fusable``   — ``get_weight`` is taint-analyzable and provably
                           ignores ``dist``/``label`` (the fields the
                           kernel cannot build per edge), so the in-kernel
                           tile/edge contexts reproduce the staged weight
                           values bit for bit.
    ``hooks_fusable``    — ``on_step``/``should_stop`` trace on the scalar
                           transition ctx and preserve the wstate
                           structure (the PR-4 "wstate fast path": state
                           updates run inside the kernel's step loop).
    ``bound_node_local`` — the compiled rejection bound depends only on
                           node-local inputs, so eRJS can consume a
                           per-node baked bound array instead of
                           re-deriving it per walker per step.

    A sampler needs at least ``weight_fusable and hooks_fusable``
    (``fusable``); the rejection regime additionally needs
    ``bound_node_local``.  Anything short of that falls back to the
    staged scan — mirroring the precomp gating, a miss is never unsound.
    """
    weight_fusable: bool
    hooks_fusable: bool
    bound_node_local: bool
    reasons: Tuple[str, ...] = ()

    @property
    def fusable(self) -> bool:
        return self.weight_fusable and self.hooks_fusable


def fuse_report(workload: Workload) -> FuseReport:
    """Decide per program what the mega-step kernel may stage in-kernel.

    Like :func:`analyze`, never raises: an untraceable or unsupported
    program simply reports non-fusable with the reason strings, and the
    engine keeps the staged scan.
    """
    reasons: List[str] = []
    taint = static_taint(workload)
    if taint is None:
        weight_fusable = False
        bound_node_local = False
        reasons.append("get_weight not analyzable (trace failed or "
                       "unsupported primitive) — staged fallback")
    else:
        bad = sorted(taint & FUSE_EDGE_EXCLUDED)
        flagged = [f for f, need in
                   [("dist", workload.needs_dist),
                    ("label", workload.needs_labels)] if need]
        weight_fusable = not bad and not flagged
        if bad:
            reasons.append(f"get_weight depends on {', '.join(bad)} — the "
                           f"kernel cannot build these per candidate edge")
        elif flagged:
            reasons.append(f"program requests {', '.join(flagged)} payloads "
                           f"the kernel does not materialise")
        bound_node_local = not (taint & FUSE_BOUND_STATE)
        if not bound_node_local:
            reasons.append(
                f"bound depends on non-node-local inputs "
                f"{sorted(taint & FUSE_BOUND_STATE)} — no baked per-node "
                f"bound; rejection stays staged")

    hooks_fusable = True
    if workload.has_hooks:
        params = workload.params()
        template_ws = workload.wstate_template()
        tctx = EdgeCtx(
            h=jnp.float32(1.0), label=jnp.int32(-1), dist=jnp.int32(-1),
            nbr=jnp.int32(0), deg_cur=jnp.int32(1), deg_prev=jnp.int32(0),
            cur=jnp.int32(0), prev=jnp.int32(-1), step=jnp.int32(0),
        )
        if workload.on_step is not None:
            try:
                out = jax.eval_shape(
                    lambda: workload.on_step(tctx, params, template_ws))
                want = jax.eval_shape(lambda: template_ws)
                if (jax.tree_util.tree_structure(out)
                        != jax.tree_util.tree_structure(want)):
                    raise TypeError("on_step changes the wstate structure")
                for o, w in zip(jax.tree_util.tree_leaves(out),
                                jax.tree_util.tree_leaves(want)):
                    if o.shape != w.shape or o.dtype != w.dtype:
                        raise TypeError(
                            f"on_step leaf {o.shape}/{o.dtype} != "
                            f"{w.shape}/{w.dtype}")
            except Exception as e:
                hooks_fusable = False
                reasons.append(f"on_step not stageable: {e!r}")
        if workload.should_stop is not None:
            try:
                out = jax.eval_shape(
                    lambda: workload.should_stop(tctx, params, template_ws))
                if jnp.shape(out) != ():
                    raise TypeError(f"should_stop returns shape "
                                    f"{jnp.shape(out)}, want a scalar")
            except Exception as e:
                hooks_fusable = False
                reasons.append(f"should_stop not stageable: {e!r}")

    return FuseReport(weight_fusable=weight_fusable,
                      hooks_fusable=hooks_fusable,
                      bound_node_local=bound_node_local,
                      reasons=tuple(reasons))
