"""Batched edge-context construction shared by all sampling kernels.

Builds EdgeCtx blocks of shape [W, T] (walkers × neighbor tile) from CSR,
computing only the fields the workload declared it needs (dist is a binary
search per edge; labels are a gather — both skipped when unused).

Rows are read through the ``row_starts`` / ``row_degs`` accessor protocol
shared by ``CSRGraph`` and ``graphs.delta.OverlayGraph``, so every
sampler built on these helpers serves delta-overlay (structurally
mutated) graphs unchanged.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import EdgeCtx, Workload
from repro.graphs.csr import CSRGraph, has_edge


def degrees_of(graph: CSRGraph, v: jax.Array) -> jax.Array:
    vs = jnp.maximum(v, 0)
    d = graph.row_degs(vs)
    return jnp.where(v >= 0, d, 0).astype(jnp.int32)


def tile_ctx(
    graph: CSRGraph,
    workload: Workload,
    cur: jax.Array,  # [W]
    prev: jax.Array,  # [W]
    step: jax.Array,  # [W]
    tile_start: jax.Array,  # [] or [W] — offset within each row
    tile: int,
) -> Tuple[EdgeCtx, jax.Array]:
    """Return (ctx[W, T], mask[W, T]) for neighbours [tile_start, tile_start+T)."""
    W = cur.shape[0]
    start = graph.row_starts(jnp.maximum(cur, 0))
    deg_cur = degrees_of(graph, cur)
    deg_prev = degrees_of(graph, prev)
    offs = tile_start[..., None] + jnp.arange(tile, dtype=jnp.int32)[None, :]
    mask = offs < deg_cur[:, None]
    pos = jnp.clip(start[:, None] + offs, 0, graph.num_edges - 1)
    nbr = jnp.where(mask, graph.indices[pos], -1)
    h = jnp.where(mask, graph.h[pos], 0.0) if workload.weighted else jnp.where(mask, 1.0, 0.0)
    if workload.needs_labels:
        label = jnp.where(mask, graph.labels[pos], -1)
    else:
        label = jnp.zeros_like(nbr)
    if workload.needs_dist:
        dist = jax.vmap(
            lambda p, us: jax.vmap(lambda u: _dist_code(graph, p, u))(us)
        )(prev, nbr)
    else:
        dist = jnp.ones_like(nbr)
    ctx = EdgeCtx(
        h=h,
        label=label,
        dist=dist,
        nbr=nbr,
        deg_cur=jnp.broadcast_to(deg_cur[:, None], (W, tile)),
        deg_prev=jnp.broadcast_to(deg_prev[:, None], (W, tile)),
        cur=jnp.broadcast_to(cur[:, None], (W, tile)),
        prev=jnp.broadcast_to(prev[:, None], (W, tile)),
        step=jnp.broadcast_to(step[:, None], (W, tile)),
    )
    return ctx, mask


def single_edge_ctx(
    graph: CSRGraph,
    workload: Workload,
    cur: jax.Array,  # [W]
    prev: jax.Array,  # [W]
    step: jax.Array,  # [W]
    offset: jax.Array,  # [W] — neighbour offset within the row (one trial)
) -> Tuple[EdgeCtx, jax.Array]:
    """EdgeCtx for exactly one candidate edge per walker (rejection trials)."""
    deg_cur = degrees_of(graph, cur)
    deg_prev = degrees_of(graph, prev)
    valid = offset < deg_cur
    pos = jnp.clip(graph.row_starts(jnp.maximum(cur, 0)) + offset, 0,
                   graph.num_edges - 1)
    nbr = jnp.where(valid, graph.indices[pos], -1)
    h = jnp.where(valid, graph.h[pos], 0.0) if workload.weighted else jnp.where(valid, 1.0, 0.0)
    label = jnp.where(valid, graph.labels[pos], -1) if workload.needs_labels else jnp.zeros_like(nbr)
    if workload.needs_dist:
        dist = jax.vmap(lambda p, u: _dist_code(graph, p, u))(prev, nbr)
    else:
        dist = jnp.ones_like(nbr)
    ctx = EdgeCtx(
        h=h, label=label, dist=dist, nbr=nbr,
        deg_cur=deg_cur, deg_prev=deg_prev, cur=cur, prev=prev, step=step,
    )
    return ctx, valid


def _dist_code(graph: CSRGraph, v_prev: jax.Array, u: jax.Array) -> jax.Array:
    from repro.graphs.csr import dist_code

    return dist_code(graph, v_prev, jnp.maximum(u, 0))


def eval_weights(workload: Workload, params, ctx: EdgeCtx, mask: jax.Array,
                 wstate=None) -> jax.Array:
    """w̃ for a ctx block; masked lanes get 0 (never sampled).

    ``wstate`` is the per-walker program state (leaves lead with the
    walker dim, matching ``ctx``'s OUTERMOST dim); it is broadcast over
    the neighbour-tile dims — every candidate edge of a walker sees the
    same state.  ``None`` for stateless programs.
    """
    flat_fn = workload.edge_weight
    # inner dims (neighbour tiles): map ctx only, broadcast wstate
    for _ in range(max(ctx.h.ndim - 1, 0)):
        flat_fn = jax.vmap(flat_fn, in_axes=(0, None, None))
    # outermost dim (walkers): map ctx AND wstate together
    if ctx.h.ndim:
        flat_fn = jax.vmap(flat_fn, in_axes=(0, None, 0))
    w = flat_fn(ctx, params, wstate)
    return jnp.where(mask, jnp.maximum(w, 0.0), 0.0)
