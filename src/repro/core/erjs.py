"""eRJS — enhanced rejection sampling (paper §3.3).

The baseline RJS (NextDoor) pays a full pass over the row to find
max(w̃) before sampling.  eRJS replaces it with an *upper bound* c ≥ max(w̃)
computed from workload structure (Flexi-Compiler's get_weight_max), which
Eqs. 5–8 prove leaves the accepted distribution exactly p — only the
acceptance *rate* (1/c-ish) degrades if the bound is loose.

TPU adaptation: per-walker retry loops are vectorised across the batch —
each round draws K candidate offsets per walker, evaluates w̃ on those K
edges only (K gathers, not a row scan), accepts the first passing trial,
and a while_loop re-runs while any walker is unresolved, up to R_max
rounds.  Unresolved walkers are flagged for the reservoir-side fallback
(the paper's §7.1 safe mode doubles as straggler mitigation here: no
data-dependent loop runs past R_max).

Engine integration: ``samplers.ERJSRejection`` wraps this function as the
rejection half of any ``PartitionedSampler`` pair — the fallback mask it
returns is what moves unresolved lanes into the reservoir partition.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ctxutil import degrees_of, single_edge_ctx
from repro.core.types import Workload
from repro.graphs.csr import CSRGraph


@partial(jax.jit, static_argnames=("workload", "params", "trials_per_round", "max_rounds"))
def erjs_step(
    graph: CSRGraph,
    workload: Workload,
    params,
    cur: jax.Array,
    prev: jax.Array,
    step: jax.Array,
    rng: jax.Array,  # [W, 2]
    bound: jax.Array,  # [W] — c ≥ max_i w̃_i (from Flexi-Compiler or max-reduce)
    trials_per_round: int = 8,
    max_rounds: int = 16,
    active: Optional[jax.Array] = None,
    wstate=None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (next [W], needs_fallback [W] bool, rounds_used [] int32).

    next = -2 for inactive walkers, -1 for zero-degree rows.
    needs_fallback marks walkers unresolved after max_rounds (engine runs
    eRVS for them — statistically fine: the accepted-so-far distribution is
    p regardless of when we stop proposing).
    """
    W = cur.shape[0]
    K = trials_per_round
    if active is None:
        active = jnp.ones((W,), bool)
    deg = degrees_of(graph, cur)
    feasible = active & (deg > 0) & (bound > 0)

    def round_body(state):
        r, done, chosen, _ = state

        def one_trial(k, inner):
            done_i, chosen_i = inner
            u_idx = _fold_uniform(rng, r * (2 * K) + 2 * k, W)
            u_acc = _fold_uniform(rng, r * (2 * K) + 2 * k + 1, W)
            # propose X ~ Uniform(N(v)) — the uniform proposal q of Eq. 5
            offset = jnp.minimum((u_idx * deg.astype(jnp.float32)).astype(jnp.int32),
                                 jnp.maximum(deg - 1, 0))
            ctx, valid = single_edge_ctx(graph, workload, cur, prev, step, offset)
            flat = jax.vmap(workload.edge_weight,
                            in_axes=(0, None, 0))(ctx, params, wstate)
            w = jnp.where(valid, jnp.maximum(flat, 0.0), 0.0)
            # accept iff u ≤ w̃(X)/c   (Eq. 5's U ≤ p(X)/(c·q(X)) with the
            # degree factors cancelled — c here bounds the raw weight)
            accept = feasible & (~done_i) & (u_acc * bound <= w) & (w > 0)
            chosen_i = jnp.where(accept, ctx.nbr, chosen_i)
            return (done_i | accept, chosen_i)

        done, chosen = jax.lax.fori_loop(0, K, one_trial, (done, chosen))
        return (r + 1, done, chosen, jnp.any(feasible & ~done))

    def cond(state):
        r, _, _, unresolved = state
        return jnp.logical_and(r < max_rounds, unresolved)

    r0 = jnp.int32(0)
    done0 = ~feasible  # infeasible walkers are trivially "done"
    chosen0 = jnp.full((W,), -1, jnp.int32)
    r, done, chosen, _ = jax.lax.while_loop(
        cond, round_body, (r0, done0, chosen0, jnp.any(feasible))
    )
    needs_fallback = feasible & ~done
    nxt = jnp.where(active, chosen, -2)
    return nxt, needs_fallback, r


def _fold_uniform(rng: jax.Array, counter, W: int) -> jax.Array:
    keys = jax.vmap(lambda k: jax.random.fold_in(k, counter))(rng)
    return jax.vmap(lambda k: jax.random.uniform(
        k, (), dtype=jnp.float32, minval=1e-12, maxval=1.0))(keys)
