"""Shared types of the FlexiWalker core: edge contexts, workloads, walker state.

The user-facing programming model mirrors the paper's gather-move-update
API (§4.2): a workload supplies

  * ``init()``        → hyperparameters (a pytree of scalars / small arrays),
  * ``get_weight(ctx, params)`` → the transition weight w̃ for ONE edge,
  * (optional) ``update``      → per-query state update after a step.

``get_weight`` must be jax-traceable on scalar inputs; the engine vmaps it
over [walkers × neighbor-tile] blocks, and Flexi-Compiler abstract-interprets
its jaxpr to synthesise the max/sum estimators (see flexi_compiler.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeCtx:
    """Context for one candidate edge (v_cur → nbr).  All scalars.

    Fields split into two provenance classes, which is what the compiler's
    flag allocator reasons about:

    per-edge (abstract at compile time, indexed at runtime):
        h      — edge property weight h(v, u)
        label  — edge label (MetaPath)
        dist   — Node2Vec distance code dist(v', u) ∈ {0, 1, 2}
        nbr    — neighbour node id u
    per-node / per-step (concrete scalars at bound-evaluation time):
        deg_cur, deg_prev — d(v), d(v')
        cur, prev         — node ids v, v'
        step              — walk step index
    """

    h: jax.Array
    label: jax.Array
    dist: jax.Array
    nbr: jax.Array
    deg_cur: jax.Array
    deg_prev: jax.Array
    cur: jax.Array
    prev: jax.Array
    step: jax.Array


# Field taxonomy used by Flexi-Compiler (paper Fig. 9c flag allocator).
EDGE_FIELDS = ("h", "label", "dist", "nbr")
NODE_FIELDS = ("deg_cur", "deg_prev", "cur", "prev", "step")
# Enumerable per-edge fields and their domains (for the Eq. 12 sum helper).
ENUM_DOMAINS = {"dist": (0, 1, 2)}


@dataclasses.dataclass(frozen=True)
class Workload:
    """A dynamic random walk workload (paper §2.1)."""

    name: str
    init: Callable[[], Any]
    get_weight: Callable[[EdgeCtx, Any], jax.Array]
    needs_dist: bool = False  # dist(v',u) is expensive; only compute on demand
    needs_labels: bool = False
    num_labels: int = 1
    weighted: bool = True  # whether ctx.h participates (paper's (un)weighted)
    walk_len: int = 80  # paper default: 80 steps (5 for MetaPath)

    def params(self):
        return self.init()


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class WalkerState:
    """State of a batch of W walkers (a pytree; leading dim W)."""

    cur: jax.Array  # [W] int32 current node
    prev: jax.Array  # [W] int32 previous node (-1 before the first step)
    step: jax.Array  # [W] int32 step counter
    alive: jax.Array  # [W] bool
    rng: jax.Array  # [W, 2] uint32 per-walker fold of the base key

    @staticmethod
    def create(starts: jax.Array, key: jax.Array) -> "WalkerState":
        W = starts.shape[0]
        keys = jax.random.split(key, W)
        return WalkerState(
            cur=starts.astype(jnp.int32),
            prev=jnp.full((W,), -1, jnp.int32),
            alive=jnp.ones((W,), bool),
            step=jnp.zeros((W,), jnp.int32),
            rng=keys,
        )


@dataclasses.dataclass
class StepStats:
    """Telemetry of one engine step (feeds Fig. 14-style analyses)."""

    frac_rjs: float = 0.0
    rng_draws: int = 0
    weight_reads: int = 0
    rjs_fallbacks: int = 0
