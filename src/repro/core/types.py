"""Shared types of the FlexiWalker core: edge contexts, walk programs,
walker state.

The user-facing programming model is the composable **walk program**
(the paper's gather-move-update API of §4.2, extended to per-walker
state): a :class:`WalkProgram` supplies

  * ``init()``              → hyperparameters (pytree of scalars/arrays),
  * ``init_walker_state(q)`` → arbitrary per-walker state pytree (or None),
  * ``get_weight(ctx, params, wstate)`` → transition weight w̃ of ONE edge,
  * ``on_step(ctx, params, wstate) → wstate``   (post-selection update),
  * ``should_stop(ctx, params, wstate) → bool`` (early termination).

``get_weight`` must be jax-traceable on scalar inputs; the engine vmaps it
over [walkers × neighbor-tile] blocks, and Flexi-Compiler abstract-interprets
its jaxpr to synthesise the max/sum estimators (see flexi_compiler.py).
:class:`Workload` — the original bare ``get_weight(ctx, params)`` protocol
— survives as a deprecated thin subclass; :func:`from_workload` is the
zero-cost adapter (the wrapped jaxpr is identical, so paths and telemetry
are bit-identical through it).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeCtx:
    """Context for one candidate edge (v_cur → nbr).  All scalars.

    Fields split into two provenance classes, which is what the compiler's
    flag allocator reasons about:

    per-edge (abstract at compile time, indexed at runtime):
        h      — edge property weight h(v, u)
        label  — edge label (MetaPath)
        dist   — Node2Vec distance code dist(v', u) ∈ {0, 1, 2}
        nbr    — neighbour node id u
    per-node / per-step (concrete scalars at bound-evaluation time):
        deg_cur, deg_prev — d(v), d(v')
        cur, prev         — node ids v, v'
        step              — walk step index
    """

    h: jax.Array
    label: jax.Array
    dist: jax.Array
    nbr: jax.Array
    deg_cur: jax.Array
    deg_prev: jax.Array
    cur: jax.Array
    prev: jax.Array
    step: jax.Array


# Field taxonomy used by Flexi-Compiler (paper Fig. 9c flag allocator).
EDGE_FIELDS = ("h", "label", "dist", "nbr")
NODE_FIELDS = ("deg_cur", "deg_prev", "cur", "prev", "step")
# Enumerable per-edge fields and their domains (for the Eq. 12 sum helper).
ENUM_DOMAINS = {"dist": (0, 1, 2)}


def _stateless(query):
    """Default ``init_walker_state``: the program carries no per-walker
    state (``wstate`` is the empty pytree ``None`` everywhere)."""
    return None


@dataclasses.dataclass(frozen=True)
class WalkProgram:
    """A composable dynamic-walk program (the framework's primary contract).

    The walk *program* — not just the edge weight — is the unit of user
    extension: per-walker state, step hooks and early termination compose
    with every registered sampler and the streaming scheduler with zero
    engine edits.

    Callable fields
    ---------------
    ``init()``
        Hyperparameters (``params``), baked in at trace time.  Must be
        hashable (frozen dataclasses / tuples), like before.
    ``init_walker_state(query)``
        Per-walker state pytree for the walker serving query id ``query``
        (an int32 scalar, traced under vmap).  Return ``None`` (the
        default) for stateless programs.  Leaves may be any shape/dtype;
        the engine batches them with a leading walker-slot dim, so under
        ``run(devices=N)`` each device carries only its own lanes' state
        (the ``WalkerState`` sharding contract).
    ``get_weight(ctx, params, wstate)``
        Transition weight w̃ ≥ 0 of ONE candidate edge.  ``wstate`` is the
        walker's CURRENT state (the value most recently returned by
        ``on_step``); it is a per-walker runtime input to the Flexi-
        Compiler's bound analysis, exactly like ``cur``/``prev``/``step``.
    ``on_step(ctx, params, wstate) -> wstate``
        Post-selection state transition, applied only to lanes that
        actually moved.  ``None`` (default) leaves ``wstate`` untouched.
    ``should_stop(ctx, params, wstate) -> bool``
        Early termination, evaluated right after ``on_step`` with the NEW
        state.  A True verdict folds into the slot ``alive`` mask: the
        walker emits no further path entries, stops counting toward
        telemetry, and its scheduler slot is refilled at the next epoch
        boundary.  ``None`` (default) walks the full ``walk_len``.

    Transition-context contract (``on_step`` / ``should_stop``)
    -----------------------------------------------------------
    Both hooks receive one per-walker :class:`EdgeCtx` describing the
    transition just taken: ``nbr`` = the node moved to, ``cur``/``prev`` =
    the nodes departed (pre-move), ``step`` = the 0-based index of the
    step just taken, ``deg_cur``/``deg_prev`` = degrees of ``cur``/
    ``prev``.  The per-edge payload fields are NOT resolved for the chosen
    edge (``h=1``, ``label=-1``, ``dist=-1``): recovering them would cost
    a row search per step, and no shipped program needs them — derive what
    you need from ``nbr`` and your own state instead.
    """

    name: str
    init: Callable[[], Any]
    get_weight: Callable[[EdgeCtx, Any, Any], jax.Array]
    init_walker_state: Callable[[jax.Array], Any] = _stateless
    on_step: Optional[Callable[[EdgeCtx, Any, Any], Any]] = None
    should_stop: Optional[Callable[[EdgeCtx, Any, Any], jax.Array]] = None
    needs_dist: bool = False  # dist(v',u) is expensive; only compute on demand
    needs_labels: bool = False
    num_labels: int = 1
    weighted: bool = True  # whether ctx.h participates (paper's (un)weighted)
    walk_len: int = 80  # paper default: 80 steps (5 for MetaPath)

    def params(self):
        return self.init()

    # Single indirection every internal weight evaluation goes through —
    # the legacy ``Workload`` subclass overrides it to drop ``wstate``, so
    # kernels never sniff signatures.
    def edge_weight(self, ctx: EdgeCtx, params, wstate) -> jax.Array:
        return self.get_weight(ctx, params, wstate)

    @property
    def has_hooks(self) -> bool:
        """Whether the engine must run the per-step hook machinery."""
        return self.on_step is not None or self.should_stop is not None

    def wstate_template(self) -> Any:
        """One walker's initial state as concrete arrays (trace template)."""
        return jax.tree_util.tree_map(
            jnp.asarray, self.init_walker_state(jnp.int32(0)))

    def init_wstate_batch(self, query_ids: jax.Array) -> Any:
        """Per-walker state for a batch of query ids ([W]-leading leaves)."""
        return jax.vmap(self.init_walker_state)(
            jnp.asarray(query_ids, jnp.int32))


@dataclasses.dataclass(frozen=True)
class Workload(WalkProgram):
    """DEPRECATED — the original bare protocol (``get_weight(ctx, params)``
    + flags).  Still constructible; adapts transparently into the
    :class:`WalkProgram` contract with bit-identical paths/telemetry (the
    wrapped weight function traces to the same jaxpr).  New code should
    construct :class:`WalkProgram` directly."""

    def __post_init__(self):
        warnings.warn(
            "Workload is deprecated; define a WalkProgram instead "
            "(get_weight takes (ctx, params, wstate), and per-walker "
            "state / on_step / should_stop become available)",
            DeprecationWarning, stacklevel=3)

    def edge_weight(self, ctx: EdgeCtx, params, wstate) -> jax.Array:
        return self.get_weight(ctx, params)  # legacy two-arg signature


def from_workload(workload) -> WalkProgram:
    """Zero-cost adapter: any legacy workload object (a :class:`Workload`
    or anything with its attributes) as a :class:`WalkProgram`.

    The returned program's ``get_weight`` simply drops the (empty)
    ``wstate`` argument, so it traces to the *identical jaxpr* — paths,
    telemetry and compiler analysis are bit-identical to the legacy path.
    """
    if isinstance(workload, WalkProgram) and not isinstance(workload, Workload):
        return workload  # already speaks the new protocol
    legacy_gw = workload.get_weight
    return WalkProgram(
        name=workload.name,
        init=workload.init,
        get_weight=lambda ctx, params, wstate: legacy_gw(ctx, params),
        needs_dist=workload.needs_dist,
        needs_labels=workload.needs_labels,
        num_labels=workload.num_labels,
        weighted=workload.weighted,
        walk_len=workload.walk_len,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class WalkerState:
    """State of a batch of W walker *slots* (a pytree; leading dim W).

    This is the carry of the engine's ``lax.scan`` step loop and the unit
    the streaming epoch scheduler refills: a slot whose walker finished is
    overwritten host-side with the next queued query (``alive`` stays False
    for empty slots, so they are masked out of kernels and telemetry).

    ``rng`` holds *raw key data* (``jax.random.key_data`` of a per-query
    fold of the run key) rather than typed key arrays so slots can be
    refilled with plain ``.at[idx].set`` updates; the engine re-wraps it
    with ``jax.random.wrap_key_data`` and folds in ``step`` each step, so a
    query's random stream is independent of slot/epoch placement.

    Field invariants (what pad/dead lanes may contain)
    --------------------------------------------------
    * A lane is **live** for a step iff ``alive ∧ degree(cur) > 0 ∧
      step < num_steps``.  Only live lanes sample, emit path entries, or
      count toward telemetry.
    * ``alive == False`` marks an *empty slot* (never filled, or already
      drained) **or** a dead-ended walk.  Every other field of such a lane
      is unspecified residue: ``cur``/``prev`` keep whatever the previous
      occupant (or the zero-init) left, ``step`` may be ≥ num_steps, and
      ``rng`` may be a stale stream.  Correctness never depends on them —
      samplers receive the live mask via ``active`` and must treat masked
      lanes' outputs as junk (the engine re-masks with -1 regardless).
    * ``cur`` is always a valid node id (≥ 0) for lanes that have ever been
      occupied; ``prev`` is -1 until the occupant's first transition.
    * ``step`` counts transitions taken by the *current occupant only*; the
      scheduler resets it to 0 on refill, so path indexing (``step + 1``)
      is per-query, not per-slot.
    * ``carry`` is sampler-owned cross-step state (e.g. the ``interleaved``
      sampler's prefetched neighbour tile).  The engine threads it through
      the scan and across epochs untouched, and it must never influence a
      lane's *distribution* — only how data is fetched.  Refills do NOT
      reset it: samplers must validate it per lane (the prefetch tile
      records which node it was gathered for and is re-fetched on
      mismatch).  ``None`` for samplers that carry nothing.
    * ``wstate`` is **program-owned** per-walker state (the ``WalkProgram``
      contract): every leaf is slot-dim-leading, advanced only by
      ``on_step`` on lanes that moved, and — unlike ``carry`` — refills DO
      reset it (a refilled slot gets ``init_walker_state(query)``, so a
      query's state, like its RNG stream, is independent of slot/epoch/
      device placement).  Dead/pad lanes hold residue the live mask hides.
      ``None`` for stateless programs.

    Sharding (docs/scaling.md)
    --------------------------
    Dim 0 of every leaf is the slot dim; its logical axis name is
    :data:`BATCH_AXIS` (``"walkers"``), which the walker mesh rules in
    ``repro.distributed.sharding`` map onto a 1D device mesh.  Lanes never
    read each other's state (the only cross-lane ops in the engine are
    telemetry sums and the tile-trip ``max``, both order-insensitive
    reductions), so sharding the slot dim changes *where* a lane computes
    but never *what* it computes — the scheduler's batch-invariance
    contract extends to topology invariance.  Carry leaves must keep the
    slot dim leading for the same reason (see ``Sampler.init_carry``).
    """

    #: logical axis name of dim 0 of every leaf (the walker-slot dim)
    BATCH_AXIS = "walkers"

    cur: jax.Array  # [W] int32 current node
    prev: jax.Array  # [W] int32 previous node (-1 before the first step)
    step: jax.Array  # [W] int32 steps taken by the current occupant
    alive: jax.Array  # [W] bool — False for empty slots and dead-ended walks
    rng: jax.Array  # [W, key_size] uint32 raw per-walker key data
    carry: Any = None  # sampler-owned pytree (see invariants above)
    wstate: Any = None  # program-owned pytree (see invariants above)

    @staticmethod
    def stream_key_data(key: jax.Array, ids: jax.Array) -> jax.Array:
        """Raw key data of the per-query streams fold_in(key, id).

        The single source of the stream derivation: ``create`` (slot i =
        query i) and the engine's refill queue (arbitrary query→slot
        placement) must use the same expression for ``run``/``walk_batch``
        bit-compatibility.
        """
        return jax.vmap(lambda i: jax.random.key_data(
            jax.random.fold_in(key, i)))(ids.astype(jnp.int32))

    @staticmethod
    def create(starts: jax.Array, key: jax.Array,
               wstate: Any = None) -> "WalkerState":
        """A fully-occupied batch: walker i gets stream fold_in(key, i)
        (and, when ``wstate`` is given, the program state for query i)."""
        W = starts.shape[0]
        rng = WalkerState.stream_key_data(key, jnp.arange(W, dtype=jnp.int32))
        return WalkerState(
            cur=starts.astype(jnp.int32),
            prev=jnp.full((W,), -1, jnp.int32),
            alive=jnp.ones((W,), bool),
            step=jnp.zeros((W,), jnp.int32),
            rng=rng,
            wstate=wstate,
        )

    def stream_keys(self) -> jax.Array:
        """[W] typed per-step keys: the walker's stream ⊕ its step count."""
        return jax.vmap(lambda kd, s: jax.random.fold_in(
            jax.random.wrap_key_data(kd), s))(self.rng, self.step)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StepStats:
    """Per-step telemetry (a pytree, stacked by the epoch scan).

    All counters cover *live* lanes only — padded/empty slots and finished
    walkers never contribute (Fig. 14 statistics stay unbiased under the
    streaming scheduler's partial epochs).
    """

    #: bit positions of the per-(lane, step) flag words the fused
    #: mega-step kernel emits (kernels/megastep_kernel.py); plain class
    #: attributes, not dataclass fields
    LIVE, RJS, FALLBACK, PRECOMP, STALE = 0, 1, 2, 3, 4

    live: jax.Array  # [] int32 — walkers that attempted this step
    rjs_served: jax.Array  # [] int32 — lanes served by rejection sampling
    fallbacks: jax.Array  # [] int32 — §7.1 rejection→reservoir fallbacks
    # lanes served from precomputed ITS/alias tables (the static regime)
    precomp_served: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.int32(0))
    # lanes that would have been table-served but hit a stale (invalidated)
    # row and took the dynamic path instead — transient while the rebuild
    # queue drains; 0 once every stale row has been re-baked
    stale_served: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.int32(0))

    def host_totals(self) -> dict:
        """Each counter summed to a host int, keyed by field name.

        The single epoch-boundary reduction both the engine's ``run`` loop
        and the serving scheduler use to accumulate telemetry: integer
        sums are order-free exact, so host-side accumulation across epochs
        is bit-identical to a single fused reduction.
        """
        return {f.name: int(np.asarray(getattr(self, f.name)).sum())
                for f in dataclasses.fields(self)}

    @classmethod
    def from_flag_bits(cls, flags: jax.Array) -> "StepStats":
        """Reduce a [W, T] int32 flag-bit matrix to per-step counters
        ([T]-leaf StepStats, the same pytree the staged epoch scan
        stacks).  Integer sums per bit, so the reduction is order-free
        exact — fused and staged telemetry match bit for bit."""
        def count(bit):
            return jnp.sum((flags >> bit) & 1, axis=0, dtype=jnp.int32)

        return cls(live=count(cls.LIVE), rjs_served=count(cls.RJS),
                   fallbacks=count(cls.FALLBACK),
                   precomp_served=count(cls.PRECOMP),
                   stale_served=count(cls.STALE))
