"""Precomputed-regime tables (C-SAW-style static sampling; paper §2.2/§6).

For workloads whose ``get_weight`` the Flexi-Compiler proves state-
independent (:func:`repro.core.flexi_compiler.is_static` — output taint
disjoint from ``dist``/``prev``/``deg_prev``/``step``), the transition
distribution of every node is a constant of the graph.  This module bakes
it once into two table families:

* **ITS** — per-row inclusive prefix sums of w̃ (``cdf``) + row totals.
  A draw is ``u·total`` followed by a *binary search* of the row: O(log d)
  per step, no weight evaluation, no RNG retries.
* **Alias** — Vose tables (``alias_off``/``alias_prob``), built host-side
  in float64.  A draw is two uniforms and two gathers: O(1) per step.

Both are one-time preprocessing (the Table-3 "Preproc." budget); C-SAW
shows this regime dominates static-weight workloads, which is why the
extended cost model (``CostModel.prefer_precomp``) routes static-provable
nodes here ahead of the Eq. 11 rejection/reservoir split.

Tables carry **two layouts of the same values**: the flat CSR-order
arrays the jnp selectors read, and the tile-aligned [R, 128] streams
(``ops.align_rows`` geometry) the Pallas kernels in
``kernels/precomp_kernel.py`` DMA.  The jnp selectors and the kernels
consume the *same* counter-based Threefry uniforms
(:func:`threefry_seeds` + the per-kernel salts), so the two execution
paths — selected by ``EngineConfig.precomp_exec`` — are bit-identical.

**Invalidation and amortized rebuild**: mutating a node's edge weights
makes its row stale.  ``PrecompTables.invalid`` is a per-node bitmap —
samplers route lanes whose current node is invalidated to the dynamic
path (eRVS over the *live* graph), so mutation costs one bitmap write
up front.  Stale rows then enter a :class:`RebuildQueue` which the
engine drains a budgeted few rows per scheduler epoch
(``EngineConfig.rebuild_budget``): each drained row is re-baked from the
current graph with the *same per-row float64 math* as a fresh build
(:func:`rebuild_rows` is bit-identical to :func:`build_tables` row by
row), and its validity bit flips back — the fallback is transient, never
permanent.  ``WalkEngine.update_graph`` is the engine-level entry point.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ctxutil import degrees_of
from repro.core.types import EdgeCtx, Workload
from repro.graphs.csr import CSRGraph
from repro.graphs.delta import host_row_layout
from repro.kernels.prng import uniform_01, uniform_pair_01

# Threefry counter salts (shared with kernels/precomp_kernel.py and the
# kernels/ref.py oracles) so table draws never collide with the uniforms
# any other sampler derives from the same per-(walker, step) stream key.
ITS_SALT = 0x175CDF
ALIAS_SALT = 0xA11A5


def threefry_seeds(rng: jax.Array) -> jax.Array:
    """[W] typed per-(walker, step) keys → [W, 2] uint32 Threefry pairs.

    The single derivation both the jnp selectors below and the Pallas
    kernel path consume — sharing it (plus the salts) is what makes the
    two ``precomp_exec`` paths bit-identical.
    """
    data = jax.random.key_data(rng)
    return jnp.asarray(data, jnp.uint32).reshape(data.shape[0], -1)[:, :2]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PrecompTables:
    """Per-node ITS + alias tables over the CSR edge order, plus the
    invalidation bitmap.  A registered pytree: the engine passes it into
    the jitted epoch as a runtime argument, so background row rebuilds
    swap in new arrays with **no retrace** (shapes never change)."""

    cdf: jax.Array  # [E] f32 — row-local inclusive prefix sums of w̃
    total: jax.Array  # [V] f32 — row sums (cdf value at each row's end)
    alias_off: jax.Array  # [E] i32 — alias partner offset within the row
    alias_prob: jax.Array  # [E] f32 — acceptance probability of the column
    invalid: jax.Array  # [V] bool — rows that must use the dynamic path
    # tile-aligned [R, 128] streams of the same values (ops.align_rows
    # geometry) + the first aligned 128-row per node — the layout the
    # Pallas kernels DMA.  None for hand-built tables; the kernel path
    # then degrades to the (bit-identical) jnp selectors.
    cdf2d: Optional[jax.Array] = None
    prob2d: Optional[jax.Array] = None
    alias2d: Optional[jax.Array] = None
    arow0: Optional[jax.Array] = None  # [V] i32

    def invalidate(self, nodes) -> "PrecompTables":
        """Mark ``nodes``' rows stale (their lanes fall back to the dynamic
        path).  Returns a new object; tables are immutable."""
        idx = jnp.asarray(np.asarray(nodes), jnp.int32)
        return dataclasses.replace(
            self, invalid=self.invalid.at[idx].set(True))

    def row_valid(self, v: jax.Array) -> jax.Array:
        """Per-lane: may this node be served from the tables?"""
        vs = jnp.maximum(v, 0)
        return (v >= 0) & ~self.invalid[vs]

    def frac_stale(self) -> jax.Array:
        """Scalar f32: fraction of table rows currently invalidated (the
        transient-fallback fraction ``CostModel.prefer_precomp`` discounts
        routing by while the rebuild queue drains)."""
        return jnp.mean(self.invalid.astype(jnp.float32))

    def with_aligned(self, indptr) -> "PrecompTables":
        """Attach the tile-aligned kernel layout (rebuilt from the flat
        arrays; geometry is a function of the topology only)."""
        # deferred import: ops pulls the Pallas kernel modules, which
        # flat-only (aligned=False) builds never need
        from repro.kernels import ops as kernel_ops

        cdf2d, prob2d, alias2d, row0, _ = kernel_ops.aligned_precomp_tables(
            self, np.asarray(indptr))
        return dataclasses.replace(self, cdf2d=cdf2d, prob2d=prob2d,
                                   alias2d=alias2d, arow0=row0)


def edge_weights_static(graph: CSRGraph, workload: Workload,
                        params) -> jax.Array:
    """w̃ for every edge of a *static* workload, in CSR order ([E] f32).

    Because ``is_static`` proved the output ignores dist/prev/deg_prev/step,
    those fields are filled with neutral placeholders (dist=1, prev=-1,
    step=0) — any values would give the same weights.
    """
    V, E = graph.num_nodes, graph.num_edges
    deg = graph.degrees()
    src = jnp.repeat(jnp.arange(V, dtype=jnp.int32), deg,
                     total_repeat_length=E)
    return _eval_static_weights(graph, workload, params,
                                jnp.arange(E, dtype=jnp.int32), src,
                                deg[src])


def _eval_static_weights(graph: CSRGraph, workload: Workload, params,
                         edge_idx: jax.Array, src: jax.Array,
                         deg_cur: jax.Array) -> jax.Array:
    """Static w̃ of the listed edges ([n] f32), with the same neutral
    placeholder context as :func:`edge_weights_static` — the shared
    evaluator that keeps full builds and row rebuilds bit-identical."""
    n = edge_idx.shape[0]
    ctx = EdgeCtx(
        h=(graph.h[edge_idx] if workload.weighted
           else jnp.ones((n,), jnp.float32)),
        label=graph.labels[edge_idx],
        dist=jnp.ones((n,), jnp.int32),
        nbr=graph.indices[edge_idx],
        deg_cur=deg_cur,
        deg_prev=jnp.zeros((n,), jnp.int32),
        cur=src,
        prev=jnp.full((n,), -1, jnp.int32),
        step=jnp.zeros((n,), jnp.int32),
    )
    # ``is_static`` also proved the weights ignore the program's per-walker
    # state, so any representative value works — use the initial state.
    ws0 = workload.wstate_template()
    w = jax.vmap(lambda c: workload.edge_weight(c, params, ws0))(ctx)
    return jnp.maximum(w, 0.0).astype(jnp.float32)


def _vose_row(ww: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Textbook two-stack Vose alias construction for ONE row, float64.
    Zero-total rows keep the neutral (alias=self-ish, prob=1) fill —
    ``total[v] == 0`` masks them at draw time."""
    d = ww.shape[0]
    alias = np.zeros(d, np.int32)
    prob = np.ones(d, np.float32)
    tot = ww.sum()
    if d == 0 or tot <= 0:
        return alias, prob
    q = ww * d / tot
    small = [i for i in range(d) if q[i] < 1.0]
    large = [i for i in range(d) if q[i] >= 1.0]
    while small and large:
        sm = small.pop()
        lg = large.pop()
        prob[sm] = q[sm]
        alias[sm] = lg
        q[lg] -= 1.0 - q[sm]
        (small if q[lg] < 1.0 else large).append(lg)
    for i in small + large:  # numerical leftovers: certain accept
        prob[i] = 1.0
        alias[i] = i
    return alias, prob


def _vose_build(w: np.ndarray, indptr: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Vose alias tables for every CSR row (host-side, one-time
    preprocessing — not the per-step serial build the ALS baseline pays)."""
    E = w.shape[0]
    V = indptr.shape[0] - 1
    alias = np.zeros(E, np.int32)
    prob = np.ones(E, np.float32)
    for v in range(V):
        s, e = int(indptr[v]), int(indptr[v + 1])
        if e > s:
            alias[s:e], prob[s:e] = _vose_row(w[s:e].astype(np.float64))
    return alias, prob


def _row_tables(ww: np.ndarray
                ) -> Tuple[np.ndarray, np.float32, np.ndarray, np.ndarray]:
    """(cdf, total, alias, prob) of ONE row from its float64 weights.

    The single per-row constructor both :func:`build_tables` and
    :func:`rebuild_rows` call — same math, same rounding, so a rebuilt
    row is bit-identical to the row a fresh build would produce.
    """
    cdf = np.cumsum(ww).astype(np.float32)
    total = cdf[-1] if cdf.shape[0] else np.float32(0.0)
    alias, prob = _vose_row(ww)
    return cdf, np.float32(total), alias, prob


def build_tables(graph: CSRGraph, workload: Workload, params,
                 aligned: bool = True) -> PrecompTables:
    """One-time table build for a static workload (host-side, row-local
    float64 accumulation so long rows keep full CDF precision).

    ``aligned`` additionally packs the tile-aligned [R, 128] kernel
    streams — required by the Pallas execution path, pure overhead
    (≈ 2× table memory + a repack) for engines pinned to the jnp
    selectors, which read only the flat arrays."""
    w = np.asarray(edge_weights_static(graph, workload, params), np.float64)
    indptr = np.asarray(graph.indptr, np.int64)
    V = graph.num_nodes
    if V and int(np.diff(indptr).max(initial=0)) >= (1 << 24):
        # alias offsets ride a float32 stream in the Pallas kernel layout
        raise ValueError("precomp tables require max degree < 2**24")
    cdf = np.zeros(w.shape[0], np.float32)
    total = np.zeros(V, np.float32)
    alias = np.zeros(w.shape[0], np.int32)
    prob = np.ones(w.shape[0], np.float32)
    for v in range(V):
        s, e = int(indptr[v]), int(indptr[v + 1])
        if e > s:
            cdf[s:e], total[v], alias[s:e], prob[s:e] = _row_tables(w[s:e])
    tables = PrecompTables(
        cdf=jnp.asarray(cdf),
        total=jnp.asarray(total),
        alias_off=jnp.asarray(alias),
        alias_prob=jnp.asarray(prob),
        invalid=jnp.zeros((V,), bool),
    )
    return tables.with_aligned(indptr) if aligned else tables


# ------------------------------------------------------ amortized rebuild
SCATTER_MODES = ("donate", "copy")


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_donate(dst, idx, vals):
    return dst.at[idx].set(vals)


@jax.jit
def _scatter_copy(dst, idx, vals):
    return dst.at[idx].set(vals)


def _scatter_rows(dst: jax.Array, idx: np.ndarray, vals: np.ndarray,
                  mode: str) -> jax.Array:
    """Jitted row scatter for the rebuild path: O(rows written), not the
    O(E) whole-table copy an unjitted ``.at[].set`` materialises.

    ``mode="donate"`` additionally donates ``dst`` so XLA writes in place
    — the caller's old table array is CONSUMED (every engine/queue call
    site reassigns the returned tables and never re-reads the old object,
    so this is the default); ``mode="copy"`` keeps the input alive (the
    fig12d before/after baseline, or callers that hold table snapshots).

    (idx, vals) are padded to the next power-of-two length by repeating
    the LAST entry — a duplicate scatter of an identical value is a
    deterministic no-op — so the jit cache holds O(log E) entries per
    dtype instead of one per drain size.
    """
    idx = np.asarray(idx)
    if idx.size == 0:
        return dst
    n = idx.shape[0]
    m = max(1, 1 << (n - 1).bit_length())
    if m != n:
        idx = np.concatenate([idx, np.full(m - n, idx[-1], idx.dtype)])
        vals = np.concatenate(
            [vals, np.broadcast_to(vals[-1:], (m - n,) + vals.shape[1:])])
    fn = _scatter_donate if mode == "donate" else _scatter_copy
    return fn(dst, jnp.asarray(idx, jnp.int32), jnp.asarray(vals))


def rebuild_rows(tables: PrecompTables, graph: CSRGraph, workload: Workload,
                 params, nodes, *, scatter: str = "donate") -> PrecompTables:
    """Re-bake the listed nodes' rows from the CURRENT graph weights and
    flip their validity bits back.

    Bit-identity contract (pinned by tests/test_rebuild.py): a rebuilt row
    equals the row :func:`build_tables` of the same graph would produce —
    the per-edge weight evaluation and the per-row float64 table math are
    the same code paths — so draining every stale row restores exactly the
    fresh-build tables.  Rows are disjoint, so rebuild order is
    irrelevant.  Updates both the flat arrays and (when present) the
    tile-aligned kernel streams; all shapes are preserved, so the jitted
    epoch closed over the *structure* never retraces.

    ``scatter`` selects the write path (see :func:`_scatter_rows`): the
    default ``"donate"`` updates the tables in place — O(rows) per drain
    instead of O(E) — and consumes the INPUT ``tables``' buffers, which
    must not be read afterwards; ``"copy"`` preserves them.
    """
    if scatter not in SCATTER_MODES:
        raise ValueError(f"scatter {scatter!r} not one of {SCATTER_MODES}")
    nodes_arr = np.unique(np.atleast_1d(np.asarray(nodes, np.int64)))
    if nodes_arr.size == 0:
        return tables
    # row layout through the shared helper, so rebuilds work on both the
    # contiguous CSR and a delta-overlay graph (whose touched rows live
    # in the patch region)
    starts_all, deg_all = host_row_layout(graph)
    degs = deg_all[nodes_arr]
    edge_idx = np.concatenate(
        [np.arange(starts_all[v], starts_all[v] + deg_all[v])
         for v in nodes_arr]
    ) if degs.sum() else np.zeros(0, np.int64)
    bounds = np.zeros(nodes_arr.size + 1, np.int64)
    np.cumsum(degs, out=bounds[1:])

    if edge_idx.size:
        src = np.repeat(nodes_arr, degs)
        w = np.asarray(_eval_static_weights(
            graph, workload, params,
            jnp.asarray(edge_idx, jnp.int32),
            jnp.asarray(src, jnp.int32),
            jnp.asarray(deg_all[src], jnp.int32)), np.float64)
    else:
        w = np.zeros(0, np.float64)

    new_cdf = np.zeros(edge_idx.size, np.float32)
    new_total = np.zeros(nodes_arr.size, np.float32)
    new_alias = np.zeros(edge_idx.size, np.int32)
    new_prob = np.ones(edge_idx.size, np.float32)
    for i in range(nodes_arr.size):
        s, e = int(bounds[i]), int(bounds[i + 1])
        if e > s:
            (new_cdf[s:e], new_total[i],
             new_alias[s:e], new_prob[s:e]) = _row_tables(w[s:e])

    out = dataclasses.replace(
        tables,
        cdf=_scatter_rows(tables.cdf, edge_idx, new_cdf, scatter),
        total=_scatter_rows(tables.total, nodes_arr, new_total, scatter),
        alias_off=_scatter_rows(tables.alias_off, edge_idx, new_alias,
                                scatter),
        alias_prob=_scatter_rows(tables.alias_prob, edge_idx, new_prob,
                                 scatter),
        invalid=_scatter_rows(tables.invalid, nodes_arr,
                              np.zeros(nodes_arr.size, bool), scatter),
    )
    if tables.arow0 is None:
        return out
    # aligned streams: each node owns rows [arow0, arow0 + ⌈d/128⌉) of the
    # [R, 128] layout exclusively, zero-padded past its degree — writing
    # the full zero-padded span reproduces align_rows' fill exactly.
    from repro.kernels.ref import LANES

    arow0 = np.asarray(tables.arow0, np.int64)
    rows: List[np.ndarray] = []
    blk_cdf: List[np.ndarray] = []
    blk_prob: List[np.ndarray] = []
    blk_alias: List[np.ndarray] = []
    for i, v in enumerate(nodes_arr):
        d = int(degs[i])
        nrows = (d + LANES - 1) // LANES
        if nrows == 0:
            continue
        s, e = int(bounds[i]), int(bounds[i + 1])
        for blocks, vals in ((blk_cdf, new_cdf[s:e]),
                             (blk_prob, new_prob[s:e]),
                             (blk_alias, new_alias[s:e].astype(np.float32))):
            buf = np.zeros(nrows * LANES, np.float32)
            buf[:d] = vals
            blocks.append(buf.reshape(nrows, LANES))
        rows.append(arow0[v] + np.arange(nrows))
    if not rows:
        return out
    ridx = np.concatenate(rows)
    return dataclasses.replace(
        out,
        cdf2d=_scatter_rows(tables.cdf2d, ridx, np.concatenate(blk_cdf),
                            scatter),
        prob2d=_scatter_rows(tables.prob2d, ridx, np.concatenate(blk_prob),
                             scatter),
        alias2d=_scatter_rows(tables.alias2d, ridx,
                              np.concatenate(blk_alias), scatter),
    )


def splice_tables(tables: PrecompTables, old_starts, old_degs,
                  new_starts, new_degs, new_len: int) -> PrecompTables:
    """Re-layout the per-edge table values onto a new row layout — the
    O(E) gather behind structural updates and overlay compaction.

    Rows whose degree is unchanged move wholesale (their values are a
    pure function of the row's weights, not of where the row lives, so a
    moved row stays bit-identical); rows whose degree changed get the
    fresh-build neutral fill and MUST be invalidated by the caller — the
    rebuild queue re-bakes them with real values.  Per-node arrays
    (``total`` / ``invalid``) are layout-independent and carry over.
    The tile-aligned kernel streams are dropped (their geometry is
    topology-bound); re-attach with :meth:`PrecompTables.with_aligned`
    after a compaction when a Pallas path needs them.
    """
    old_starts = np.asarray(old_starts, np.int64)
    old_degs = np.asarray(old_degs, np.int64)
    new_starts = np.asarray(new_starts, np.int64)
    new_degs = np.asarray(new_degs, np.int64)
    V = old_starts.shape[0]
    copy_deg = np.where(old_degs == new_degs, new_degs, 0)
    n = int(copy_deg.sum())
    src_rows = np.repeat(np.arange(V, dtype=np.int64), copy_deg)
    bounds = np.zeros(V + 1, np.int64)
    np.cumsum(copy_deg, out=bounds[1:])
    within = np.arange(n, dtype=np.int64) - np.repeat(bounds[:-1], copy_deg)
    gather = old_starts[src_rows] + within
    scatter = new_starts[src_rows] + within

    def move(arr, fill, dtype):
        out = np.full(int(new_len), fill, dtype)
        if n:
            out[scatter] = np.asarray(arr)[gather]
        return jnp.asarray(out)

    return PrecompTables(
        cdf=move(tables.cdf, 0.0, np.float32),
        total=tables.total,
        alias_off=move(tables.alias_off, 0, np.int32),
        alias_prob=move(tables.alias_prob, 1.0, np.float32),
        invalid=tables.invalid,
    )


def grow_tables(tables: PrecompTables, new_len: int) -> PrecompTables:
    """Keep the per-edge tables in the *overlay* layout across an
    ``apply_updates`` — the O(touched) replacement for running
    :func:`splice_tables` on every structural edit.

    While a delta overlay is active the table arrays are addressed
    through the overlay's ``row_starts``/``row_degs``, and the overlay's
    patch allocator keeps every row's span stable between compactions —
    so a valid row's table values are *already* at the right offsets and
    the only thing an apply has to do is extend the arrays to the new
    edge-array length (base + patch capacity).  Capacities are powers of
    two, so the O(E) concatenate here runs O(log) times per compaction
    cycle and this is an O(1) no-op on every other apply; the one-shot
    O(E) re-layout back to the contiguous order is deferred to
    ``WalkEngine.compact()`` (which still uses :func:`splice_tables`).

    Newly exposed positions get the fresh-build neutral fill (cdf 0.0,
    alias_off 0, alias_prob 1.0) and are only ever read after
    ``rebuild_rows`` wrote real values — callers invalidate the touched
    rows, exactly like the splice path.  Per-node arrays (``total`` /
    ``invalid``) are layout-independent and carry over.  The
    tile-aligned kernel streams are ALWAYS dropped, even when the length
    is unchanged: their geometry is bound to the pre-mutation topology,
    and serving a kernel DMA from a stale stream would be a silent wrong
    draw (``precomp_table_select`` guards against a partial layout).
    """
    cur = int(tables.cdf.shape[0])
    new_len = int(new_len)
    if new_len < cur:
        raise ValueError(
            f"grow_tables cannot shrink: tables hold {cur} edge slots, "
            f"overlay asks for {new_len} — compaction goes through "
            f"splice_tables")
    out = tables
    if (tables.cdf2d is not None or tables.prob2d is not None
            or tables.alias2d is not None or tables.arow0 is not None):
        out = dataclasses.replace(out, cdf2d=None, prob2d=None,
                                  alias2d=None, arow0=None)
    if new_len == cur:
        return out
    ext = new_len - cur
    return dataclasses.replace(
        out,
        cdf=jnp.concatenate(
            [out.cdf, jnp.zeros((ext,), out.cdf.dtype)]),
        alias_off=jnp.concatenate(
            [out.alias_off, jnp.zeros((ext,), out.alias_off.dtype)]),
        alias_prob=jnp.concatenate(
            [out.alias_prob, jnp.ones((ext,), out.alias_prob.dtype)]),
    )


class RebuildQueue:
    """Host-side FIFO of stale table rows awaiting amortized rebuild.

    The engine pushes every node ``update_graph`` invalidates and drains a
    budgeted few rows per scheduler epoch (between jitted epochs, where
    host work is free) — so a weight mutation costs one bitmap write now
    and O(row) rebuild work spread over the following epochs, instead of
    demoting the row to the dynamic path forever.  Deliberately not a
    pytree: it never enters a traced computation.

    Invariant (pinned by the tests/test_rebuild.py property suite): when
    all invalidation flows through :meth:`push`, the queue's membership is
    exactly the set of ``True`` bits in ``PrecompTables.invalid`` — a row
    is pending iff it is stale, and a fully drained queue means a fully
    valid bitmap.
    """

    def __init__(self):
        self._pending: deque = deque()
        self._member: set = set()

    def push(self, nodes) -> int:
        """Enqueue stale rows (deduplicated; re-invalidating a pending row
        is a no-op — its eventual rebuild reads the latest graph anyway).
        Returns how many rows were newly enqueued."""
        added = 0
        for v in np.atleast_1d(np.asarray(nodes, np.int64)).tolist():
            if v not in self._member:
                self._member.add(v)
                self._pending.append(v)
                added += 1
        return added

    def __len__(self) -> int:
        return len(self._pending)

    def pending(self) -> Tuple[int, ...]:
        return tuple(self._pending)

    def drain(self, tables: PrecompTables, graph: CSRGraph,
              workload: Workload, params, budget: Optional[int] = None,
              scatter: str = "donate") -> Tuple[PrecompTables, List[int]]:
        """Rebuild up to ``budget`` queued rows (all of them when None).
        Returns (new tables, the rows rebuilt).  ``scatter`` follows
        :func:`rebuild_rows`: the default donates the old tables' buffers
        to the in-place row scatter, so callers must adopt the returned
        tables and drop the input object (every engine call site does)."""
        n = len(self._pending) if budget is None \
            else min(int(budget), len(self._pending))
        if n <= 0:
            return tables, []
        nodes = [self._pending.popleft() for _ in range(n)]
        self._member.difference_update(nodes)
        return rebuild_rows(tables, graph, workload, params, nodes,
                            scatter=scatter), nodes


# ----------------------------------------------------------- jnp selectors
def search_depth(max_degree: int) -> int:
    """Binary-search iterations guaranteed to converge for rows with at
    most ``max_degree`` neighbours (+1 slack).  Must be computed from a
    *static* bound (e.g. ``SamplerContext.pad``) — inside a jitted epoch
    the graph arrays are tracers, so the depth cannot be derived there.
    Extra iterations past convergence are no-ops (the ``lo < hi`` guard),
    which is why any sufficient depth matches the Pallas kernel's
    run-to-convergence ``while_loop`` bit for bit."""
    return int(np.ceil(np.log2(max(max_degree, 1) + 1))) + 1


def its_select(graph: CSRGraph, tables: PrecompTables, cur: jax.Array,
               rng: jax.Array, *, active: jax.Array,
               depth: int = 32) -> jax.Array:
    """O(log d) inverse-transform draw from the baked CDF.

    ``u·total`` → fixed-depth binary search for the first row offset whose
    inclusive prefix exceeds the target (zero-weight neighbours share the
    previous prefix value, so they can never be landed on).  ``depth``
    bounds the halvings (see :func:`search_depth`; the default 32 covers
    any int32 degree).  The uniform comes from the counter-based Threefry
    stream (:func:`threefry_seeds` + ``ITS_SALT``) — the same draw the
    Pallas ``its_search`` kernel makes, so both paths pick the same
    offset.  Returns next nodes [W]; -1 for inactive / empty /
    zero-total lanes.
    """
    E = graph.num_edges
    deg = degrees_of(graph, cur)
    vs = jnp.maximum(cur, 0)
    start = graph.row_starts(vs)
    seeds = threefry_seeds(rng)
    u = uniform_01(seeds[:, 0], seeds[:, 1], jnp.uint32(0),
                   jnp.uint32(ITS_SALT))
    total = tables.total[vs]
    target = u * total

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) // 2
        val = tables.cdf[jnp.clip(start + mid, 0, E - 1)]
        go_right = (val <= target) & (lo < hi)
        new_lo = jnp.where(go_right, mid + 1, lo)
        new_hi = jnp.where(go_right | (lo >= hi), hi, mid)
        return (new_lo, new_hi)

    lo0 = jnp.zeros_like(deg)
    lo, _ = jax.lax.fori_loop(0, depth, body, (lo0, deg))
    sel = jnp.clip(lo, 0, jnp.maximum(deg - 1, 0))
    nxt = graph.indices[jnp.clip(start + sel, 0, E - 1)]
    ok = active & (deg > 0) & (total > 0)
    return jnp.where(ok, nxt, -1)


def alias_select(graph: CSRGraph, tables: PrecompTables, cur: jax.Array,
                 rng: jax.Array, *, active: jax.Array) -> jax.Array:
    """O(1) alias draw: column = ⌊u₁·d⌋, keep it iff u₂ < prob, else take
    its alias partner.  Uniforms come from the shared Threefry stream
    (``ALIAS_SALT``), matching the Pallas ``alias_pick`` kernel draw for
    draw.  Returns next nodes [W]; -1 as in its_select."""
    E = graph.num_edges
    deg = degrees_of(graph, cur)
    vs = jnp.maximum(cur, 0)
    start = graph.row_starts(vs)
    seeds = threefry_seeds(rng)
    u1, u2 = uniform_pair_01(seeds[:, 0], seeds[:, 1], jnp.uint32(0),
                             jnp.uint32(ALIAS_SALT))
    col = jnp.minimum((u1 * deg.astype(jnp.float32)).astype(jnp.int32),
                      jnp.maximum(deg - 1, 0))
    pos = jnp.clip(start + col, 0, E - 1)
    p_col = tables.alias_prob[pos]
    a_col = tables.alias_off[pos]
    sel = jnp.where(u2 < p_col, col, a_col)
    nxt = graph.indices[jnp.clip(start + sel, 0, E - 1)]
    ok = active & (deg > 0) & (tables.total[vs] > 0)
    return jnp.where(ok, nxt, -1)
