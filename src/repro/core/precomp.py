"""Precomputed-regime tables (C-SAW-style static sampling; paper §2.2/§6).

For workloads whose ``get_weight`` the Flexi-Compiler proves state-
independent (:func:`repro.core.flexi_compiler.is_static` — output taint
disjoint from ``dist``/``prev``/``deg_prev``/``step``), the transition
distribution of every node is a constant of the graph.  This module bakes
it once into two table families:

* **ITS** — per-row inclusive prefix sums of w̃ (``cdf``) + row totals.
  A draw is ``u·total`` followed by a *binary search* of the row: O(log d)
  per step, no weight evaluation, no RNG retries.
* **Alias** — Vose tables (``alias_off``/``alias_prob``), built host-side
  in float64.  A draw is two uniforms and two gathers: O(1) per step.

Both are one-time preprocessing (the Table-3 "Preproc." budget); C-SAW
shows this regime dominates static-weight workloads, which is why the
extended cost model (``CostModel.prefer_precomp``) routes static-provable
nodes here ahead of the Eq. 11 rejection/reservoir split.

**Invalidation**: mutating a node's edge weights makes its row stale.
``PrecompTables.invalid`` is a per-node bitmap — samplers route lanes whose
current node is invalidated to the dynamic path (eRVS over the *live*
graph), so mutation costs one bitmap write, not a table rebuild
(``WalkEngine.update_graph`` is the engine-level entry point).

The jnp selectors here are the semantic oracles; the TPU-native variants
(DMA-probed binary search / alias pick) live in
``kernels/precomp_kernel.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ctxutil import degrees_of
from repro.core.types import EdgeCtx, Workload
from repro.graphs.csr import CSRGraph

# Distinct fold_in salts so table draws never collide with the uniforms any
# other sampler derives from the same per-(walker, step) stream key.
ITS_SALT = 0x175CDF
ALIAS_SALT = 0xA11A5


@dataclasses.dataclass(frozen=True)
class PrecompTables:
    """Per-node ITS + alias tables over the CSR edge order, plus the
    invalidation bitmap.  All arrays are device arrays; the object is a
    trace-time constant closed over by the jitted epoch."""

    cdf: jax.Array  # [E] f32 — row-local inclusive prefix sums of w̃
    total: jax.Array  # [V] f32 — row sums (cdf value at each row's end)
    alias_off: jax.Array  # [E] i32 — alias partner offset within the row
    alias_prob: jax.Array  # [E] f32 — acceptance probability of the column
    invalid: jax.Array  # [V] bool — rows that must use the dynamic path

    def invalidate(self, nodes) -> "PrecompTables":
        """Mark ``nodes``' rows stale (their lanes fall back to the dynamic
        path).  Returns a new object; tables are immutable."""
        idx = jnp.asarray(np.asarray(nodes), jnp.int32)
        return dataclasses.replace(
            self, invalid=self.invalid.at[idx].set(True))

    def row_valid(self, v: jax.Array) -> jax.Array:
        """Per-lane: may this node be served from the tables?"""
        vs = jnp.maximum(v, 0)
        return (v >= 0) & ~self.invalid[vs]


def edge_weights_static(graph: CSRGraph, workload: Workload,
                        params) -> jax.Array:
    """w̃ for every edge of a *static* workload, in CSR order ([E] f32).

    Because ``is_static`` proved the output ignores dist/prev/deg_prev/step,
    those fields are filled with neutral placeholders (dist=1, prev=-1,
    step=0) — any values would give the same weights.
    """
    V, E = graph.num_nodes, graph.num_edges
    deg = graph.degrees()
    src = jnp.repeat(jnp.arange(V, dtype=jnp.int32), deg,
                     total_repeat_length=E)
    ctx = EdgeCtx(
        h=graph.h if workload.weighted else jnp.ones((E,), jnp.float32),
        label=graph.labels,
        dist=jnp.ones((E,), jnp.int32),
        nbr=graph.indices,
        deg_cur=deg[src],
        deg_prev=jnp.zeros((E,), jnp.int32),
        cur=src,
        prev=jnp.full((E,), -1, jnp.int32),
        step=jnp.zeros((E,), jnp.int32),
    )
    # ``is_static`` also proved the weights ignore the program's per-walker
    # state, so any representative value works — use the initial state.
    ws0 = workload.wstate_template()
    w = jax.vmap(lambda c: workload.edge_weight(c, params, ws0))(ctx)
    return jnp.maximum(w, 0.0).astype(jnp.float32)


def _vose_build(w: np.ndarray, indptr: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Textbook two-stack Vose alias construction, per CSR row, float64.

    Host-side and sequential per row — this is one-time preprocessing, not
    the per-step serial build the ALS baseline pays (baselines.als_step).
    """
    E = w.shape[0]
    V = indptr.shape[0] - 1
    alias = np.zeros(E, np.int32)
    prob = np.ones(E, np.float32)
    for v in range(V):
        s, e = int(indptr[v]), int(indptr[v + 1])
        d = e - s
        if d == 0:
            continue
        ww = w[s:e].astype(np.float64)
        tot = ww.sum()
        if tot <= 0:
            continue  # zero-total row: total[v]==0 masks it at draw time
        q = ww * d / tot
        small = [i for i in range(d) if q[i] < 1.0]
        large = [i for i in range(d) if q[i] >= 1.0]
        while small and large:
            sm = small.pop()
            lg = large.pop()
            prob[s + sm] = q[sm]
            alias[s + sm] = lg
            q[lg] -= 1.0 - q[sm]
            (small if q[lg] < 1.0 else large).append(lg)
        for i in small + large:  # numerical leftovers: certain accept
            prob[s + i] = 1.0
            alias[s + i] = i
    return alias, prob


def build_tables(graph: CSRGraph, workload: Workload, params
                 ) -> PrecompTables:
    """One-time table build for a static workload (host-side, float64
    accumulation so long rows keep full CDF precision)."""
    w = np.asarray(edge_weights_static(graph, workload, params), np.float64)
    indptr = np.asarray(graph.indptr, np.int64)
    V = graph.num_nodes
    if V and int(np.diff(indptr).max(initial=0)) >= (1 << 24):
        # alias offsets ride a float32 stream in the Pallas kernel layout
        raise ValueError("precomp tables require max degree < 2**24")
    csum = np.cumsum(w)
    base = np.where(indptr[:-1] > 0, csum[indptr[:-1] - 1], 0.0)
    src = np.repeat(np.arange(V), np.diff(indptr))
    cdf = (csum - base[src]).astype(np.float32)
    total = np.zeros(V, np.float32)
    rows = np.nonzero(np.diff(indptr) > 0)[0]
    total[rows] = cdf[indptr[rows + 1] - 1]
    alias, prob = _vose_build(w, indptr)
    return PrecompTables(
        cdf=jnp.asarray(cdf),
        total=jnp.asarray(total),
        alias_off=jnp.asarray(alias),
        alias_prob=jnp.asarray(prob),
        invalid=jnp.zeros((V,), bool),
    )


# ----------------------------------------------------------- jnp selectors
def search_depth(max_degree: int) -> int:
    """Binary-search iterations guaranteed to converge for rows with at
    most ``max_degree`` neighbours (+1 slack).  Must be computed from a
    *static* bound (e.g. ``SamplerContext.pad``) — inside a jitted epoch
    the graph arrays are tracers, so the depth cannot be derived there."""
    return int(np.ceil(np.log2(max(max_degree, 1) + 1))) + 1


def its_select(graph: CSRGraph, tables: PrecompTables, cur: jax.Array,
               rng: jax.Array, *, active: jax.Array,
               depth: int = 32) -> jax.Array:
    """O(log d) inverse-transform draw from the baked CDF.

    ``u·total`` → fixed-depth binary search for the first row offset whose
    inclusive prefix exceeds the target (zero-weight neighbours share the
    previous prefix value, so they can never be landed on).  ``depth``
    bounds the halvings (see :func:`search_depth`; the default 32 covers
    any int32 degree).  Returns next nodes [W]; -1 for inactive / empty /
    zero-total lanes.
    """
    E = graph.num_edges
    deg = degrees_of(graph, cur)
    vs = jnp.maximum(cur, 0)
    start = graph.indptr[vs]
    u = jax.vmap(lambda k: jax.random.uniform(
        jax.random.fold_in(k, ITS_SALT), ()))(rng)
    total = tables.total[vs]
    target = u * total

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) // 2
        val = tables.cdf[jnp.clip(start + mid, 0, E - 1)]
        go_right = (val <= target) & (lo < hi)
        new_lo = jnp.where(go_right, mid + 1, lo)
        new_hi = jnp.where(go_right | (lo >= hi), hi, mid)
        return (new_lo, new_hi)

    lo0 = jnp.zeros_like(deg)
    lo, _ = jax.lax.fori_loop(0, depth, body, (lo0, deg))
    sel = jnp.clip(lo, 0, jnp.maximum(deg - 1, 0))
    nxt = graph.indices[jnp.clip(start + sel, 0, E - 1)]
    ok = active & (deg > 0) & (total > 0)
    return jnp.where(ok, nxt, -1)


def alias_select(graph: CSRGraph, tables: PrecompTables, cur: jax.Array,
                 rng: jax.Array, *, active: jax.Array) -> jax.Array:
    """O(1) alias draw: column = ⌊u₁·d⌋, keep it iff u₂ < prob, else take
    its alias partner.  Returns next nodes [W]; -1 as in its_select."""
    E = graph.num_edges
    deg = degrees_of(graph, cur)
    vs = jnp.maximum(cur, 0)
    start = graph.indptr[vs]
    uu = jax.vmap(lambda k: jax.random.uniform(
        jax.random.fold_in(k, ALIAS_SALT), (2,)))(rng)
    col = jnp.minimum((uu[:, 0] * deg.astype(jnp.float32)).astype(jnp.int32),
                      jnp.maximum(deg - 1, 0))
    pos = jnp.clip(start + col, 0, E - 1)
    p_col = tables.alias_prob[pos]
    a_col = tables.alias_off[pos]
    sel = jnp.where(uu[:, 1] < p_col, col, a_col)
    nxt = graph.indices[jnp.clip(start + sel, 0, E - 1)]
    ok = active & (deg > 0) & (tables.total[vs] > 0)
    return jnp.where(ok, nxt, -1)
