"""Fault-tolerant checkpointing: sharded npz + manifest, elastic restore.

Design (production pattern, host-side):
* one ``.npy`` file per pytree leaf under ``step_<N>/``, plus a JSON
  manifest (tree structure, dtypes, shapes, step, wall-time);
* writes go to ``<dir>.tmp`` then ``os.rename`` — a crash mid-save never
  corrupts the latest checkpoint (atomic-commit);
* optional async save thread (snapshot to host first, write in background)
  so the train loop never blocks on disk;
* restore is **elastic**: arrays are materialised with whatever sharding
  the *current* mesh rules dictate (device_put with the target
  NamedSharding), so a job saved on a 2×16×16 mesh restarts cleanly on
  16×16 or on one host — the multi-pod FT story.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"


def _bfloat16_dtype():
    import ml_dtypes

    return np.dtype(ml_dtypes.bfloat16)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
            else:
                keys.append(str(p))
        out.append((SEP.join(keys) or "leaf", leaf))
    return out, treedef


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Atomic synchronous save.  Returns the final checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "time": time.time(), "leaves": []}
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        if arr.dtype == _bfloat16_dtype():  # npy can't round-trip bf16
            arr = arr.view(np.uint16)
            dtype_name = "bfloat16"
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {"name": name, "file": fn, "shape": list(arr.shape),
             "dtype": dtype_name})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(directory: str, like: Any,
                    step: Optional[int] = None,
                    shardings: Optional[Any] = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``.  ``shardings`` (optional
    pytree of NamedSharding matching ``like``) reshards on load — elastic
    restore onto a different mesh."""
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = step if step is not None else steps[-1]
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _flatten_with_paths(like)
    if len(leaves_like) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, expected "
            f"{len(leaves_like)} — structure changed?")
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
    out = []
    for i, ((name, leaf), meta) in enumerate(zip(leaves_like,
                                                 manifest["leaves"])):
        if meta["name"] != name:
            raise ValueError(f"leaf {i}: name mismatch {meta['name']} != {name}")
        arr = np.load(os.path.join(path, meta["file"]))
        if meta["dtype"] == "bfloat16":
            arr = arr.view(_bfloat16_dtype())
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(f"leaf {name}: shape {arr.shape} != {np.shape(leaf)}")
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)
    return tree, step


def available_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                steps.append(int(d[5:]))
            except ValueError:
                pass
    return sorted(steps)


@dataclasses.dataclass
class CheckpointManager:
    """save-every-N with retention + optional async writes."""

    directory: str
    save_every: int = 100
    keep: int = 3
    async_save: bool = True

    def __post_init__(self):
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree: Any, force: bool = False) -> bool:
        if not force and (step == 0 or step % self.save_every != 0):
            return False
        # snapshot to host memory *now*, write in background
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, host_tree), daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(step, host_tree)
        return True

    def _save_and_gc(self, step: int, tree: Any):
        save_checkpoint(self.directory, step, tree)
        for old in available_steps(self.directory)[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{old:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def restore_latest(self, like: Any, shardings=None):
        return load_checkpoint(self.directory, like, shardings=shardings)
