from repro.serving.engine import GenerateConfig, generate, make_serve_step

__all__ = ["GenerateConfig", "generate", "make_serve_step"]
