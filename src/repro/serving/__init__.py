from repro.serving.engine import GenerateConfig, generate, make_serve_step
from repro.serving.frontend import FrontendConfig, WalkFrontend
from repro.serving.stats import LatencyWindow, percentile
from repro.serving.walk_service import (
    CANCELLED,
    COMPLETED,
    EXPIRED,
    REJECT_DEADLINE,
    REJECT_QUEUE_FULL,
    REJECT_UNKNOWN_PROGRAM,
    AdmissionQueue,
    DeficitRoundRobin,
    ServedWalk,
    ServiceConfig,
    ServiceStats,
    ServiceTenant,
    SimClock,
    SubmitReceipt,
    WalkQuery,
    WalkService,
)

__all__ = [
    "GenerateConfig", "generate", "make_serve_step",
    "FrontendConfig", "WalkFrontend",
    "LatencyWindow", "percentile",
    "CANCELLED", "COMPLETED", "EXPIRED",
    "REJECT_DEADLINE", "REJECT_QUEUE_FULL", "REJECT_UNKNOWN_PROGRAM",
    "AdmissionQueue", "DeficitRoundRobin", "ServedWalk", "ServiceConfig",
    "ServiceStats", "ServiceTenant", "SimClock", "SubmitReceipt",
    "WalkQuery", "WalkService",
]
