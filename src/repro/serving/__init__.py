from repro.serving.engine import GenerateConfig, generate, make_serve_step
from repro.serving.stats import LatencyWindow, percentile
from repro.serving.walk_service import (
    COMPLETED,
    EXPIRED,
    REJECT_DEADLINE,
    REJECT_QUEUE_FULL,
    REJECT_UNKNOWN_PROGRAM,
    AdmissionQueue,
    ServedWalk,
    ServiceConfig,
    ServiceStats,
    ServiceTenant,
    SimClock,
    SubmitReceipt,
    WalkQuery,
    WalkService,
)

__all__ = [
    "GenerateConfig", "generate", "make_serve_step",
    "LatencyWindow", "percentile",
    "COMPLETED", "EXPIRED",
    "REJECT_DEADLINE", "REJECT_QUEUE_FULL", "REJECT_UNKNOWN_PROGRAM",
    "AdmissionQueue", "ServedWalk", "ServiceConfig", "ServiceStats",
    "ServiceTenant", "SimClock", "SubmitReceipt", "WalkQuery",
    "WalkService",
]
