"""SLO latency telemetry: ring-buffered samples with exact percentiles.

``LatencyWindow`` is the building block behind the walk service's p50/p99
queue-wait and completion-latency counters: a fixed-capacity ring buffer
of float samples whose :meth:`percentile` matches ``numpy.percentile``
(the default ``linear`` interpolation) over the retained window exactly —
pinned by unit tests against numpy on the edge cases (empty window,
single sample, ties, wraparound).
"""
from __future__ import annotations

import math

import numpy as np


def percentile(values, q: float) -> float:
    """Exact q-th percentile (numpy's default ``linear`` interpolation).

    Returns ``nan`` for an empty sample set — a window with no completed
    queries has no latency, and ``nan`` propagates visibly instead of
    masquerading as 0ms.
    """
    a = np.sort(np.asarray(values, np.float64).reshape(-1))
    if a.size == 0:
        return float("nan")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    rank = (q / 100.0) * (a.size - 1)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    t = rank - lo
    # numpy's _lerp, bit for bit: one fused form per half so the unit
    # tests can assert == against numpy.percentile, not approx
    diff = a[hi] - a[lo]
    if t < 0.5:
        return float(a[lo] + diff * t)
    return float(a[hi] - diff * (1.0 - t))


class LatencyWindow:
    """Fixed-capacity ring buffer of latency samples (seconds).

    Keeps the most recent ``capacity`` samples; ``add`` is O(1), the
    percentiles sort the retained window on demand (windows are small —
    the service reads them once per epoch, not per query).
    """

    def __init__(self, capacity: int = 2048):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._buf = np.zeros(self.capacity, np.float64)
        self._n = 0  # total samples ever added

    def __len__(self) -> int:
        """Samples currently retained (≤ capacity)."""
        return min(self._n, self.capacity)

    @property
    def total(self) -> int:
        """Samples ever added (retained + evicted)."""
        return self._n

    def add(self, value: float) -> None:
        self._buf[self._n % self.capacity] = float(value)
        self._n += 1

    def values(self) -> np.ndarray:
        """The retained window, oldest first."""
        if self._n <= self.capacity:
            return self._buf[:self._n].copy()
        cut = self._n % self.capacity
        return np.concatenate([self._buf[cut:], self._buf[:cut]])

    def percentile(self, q: float) -> float:
        """Exact q-th percentile of the retained window (nan if empty)."""
        return percentile(self._buf[:len(self)], q)

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)
