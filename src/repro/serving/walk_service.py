"""Walk-as-a-service: a continuously-batched query serving loop.

``WalkService`` turns the engine's streaming epoch scheduler
(:class:`repro.core.EpochScheduler` — fixed walker slots, host refill
queue, mid-run slot recycling) into a long-lived service: concurrent
clients :meth:`~WalkService.submit` walk queries, the service admits them
into free slots at epoch boundaries without retrace, streams completed
paths back as walkers terminate, and interleaves ``RebuildQueue`` drains
from concurrent :meth:`~WalkService.update_graph` calls.

On top of the scheduler it adds the serving layer a batch engine lacks:

* **Multi-tenancy** — each query carries its own walk-program choice
  (:attr:`WalkQuery.program`, a name resolved against the
  ``repro.walks`` registry or the service's ``programs`` mapping).  Each
  program gets its own engine + slot pool (one jitted epoch per tenant;
  lanes of different programs never share a kernel, so per-tenant
  results stay bit-identical to a batch run).
* **Admission control** — a bounded pending queue with priorities and
  arrival-order fairness (FIFO within priority, optional aging so low
  priorities cannot starve), rejecting with a reason when the queue is
  full or a deadline is infeasible.
* **Cross-tenant fairness** — a deficit-round-robin scheduler
  (:class:`DeficitRoundRobin`) apportions GPU time between tenants in
  *walker-steps* (the ``EpochReport.walker_steps`` charge): each tenant
  accrues ``quantum * weight`` credit per service step and runs epochs
  until its credit is spent, so a hot tenant cannot starve light ones,
  idle quanta roll over (bounded by ``deficit_cap``), and weighted
  walker-step shares converge to the configured ratio under overload.
* **Cancellation** — :meth:`~WalkService.cancel` retires a ticket
  wherever it is: dropped from the pending queue, or killed in its slot
  through the alive-mask machinery with the partial path returned.
* **Deadline enforcement** — pending queries past their deadline expire
  in the queue; in-flight walkers past theirs are killed at the next
  epoch boundary through the scheduler's alive-mask machinery (exactly
  how ``should_stop`` retires a lane), returning the partial path.
* **SLO telemetry** — :class:`ServiceStats`, the service counterpart of
  ``WalkResult``: p50/p99 queue wait and completion latency over ring
  buffers (:mod:`repro.serving.stats`), slot occupancy, and counters
  that conserve — ``admitted == completed + expired + pending +
  in_flight`` after every event.

Determinism contract (what tests/test_service.py pins)
------------------------------------------------------
Random streams are keyed per *tenant-local query id* in submission
order, exactly like a batch run keys them per query index — so every
served path is bit-identical to ``WalkEngine.run`` over the same
queries: the i-th accepted query of a program matches row i of
``run(starts_in_submission_order)`` with the same key, regardless of
arrival pattern, priorities, slot count or epoch cadence.  The clock is
injected (``clock=``), so a simulated clock makes whole traces —
arrivals, deadline storms, overload — exactly replayable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import jax
import numpy as np

from repro.core import EngineConfig, WalkEngine
from repro.core.runtime import DEFAULT_EPOCH_LEN
from repro.core.types import WalkProgram
from repro.graphs import GraphDelta
from repro.serving.stats import LatencyWindow

# Rejection reason codes (SubmitReceipt.reason)
REJECT_QUEUE_FULL = "queue-full"
REJECT_DEADLINE = "deadline-infeasible"
REJECT_UNKNOWN_PROGRAM = "unknown-program"

# ServedWalk.status values
COMPLETED = "completed"
EXPIRED = "expired"
CANCELLED = "cancelled"

# ServiceConfig.fairness modes
FAIRNESS_MODES = ("drr", "epoch")


class SimClock:
    """Deterministic manually-advanced clock for replayable traces.

    Pass an instance as ``WalkService(clock=...)`` (it is callable like
    ``time.monotonic``); tests and the ``--sim-clock`` CLI mode advance
    it explicitly, so deadline storms and arrival bursts replay exactly.
    """

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"SimClock cannot run backwards (dt={dt})")
        self.now += float(dt)
        return self.now


@dataclasses.dataclass(frozen=True)
class WalkQuery:
    """One client walk request.

    ``program`` names the walk program (multi-tenant: resolved against
    the service's ``programs`` mapping, then the ``repro.walks``
    registry).  ``deadline`` is an *absolute* service-clock time by which
    the full path must be delivered; ``priority`` orders admission
    (higher first, FIFO within a priority level).
    """

    start: int
    program: str = "deepwalk"
    priority: int = 0
    deadline: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class SubmitReceipt:
    """What ``submit`` returns: the ticket (a service-global query id)
    when accepted, or the rejection reason code + human detail."""

    accepted: bool
    ticket: Optional[int] = None
    reason: Optional[str] = None
    detail: Optional[str] = None


@dataclasses.dataclass
class ServedWalk:
    """One finished query, streamed back from ``step``.

    ``status`` is ``"completed"`` (walked to termination: full length,
    dead end, or the program's own ``should_stop``), ``"expired"``
    (deadline passed) or ``"cancelled"`` (client cancel) — for the
    latter two ``path`` holds the partial walk if the query ever held a
    slot, else ``None``.  ``wait`` is queue time (nan when never
    admitted); ``latency`` is submit → finish.
    """

    ticket: int
    program: str
    status: str
    path: Optional[np.ndarray]
    steps: int
    submit_time: float
    admit_time: Optional[float]
    finish_time: float
    wait: float
    latency: float


@dataclasses.dataclass
class _Ticket:
    """Service-side bookkeeping for one accepted query."""

    ticket: int  # service-global id (client-facing)
    qid: int  # tenant-local query id — picks the RNG stream + path row
    query: WalkQuery
    submit_time: float
    admit_time: Optional[float] = None

    # AdmissionQueue reads these off the queued item:
    @property
    def priority(self) -> int:
        return self.query.priority

    @property
    def deadline(self) -> Optional[float]:
        return self.query.deadline


class AdmissionQueue:
    """Bounded pending queue: priority order, FIFO within a priority,
    optional aging so sustained high-priority load cannot starve anyone.

    Items need ``priority`` / ``deadline`` / ``submit_time`` attributes.
    Effective priority at time ``now`` is ``priority + floor((now -
    submit_time) / aging_interval)`` (aging disabled at 0) — two items
    with the same base priority age in lockstep, so arrival order between
    them is always preserved, while a waiting low-priority item
    eventually outranks any bounded fresh priority: an item of priority
    ``p`` waits at most ``(P - p) * aging_interval`` behind priority-``P``
    arrivals before it wins the tie-break (lower sequence number) too.
    """

    def __init__(self, max_pending: Optional[int] = None,
                 aging_interval: float = 0.0):
        if max_pending is not None and max_pending < 0:
            raise ValueError(
                f"max_pending must be >= 0 or None, got {max_pending}")
        if aging_interval < 0:
            raise ValueError(
                f"aging_interval must be >= 0 (0 disables aging), "
                f"got {aging_interval}")
        self.max_pending = max_pending
        self.aging_interval = float(aging_interval)
        self._items: List[tuple] = []  # (seq, item), seq strictly increasing
        self._seq = 0

    def __len__(self) -> int:
        return len(self._items)

    def items(self) -> list:
        """Pending items in arrival order (inspection only)."""
        return [it for _, it in self._items]

    def effective_priority(self, item, now: float) -> int:
        p = int(item.priority)
        if self.aging_interval > 0:
            p += int(max(0.0, now - item.submit_time)
                     // self.aging_interval)
        return p

    def push(self, item) -> bool:
        """Enqueue; False when the queue is at ``max_pending``."""
        if (self.max_pending is not None
                and len(self._items) >= self.max_pending):
            return False
        self._items.append((self._seq, item))
        self._seq += 1
        return True

    def pop_batch(self, k: int, now: float) -> list:
        """The next ``k`` items to admit: highest effective priority
        first, sequence number (arrival order) breaking ties."""
        if k <= 0 or not self._items:
            return []
        order = sorted(
            range(len(self._items)),
            key=lambda i: (-self.effective_priority(self._items[i][1], now),
                           self._items[i][0]))
        chosen = order[:k]
        batch = [self._items[i][1] for i in chosen]
        drop = set(chosen)
        self._items = [x for i, x in enumerate(self._items)
                       if i not in drop]
        return batch

    def remove(self, item) -> bool:
        """Drop one queued item by identity (cancellation); False when
        the item is not pending here."""
        for i, (_, it) in enumerate(self._items):
            if it is item:
                del self._items[i]
                return True
        return False

    def expire(self, now: float) -> list:
        """Remove and return every pending item whose deadline passed."""
        out = [it for _, it in self._items
               if it.deadline is not None and it.deadline <= now]
        if out:
            self._items = [(s, it) for s, it in self._items
                           if not (it.deadline is not None
                                   and it.deadline <= now)]
        return out


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of the serving loop (the ``EngineConfig`` counterpart)."""

    #: walker slots per tenant (one slot pool per walk program)
    slots: int = 64
    #: scan steps between epoch boundaries (admission/expiry/streaming
    #: all happen at boundaries); None → the engine default cadence
    epoch_len: Optional[int] = 8
    #: walk length served per query; None → each program's ``walk_len``
    num_steps: Optional[int] = None
    #: total pending queries across tenants before queue-full rejection
    max_pending: int = 1024
    #: seconds of queue wait per +1 effective priority (0 disables
    #: aging; see AdmissionQueue — bounds starvation under load)
    aging_interval: float = 0.0
    #: a deadline closer than this to now is rejected as infeasible
    #: instead of admitted-then-expired
    min_service_time: float = 0.0
    #: ring-buffer capacity of the p50/p99 latency windows
    latency_window: int = 2048
    #: per-tenant run key seed (stream i of a tenant = fold_in(key(seed), i))
    seed: int = 0
    #: cross-tenant scheduling: "drr" (deficit round robin in
    #: walker-steps — see DeficitRoundRobin) or "epoch" (the legacy one-
    #: epoch-per-busy-tenant round robin, load-blind)
    fairness: str = "drr"
    #: DRR credit accrued per tenant per service step, in walker-steps;
    #: None → slots * epoch_len (one fully-occupied epoch's worth)
    quantum: Optional[int] = None
    #: idle quanta roll over up to deficit_cap * quantum * weight
    deficit_cap: float = 4.0
    #: per-tenant walker-step weight by program name (unlisted → 1.0)
    weights: Optional[Mapping[str, float]] = None
    #: shard every tenant's slot pool over this many local devices
    #: (scheduler(devices=N); results stay bit-identical to devices=1)
    devices: int = 1

    def __post_init__(self):
        if self.slots <= 0:
            raise ValueError(f"slots must be positive, got {self.slots}")
        if self.epoch_len is not None and self.epoch_len <= 0:
            raise ValueError(
                f"epoch_len must be positive or None, got {self.epoch_len}")
        if self.num_steps is not None and self.num_steps <= 0:
            raise ValueError(
                f"num_steps must be positive or None, got {self.num_steps}")
        if self.max_pending < 0:
            raise ValueError(
                f"max_pending must be >= 0, got {self.max_pending}")
        if self.aging_interval < 0:
            raise ValueError(
                f"aging_interval must be >= 0, got {self.aging_interval}")
        if self.min_service_time < 0:
            raise ValueError(
                f"min_service_time must be >= 0, "
                f"got {self.min_service_time}")
        if self.fairness not in FAIRNESS_MODES:
            raise ValueError(
                f"fairness must be one of {FAIRNESS_MODES}, "
                f"got {self.fairness!r}")
        if self.quantum is not None and self.quantum <= 0:
            raise ValueError(
                f"quantum must be positive or None, got {self.quantum}")
        if self.deficit_cap < 1:
            raise ValueError(
                f"deficit_cap must be >= 1, got {self.deficit_cap}")
        if self.devices <= 0:
            raise ValueError(
                f"devices must be positive, got {self.devices}")
        for name, w in dict(self.weights or {}).items():
            if w <= 0:
                raise ValueError(
                    f"tenant weight must be positive, got {name}={w}")


class DeficitRoundRobin:
    """Cross-tenant deficit-round-robin credit ledger, in walker-steps.

    Classic DRR (Shreedhar & Varghese) with the epoch as the service
    unit and ``EpochReport.walker_steps`` — live walker-steps actually
    executed — as the cost: per round every *busy* tenant accrues
    ``quantum * weight`` credit (capped at ``cap`` rounds' worth, so
    idle quanta roll over but cannot bank unboundedly), and a tenant
    runs epochs while its deficit stays positive, each epoch charged at
    its true live cost.  A deficit may go negative by at most one
    epoch's cost, which is what bounds any tenant's overdraft — hence
    long-run walker-step shares converge to the weight ratio whenever
    demand saturates, and no busy tenant waits more than
    ``ceil(max_epoch_cost / (quantum * weight))`` rounds for service.

    The ledger is pure host arithmetic (no clock, no RNG) so schedules
    are exactly replayable; tests/test_transport.py property-tests work
    conservation, weighted shares, and the starvation bound over random
    cost sequences.
    """

    def __init__(self, quantum: int, cap: float = 4.0):
        if quantum <= 0:
            raise ValueError(f"quantum must be positive, got {quantum}")
        if cap < 1:
            raise ValueError(f"cap must be >= 1, got {cap}")
        self.quantum = int(quantum)
        self.cap = float(cap)
        self._weight: Dict[str, float] = {}
        self._deficit: Dict[str, float] = {}
        self._charged: Dict[str, int] = {}

    def register(self, name: str, weight: float = 1.0) -> None:
        if weight <= 0:
            raise ValueError(
                f"tenant weight must be positive, got {name}={weight}")
        if name not in self._weight:
            self._weight[name] = float(weight)
            self._deficit[name] = 0.0
            self._charged[name] = 0

    def weight(self, name: str) -> float:
        return self._weight[name]

    def deficit(self, name: str) -> float:
        return self._deficit[name]

    def charged(self, name: str) -> int:
        """Total walker-steps ever charged to ``name``."""
        return self._charged[name]

    def begin_round(self, active) -> None:
        """Accrue one quantum (weight-scaled, cap-bounded) for every
        busy tenant; tenants with nothing to run accrue nothing, so an
        idle tenant never banks credit against future arrivals beyond
        the rollover cap."""
        for name in active:
            q = self.quantum * self._weight[name]
            self._deficit[name] = min(self._deficit[name] + q,
                                      q * self.cap)

    def runnable(self, name: str) -> bool:
        return self._deficit[name] > 0.0

    def charge(self, name: str, cost: int) -> None:
        if cost < 0:
            raise ValueError(f"cost must be >= 0, got {cost}")
        self._deficit[name] -= float(cost)
        self._charged[name] += int(cost)

    def pick(self, active) -> str:
        """Work-conservation backstop: when no busy tenant is runnable
        (all deficits spent), the device must not idle — serve the
        least-overdrawn tenant (max deficit; first in ``active`` order
        on ties, so the choice is deterministic)."""
        return max(active, key=lambda n: self._deficit[n])


@dataclasses.dataclass(frozen=True)
class ServiceStats:
    """Snapshot of the service counters — the ``WalkResult`` of serving.

    Counter conservation (asserted by tests after every scripted event):
    ``submitted == admitted + rejected`` and ``admitted == completed +
    expired + cancelled + pending + in_flight`` — a query is always in
    exactly one place.  ``occupancy`` never exceeds ``slots``.
    ``per_tenant`` attributes epochs and walker-steps to each tenant
    (plus its DRR weight and current deficit); the per-tenant sums must
    equal the service-wide ``epochs`` / ``live_steps`` totals, and
    ``conserves()`` checks that too.
    """

    submitted: int
    admitted: int
    rejected_full: int
    rejected_deadline: int
    rejected_unknown: int
    completed: int
    expired: int
    cancelled: int
    pending: int
    in_flight: int
    epochs: int
    slots: int
    occupancy: int
    peak_occupancy: int
    live_steps: int
    frac_rjs: float
    frac_precomp: float
    frac_stale: float
    rebuilt_rows: int
    queue_wait_p50: float
    queue_wait_p99: float
    latency_p50: float
    latency_p99: float
    #: tenant name -> {"epochs_run", "walker_steps", "weight", "deficit"}
    per_tenant: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)

    @property
    def rejected(self) -> int:
        return (self.rejected_full + self.rejected_deadline
                + self.rejected_unknown)

    def conserves(self) -> bool:
        """The admission ledger balances (see class docstring)."""
        return (self.submitted == self.admitted + self.rejected
                and self.admitted == self.completed + self.expired
                + self.cancelled + self.pending + self.in_flight
                and 0 <= self.occupancy <= max(self.slots, 0)
                # every in-flight query holds exactly one slot
                and self.in_flight == self.occupancy
                # per-tenant attribution sums back to the totals
                and self.epochs == sum(
                    int(pt["epochs_run"]) for pt in self.per_tenant.values())
                and self.live_steps == sum(
                    int(pt["walker_steps"])
                    for pt in self.per_tenant.values()))


class ServiceTenant:
    """One walk program's serving lane group: engine + slot pool +
    pending queue + in-flight ledger.  Created on a program's first
    accepted query."""

    def __init__(self, name: str, program: WalkProgram, graph,
                 engine_config: EngineConfig, config: ServiceConfig):
        self.name = name
        self.program = program
        self.engine = WalkEngine(graph, program, engine_config)
        self.num_steps = int(config.num_steps or program.walk_len)
        self.key = jax.random.key(config.seed)
        # track_tables: the serving loop re-adopts the engine's precomp
        # tables every epoch, so background rebuild repairs (and graph
        # mutations) become visible at epoch granularity — the piecewise-
        # deterministic serving contract (vs. the per-run pinned view a
        # batch WalkEngine.run serves from)
        self.sched = self.engine.scheduler(
            num_steps=self.num_steps, key=self.key, slots=config.slots,
            epoch_len=config.epoch_len, track_tables=True,
            devices=config.devices)
        self.queue = AdmissionQueue(max_pending=None,
                                    aging_interval=config.aging_interval)
        self.next_qid = 0  # tenant-local id = offline run's query index
        self.inflight: Dict[int, _Ticket] = {}
        self.epochs_run = 0  # per-tenant attribution (ServiceStats)


class WalkService:
    """The long-lived serving loop (see module docstring).

    The loop is a synchronous state machine: :meth:`submit` enqueues,
    :meth:`step` runs ONE epoch boundary — expire, admit, execute, and
    stream back whatever finished — and :meth:`drain` steps until idle.
    Drive :meth:`step` from a thread, an event loop, or a test's
    simulated clock; the service itself never sleeps or spawns threads,
    which is what makes scripted traces exactly replayable.
    """

    def __init__(self, graph, config: Optional[ServiceConfig] = None,
                 engine_config: Optional[EngineConfig] = None,
                 programs: Optional[Dict[str, WalkProgram]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.graph = graph
        self.config = config or ServiceConfig()
        self.engine_config = engine_config or EngineConfig()
        self.clock = clock
        self._programs = dict(programs or {})
        self._tenants: Dict[str, ServiceTenant] = {}
        self._next_ticket = 0
        self._epochs = 0
        self._peak_occupancy = 0
        self._c = {"submitted": 0, "admitted": 0, "rejected_full": 0,
                   "rejected_deadline": 0, "rejected_unknown": 0,
                   "completed": 0, "expired": 0, "cancelled": 0}
        self._wait_window = LatencyWindow(self.config.latency_window)
        self._latency_window = LatencyWindow(self.config.latency_window)
        # live ticket index (popped on completion/expiry/cancel) — what
        # lets cancel() find a query wherever it currently is
        self._tickets: Dict[int, Tuple[str, _Ticket]] = {}
        quantum = int(self.config.quantum
                      or self.config.slots * (self.config.epoch_len
                                              or DEFAULT_EPOCH_LEN))
        self._drr = DeficitRoundRobin(quantum=quantum,
                                      cap=self.config.deficit_cap)

    # ------------------------------------------------------------ tenants
    def _resolve_program(self, name: str) -> Optional[WalkProgram]:
        if name in self._programs:
            return self._programs[name]
        from repro.walks import WORKLOADS, make_workload
        if name in WORKLOADS:
            return make_workload(name)
        return None

    def tenant(self, name: str) -> ServiceTenant:
        """The lane group serving ``name``, created on first use.
        Raises KeyError for a program neither registered nor supplied."""
        t = self._tenants.get(name)
        if t is None:
            program = self._resolve_program(name)
            if program is None:
                from repro.walks import WORKLOADS
                raise KeyError(
                    f"{name!r} names no walk program; known: "
                    f"{sorted(set(WORKLOADS) | set(self._programs))}")
            t = ServiceTenant(name, program, self.graph,
                              self.engine_config, self.config)
            self._tenants[name] = t
            self._drr.register(
                name, dict(self.config.weights or {}).get(name, 1.0))
        return t

    @property
    def pending(self) -> int:
        return sum(len(t.queue) for t in self._tenants.values())

    @property
    def in_flight(self) -> int:
        return sum(len(t.inflight) for t in self._tenants.values())

    @property
    def idle(self) -> bool:
        return self.pending == 0 and self.in_flight == 0

    # ------------------------------------------------------------- submit
    def submit(self, query: WalkQuery) -> SubmitReceipt:
        """Admission control: accept into the pending queue (returning
        the ticket) or reject with a reason — the queue is full, the
        deadline is infeasible, or the program is unknown.  Rejection
        never builds a tenant, so a typo cannot cost an engine trace."""
        now = self.clock()
        self._c["submitted"] += 1
        if (query.program not in self._tenants
                and self._resolve_program(query.program) is None):
            self._c["rejected_unknown"] += 1
            return SubmitReceipt(
                accepted=False, reason=REJECT_UNKNOWN_PROGRAM,
                detail=f"{query.program!r} names no walk program")
        if (query.deadline is not None
                and query.deadline - now <= self.config.min_service_time):
            self._c["rejected_deadline"] += 1
            return SubmitReceipt(
                accepted=False, reason=REJECT_DEADLINE,
                detail=f"deadline {query.deadline:.3f} within "
                       f"min_service_time of now={now:.3f}")
        if self.pending >= self.config.max_pending:
            self._c["rejected_full"] += 1
            return SubmitReceipt(
                accepted=False, reason=REJECT_QUEUE_FULL,
                detail=f"{self.pending} pending >= max_pending="
                       f"{self.config.max_pending}")
        tenant = self.tenant(query.program)
        ticket = self._next_ticket
        self._next_ticket += 1
        t = _Ticket(ticket=ticket, qid=tenant.next_qid, query=query,
                    submit_time=now)
        tenant.next_qid += 1
        tenant.queue.push(t)  # per-tenant queue is unbounded; the
        self._c["admitted"] += 1  # service-level max_pending bound held
        self._tickets[ticket] = (tenant.name, t)
        return SubmitReceipt(accepted=True, ticket=ticket)

    def cancel(self, ticket: int) -> Optional[ServedWalk]:
        """Retire an accepted query by ticket, wherever it is: dropped
        from the pending queue (``path=None``), or killed in its slot
        through the scheduler's alive-mask machinery with the partial
        path harvested so far.  Returns the terminal ``ServedWalk``
        (status ``"cancelled"``), or None when the ticket is unknown or
        already finished — cancellation never races a delivered result."""
        owner = self._tickets.get(int(ticket))
        if owner is None:
            return None
        now = self.clock()
        name, t = owner
        tenant = self._tenants[name]
        if tenant.queue.remove(t):
            walk = self._finish_walk(t, tenant, now, admitted=False,
                                     status=CANCELLED)
        elif t.qid in tenant.inflight:
            tenant.sched.kill([t.qid])
            del tenant.inflight[t.qid]
            walk = self._finish_walk(t, tenant, now, admitted=True,
                                     status=CANCELLED)
        else:  # pragma: no cover — _tickets is popped on every finish
            return None
        del self._tickets[t.ticket]
        self._c["cancelled"] += 1
        return walk

    # --------------------------------------------------------------- loop
    def _finish_walk(self, t: _Ticket, tenant: ServiceTenant,
                     now: float, admitted: bool, status: str) -> ServedWalk:
        """Terminal ServedWalk for a query that did NOT walk to
        completion (expired or cancelled): partial path when it ever
        held a slot, else ``path=None``."""
        path = steps = None
        if admitted:
            path = tenant.sched.paths[t.qid].copy()
            steps = int((path[1:] >= 0).sum())
        return ServedWalk(
            ticket=t.ticket, program=tenant.name, status=status,
            path=path, steps=steps or 0, submit_time=t.submit_time,
            admit_time=t.admit_time, finish_time=now,
            wait=(t.admit_time - t.submit_time) if admitted
            else float("nan"),
            latency=now - t.submit_time)

    def _expire_tenant(self, tenant: ServiceTenant, now: float,
                       served: List[ServedWalk]) -> None:
        """Deadline expiry — pending queries never get a slot, and
        in-flight walkers are retired through the scheduler's alive-mask
        machinery (like a should_stop verdict), keeping the partial path
        harvested so far."""
        for t in tenant.queue.expire(now):
            self._c["expired"] += 1
            self._tickets.pop(t.ticket, None)
            served.append(self._finish_walk(t, tenant, now,
                                            admitted=False,
                                            status=EXPIRED))
        late = [qid for qid, t in tenant.inflight.items()
                if t.deadline is not None and t.deadline <= now]
        if late:
            tenant.sched.kill(late)
            for qid in late:
                t = tenant.inflight.pop(qid)
                self._c["expired"] += 1
                self._tickets.pop(t.ticket, None)
                served.append(self._finish_walk(t, tenant, now,
                                                admitted=True,
                                                status=EXPIRED))

    def _admit_tenant(self, tenant: ServiceTenant, now: float) -> None:
        """Epoch-boundary admission into free slots, by effective
        priority (FIFO within priority, aged against starvation)."""
        free = tenant.sched.free_slots()
        if free.size and len(tenant.queue):
            batch = tenant.queue.pop_batch(int(free.size), now)
            tenant.sched.admit([t.qid for t in batch],
                               [t.query.start for t in batch])
            for t in batch:
                t.admit_time = now
                tenant.inflight[t.qid] = t
                self._wait_window.add(now - t.submit_time)

    def _run_tenant_epoch(self, tenant: ServiceTenant,
                          served: List[ServedWalk]):
        """One jitted epoch for ``tenant``; completions stream back
        immediately.  Returns the EpochReport (DRR charges off it)."""
        report = tenant.sched.run_epoch()
        self._epochs += 1
        tenant.epochs_run += 1
        self._peak_occupancy = max(self._peak_occupancy, report.occupied)
        fin = self.clock()
        for qid, steps in zip(report.completed, report.steps_taken):
            t = tenant.inflight.pop(int(qid))
            self._c["completed"] += 1
            self._tickets.pop(t.ticket, None)
            self._latency_window.add(fin - t.submit_time)
            served.append(ServedWalk(
                ticket=t.ticket, program=tenant.name,
                status=COMPLETED,
                path=tenant.sched.paths[int(qid)].copy(),
                steps=int(steps), submit_time=t.submit_time,
                admit_time=t.admit_time, finish_time=fin,
                wait=t.admit_time - t.submit_time,
                latency=fin - t.submit_time))
        return report

    def step(self) -> List[ServedWalk]:
        """Run one service step across every active tenant: expire
        lapsed deadlines (pending AND in-flight), admit from the queues
        into free slots, then apportion epochs by the configured
        fairness mode and return every query that finished — completed
        walkers stream out the epoch they terminate.

        Under ``fairness="drr"`` (the default) each busy tenant accrues
        one weighted quantum of walker-step credit and runs epochs until
        it is spent (re-admitting from its queue as slots free), so a
        backlogged tenant gets GPU time proportional to its weight —
        not to how often it happens to be busy.  ``fairness="epoch"``
        is the legacy one-epoch-per-busy-tenant round robin.  Both
        modes key random streams per tenant-local query id, so the
        fairness mode can never change a served path — only when it is
        served.
        """
        now = self.clock()
        served: List[ServedWalk] = []
        for tenant in self._tenants.values():
            self._expire_tenant(tenant, now, served)
            self._admit_tenant(tenant, now)
        if self.config.fairness == "epoch":
            for tenant in self._tenants.values():
                if tenant.sched.busy:
                    self._run_tenant_epoch(tenant, served)
            return served
        busy = [t for t in self._tenants.values() if t.sched.busy]
        if not busy:
            return served
        self._drr.begin_round([t.name for t in busy])
        ran = 0
        for tenant in busy:
            while tenant.sched.busy and self._drr.runnable(tenant.name):
                report = self._run_tenant_epoch(tenant, served)
                self._drr.charge(tenant.name, report.walker_steps)
                ran += 1
                # freed slots refill immediately so the next epoch of
                # this quantum runs full
                self._admit_tenant(tenant, now)
        if not ran:
            # Work conservation: every deficit can be overdrawn from the
            # previous round (an epoch's true cost lands after the
            # runnable check).  Never let the device idle while queries
            # wait — serve the least-overdrawn busy tenant.
            tenant = self._tenants[self._drr.pick([t.name for t in busy])]
            report = self._run_tenant_epoch(tenant, served)
            self._drr.charge(tenant.name, report.walker_steps)
        return served

    def drain(self, max_steps: Optional[int] = 100_000
              ) -> List[ServedWalk]:
        """Step until idle (deadlock guard: raises after ``max_steps``).
        Note a pending query whose deadline never passes and whose slots
        never free would spin — that cannot happen, since every admitted
        walker terminates within ``ceil(num_steps / epoch_len)`` epochs."""
        out: List[ServedWalk] = []
        steps = 0
        while not self.idle:
            if max_steps is not None and steps >= max_steps:
                raise RuntimeError(
                    f"drain() still busy after {steps} steps: "
                    f"{self.pending} pending, {self.in_flight} in flight")
            out.extend(self.step())
            steps += 1
        return out

    # ------------------------------------------------------ graph updates
    def update_graph(self, graph, invalidated=()) -> None:
        """Swap mutated edge weights in under live traffic: forwarded to
        every tenant engine (stale precomp rows enter each engine's
        ``RebuildQueue``, drained ``rebuild_budget`` rows per epoch by
        the serving loop — walks in flight keep stepping, falling back
        to the dynamic path on stale rows until the drains catch up).
        Tenants created later serve the new graph from scratch."""
        self.graph = graph
        for tenant in self._tenants.values():
            tenant.engine.update_graph(graph, invalidated)

    def apply_updates(self, inserts=None, deletes=None) -> dict:
        """Apply structural edits — edge inserts/deletes — under live
        traffic (see :meth:`WalkEngine.apply_updates` for the edit
        format and the delta-overlay semantics).

        Every tenant engine overlays the edits and queues its touched
        precomp rows for the amortized background rebuild; walks in
        flight keep stepping (their next epoch re-pins the spliced
        tables and resets the sampler carry, so they read post-edit
        payloads exactly like a fresh engine's walkers).  The service's
        own graph — what tenants created *later* are built from — is
        advanced by folding the same edits into a fresh CSR.  Returns
        ``{tenant name: UpdateReport}`` (the ``""`` key reports the
        service-graph fold)."""
        reports = {}
        delta = GraphDelta(self.graph)
        reports[""] = delta.apply(inserts, deletes)
        self.graph = delta.compact()
        for tenant in self._tenants.values():
            reports[tenant.name] = tenant.engine.apply_updates(
                inserts, deletes)
        return reports

    # ------------------------------------------------------------- stats
    def stats(self) -> ServiceStats:
        """Counter snapshot; ``stats().conserves()`` holds at any point
        between ``submit``/``step`` calls."""
        totals = {"live": 0, "rjs_served": 0, "fallbacks": 0,
                  "precomp_served": 0, "stale_served": 0}
        rebuilt = 0
        per_tenant = {}
        for t in self._tenants.values():
            for k in totals:
                totals[k] += t.sched.totals[k]
            rebuilt += t.sched.rebuilt_rows
            per_tenant[t.name] = {
                "epochs_run": t.epochs_run,
                "walker_steps": int(t.sched.totals["live"]),
                "weight": self._drr.weight(t.name),
                "deficit": self._drr.deficit(t.name),
            }
        live = totals["live"]
        return ServiceStats(
            submitted=self._c["submitted"],
            admitted=self._c["admitted"],
            rejected_full=self._c["rejected_full"],
            rejected_deadline=self._c["rejected_deadline"],
            rejected_unknown=self._c["rejected_unknown"],
            completed=self._c["completed"],
            expired=self._c["expired"],
            cancelled=self._c["cancelled"],
            pending=self.pending,
            in_flight=self.in_flight,
            epochs=self._epochs,
            slots=sum(t.sched.W for t in self._tenants.values()),
            occupancy=sum(t.sched.occupancy
                          for t in self._tenants.values()),
            peak_occupancy=self._peak_occupancy,
            live_steps=live,
            frac_rjs=totals["rjs_served"] / max(live, 1),
            frac_precomp=totals["precomp_served"] / max(live, 1),
            frac_stale=totals["stale_served"] / max(live, 1),
            rebuilt_rows=rebuilt,
            queue_wait_p50=self._wait_window.p50,
            queue_wait_p99=self._wait_window.p99,
            latency_p50=self._latency_window.p50,
            latency_p99=self._latency_window.p99,
            per_tenant=per_tenant,
        )
