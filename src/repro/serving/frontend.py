"""Asyncio TCP front-end for :class:`~repro.serving.WalkService`.

``WalkFrontend`` puts a network transport (length-prefixed JSON frames,
:mod:`repro.serving.transport`) in front of the synchronous serving
loop, without giving up what makes the loop testable: the service is
still a single-threaded state machine, and every interaction with it
happens under one lock.

Threading model
---------------
Two threads share the service through ``self.lock``:

* the **event-loop thread** runs a stdlib asyncio server; each
  connection's frames are decoded and dispatched inline — submit /
  poll / cancel / stats are cheap host-side operations, so handling
  them on the loop under the lock keeps request handling strictly
  ordered per connection (the determinism tests rely on this);
* the **driver thread** (``driver="thread"``) loops :meth:`pump` — one
  locked pass of ``service.step()`` (the jitted epoch work) plus
  routing finished walks into the owning connection's delivery buffer.
  ``driver="manual"`` starts no thread: the harness calls ``pump()``
  itself, which pins the event interleaving and makes loopback traces
  exactly replayable (the bit-identity tests run this way).

Control-plane requests can stall for the duration of one epoch while
the driver holds the lock — that bounded latency is the price of
keeping the service single-threaded, and epochs are short by
construction (``epoch_len`` steps).

Backpressure (credit-based, never blocking the driver)
------------------------------------------------------
Each connection holds ``client_buffer`` credits and the invariant

    len(delivery buffer) + outstanding tickets  <=  client_buffer

A submit consumes a credit; polling a finished walk out of the buffer
returns one.  Because every outstanding ticket terminates into the
buffer (completion, expiry, or cancel — the sum is constant), the
buffer can never overflow and the driver never waits on a slow client.
A submit arriving with no credit left is handled by policy:

* ``slow_client="suspend"`` (default): the submit is parked on the
  connection's stall list and admitted automatically when a poll frees
  credit — the client just sees a delayed ``submit-ok``.  The socket
  is *never* left unread (a parked submit must not block the poll that
  would unpark it); the stall list is itself bounded at
  ``client_buffer``, beyond which submits are rejected.
* ``slow_client="reject"``: a typed ``backpressure`` error frame.

Graceful drain
--------------
:meth:`drain` (or a client ``drain`` frame) stops admission — new
submits get ``draining`` errors, parked submits are flushed with the
same — then runs the service until idle or a wall-clock timeout, and
finally (``flush=True``) cancels whatever is left so every accepted
ticket terminates: in-flight walks are killed through the scheduler's
alive mask and delivered with their partial paths.
"""
from __future__ import annotations

import asyncio
import dataclasses
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

from repro.serving import transport as tp
from repro.serving.walk_service import WalkQuery, WalkService

SLOW_CLIENT_POLICIES = ("suspend", "reject")


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Transport/front-end knobs (the ServiceConfig counterpart)."""

    #: bind address; port 0 picks an ephemeral port (start() returns it)
    host: str = "127.0.0.1"
    port: int = 0
    #: per-connection delivery credits: buffered + outstanding walks
    client_buffer: int = 64
    #: what happens to a submit over the credit bound (module docstring)
    slow_client: str = "suspend"
    #: per-frame byte bound, both directions
    max_frame: int = tp.MAX_FRAME
    #: driver-thread sleep when the service is idle
    idle_sleep: float = 0.001
    #: default drain() wall-clock budget before the flush kicks in
    drain_timeout: float = 30.0

    def __post_init__(self):
        if self.client_buffer <= 0:
            raise ValueError(
                f"client_buffer must be positive, got {self.client_buffer}")
        if self.slow_client not in SLOW_CLIENT_POLICIES:
            raise ValueError(
                f"slow_client must be one of {SLOW_CLIENT_POLICIES}, "
                f"got {self.slow_client!r}")
        if self.max_frame <= 0:
            raise ValueError(
                f"max_frame must be positive, got {self.max_frame}")
        if self.idle_sleep < 0:
            raise ValueError(
                f"idle_sleep must be >= 0, got {self.idle_sleep}")
        if self.drain_timeout < 0:
            raise ValueError(
                f"drain_timeout must be >= 0, got {self.drain_timeout}")


class _Client:
    """Per-connection state (all access under WalkFrontend.lock)."""

    def __init__(self, cid: int):
        self.cid = cid
        self.buffer: deque = deque()  # finished walks awaiting poll
        self.outstanding: set = set()  # live tickets owned by this conn
        self.stalled: deque = deque()  # parked (rid, WalkQuery) submits
        self.writer: Optional[asyncio.StreamWriter] = None
        self.closed = False

    @property
    def used_credits(self) -> int:
        return len(self.buffer) + len(self.outstanding)


class WalkFrontend:
    """The TCP front-end (see module docstring).

    >>> fe = WalkFrontend(service)           # doctest: +SKIP
    >>> host, port = fe.start()              # doctest: +SKIP
    >>> ... clients connect, fe serves ...   # doctest: +SKIP
    >>> fe.drain(); fe.stop()                # doctest: +SKIP
    """

    def __init__(self, service: WalkService,
                 config: Optional[FrontendConfig] = None,
                 driver: str = "thread"):
        if driver not in ("thread", "manual"):
            raise ValueError(
                f"driver must be 'thread' or 'manual', got {driver!r}")
        self.service = service
        self.config = config or FrontendConfig()
        self.driver = driver
        self.lock = threading.RLock()
        self.address: Optional[Tuple[str, int]] = None
        self._clients: Dict[int, _Client] = {}
        self._next_cid = 0
        #: live ticket -> owning connection (routed on completion)
        self._ticket_owner: Dict[int, _Client] = {}
        self._draining = False
        self._stop_event = threading.Event()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._loop_thread: Optional[threading.Thread] = None
        self._driver_thread: Optional[threading.Thread] = None
        self._loop_error: Optional[BaseException] = None
        self._dropped_walks = 0  # finished walks of disconnected clients

    # ---------------------------------------------------------- lifecycle
    def start(self) -> Tuple[str, int]:
        """Bind, start the event-loop thread (and the driver thread
        unless ``driver="manual"``); returns the bound ``(host, port)``."""
        if self._loop_thread is not None:
            raise RuntimeError("frontend already started")
        ready = threading.Event()
        self._loop_thread = threading.Thread(
            target=self._loop_main, args=(ready,), daemon=True,
            name="walk-frontend-loop")
        self._loop_thread.start()
        if not ready.wait(timeout=30):
            raise RuntimeError("frontend event loop failed to start")
        if self._loop_error is not None:
            raise self._loop_error
        if self.driver == "thread":
            self._driver_thread = threading.Thread(
                target=self._drive, daemon=True,
                name="walk-frontend-driver")
            self._driver_thread.start()
        assert self.address is not None
        return self.address

    def _loop_main(self, ready: threading.Event) -> None:
        loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)
        self._loop = loop
        try:
            server = loop.run_until_complete(asyncio.start_server(
                self._handle_conn, self.config.host, self.config.port))
        except BaseException as e:  # surface bind errors to start()
            self._loop_error = e
            ready.set()
            loop.close()
            return
        sock = server.sockets[0].getsockname()
        self.address = (sock[0], sock[1])
        ready.set()
        try:
            loop.run_forever()
        finally:
            server.close()
            loop.run_until_complete(server.wait_closed())
            pending = asyncio.all_tasks(loop)
            for task in pending:
                task.cancel()
            if pending:
                loop.run_until_complete(
                    asyncio.gather(*pending, return_exceptions=True))
            loop.close()

    def _drive(self) -> None:
        while not self._stop_event.is_set():
            if not self.pump():
                time.sleep(self.config.idle_sleep)

    def stop(self) -> None:
        """Stop threads and close the listener.  Does NOT drain — call
        :meth:`drain` first for a graceful shutdown."""
        self._stop_event.set()
        if self._driver_thread is not None:
            self._driver_thread.join(timeout=30)
            self._driver_thread = None
        if self._loop is not None:
            self._loop.call_soon_threadsafe(self._loop.stop)
        if self._loop_thread is not None:
            self._loop_thread.join(timeout=30)
            self._loop_thread = None

    # ------------------------------------------------------------- driver
    def pump(self) -> bool:
        """One driver pass: step the service (expire/admit/epochs) and
        route finished walks into their owners' delivery buffers.
        Returns False when the service was idle (nothing ran)."""
        with self.lock:
            if self.service.idle:
                return False
            walks = self.service.step()
            self._route(walks)
            return True

    def _route(self, walks) -> None:
        for w in walks:
            client = self._ticket_owner.pop(w.ticket, None)
            if client is None or client.closed:
                self._dropped_walks += 1
                continue
            client.outstanding.discard(w.ticket)
            client.buffer.append(w)  # credit invariant: sum unchanged

    # -------------------------------------------------------------- drain
    @property
    def draining(self) -> bool:
        return self._draining

    @property
    def drained(self) -> bool:
        """Drain requested, service idle, and every delivery buffer
        polled empty — the point where a serving CLI can exit."""
        with self.lock:
            return (self._draining and self.service.idle
                    and all(not c.buffer and not c.stalled
                            for c in self._clients.values()))

    def drain(self, timeout: Optional[float] = None,
              flush: bool = True) -> Dict[str, int]:
        """Graceful drain (module docstring).  Returns summary counts."""
        limit = self.config.drain_timeout if timeout is None else timeout
        with self.lock:
            self._draining = True
            for client in self._clients.values():
                self._flush_stalled_locked(client, post=True)
        deadline = time.monotonic() + limit
        while time.monotonic() < deadline:
            if self.driver == "manual":
                if not self.pump():
                    break
            else:
                with self.lock:
                    if self.service.idle:
                        break
                time.sleep(min(0.01, self.config.idle_sleep or 0.01))
        flushed = 0
        if flush:
            with self.lock:
                for client in list(self._clients.values()):
                    for ticket in list(client.outstanding):
                        walk = self.service.cancel(ticket)
                        if walk is None:
                            continue
                        self._ticket_owner.pop(ticket, None)
                        client.outstanding.discard(ticket)
                        client.buffer.append(walk)
                        flushed += 1
        with self.lock:
            return {"flushed": flushed,
                    "pending": self.service.pending,
                    "in_flight": self.service.in_flight}

    def _flush_stalled_locked(self, client: _Client,
                              post: bool = False) -> List[dict]:
        """Reject every parked submit with a ``draining`` error frame;
        ``post=True`` pushes them onto the connection from whatever
        thread is draining (otherwise the caller sends them inline)."""
        frames = []
        while client.stalled:
            rid, _ = client.stalled.popleft()
            frames.append(tp.error_frame(
                rid, tp.ERR_DRAINING,
                "frontend is draining; parked submit rejected"))
        if post and frames:
            self._post_frames(client, frames)
            return []
        return frames

    def _post_frames(self, client: _Client, frames: List[dict]) -> None:
        """Thread-safe frame push onto a connection (used by non-loop
        threads; the event loop writes inline instead)."""
        if self._loop is None or client.closed or client.writer is None:
            return
        data = b"".join(tp.encode_frame(f, self.config.max_frame)
                        for f in frames)

        def _write():
            if not client.closed and client.writer is not None:
                client.writer.write(data)

        self._loop.call_soon_threadsafe(_write)

    # --------------------------------------------------------- connection
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        with self.lock:
            cid = self._next_cid
            self._next_cid += 1
            client = _Client(cid)
            client.writer = writer
            self._clients[cid] = client
        decoder = tp.FrameDecoder(self.config.max_frame)
        try:
            while True:
                data = await reader.read(1 << 16)
                if not data:
                    break
                try:
                    msgs = decoder.feed(data)
                except tp.ProtocolError as e:
                    # framing is unrecoverable: answer, then hang up
                    writer.write(tp.encode_frame(
                        tp.error_frame(None, e.code, e.detail),
                        self.config.max_frame))
                    await writer.drain()
                    break
                out: List[dict] = []
                with self.lock:
                    for msg in msgs:
                        out.extend(self._dispatch(client, msg))
                for frame in out:
                    writer.write(tp.encode_frame(frame,
                                                 self.config.max_frame))
                if out:
                    await writer.drain()
        except (ConnectionResetError, BrokenPipeError, TimeoutError):
            pass
        finally:
            self._disconnect(client)
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError, OSError):
                pass

    def _disconnect(self, client: _Client) -> None:
        with self.lock:
            client.closed = True
            client.writer = None
            self._clients.pop(client.cid, None)
            # a gone client cannot poll: cancel its live queries so
            # their slots free immediately, and drop its buffer
            for ticket in list(client.outstanding):
                self.service.cancel(ticket)
                self._ticket_owner.pop(ticket, None)
            self._dropped_walks += len(client.buffer)
            client.outstanding.clear()
            client.buffer.clear()
            client.stalled.clear()

    # ----------------------------------------------------------- dispatch
    def _dispatch(self, client: _Client, msg: dict) -> List[dict]:
        """One request frame -> response frames (lock held)."""
        try:
            op, rid, kw = tp.parse_request(msg)
        except tp.ProtocolError as e:
            return [tp.error_frame(msg.get("id"), e.code, e.detail)]
        if op == tp.OP_SUBMIT:
            return self._on_submit(client, rid, kw)
        if op == tp.OP_POLL:
            return self._on_poll(client, rid, kw["max"])
        if op == tp.OP_CANCEL:
            return self._on_cancel(client, rid, kw["ticket"])
        if op == tp.OP_STATS:
            return self._on_stats(rid)
        return self._on_drain(client, rid)

    def _admit_submit(self, client: _Client, rid, query: WalkQuery) -> dict:
        receipt = self.service.submit(query)
        if not receipt.accepted:
            return tp.error_frame(rid, receipt.reason, receipt.detail)
        client.outstanding.add(receipt.ticket)
        self._ticket_owner[receipt.ticket] = client
        return {"op": tp.OP_SUBMIT_OK, "id": rid,
                "ticket": receipt.ticket}

    def _on_submit(self, client: _Client, rid, kw: dict) -> List[dict]:
        if self._draining:
            return [tp.error_frame(rid, tp.ERR_DRAINING,
                                   "frontend is draining; "
                                   "no new queries accepted")]
        query = WalkQuery(start=kw["start"], program=kw["program"],
                          priority=kw["priority"],
                          deadline=kw["deadline"])
        if client.used_credits >= self.config.client_buffer:
            if (self.config.slow_client == "reject"
                    or len(client.stalled) >= self.config.client_buffer):
                return [tp.error_frame(
                    rid, tp.ERR_BACKPRESSURE,
                    f"{client.used_credits} undelivered walks at "
                    f"client_buffer={self.config.client_buffer}; "
                    f"poll before submitting more")]
            client.stalled.append((rid, query))
            return []  # submit-ok arrives when a poll frees credit
        return [self._admit_submit(client, rid, query)]

    def _on_poll(self, client: _Client, rid, mx: int) -> List[dict]:
        walks = [client.buffer.popleft()
                 for _ in range(min(mx, len(client.buffer)))]
        frames = [{"op": tp.OP_WALKS, "id": rid,
                   "walks": [tp.walk_to_wire(w) for w in walks],
                   "buffered": len(client.buffer),
                   "outstanding": (len(client.outstanding)
                                   + len(client.stalled))}]
        # freed credits admit parked submits, oldest first
        if self._draining:
            frames.extend(self._flush_stalled_locked(client))
        else:
            while (client.stalled
                   and client.used_credits < self.config.client_buffer):
                srid, query = client.stalled.popleft()
                frames.append(self._admit_submit(client, srid, query))
        return frames

    def _on_cancel(self, client: _Client, rid, ticket: int) -> List[dict]:
        if self._ticket_owner.get(ticket) is not client:
            # unknown, finished, or another connection's: never cancel
            # across clients
            return [{"op": tp.OP_CANCEL_OK, "id": rid,
                     "ticket": ticket, "status": "not-found"}]
        walk = self.service.cancel(ticket)
        if walk is None:  # pragma: no cover — owner map is popped on finish
            return [{"op": tp.OP_CANCEL_OK, "id": rid,
                     "ticket": ticket, "status": "not-found"}]
        self._ticket_owner.pop(ticket, None)
        client.outstanding.discard(ticket)
        client.buffer.append(walk)  # delivered like any terminal walk
        return [{"op": tp.OP_CANCEL_OK, "id": rid,
                 "ticket": ticket, "status": walk.status}]

    def _on_stats(self, rid) -> List[dict]:
        stats = tp.sanitize(dataclasses.asdict(self.service.stats()))
        stats["frontend"] = {
            "clients": len(self._clients),
            "buffered": sum(len(c.buffer)
                            for c in self._clients.values()),
            "stalled": sum(len(c.stalled)
                           for c in self._clients.values()),
            "dropped_walks": self._dropped_walks,
            "draining": self._draining,
        }
        return [{"op": tp.OP_STATS_OK, "id": rid, "stats": stats}]

    def _on_drain(self, client: _Client, rid) -> List[dict]:
        self._draining = True
        frames: List[dict] = []
        for c in list(self._clients.values()):
            if c is client:
                frames.extend(self._flush_stalled_locked(c))
            else:
                self._flush_stalled_locked(c, post=True)
        frames.append({"op": tp.OP_DRAIN_OK, "id": rid,
                       "pending": (self.service.pending
                                   + self.service.in_flight)})
        return frames
