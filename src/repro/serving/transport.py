"""Wire protocol for the WalkService network front-end.

Frame format — deliberately boring, stdlib-only, language-agnostic:

    +----------------+----------------------------------+
    | 4 bytes        | N bytes                          |
    | big-endian u32 | UTF-8 JSON object                |
    | body length N  |                                  |
    +----------------+----------------------------------+

Every frame body is one JSON object with an ``op`` field.  Requests
carry a client-chosen ``id`` that the response echoes verbatim, so a
client may pipeline requests and match responses out of order.

Request ops (client -> server)
------------------------------
``submit``  ``{op, id, start, program?, priority?, deadline?}``
``poll``    ``{op, id, max?}`` — drain up to ``max`` finished walks
            from this connection's delivery buffer
``cancel``  ``{op, id, ticket}``
``stats``   ``{op, id}``
``drain``   ``{op, id}`` — begin graceful drain (server-wide)

Response ops (server -> client)
-------------------------------
``submit-ok``  ``{op, id, ticket}``
``walks``      ``{op, id, walks: [...], buffered, outstanding}``
``cancel-ok``  ``{op, id, ticket, status}``
``stats-ok``   ``{op, id, stats}``
``drain-ok``   ``{op, id, pending}``
``error``      ``{op, id, code, detail}``

Error codes: ``bad-frame`` (framing/JSON violation — fatal, the server
closes the connection because resynchronising a corrupt length-prefixed
stream is impossible), ``bad-request`` (malformed request object —
non-fatal), ``backpressure`` (the client is at its delivery-buffer
credit bound under the ``reject`` policy), ``draining`` (submit during
graceful drain), plus the service's own admission-rejection codes
passed through verbatim (``queue-full``, ``deadline-infeasible``,
``unknown-program``).

Floats that JSON cannot carry (``wait`` is nan for never-admitted
queries) are serialized as ``null`` and restored to nan on the way in;
paths travel as plain int lists and come back as ``np.int32`` arrays,
so :func:`walk_from_wire` round-trips a :class:`ServedWalk` exactly.
"""
from __future__ import annotations

import json
import math
import struct
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.walk_service import ServedWalk

#: default per-frame byte bound (a 1k-step path is ~6KB of JSON)
MAX_FRAME = 1 << 20

_HEADER = struct.Struct(">I")

# request ops
OP_SUBMIT = "submit"
OP_POLL = "poll"
OP_CANCEL = "cancel"
OP_STATS = "stats"
OP_DRAIN = "drain"
REQUEST_OPS = (OP_SUBMIT, OP_POLL, OP_CANCEL, OP_STATS, OP_DRAIN)

# response ops
OP_SUBMIT_OK = "submit-ok"
OP_WALKS = "walks"
OP_CANCEL_OK = "cancel-ok"
OP_STATS_OK = "stats-ok"
OP_DRAIN_OK = "drain-ok"
OP_ERROR = "error"

# frontend-level error codes (service rejection reasons pass through)
ERR_BAD_FRAME = "bad-frame"
ERR_BAD_REQUEST = "bad-request"
ERR_BACKPRESSURE = "backpressure"
ERR_DRAINING = "draining"


class ProtocolError(Exception):
    """A wire-protocol violation.  ``fatal`` frames (length/JSON
    corruption) force the server to drop the connection — there is no
    way to find the next frame boundary in a corrupt prefix stream."""

    def __init__(self, code: str, detail: str, fatal: bool = False):
        super().__init__(f"{code}: {detail}")
        self.code = code
        self.detail = detail
        self.fatal = fatal


# ------------------------------------------------------------- framing
def encode_frame(obj: Dict[str, Any], max_frame: int = MAX_FRAME) -> bytes:
    """One length-prefixed frame for ``obj`` (see module docstring)."""
    body = json.dumps(obj, separators=(",", ":"),
                      allow_nan=False).encode("utf-8")
    if len(body) > max_frame:
        raise ProtocolError(
            ERR_BAD_FRAME,
            f"frame body of {len(body)} bytes exceeds "
            f"max_frame={max_frame}", fatal=True)
    return _HEADER.pack(len(body)) + body


class FrameDecoder:
    """Incremental frame decoder: ``feed`` it byte chunks as they
    arrive (any split, down to one byte at a time) and get back every
    frame completed so far, in order."""

    def __init__(self, max_frame: int = MAX_FRAME):
        self.max_frame = int(max_frame)
        self._buf = bytearray()

    def feed(self, data: bytes) -> List[Dict[str, Any]]:
        self._buf += data
        frames: List[Dict[str, Any]] = []
        while len(self._buf) >= _HEADER.size:
            (n,) = _HEADER.unpack_from(self._buf)
            if n > self.max_frame:
                raise ProtocolError(
                    ERR_BAD_FRAME,
                    f"frame of {n} bytes exceeds max_frame="
                    f"{self.max_frame}", fatal=True)
            if len(self._buf) < _HEADER.size + n:
                break
            body = bytes(self._buf[_HEADER.size:_HEADER.size + n])
            del self._buf[:_HEADER.size + n]
            try:
                obj = json.loads(body)
            except ValueError:
                raise ProtocolError(ERR_BAD_FRAME,
                                    "frame body is not valid JSON",
                                    fatal=True)
            if not isinstance(obj, dict):
                raise ProtocolError(ERR_BAD_FRAME,
                                    "frame body must be a JSON object",
                                    fatal=True)
            frames.append(obj)
        return frames


# --------------------------------------------------- request validation
def _field(obj: Dict[str, Any], name: str, types, default=_HEADER):
    # _HEADER doubles as a "no default" sentinel (never a valid value)
    v = obj.get(name, default)
    if v is _HEADER:
        raise ProtocolError(ERR_BAD_REQUEST,
                            f"{obj.get('op')!r} request missing {name!r}")
    if v is not None and not isinstance(v, types):
        raise ProtocolError(
            ERR_BAD_REQUEST,
            f"field {name!r} must be {types}, got {type(v).__name__}")
    return v


def parse_request(obj: Dict[str, Any]) -> Tuple[str, Any, Dict[str, Any]]:
    """Validate one request frame -> ``(op, id, normalized kwargs)``.
    Raises non-fatal :class:`ProtocolError` (code ``bad-request``) on
    anything malformed — the connection survives, only this request is
    answered with an error frame."""
    op = obj.get("op")
    if op not in REQUEST_OPS:
        raise ProtocolError(ERR_BAD_REQUEST,
                            f"unknown op {op!r}; expected one of "
                            f"{list(REQUEST_OPS)}")
    rid = obj.get("id")
    if rid is not None and not isinstance(rid, (int, str)):
        raise ProtocolError(ERR_BAD_REQUEST,
                            "request id must be an int or string")
    kw: Dict[str, Any] = {}
    if op == OP_SUBMIT:
        start = _field(obj, "start", (int,))
        if isinstance(start, bool) or start < 0:
            raise ProtocolError(ERR_BAD_REQUEST,
                                f"start must be a node id >= 0, "
                                f"got {start!r}")
        kw["start"] = start
        kw["program"] = _field(obj, "program", (str,), "deepwalk")
        priority = _field(obj, "priority", (int,), 0)
        if isinstance(priority, bool):
            raise ProtocolError(ERR_BAD_REQUEST, "priority must be an int")
        kw["priority"] = priority
        deadline = _field(obj, "deadline", (int, float), None)
        kw["deadline"] = None if deadline is None else float(deadline)
    elif op == OP_POLL:
        mx = _field(obj, "max", (int,), 64)
        if isinstance(mx, bool) or mx <= 0:
            raise ProtocolError(ERR_BAD_REQUEST,
                                f"max must be a positive int, got {mx!r}")
        kw["max"] = mx
    elif op == OP_CANCEL:
        ticket = _field(obj, "ticket", (int,))
        if isinstance(ticket, bool):
            raise ProtocolError(ERR_BAD_REQUEST, "ticket must be an int")
        kw["ticket"] = ticket
    return op, rid, kw


def error_frame(rid: Any, code: str, detail: str) -> Dict[str, Any]:
    return {"op": OP_ERROR, "id": rid, "code": code, "detail": detail}


# ------------------------------------------------- value serialization
def sanitize(value: Any) -> Any:
    """Recursively coerce a value to strict-JSON types: numpy scalars
    and arrays to python ints/floats/lists, non-finite floats to None
    (``encode_frame`` runs with ``allow_nan=False``)."""
    if isinstance(value, dict):
        return {str(k): sanitize(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [sanitize(v) for v in value]
    if isinstance(value, np.ndarray):
        return [sanitize(v) for v in value.tolist()]
    if isinstance(value, (np.integer,)):
        return int(value)
    if isinstance(value, (float, np.floating)):
        f = float(value)
        return f if math.isfinite(f) else None
    return value


def walk_to_wire(walk: ServedWalk) -> Dict[str, Any]:
    """A ServedWalk as a JSON-safe dict (inverse of walk_from_wire)."""
    return {
        "ticket": int(walk.ticket),
        "program": walk.program,
        "status": walk.status,
        "path": (None if walk.path is None
                 else [int(v) for v in np.asarray(walk.path)]),
        "steps": int(walk.steps),
        "submit_time": sanitize(walk.submit_time),
        "admit_time": sanitize(walk.admit_time),
        "finish_time": sanitize(walk.finish_time),
        "wait": sanitize(walk.wait),
        "latency": sanitize(walk.latency),
    }


def _or_nan(v: Optional[float]) -> float:
    return float("nan") if v is None else float(v)


def walk_from_wire(d: Dict[str, Any]) -> ServedWalk:
    """Rebuild a ServedWalk from its wire dict: the client sees the
    same dataclass the in-process service returns (nan ``wait`` for
    never-admitted queries, int32 path array)."""
    path = d.get("path")
    return ServedWalk(
        ticket=int(d["ticket"]),
        program=d["program"],
        status=d["status"],
        path=None if path is None else np.asarray(path, np.int32),
        steps=int(d["steps"]),
        submit_time=_or_nan(d.get("submit_time")),
        admit_time=(None if d.get("admit_time") is None
                    else float(d["admit_time"])),
        finish_time=_or_nan(d.get("finish_time")),
        wait=_or_nan(d.get("wait")),
        latency=_or_nan(d.get("latency")),
    )


# ------------------------------------------- blocking-socket utilities
def send_frame(sock, obj: Dict[str, Any],
               max_frame: int = MAX_FRAME) -> None:
    """Blocking send of one frame (client-side helper)."""
    sock.sendall(encode_frame(obj, max_frame))


def recv_frame(sock, max_frame: int = MAX_FRAME) -> Optional[Dict[str, Any]]:
    """Blocking receive of exactly one frame; None on clean EOF at a
    frame boundary.  (The asyncio server uses FrameDecoder instead.)"""
    header = _recv_exact(sock, _HEADER.size)
    if header is None:
        return None
    (n,) = _HEADER.unpack(header)
    if n > max_frame:
        raise ProtocolError(ERR_BAD_FRAME,
                            f"frame of {n} bytes exceeds max_frame="
                            f"{max_frame}", fatal=True)
    body = _recv_exact(sock, n)
    if body is None:
        raise ProtocolError(ERR_BAD_FRAME,
                            "connection closed mid-frame", fatal=True)
    obj = json.loads(body)
    if not isinstance(obj, dict):
        raise ProtocolError(ERR_BAD_FRAME,
                            "frame body must be a JSON object", fatal=True)
    return obj


def _recv_exact(sock, n: int) -> Optional[bytes]:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if buf:
                raise ProtocolError(ERR_BAD_FRAME,
                                    "connection closed mid-frame",
                                    fatal=True)
            return None
        buf += chunk
    return bytes(buf)
