"""Batched serving: prefill + decode loop with the eRVS token sampler.

``make_serve_step`` builds the jittable one-token decode step used by the
dry-run cells (decode_32k / long_500k): embed → stacked-layer scan with
cache update → logits → sample.  Sampling is the paper's exponential-key
mechanism (Gumbel-max): the Pallas kernel in interpret mode for real runs
on this host, or the identical-math XLA fallback when jitting for the
dry-run meshes (Pallas does not lower to the host CPU backend).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import decode_step, forward, init_cache
from repro.models.config import ModelConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class GenerateConfig:
    max_new_tokens: int = 32
    temperature: float = 1.0
    greedy: bool = False
    use_pallas_sampler: bool = True  # interpret-mode kernel on this host


def sample_tokens(logits: jax.Array, seed: jax.Array, temperature: float,
                  greedy: bool, use_pallas: bool) -> jax.Array:
    if use_pallas:
        return kops.token_sample(logits, seed, temperature=temperature,
                                 greedy=greedy, interpret=True)
    return kref.token_sample_ref(logits, seed, temperature=temperature,
                                 greedy=greedy)


def make_serve_step(cfg: ModelConfig, temperature: float = 1.0,
                    greedy: bool = False, use_pallas: bool = False,
                    unroll: bool = False):
    """serve_step(params, tokens [B,1], caches, index, seed) →
    (next_tokens [B], caches').  This is the function the decode dry-run
    cells lower: one new token against a KV cache of the shape's seq_len.
    ``unroll`` uses the in-place stacked-cache decode path (§Perf C2)."""

    def serve_step(params, tokens, caches, index, seed):
        logits, caches = decode_step(params, cfg, tokens, caches, index,
                                     unroll=unroll)
        nxt = sample_tokens(logits, seed, temperature, greedy, use_pallas)
        return nxt, caches

    return serve_step


def generate(params, cfg: ModelConfig, prompt: jax.Array,
             gcfg: GenerateConfig, key: Optional[jax.Array] = None,
             max_len: Optional[int] = None) -> jax.Array:
    """Greedy/sampled generation for a [B, S0] prompt batch.

    Prefill runs the chunked forward; decode then advances one token at a
    time.  Returns [B, S0 + max_new_tokens] token ids.
    """
    key = key if key is not None else jax.random.key(0)
    B, S0 = prompt.shape
    total = S0 + gcfg.max_new_tokens
    max_len = max_len or total
    caches = init_cache(cfg, B, max_len)

    # prefill: feed prompt tokens through decode steps to fill the cache
    # (cache-correct; a fused prefill kernel is a serving optimisation the
    # dry-run measures separately via the prefill cells).
    step_fn = make_serve_step(cfg, gcfg.temperature, gcfg.greedy,
                              use_pallas=gcfg.use_pallas_sampler)
    out = jnp.zeros((B, total), jnp.int32)
    out = out.at[:, :S0].set(prompt)
    tok = prompt[:, :1]
    for i in range(total - 1):
        seed = kops.make_seeds(jax.random.fold_in(key, i), 1)[0]
        nxt, caches = step_fn(params, tok, caches, jnp.int32(i), seed)
        is_prompt = i + 1 < S0
        tok = jnp.where(is_prompt, out[:, i + 1:i + 2], nxt[:, None])
        out = out.at[:, i + 1].set(tok[:, 0])
    return out
