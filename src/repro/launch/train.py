"""Training launcher: ``--arch <id>`` selects an assigned architecture
(``--smoke`` uses its reduced config so the loop runs on this host), with
checkpoint/resume, WSD/cosine schedules, grad compression, and mesh-aware
sharding when more than one device is present.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 50 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.checkpoint.manager import available_steps
from repro.configs import ARCHS, get_config, get_smoke, train_schedule
from repro.data import DataConfig
from repro.data.pipeline import synthetic_batch
from repro.models import init_params
from repro.train import TrainConfig, adamw_init, compress_init, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"schedule={train_schedule(args.arch)}")
    tcfg = TrainConfig(base_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps,
                       schedule=train_schedule(args.arch),
                       compress_grads=args.compress_grads,
                       microbatches=args.microbatches)
    params = init_params(cfg, jax.random.key(0))
    state = dict(params=params, opt=adamw_init(params),
                 comp=compress_init(params) if args.compress_grads else (),
                 step=jnp.int32(0))
    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, save_every=args.save_every)
        if args.resume and available_steps(args.ckpt_dir):
            state, start = mgr.restore_latest(state)
            print(f"[train] resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    dcfg = DataConfig(batch_size=args.batch, seq_len=args.seq,
                      vocab_size=cfg.vocab_size)
    t0 = time.time()
    for i in range(start, args.steps):
        state, m = step_fn(state, synthetic_batch(dcfg, i))
        if mgr:
            mgr.maybe_save(int(state["step"]), state)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} "
                  f"gnorm={float(m['grad_norm']):.2f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
    if mgr:
        mgr.maybe_save(int(state["step"]), state, force=True)
        mgr.wait()
        print(f"[train] checkpoints: {available_steps(args.ckpt_dir)}")


if __name__ == "__main__":
    main()
