import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Optimized dry-run sweep: the beyond-paper configuration per cell kind
(§Perf).  Baselines live in results/dryrun; this writes results/dryrun_opt.

  train   : int8 AdamW moments + FSDP over pod×data (fits 16 GB/chip for
            every arch incl. the 1T kimi) + einsum MoE dispatch
  prefill : last-token logits + ZeRO-3 weight-gathered layout with
            sequence parallelism over the model axis (attention archs)
  decode  : bf16-operand attention einsums (no fp32 cache copies)
"""
import argparse

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.dryrun import run_cell


def cell_kwargs(arch: str, shape: str) -> dict:
    kind = SHAPES[shape].kind
    cfg = get_config(arch)
    if kind == "train":
        return dict(moments_dtype="int8")
    if kind == "prefill":
        kw = dict(last_token_logits=True)
        if cfg.family not in ("ssm", "hybrid", "moe"):
            # seq-over-model context parallelism needs attention-only mixing
            # (SSD/RG-LRU state flows along the sequence), and gathering MoE
            # weights per layer streams the full expert set (1T for kimi) —
            # measured 26× WORSE there; both keep the TP layout.
            kw["weight_gathered"] = True
        return kw
    return {}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default="results/dryrun_opt")
    args = ap.parse_args()
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_ok = n_fail = n_skip = 0
    for arch in ARCHS:
        for shape in SHAPES:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out,
                               **cell_kwargs(arch, shape))
                n_ok += rec["status"] == "OK"
                n_fail += rec["status"] == "FAIL"
                n_skip += rec["status"] == "SKIPPED"
    print(f"[dryrun-opt] done: {n_ok} OK, {n_fail} FAIL, {n_skip} SKIPPED",
          flush=True)


if __name__ == "__main__":
    main()
