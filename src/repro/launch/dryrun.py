import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import — jax locks the device
# count at first backend init (assignment MULTI-POD DRY-RUN §0).  The env
# override below exists for the plumbing tests only.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver
  1. builds the production mesh (16×16 single-pod or 2×16×16 multi-pod),
  2. constructs ShapeDtypeStruct stand-ins for params / optimizer state /
     batch / caches (never allocating),
  3. jit-lowers the train_step / prefill / serve_step with explicit
     in/out shardings (logical rules + divisibility fallback),
  4. compiles, prints memory_analysis() (fits-per-device proof) and
     cost_analysis(), parses the per-device HLO for the roofline terms,
  5. appends the cell record to a JSON report consumed by
     benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k --mesh both --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCHS, SHAPES, all_cells, cell_supported,
                           get_config, train_schedule)
from repro.distributed.sharding import (activation_sharding_ctx,
                                        logical_to_spec, named_shardings,
                                        param_specs)
from repro.launch.mesh import make_production_mesh
from repro.models import init_cache, init_params, prefill
from repro.models.config import ModelConfig
from repro.roofline import analyze_cell, parse_hlo
from repro.serving import make_serve_step
from repro.train import TrainConfig, adamw_init, make_train_step

F32 = jnp.float32


# ------------------------------------------------------------ input specs
def input_specs(arch: str, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell
    (weak-type-correct, shardable, no device allocation)."""
    cfg = get_config(arch)
    spec = SHAPES[shape]
    B, S = spec.global_batch, spec.seq_len
    sds = jax.ShapeDtypeStruct
    if spec.kind == "train":
        return {"tokens": sds((B, S), jnp.int32),
                "labels": sds((B, S), jnp.int32)}
    if spec.kind == "prefill":
        return {"tokens": sds((B, S), jnp.int32)}
    # decode: one new token against a seq_len KV cache
    caches = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return {"tokens": sds((B, 1), jnp.int32),
            "caches": caches,
            "index": sds((), jnp.int32),
            "seed": sds((2,), jnp.uint32)}


# ------------------------------------------------------- sharding helpers
# KV caches shard the SEQUENCE dim over the model axis (decode-time context
# parallelism): works for every kv_heads count (yi's 4 KV heads cannot split
# a 16-way model axis, 32k sequence always can), and GSPMD partitions the
# masked softmax over the sharded length with small [B, H] all-reduces.
_CACHE_AXES = {
    "k": ("batch", "kv_seq", "kv_heads", None),
    "v": ("batch", "kv_seq", "kv_heads", None),
    "h": ("batch", "mlp"),
    "conv": ("batch", None, "mlp"),
    "ssm": ("batch", "mlp", None, None),
}


def cache_shardings(caches, mesh, rules):
    def spec_of(path, leaf):
        name = None
        for entry in reversed(path):
            key = getattr(entry, "key", None)
            if isinstance(key, str) and key in _CACHE_AXES:
                name = key
                break
        if name is None:
            return NamedSharding(mesh, P())
        axes = _CACHE_AXES[name]
        rank = len(leaf.shape)
        axes = (None,) * (rank - len(axes)) + axes  # stacked layer dims
        return NamedSharding(mesh, logical_to_spec(axes, leaf.shape, rules))

    return jax.tree_util.tree_map_with_path(spec_of, caches)


def _moment_shardings(pshard, mu_shapes, mesh):
    """Shardings for optimizer moments.  fp32 moments mirror the param
    shardings; int8-quantised moments put the param's spec on the payload
    and the spec-minus-last-dim on the per-block scales."""

    def is_q(x):
        return isinstance(x, dict) and set(x) == {"q", "scale"}

    def one(s, m):
        if not is_q(m):
            return s
        spec = tuple(s.spec)
        scale_spec = P(*spec[:max(len(m["scale"].shape) - 1, 0)])
        return {"q": s, "scale": NamedSharding(mesh, scale_spec)}

    return jax.tree_util.tree_map(one, pshard, mu_shapes,
                                  is_leaf=lambda x: isinstance(
                                      x, NamedSharding))


def batch_sharding(mesh, rules, shape):
    return NamedSharding(mesh, logical_to_spec(
        ("batch",) + (None,) * (len(shape) - 1), shape, rules))


# ---------------------------------------------------------------- lowering
def lower_cell(arch: str, shape: str, multi_pod: bool,
               fsdp: Optional[bool] = None, seqpar: bool = False,
               remat: bool = True, microbatches: int = 0,
               moments_dtype: str = "float32",
               last_token_logits: bool = False,
               decode_unroll: bool = False,
               tp_bf16_reduce: bool = False,
               weight_gathered: bool = False):
    """Build + lower + compile one cell.  Returns (compiled, meta dict)."""
    cfg = get_config(arch)
    spec = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    if fsdp is None:
        # big models need optimizer state sharded over data too
        fsdp = cfg.param_count() * 16 / chips > 8e9 or spec.kind == "train"

    logical_override = None
    if weight_gathered:
        # ZeRO-3-style inference + context parallelism: params sharded over
        # EVERY axis and all-gathered per layer; activations sharded batch×
        # SEQUENCE (seq over the model axis) so no compute is replicated.
        # Per layer the wire carries one weight all-gather + one K/V
        # all-gather instead of two [B,S,D] TP all-reduces (§Perf B3/B4).
        logical_override = {
            "heads": (), "kv_heads": (), "mlp": (), "experts": (),
            "vocab": (), "embed": ("data", "model"), "embed_act": (),
            "kv_seq": (), "seq": ("model",),
        }
    with activation_sharding_ctx(mesh, fsdp=fsdp, seqpar=seqpar,
                                 tp_bf16_reduce=tp_bf16_reduce,
                                 logical=logical_override) as rules:
        pshapes = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
        pshard = named_shardings(pshapes, mesh, rules)
        ins = input_specs(arch, shape)

        if spec.kind == "train":
            if microbatches == 0:  # auto: ≤4 sequences per device per pass
                dp = rules.axis_size(rules.logical.get("batch", ()))
                per_dev = max(spec.global_batch // max(dp, 1), 1)
                microbatches = max(1, min(per_dev // 4, spec.global_batch))
            tcfg = TrainConfig(schedule=train_schedule(arch), remat=remat,
                               microbatches=microbatches,
                               moments_dtype=moments_dtype)
            step = make_train_step(cfg, tcfg)
            state_shapes = jax.eval_shape(
                lambda p: dict(params=p,
                               opt=adamw_init(p, moments_dtype), comp=(),
                               step=jnp.int32(0)), pshapes)
            rep = NamedSharding(mesh, P())
            moment_shard = _moment_shardings(pshard, state_shapes["opt"].mu,
                                             mesh)
            state_shard = dict(
                params=pshard,
                opt=type(state_shapes["opt"])(
                    step=rep, mu=moment_shard,
                    nu=jax.tree.map(lambda s: s, moment_shard)),
                comp=(),
                step=rep)
            bshard = {k: batch_sharding(mesh, rules, v.shape)
                      for k, v in ins.items()}
            jitted = jax.jit(step,
                             in_shardings=(state_shard, bshard),
                             out_shardings=(state_shard, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shapes, ins)
        elif spec.kind == "prefill":
            fn = lambda p, tokens: prefill(p, cfg, tokens,
                                           last_only=last_token_logits)
            bshard = batch_sharding(mesh, rules, ins["tokens"].shape)
            jitted = jax.jit(fn, in_shardings=(pshard, bshard))
            lowered = jitted.lower(pshapes, ins["tokens"])
        else:  # decode
            serve = make_serve_step(cfg, use_pallas=False,
                                    unroll=decode_unroll)
            cshard = cache_shardings(ins["caches"], mesh, rules)
            rep = NamedSharding(mesh, P())
            jitted = jax.jit(
                serve,
                in_shardings=(pshard,
                              batch_sharding(mesh, rules,
                                             ins["tokens"].shape),
                              cshard, rep, rep),
                out_shardings=(batch_sharding(mesh, rules,
                                              (spec.global_batch,)), cshard),
                donate_argnums=(2,))
            lowered = jitted.lower(pshapes, ins["tokens"], ins["caches"],
                                   ins["index"], ins["seed"])

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
    meta = dict(arch=arch, shape=shape, chips=chips,
                mesh="2x16x16" if multi_pod else "16x16",
                kind=spec.kind, fsdp=fsdp, seqpar=seqpar,
                compile_seconds=compile_s)
    return compiled, meta, cfg, spec


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Optional[str],
             verbose: bool = True, **kw) -> Dict[str, Any]:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape}__{mesh_name}"
    ok, why = cell_supported(arch, shape)
    if not ok:
        rec = {"cell": tag, "status": "SKIPPED", "reason": why}
        _write(out_dir, tag, rec)
        if verbose:
            print(f"[dryrun] {tag}: SKIPPED ({why})", flush=True)
        return rec
    try:
        compiled, meta, cfg, spec = lower_cell(arch, shape, multi_pod, **kw)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # newer jax: per-program list
            cost = cost[0] if cost else {}
        stats = parse_hlo(compiled.as_text())
        report = analyze_cell(
            arch, shape, mesh_name, meta["chips"], spec.kind, cfg,
            spec.seq_len, spec.global_batch, stats,
            argument_bytes=getattr(mem, "argument_size_in_bytes", -1),
            temp_bytes=getattr(mem, "temp_size_in_bytes", -1))
        rec = {"cell": tag, "status": "OK", **meta,
               "memory_analysis": str(mem),
               "cost_analysis_flops_raw": float(cost.get("flops", -1.0)),
               "cost_analysis_bytes_raw": float(
                   cost.get("bytes accessed", -1.0)),
               "while_trips": stats.while_trips,
               "hlo_warnings": stats.warnings,
               **report.to_dict()}
        if verbose:
            print(f"[dryrun] {tag}: OK compile={meta['compile_seconds']:.1f}s "
                  f"args/dev={rec['argument_bytes']/1e9:.2f}GB "
                  f"temp/dev={rec['temp_bytes']/1e9:.2f}GB "
                  f"dominant={rec['dominant']} "
                  f"roofline={rec['roofline_fraction']:.3f}", flush=True)
            print(f"  memory_analysis: {mem}", flush=True)
            print(f"  cost_analysis: flops={cost.get('flops')} "
                  f"bytes={cost.get('bytes accessed')}", flush=True)
    except Exception as e:
        rec = {"cell": tag, "status": "FAIL",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
        if verbose:
            print(f"[dryrun] {tag}: FAIL {rec['error'][:300]}", flush=True)
    _write(out_dir, tag, rec)
    return rec


def _write(out_dir: Optional[str], tag: str, rec: Dict[str, Any]):
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--fsdp", type=int, default=-1,
                    help="-1 auto, 0 off, 1 on")
    ap.add_argument("--seqpar", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = auto (≤4 sequences per device per pass)")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    kw = dict(remat=not args.no_remat, microbatches=args.microbatches,
              seqpar=args.seqpar)
    if args.fsdp >= 0:
        kw["fsdp"] = bool(args.fsdp)
    n_ok = n_fail = n_skip = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, args.out, **kw)
            n_ok += rec["status"] == "OK"
            n_fail += rec["status"] == "FAIL"
            n_skip += rec["status"] == "SKIPPED"
    print(f"[dryrun] done: {n_ok} OK, {n_fail} FAIL, {n_skip} SKIPPED",
          flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
