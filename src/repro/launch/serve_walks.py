"""Walk-service launcher — drive the continuously-batched serving loop.

    PYTHONPATH=src python -m repro.launch.serve_walks --trace overload \
        --queries 256 --slots 32 --max-pending 64 --sim-clock

Replays a scripted arrival trace (steady / burst / overload /
deadline-storm) against a live :class:`repro.serving.WalkService` and
reports the SLO telemetry: queries/s, p50/p99 queue wait and completion
latency, slot occupancy, and the rejected/expired counters.  With
``--sim-clock`` the whole trace runs on a deterministic simulated clock
(no sleeping, bit-identical replays — the mode the service test harness
pins); without it, arrivals pace against the wall clock.

``--transport tcp`` serves real clients instead of a scripted trace: a
:class:`repro.serving.WalkFrontend` listens on ``--host``/``--port``
(port 0 picks one; the bound port is printed on startup), clients speak
the length-prefixed JSON frame protocol (``repro.launch.walk_client``
is the stock client), and the server runs until a client sends a
``drain`` frame and every delivered walk has been polled out:

    PYTHONPATH=src python -m repro.launch.serve_walks \
        --transport tcp --port 7421 --slots 64

``--mutate-at T`` mutates the graph mid-serve, exercising the
rebuild-queue drain under live traffic: ``--mutate-kind weights``
(default) rescales edge weights through ``WalkService.update_graph``;
``--mutate-kind structural`` deletes and inserts edges through
``WalkService.apply_updates`` (the delta-overlay path — walks in
flight keep stepping over the mutated topology).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.core import EngineConfig
from repro.core.runtime import STEP_EXEC_CHOICES
from repro.core.samplers import PRECOMP_EXEC_CHOICES
from repro.graphs import power_law_graph, random_graph
from repro.serving import (FrontendConfig, ServiceConfig, SimClock,
                           WalkFrontend, WalkQuery, WalkService)
from repro.serving.frontend import SLOW_CLIENT_POLICIES
from repro.serving.walk_service import FAIRNESS_MODES
from repro.walks import WORKLOADS

TRACES = ("steady", "burst", "overload", "deadline-storm")


def parse_tenant_weights(spec: str) -> dict:
    """``"deepwalk=3,node2vec=1"`` -> ``{"deepwalk": 3.0, ...}``."""
    weights = {}
    for part in (spec or "").split(","):
        part = part.strip()
        if not part:
            continue
        name, _, value = part.partition("=")
        if not name or not value:
            raise ValueError(
                f"--tenant-weights entries must be name=weight, "
                f"got {part!r}")
        weights[name] = float(value)
    return weights


def build_parser() -> argparse.ArgumentParser:
    """The CLI surface, as one inspectable object.

    ``tools/check_docs.py`` cross-checks every ``--flag`` the docs show
    in a ``repro.launch.serve_walks`` command against this parser, so a
    removed or renamed flag fails the docs gate instead of rotting.
    """
    ap = argparse.ArgumentParser(prog="repro.launch.serve_walks")
    # --- trace shape
    ap.add_argument("--trace", choices=TRACES, default="steady",
                    help="scripted arrival pattern: evenly spaced, a few "
                         "synchronized bursts, everything at t=0 against "
                         "a small pending bound (forcing queue-full "
                         "rejections), or tight per-query deadlines "
                         "(forcing infeasible rejections and expiries)")
    ap.add_argument("--queries", type=int, default=256,
                    help="total queries in the trace")
    ap.add_argument("--interarrival", type=float, default=0.01,
                    help="seconds between arrivals (steady) or bursts")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-query deadline budget in seconds after "
                         "arrival (default: only the deadline-storm "
                         "trace sets one)")
    ap.add_argument("--programs", default="deepwalk",
                    help="comma-separated walk programs to round-robin "
                         "queries over (multi-tenant serving), e.g. "
                         "deepwalk,node2vec")
    ap.add_argument("--mutate-at", type=float, default=None,
                    help="service-clock time at which to mutate the "
                         "graph mid-serve (see --mutate-kind)")
    ap.add_argument("--mutate-kind", choices=["weights", "structural"],
                    default="weights",
                    help="what --mutate-at mutates: 'weights' rescales "
                         "edge weights via update_graph; 'structural' "
                         "deletes and inserts edges via apply_updates "
                         "(the delta-overlay path)")
    # --- transport
    ap.add_argument("--transport", choices=["trace", "tcp"],
                    default="trace",
                    help="'trace' replays the scripted arrival trace "
                         "in-process; 'tcp' serves real clients over "
                         "the length-prefixed JSON frame protocol "
                         "(repro.launch.walk_client) until a client "
                         "drains the server")
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address for --transport tcp")
    ap.add_argument("--port", type=int, default=0,
                    help="bind port for --transport tcp (0 picks an "
                         "ephemeral port; it is printed on startup)")
    ap.add_argument("--client-buffer", type=int, default=64,
                    help="per-connection delivery credits (buffered + "
                         "outstanding walks) before backpressure")
    ap.add_argument("--slow-client", choices=list(SLOW_CLIENT_POLICIES),
                    default="suspend",
                    help="over-credit submits are parked until a poll "
                         "frees credit ('suspend') or answered with a "
                         "typed backpressure error ('reject')")
    # --- fairness
    ap.add_argument("--fairness", choices=list(FAIRNESS_MODES),
                    default="drr",
                    help="cross-tenant scheduling: deficit round robin "
                         "in walker-steps ('drr') or the legacy one-"
                         "epoch-per-busy-tenant round robin ('epoch')")
    ap.add_argument("--quantum", type=int, default=None,
                    help="DRR walker-step credit per tenant per service "
                         "step (default: slots * epoch_len)")
    ap.add_argument("--tenant-weights", default="",
                    help="per-tenant DRR weights as name=w pairs, e.g. "
                         "deepwalk=3,node2vec=1 (unlisted tenants "
                         "weigh 1)")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard every tenant's slot pool over this many "
                         "local devices (bit-identical to 1)")
    # --- clock
    ap.add_argument("--sim-clock", action="store_true",
                    help="run the trace on a deterministic simulated "
                         "clock (exact replays, no sleeping)")
    ap.add_argument("--tick", type=float, default=0.005,
                    help="simulated seconds advanced per service step "
                         "(sim-clock mode only)")
    # --- service knobs
    ap.add_argument("--slots", type=int, default=32,
                    help="walker slots per tenant program")
    ap.add_argument("--epoch-len", type=int, default=8,
                    help="scan steps between epoch boundaries (admission "
                         "/ expiry / streaming cadence)")
    ap.add_argument("--steps", type=int, default=None,
                    help="walk length served per query (default: each "
                         "program's walk_len)")
    ap.add_argument("--max-pending", type=int, default=1024,
                    help="pending-queue bound before queue-full rejection")
    ap.add_argument("--aging-interval", type=float, default=0.0,
                    help="seconds of queue wait per +1 effective "
                         "priority (0 disables aging)")
    # --- engine knobs (same semantics as repro.launch.walk)
    ap.add_argument("--method", default="adaptive")
    ap.add_argument("--precomp-exec", choices=list(PRECOMP_EXEC_CHOICES),
                    default="auto")
    ap.add_argument("--step-exec", choices=list(STEP_EXEC_CHOICES),
                    default="auto")
    ap.add_argument("--rebuild-budget", type=int, default=8)
    # --- graph
    ap.add_argument("--nodes", type=int, default=2_000)
    ap.add_argument("--avg-degree", type=int, default=12)
    ap.add_argument("--graph", choices=["random", "powerlaw"],
                    default="powerlaw")
    ap.add_argument("--weights", choices=["uniform", "pareto", "degree",
                                          "ones"], default="uniform")
    ap.add_argument("--alpha", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def scripted_trace(args, num_nodes: int) -> list:
    """The arrival script: a list of ``(arrival_time, WalkQuery)`` sorted
    by time — a pure function of the flags and seed, so a sim-clock run
    replays it exactly."""
    rng = np.random.default_rng(args.seed)
    programs = [p for p in args.programs.split(",") if p]
    starts = rng.integers(0, num_nodes, size=args.queries)
    priorities = rng.integers(0, 3, size=args.queries)
    if args.trace == "steady":
        times = np.arange(args.queries) * args.interarrival
    elif args.trace == "burst":
        # 4 synchronized bursts of queries/4 each
        times = (np.arange(args.queries) // max(args.queries // 4, 1)
                 ) * args.interarrival
    else:  # overload / deadline-storm: everything lands at t=0
        times = np.zeros(args.queries)
    deadline_budget = args.deadline
    if args.trace == "deadline-storm" and deadline_budget is None:
        deadline_budget = 0.05
    trace = []
    for i in range(args.queries):
        t = float(times[i])
        deadline = None
        if deadline_budget is not None:
            # storm: half the deadlines are generous, half are tight
            # enough that late-queued queries expire or get rejected
            scale = 1.0 if i % 2 == 0 else 0.1
            deadline = t + deadline_budget * scale
        trace.append((t, WalkQuery(
            start=int(starts[i]), program=programs[i % len(programs)],
            priority=int(priorities[i]), deadline=deadline)))
    return trace


def run_trace(svc: WalkService, trace: list, args,
              clock) -> tuple:
    """Drive the service through the trace until idle.  Returns
    ``(receipts, served)``.  Never deadlocks: every admitted walker
    terminates within ceil(steps/epoch_len) epochs, expiries free slots,
    and the loop always either submits, steps, or advances time."""
    mutated = args.mutate_at is None
    receipts, served, i = [], [], 0
    while i < len(trace) or not svc.idle:
        now = clock()
        if not mutated and now >= args.mutate_at:
            if args.mutate_kind == "structural":
                # deterministic seeded burst: delete a few existing
                # edges, insert a few random ones (an insert hitting a
                # surviving edge re-weights it — also exercised)
                rng = np.random.default_rng(args.seed + 1)
                indptr = np.asarray(svc.graph.indptr, np.int64)
                indices = np.asarray(svc.graph.indices, np.int64)
                src_all = np.repeat(np.arange(svc.graph.num_nodes),
                                    np.diff(indptr))
                pick = rng.choice(indices.size,
                                  size=min(16, indices.size),
                                  replace=False)
                V = svc.graph.num_nodes
                svc.apply_updates(
                    inserts=(rng.integers(0, V, 24),
                             rng.integers(0, V, 24),
                             rng.uniform(0.5, 1.5, 24)
                             .astype(np.float32)),
                    deletes=(src_all[pick], indices[pick]))
            else:
                nodes = np.arange(min(64, svc.graph.num_nodes))
                g2 = dataclasses.replace(
                    svc.graph, h=svc.graph.h * np.float32(1.5))
                svc.update_graph(g2, invalidated=nodes)
            mutated = True
        while i < len(trace) and trace[i][0] <= now:
            receipts.append(svc.submit(trace[i][1]))
            i += 1
        out = svc.step()
        served.extend(out)
        if args.sim_clock:
            dt = args.tick
            if svc.idle and i < len(trace):  # jump to the next arrival
                dt = max(dt, trace[i][0] - clock())
            clock.advance(dt)
        elif svc.idle and i < len(trace):
            time.sleep(min(0.001, max(0.0, trace[i][0] - clock())))
    return receipts, served


def serve_tcp(svc: WalkService, args) -> None:
    """The --transport tcp loop: listen, serve until a client drains
    the server (or Ctrl-C), then flush and report."""
    frontend = WalkFrontend(
        svc, FrontendConfig(host=args.host, port=args.port,
                            client_buffer=args.client_buffer,
                            slow_client=args.slow_client))
    host, port = frontend.start()
    print(f"[serve] listening on {host}:{port} "
          f"(walk_client --port {port})", flush=True)
    try:
        while not frontend.drained:
            time.sleep(0.05)
    except KeyboardInterrupt:
        print("[serve] interrupted; draining", flush=True)
    finally:
        summary = frontend.drain()
        frontend.stop()
    st = svc.stats()
    assert st.conserves(), st
    print(f"[serve] drained (flushed {summary['flushed']} partial): "
          f"{st.completed} completed, {st.expired} expired, "
          f"{st.cancelled} cancelled over {st.epochs} epochs")


def main():
    args = build_parser().parse_args()
    if args.trace == "overload" and args.max_pending > args.queries // 4:
        # make the overload trace actually overload by default
        args.max_pending = max(args.queries // 4, 1)
    gen = power_law_graph if args.graph == "powerlaw" else random_graph
    graph = gen(args.nodes, args.avg_degree, weight_dist=args.weights,
                alpha=args.alpha, seed=args.seed)
    print(f"[serve] graph: V={graph.num_nodes} E={graph.num_edges} "
          f"trace={args.trace} queries={args.queries} "
          f"clock={'sim' if args.sim_clock else 'wall'}")
    for p in args.programs.split(","):
        if p and p not in WORKLOADS:
            raise SystemExit(f"--programs: {p!r} not in "
                             f"{sorted(WORKLOADS)}")
    if args.transport == "tcp" and args.sim_clock:
        raise SystemExit("--transport tcp paces against real clients; "
                         "it needs the wall clock (drop --sim-clock)")
    clock = SimClock() if args.sim_clock else time.monotonic
    svc = WalkService(
        graph,
        ServiceConfig(slots=args.slots, epoch_len=args.epoch_len,
                      num_steps=args.steps, max_pending=args.max_pending,
                      aging_interval=args.aging_interval, seed=args.seed,
                      fairness=args.fairness, quantum=args.quantum,
                      weights=parse_tenant_weights(args.tenant_weights),
                      devices=args.devices),
        EngineConfig(method=args.method, precomp_exec=args.precomp_exec,
                     step_exec=args.step_exec,
                     rebuild_budget=args.rebuild_budget, seed=args.seed),
        clock=clock)
    if args.transport == "tcp":
        serve_tcp(svc, args)
        return
    t0 = time.time()
    trace = scripted_trace(args, graph.num_nodes)
    receipts, served = run_trace(svc, trace, args, clock)
    wall = time.time() - t0
    st = svc.stats()
    assert st.conserves(), st
    done = sum(1 for s in served if s.status == "completed")
    print(f"[serve] {st.submitted} submitted -> {st.admitted} admitted "
          f"({st.rejected_full} queue-full, {st.rejected_deadline} "
          f"deadline-infeasible, {st.rejected_unknown} unknown-program "
          f"rejected)")
    print(f"[serve] {done} completed + {st.expired} expired over "
          f"{st.epochs} epochs; peak occupancy {st.peak_occupancy}/"
          f"{st.slots} slots")
    print(f"[serve] throughput {done / max(wall, 1e-9):.0f} queries/s "
          f"(wall {wall:.2f}s); frac_rjs={st.frac_rjs:.2f} "
          f"frac_precomp={st.frac_precomp:.2f} "
          f"frac_stale={st.frac_stale:.2f} "
          f"rebuilt_rows={st.rebuilt_rows}")
    print(f"[serve] queue wait p50={st.queue_wait_p50 * 1e3:.2f}ms "
          f"p99={st.queue_wait_p99 * 1e3:.2f}ms | latency "
          f"p50={st.latency_p50 * 1e3:.2f}ms "
          f"p99={st.latency_p99 * 1e3:.2f}ms")


if __name__ == "__main__":
    main()
