"""Walk launcher — the paper's primary entry point.

    PYTHONPATH=src python -m repro.launch.walk --workload node2vec \
        --nodes 20000 --avg-degree 12 --queries 2048 --steps 40 \
        --method adaptive

Multi-device (docs/scaling.md): ``--devices N`` shards the scheduler's
slot pool over a 1D walker mesh and prints per-device telemetry.  On a
CPU-only host, force N host devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python -m repro.launch.walk --devices 2 ...
"""
from __future__ import annotations

import argparse
import ast
import time

import numpy as np

from repro.core import (EngineConfig, WalkEngine, available_samplers,
                        profile_edge_cost_ratio)
from repro.core.cost_model import CostModel
from repro.core.runtime import STEP_EXEC_CHOICES
from repro.core.samplers import PRECOMP_EXEC_CHOICES
from repro.graphs import power_law_graph, random_graph
from repro.walks import WORKLOADS, make_workload


def build_parser() -> argparse.ArgumentParser:
    """The CLI surface, as one inspectable object.

    ``tools/check_docs.py`` cross-checks every ``--flag`` the docs show in
    a ``repro.launch.walk`` command against this parser, so a removed or
    renamed flag fails the docs gate instead of rotting silently.
    """
    ap = argparse.ArgumentParser(prog="repro.launch.walk")
    ap.add_argument("--workload", choices=sorted(WORKLOADS), default="node2vec")
    ap.add_argument("--list-workloads", action="store_true",
                    help="print the registered workload names (one per "
                         "line, sorted like the registry) and exit")
    ap.add_argument("--workload-arg", action="append", default=[],
                    metavar="KEY=VALUE", dest="workload_arg",
                    help="factory keyword for the selected workload, e.g. "
                         "--workload-arg a=4.0 --workload-arg window=32 "
                         "(values parsed as Python literals, falling back "
                         "to strings; repeatable)")
    # choices come from the sampler registry, so plugin samplers registered
    # before main() runs are selectable from the CLI too.
    ap.add_argument("--method", choices=available_samplers(),
                    default="adaptive")
    ap.add_argument("--precomp-exec", choices=list(PRECOMP_EXEC_CHOICES),
                    default="auto",
                    help="execution path for precomputed-table draws: the "
                         "Pallas DMA kernels or the jnp selectors "
                         "(bit-identical; auto = pallas on TPU)")
    ap.add_argument("--step-exec", choices=list(STEP_EXEC_CHOICES),
                    default="auto",
                    help="step execution path: the fused Pallas mega-step "
                         "kernel or the staged lax.scan loop (bit-identical; "
                         "auto = fused on TPU when the sampler × workload "
                         "cell is provably fusable, staged otherwise)")
    ap.add_argument("--rebuild-budget", type=int, default=8,
                    help="stale precomp table rows re-baked per scheduler "
                         "epoch after a weight mutation (0 disables the "
                         "amortized background rebuild)")
    ap.add_argument("--batch", type=int, default=None,
                    help="walker slots for the streaming scheduler "
                         "(default: all queries at once)")
    ap.add_argument("--epoch-len", type=int, default=None,
                    help="scan steps between host-side slot refills")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the slot pool over this many local devices "
                         "(1D walker mesh; results are bit-identical to a "
                         "single-device run — see docs/scaling.md)")
    ap.add_argument("--nodes", type=int, default=20_000)
    ap.add_argument("--avg-degree", type=int, default=12)
    ap.add_argument("--graph", choices=["random", "powerlaw"],
                    default="powerlaw")
    ap.add_argument("--weights", choices=["uniform", "pareto", "degree",
                                          "ones"], default="uniform")
    ap.add_argument("--alpha", type=float, default=2.0)
    ap.add_argument("--queries", type=int, default=2048)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--profile", action="store_true",
                    help="profile the EdgeCost ratio first (§5.1)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def parse_workload_args(pairs) -> dict:
    """``--workload-arg key=value`` pairs as a factory-kwargs dict.

    Values go through ``ast.literal_eval`` (ints, floats, bools, tuples —
    e.g. ``schema=(0,1,2)``); anything that does not parse stays a string.
    """
    kw = {}
    for pair in pairs:
        key, sep, value = pair.partition("=")
        if not sep or not key:
            raise SystemExit(
                f"--workload-arg expects KEY=VALUE, got {pair!r}")
        try:
            kw[key] = ast.literal_eval(value)
        except (ValueError, SyntaxError):
            kw[key] = value
    return kw


def main():
    args = build_parser().parse_args()
    if args.list_workloads:
        for name in sorted(WORKLOADS):
            print(name)
        return

    gen = power_law_graph if args.graph == "powerlaw" else random_graph
    graph = gen(args.nodes, args.avg_degree, weight_dist=args.weights,
                alpha=args.alpha, seed=args.seed)
    print(f"[walk] graph: V={graph.num_nodes} E={graph.num_edges} "
          f"maxdeg={graph.max_degree()}")
    wl = make_workload(args.workload, **parse_workload_args(args.workload_arg))
    cm = CostModel()
    if args.profile:
        t0 = time.time()
        ratio = profile_edge_cost_ratio(graph)
        cm = CostModel(edge_cost_ratio=ratio)
        print(f"[walk] profiled EdgeCost ratio = {ratio:.2f} "
              f"({time.time()-t0:.2f}s)")
    eng = WalkEngine(graph, wl, EngineConfig(
        method=args.method, cost_model=cm, seed=args.seed,
        precomp_exec=args.precomp_exec, step_exec=args.step_exec,
        rebuild_budget=args.rebuild_budget))
    print(f"[walk] compiler flag: {eng.compiled.flag} "
          f"warnings={eng.compiled.warnings} "
          f"step_exec={eng.step_exec_resolved}")
    starts = np.arange(args.queries) % graph.num_nodes
    t0 = time.time()
    res = eng.run(starts, num_steps=args.steps, batch=args.batch,
                  epoch_len=args.epoch_len, devices=args.devices)
    dt = time.time() - t0
    total_steps = int((res.paths[:, 1:] >= 0).sum())
    print(f"[walk] {args.queries} queries × {res.steps} steps in {dt:.2f}s "
          f"({total_steps / dt:.0f} steps/s) frac_rjs={res.frac_rjs:.2f} "
          f"frac_precomp={res.frac_precomp:.2f} "
          f"frac_stale={res.frac_stale:.2f} "
          f"(over {res.live_steps} live steps) "
          f"fallbacks={res.rjs_fallbacks} "
          f"rebuilt_rows={res.rebuilt_rows}")
    if res.per_device is not None:
        for d in res.per_device:
            print(f"[walk]   device {d['device']}: {d['slots']} slots, "
                  f"{d['queries']} queries, "
                  f"{d['emitted_steps']} emitted steps")


if __name__ == "__main__":
    main()
