"""Serving launcher: batched generation with the eRVS token sampler.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke
from repro.models import init_params
from repro.serving import GenerateConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--greedy", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    print(f"[serve] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    params = init_params(cfg, jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    gcfg = GenerateConfig(max_new_tokens=args.new_tokens,
                          temperature=args.temperature, greedy=args.greedy,
                          use_pallas_sampler=True)
    t0 = time.time()
    out = generate(params, cfg, prompts, gcfg, key=jax.random.key(2))
    dt = time.time() - t0
    print(f"[serve] {args.batch}×{args.new_tokens} tokens in {dt:.1f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s, host CPU)")
    import numpy as np
    for b in range(args.batch):
        print("  req", b, np.asarray(out[b]).tolist())


if __name__ == "__main__":
    main()
