"""Walk-service client — library and CLI for the TCP front-end.

    PYTHONPATH=src python -m repro.launch.walk_client \
        --port 7421 --starts 0,17,42 --program deepwalk

Connects to a ``repro.launch.serve_walks --transport tcp`` server (or
any :class:`repro.serving.WalkFrontend`), submits the given start
nodes, polls the walks back, and prints one path per line.  The same
:class:`WalkServiceClient` class is the library examples and tests use:
a small blocking-socket client speaking the length-prefixed JSON frame
protocol of :mod:`repro.serving.transport`, with pipelining (responses
are matched to requests by id, so out-of-order arrival is fine — polls
answered while a parked submit waits on backpressure credit just work).
"""
from __future__ import annotations

import argparse
import itertools
import socket
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.serving import transport as tp
from repro.serving.walk_service import ServedWalk


class WalkRejected(RuntimeError):
    """A submit answered with a typed error frame (``code`` is the
    service rejection reason or a frontend code like ``backpressure``)."""

    def __init__(self, code: str, detail: Optional[str]):
        super().__init__(f"{code}: {detail}")
        self.code = code
        self.detail = detail


class WalkServiceClient:
    """Blocking client for one front-end connection (module docstring).

    Not thread-safe: one client per thread (connections are cheap).
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 timeout: Optional[float] = 30.0,
                 max_frame: int = tp.MAX_FRAME):
        self._sock = socket.create_connection((host, port),
                                              timeout=timeout)
        self._max_frame = max_frame
        self._rid = itertools.count()
        self._responses: Dict[Any, dict] = {}  # out-of-order arrivals

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "WalkServiceClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ framing
    def send(self, obj: dict) -> Any:
        """Send one request (stamping a fresh id) without waiting for
        the response; returns the id for a later :meth:`result`."""
        rid = next(self._rid)
        obj = dict(obj, id=rid)
        tp.send_frame(self._sock, obj, self._max_frame)
        return rid

    def result(self, rid: Any) -> dict:
        """Block until the response for ``rid`` arrives (buffering any
        other responses that land first)."""
        while rid not in self._responses:
            frame = tp.recv_frame(self._sock, self._max_frame)
            if frame is None:
                raise ConnectionError("server closed the connection")
            fid = frame.get("id")
            if fid is None:  # connection-fatal server error frame
                raise tp.ProtocolError(frame.get("code", tp.ERR_BAD_FRAME),
                                       frame.get("detail", ""), fatal=True)
            self._responses[fid] = frame
        return self._responses.pop(rid)

    def request(self, obj: dict) -> dict:
        return self.result(self.send(obj))

    # ------------------------------------------------------------ the API
    def submit(self, start: int, program: str = "deepwalk",
               priority: int = 0,
               deadline: Optional[float] = None) -> int:
        """Submit one query; returns the ticket or raises WalkRejected.
        Under the ``suspend`` backpressure policy this blocks until the
        server admits the parked submit — interleave :meth:`send` /
        :meth:`result` yourself for non-blocking pipelining."""
        r = self.request(self.submit_frame(start, program, priority,
                                           deadline))
        if r["op"] == tp.OP_ERROR:
            raise WalkRejected(r["code"], r.get("detail"))
        return int(r["ticket"])

    @staticmethod
    def submit_frame(start: int, program: str = "deepwalk",
                     priority: int = 0,
                     deadline: Optional[float] = None) -> dict:
        frame: Dict[str, Any] = {"op": tp.OP_SUBMIT, "start": int(start),
                                 "program": program,
                                 "priority": int(priority)}
        if deadline is not None:
            frame["deadline"] = float(deadline)
        return frame

    def poll(self, max_walks: int = 64) -> List[ServedWalk]:
        """Drain up to ``max_walks`` finished walks from this
        connection's delivery buffer (may be empty; never blocks on
        walk production, only on the response frame)."""
        r = self.request({"op": tp.OP_POLL, "max": int(max_walks)})
        return [tp.walk_from_wire(d) for d in r["walks"]]

    def cancel(self, ticket: int) -> str:
        """Cancel a ticket; returns the terminal status (``cancelled``,
        or ``not-found`` when it already finished — poll for it)."""
        r = self.request({"op": tp.OP_CANCEL, "ticket": int(ticket)})
        return r["status"]

    def stats(self) -> dict:
        """The server's ServiceStats snapshot as a dict, plus a
        ``frontend`` section (clients, buffered, stalled, draining)."""
        return self.request({"op": tp.OP_STATS})["stats"]

    def drain(self) -> dict:
        """Ask the server to drain gracefully; returns the drain-ok
        frame (``pending`` = queries still working at that instant)."""
        return self.request({"op": tp.OP_DRAIN})

    def walk(self, starts, program: str = "deepwalk", priority: int = 0,
             deadline: Optional[float] = None,
             poll_interval: float = 0.005,
             pump: Optional[Callable[[], Any]] = None
             ) -> List[ServedWalk]:
        """Submit every start node and block until all walks are back,
        returned in submission order.  Submits are pipelined — all sent
        up front, responses matched by id — so a submit parked on
        backpressure credit cannot deadlock the polls that free it.
        ``pump`` is the manual-driver hook: a callable run between
        empty polls instead of sleeping (tests pass ``frontend.pump``
        to pin the event interleaving)."""
        import time as _time
        rids = [self.send(self.submit_frame(int(s), program, priority,
                                            deadline))
                for s in np.asarray(starts).tolist()]
        tickets: Dict[Any, int] = {}  # rid -> ticket, as receipts land
        walks: Dict[int, ServedWalk] = {}

        def harvest_receipts():
            for rid in rids:
                if rid not in tickets and rid in self._responses:
                    r = self._responses.pop(rid)
                    if r["op"] == tp.OP_ERROR:
                        raise WalkRejected(r["code"], r.get("detail"))
                    tickets[rid] = int(r["ticket"])

        while True:
            harvest_receipts()
            if len(tickets) == len(rids) and len(walks) >= len(rids):
                break
            got = self.poll(max_walks=max(len(rids), 1))
            for w in got:
                walks[w.ticket] = w
            if not got:
                if pump is not None:
                    pump()
                else:
                    _time.sleep(poll_interval)
        return [walks[tickets[r]] for r in rids]


# ------------------------------------------------------------------ CLI
def build_parser() -> argparse.ArgumentParser:
    """The CLI surface, as one inspectable object (audited by
    ``tools/check_docs.py`` exactly like the other launchers)."""
    ap = argparse.ArgumentParser(prog="repro.launch.walk_client")
    ap.add_argument("--host", default="127.0.0.1",
                    help="front-end host to connect to")
    ap.add_argument("--port", type=int, required=True,
                    help="front-end port (serve_walks --transport tcp "
                         "prints it on startup)")
    ap.add_argument("--starts", default="0",
                    help="comma-separated start node ids to walk from")
    ap.add_argument("--program", default="deepwalk",
                    help="walk program name for every submitted query")
    ap.add_argument("--priority", type=int, default=0,
                    help="admission priority (higher first)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="absolute service-clock deadline for every "
                         "query (default: none)")
    ap.add_argument("--timeout", type=float, default=30.0,
                    help="socket timeout in seconds")
    ap.add_argument("--stats", action="store_true",
                    help="print the server's stats snapshot after the "
                         "walks return")
    ap.add_argument("--drain", action="store_true",
                    help="ask the server to drain gracefully after the "
                         "walks return (server exits once idle)")
    return ap


def main():
    args = build_parser().parse_args()
    starts = [int(s) for s in args.starts.split(",") if s]
    with WalkServiceClient(host=args.host, port=args.port,
                           timeout=args.timeout) as client:
        walks = client.walk(starts, program=args.program,
                            priority=args.priority,
                            deadline=args.deadline)
        for w in walks:
            path = ("-" if w.path is None
                    else ",".join(str(v) for v in w.path[w.path >= 0]))
            print(f"[client] ticket={w.ticket} status={w.status} "
                  f"steps={w.steps} path={path}")
        if args.stats:
            st = client.stats()
            print(f"[client] stats: {st}")
        if args.drain:
            r = client.drain()
            print(f"[client] drain requested "
                  f"(pending={r.get('pending')})")


if __name__ == "__main__":
    main()
