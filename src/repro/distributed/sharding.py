"""Logical-axis sharding: the one place that knows how tensors map to mesh.

Model code annotates tensors with *logical* axis names ("batch", "heads",
"mlp", "experts", …) via :func:`shard`.  A :class:`MeshRules` context maps
logical names to mesh axes with **automatic divisibility fallback**: a mesh
axis that does not evenly divide the tensor dimension is dropped from the
spec (e.g. yi-6b's 4 KV heads on a 16-way model axis → replicated KV while
Q stays tensor-parallel).  Outside any context, annotations are no-ops, so
smoke tests and single-host runs never touch device state.

Parameter sharding is name-based: every parameter leaf name has a logical
signature in :data:`LEAF_LOGICAL`; :func:`param_specs` walks a params
pytree and emits a matching PartitionSpec pytree (consumed by pjit
in_shardings and by the checkpoint resharder).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ----------------------------------------------------------------- rules

#: logical axis -> tuple of mesh axes (order matters; composite allowed)
DEFAULT_LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),       # DP over pod × data
    "seq": (),                       # replicated by default; SP opt-in
    "embed": (),                     # d_model — FSDP shards it over "data"
    "heads": ("model",),             # TP
    "kv_heads": ("model",),          # TP (falls back when indivisible)
    "mlp": ("model",),               # TP
    "experts": ("model",),           # EP
    "vocab": ("model",),             # TP on vocab dim
    "kv_seq": ("model",),            # decode KV-cache context parallelism
    "capacity": (),
    "state": (),
    "conv": (),
    "qk_depth": (),
    # walk-engine slot pool: the leading dim of every WalkerState leaf
    # (see repro.core.types.WalkerState.BATCH_AXIS) shards over a 1D
    # walker mesh.  Lanes are independent, so this is pure data
    # parallelism; the graph stays replicated per device.
    "walkers": ("walkers",),
}

FSDP_RULES = dict(DEFAULT_LOGICAL_RULES, embed=("pod", "data"))
# sequence-parallel long-context rules: shard sequence over data axis
SP_RULES = dict(DEFAULT_LOGICAL_RULES, seq=("data",))


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    logical: Dict[str, Tuple[str, ...]]
    # explicit bf16 tensor-parallel reductions (shard_map psum) for the
    # attention-out / MLP-down projections — halves the TP wire bytes vs
    # the fp32 all-reduce GSPMD otherwise emits (§Perf iteration B2)
    tp_bf16_reduce: bool = False

    def axis_size(self, names: Tuple[str, ...]) -> int:
        n = 1
        for a in names:
            n *= self.mesh.shape[a]
        return n


_STATE = threading.local()


def current_rules() -> Optional[MeshRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def activation_sharding_ctx(mesh: Mesh, logical: Optional[Dict] = None,
                            fsdp: bool = False, seqpar: bool = False,
                            tp_bf16_reduce: bool = False):
    base = FSDP_RULES if fsdp else DEFAULT_LOGICAL_RULES
    if seqpar:
        base = dict(base, seq=("data",))
    logical = dict(base, **(logical or {}))
    # drop mesh axes the mesh does not actually have (single-pod meshes)
    have = set(mesh.axis_names)
    logical = {k: tuple(a for a in v if a in have) for k, v in logical.items()}
    prev = current_rules()
    _STATE.rules = MeshRules(mesh=mesh, logical=logical,
                             tp_bf16_reduce=tp_bf16_reduce)
    try:
        yield _STATE.rules
    finally:
        _STATE.rules = prev


# ------------------------------------------------------- walker slot pool
#
# The streaming epoch scheduler (repro.core.runtime) shards its fixed pool
# of walker slots over a 1D mesh: each device owns a contiguous block of
# slots, the single host-side refill queue feeds them round-robin, and the
# graph is replicated.  Because every lane's RNG stream is keyed per
# *query* (never per slot or device), results are bit-identical for any
# device count — sharding only changes where a lane's arithmetic runs.

def walker_mesh(num_devices: Optional[int] = None) -> Mesh:
    """A 1D mesh over ``num_devices`` (default: all local devices) whose
    single axis is named ``"walkers"`` — the axis ``DEFAULT_LOGICAL_RULES``
    maps the slot-pool batch dim onto."""
    devs = jax.devices()
    n = len(devs) if num_devices is None else int(num_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(f"num_devices must be in [1, {len(devs)}], got {n}")
    return jax.make_mesh((n,), ("walkers",), devices=devs[:n])


def walker_rules(mesh: Mesh) -> MeshRules:
    """MeshRules exposing only the walker axis (engine-internal; model
    activations never see it)."""
    return MeshRules(mesh=mesh, logical={"walkers": ("walkers",)})


def walker_spec(leaf: jax.Array, num_slots: int, mesh: Mesh) -> P:
    """PartitionSpec for one slot-pool pytree leaf: dim 0 shards over the
    walker axis iff it is the slot dim (``shape[0] == num_slots``); every
    other dim — and slot-count-free leaves, e.g. a scalar carry — stays
    replicated.  Divisibility fallback applies: a pool that does not
    divide the mesh is replicated rather than mis-sharded (the engine
    pads the pool so this never triggers in practice)."""
    shape = jnp.shape(leaf)
    if not shape or shape[0] != num_slots:
        return P()
    axes = ("walkers",) + (None,) * (len(shape) - 1)
    return logical_to_spec(axes, shape, walker_rules(mesh))


def shard_walker_state(state, num_slots: int, mesh: Mesh):
    """Place every leaf of a WalkerState (or any slot-pool pytree) on the
    walker mesh.  Leaves already laid out correctly are untouched
    (``device_put`` with an equal sharding is a no-op), so the scheduler
    can cheaply re-assert the layout after each host-side refill."""
    return jax.tree_util.tree_map(
        lambda leaf: jax.device_put(
            leaf, NamedSharding(mesh, walker_spec(leaf, num_slots, mesh))),
        state)


def tp_down_proj(x: jax.Array, w: jax.Array) -> jax.Array:
    """Down-projection x @ w with the contraction dim tensor-parallel.

    Default: plain matmul (GSPMD inserts the all-reduce — observed at
    fp32 on partial products, 2× the necessary wire bytes).  With
    ``tp_bf16_reduce``: shard_map with an explicit bf16 psum over the
    model axis — the standard production trick of reducing activations
    at their storage dtype.
    """
    rules = current_rules()
    if rules is None or not rules.tp_bf16_reduce:
        return x @ w
    mesh = rules.mesh
    if "model" not in mesh.axis_names or \
            x.shape[-1] % mesh.shape["model"] != 0:
        return x @ w
    from jax.experimental.shard_map import shard_map

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    x_spec = P(batch_axes if len(batch_axes) > 1 else
               (batch_axes[0] if batch_axes else None),
               *([None] * (x.ndim - 2)), "model")
    w_spec = P("model", None)
    out_spec = P(x_spec[0], *([None] * (x.ndim - 1)))

    def local(xl, wl):
        part = (xl @ wl).astype(x.dtype)  # reduce at bf16, not fp32
        return jax.lax.psum(part, "model")

    return shard_map(local, mesh=mesh, in_specs=(x_spec, w_spec),
                     out_specs=out_spec)(x, w)


def logical_to_spec(logical_axes: Tuple[Optional[str], ...],
                    shape: Tuple[int, ...],
                    rules: Optional[MeshRules] = None) -> P:
    """PartitionSpec for a tensor, with divisibility fallback per dim."""
    rules = rules or current_rules()
    if rules is None:
        return P()
    out = []
    used = set()
    for dim, name in zip(shape, logical_axes):
        if name is None or name not in rules.logical:
            out.append(None)
            continue
        axes = tuple(a for a in rules.logical[name] if a not in used)
        if not axes:
            out.append(None)
            continue
        size = 1
        kept = []
        for a in axes:
            if dim % (size * rules.mesh.shape[a]) == 0:
                kept.append(a)
                size *= rules.mesh.shape[a]
        if not kept:
            out.append(None)
        else:
            used.update(kept)
            out.append(tuple(kept) if len(kept) > 1 else kept[0])
    return P(*out)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes (no-op outside a context).

    If the rules define "embed_act", activations asking for "embed" get it
    instead — this splits the parameter d_model sharding (e.g. ZeRO-3
    weight-gathered inference shards params 256-way) from the activation
    residual-stream sharding (replicated on D in that layout).
    """
    rules = current_rules()
    if rules is None:
        return x
    axes = tuple(("embed_act" if (a == "embed" and
                                  "embed_act" in rules.logical) else a)
                 for a in logical_axes)
    spec = logical_to_spec(axes, x.shape, rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


# ------------------------------------------------------- parameter rules

#: parameter leaf name -> logical axes per dim (rank must match)
LEAF_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / head.  The token-embedding table shards the VOCAB dim:
    # GSPMD partitions the lookup as masked-local-gather + all-reduce of
    # the [B,S,D] result (cheap).  Sharding d_model instead trips an SPMD
    # partitioner bug on multi-segment models (invalid reshard slice,
    # observed on the 16×16 mesh).  The LM head shards the vocab dim
    # (Megatron-style); its d_model contraction stays local.
    "embed": ("vocab", None),
    "lm_head": (None, "vocab"),
    # attention
    "wq": ("embed", "heads"),
    "wk": ("embed", "kv_heads"),
    "wv": ("embed", "kv_heads"),
    "wo": ("heads", "embed"),
    "q_norm": ("qk_depth",),
    "k_norm": ("qk_depth",),
    # dense mlp
    "wi": ("embed", "mlp"),
    "wg": ("embed", "mlp"),
    "wd": ("mlp", "embed"),
    # MoE
    "router": ("embed", "experts"),
    "we_i": ("experts", "embed", "mlp"),
    "we_g": ("experts", "embed", "mlp"),
    "we_d": ("experts", "mlp", "embed"),
    "ws_i": ("embed", "mlp"),
    "ws_g": ("embed", "mlp"),
    "ws_d": ("mlp", "embed"),
    # norms
    "norm1": ("embed",),
    "norm2": ("embed",),
    "final_norm": ("embed",),
    "norm": ("embed",),
    # RG-LRU recurrent block
    "rg_in": ("embed", "mlp"),
    "rg_gate": ("embed", "mlp"),
    "rg_out": ("mlp", "embed"),
    "rg_conv": ("conv", "mlp"),
    "rg_a": ("mlp",),
    "rg_input_gate": ("mlp", "conv"),
    "rg_a_gate": ("mlp", "conv"),
    # Mamba2
    "m_in": ("embed", "mlp"),
    "m_conv": ("conv", "mlp"),
    "m_alog": ("state",),
    "m_d": ("state",),
    "m_norm": ("mlp",),
    "m_out": ("mlp", "embed"),
    "m_dtbias": ("state",),
}


def param_specs(params, rules: Optional[MeshRules] = None):
    """PartitionSpec pytree for a params pytree (name-based; stacked layer
    dims — leading dims beyond the leaf signature — are replicated)."""
    rules = rules or current_rules()

    def spec_of(path, leaf):
        name = None
        for entry in reversed(path):
            key = getattr(entry, "key", None) or getattr(entry, "name", None)
            if isinstance(key, str) and key in LEAF_LOGICAL:
                name = key
                break
        if name is None:
            return P()
        logical = LEAF_LOGICAL[name]
        rank = len(leaf.shape)
        # stacked-layer leading dims (scan over layers) -> None
        pad = (None,) * (rank - len(logical))
        axes = pad + logical
        if rules is None:
            return P(*([None] * rank))
        return logical_to_spec(axes, leaf.shape, rules)

    return jax.tree_util.tree_map_with_path(spec_of, params)


def named_shardings(params, mesh: Mesh, rules: Optional[MeshRules] = None):
    specs = param_specs(params, rules)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)
