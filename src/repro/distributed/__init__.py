from repro.distributed.sharding import (
    MeshRules,
    activation_sharding_ctx,
    current_rules,
    logical_to_spec,
    param_specs,
    shard,
)

__all__ = [
    "MeshRules",
    "activation_sharding_ctx",
    "current_rules",
    "logical_to_spec",
    "param_specs",
    "shard",
]
