from repro.distributed.sharding import (
    MeshRules,
    activation_sharding_ctx,
    current_rules,
    logical_to_spec,
    param_specs,
    shard,
    shard_walker_state,
    walker_mesh,
    walker_rules,
    walker_spec,
)

__all__ = [
    "MeshRules",
    "activation_sharding_ctx",
    "current_rules",
    "logical_to_spec",
    "param_specs",
    "shard",
    "shard_walker_state",
    "walker_mesh",
    "walker_rules",
    "walker_spec",
]
