"""Deterministic, resumable data pipeline.

Batches are a pure function of (seed, step) — fold_in(step) — so restart
from a checkpoint replays the exact stream with no stored iterator state
(the standard deterministic-dataloader design for fault-tolerant training).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    vocab_size: int = 256
    seed: int = 0


def synthetic_batch(dcfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """Markov-ish synthetic tokens (not uniform — loss can actually drop)."""
    key = jax.random.fold_in(jax.random.key(dcfg.seed), step)
    k1, k2 = jax.random.split(key)
    base = jax.random.randint(k1, (dcfg.batch_size, dcfg.seq_len),
                              0, dcfg.vocab_size, jnp.int32)
    # inject learnable structure: every even position repeats previous token
    shifted = jnp.roll(base, 1, axis=1)
    pos = jnp.arange(dcfg.seq_len) % 2 == 0
    tokens = jnp.where(pos[None, :], shifted, base)
    labels = jnp.concatenate([tokens[:, 1:],
                              jnp.full((dcfg.batch_size, 1), -1, jnp.int32)],
                             axis=1)
    return {"tokens": tokens, "labels": labels}


def synthetic_batches(dcfg: DataConfig, start_step: int = 0
                      ) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield synthetic_batch(dcfg, step)
        step += 1


def walk_corpus_batches(corpus, dcfg: DataConfig, start_step: int = 0
                        ) -> Iterator[Dict[str, jax.Array]]:
    """LM batches over walk sequences (vocab = num_nodes + 1)."""
    step = start_step
    while True:
        seqs = corpus.lm_sequences(dcfg.batch_size, dcfg.seq_len + 1,
                                   seed=dcfg.seed + step)
        tokens = jnp.asarray(seqs[:, :-1])
        labels = jnp.asarray(seqs[:, 1:])
        yield {"tokens": tokens, "labels": labels}
        step += 1


class PrefetchIterator:
    """Double-buffered producer: walk generation overlaps training steps.

    A background thread drains ``source`` into a bounded queue (``depth``
    batches — the classic double buffer at the default 2) so the walk
    engine produces batch ``k+1`` while the trainer consumes batch ``k``.
    Because every pipeline batch is a pure function of ``(seed, step)``,
    overlap changes *nothing* about the stream: the prefetched iterator
    yields bit-identical batches in the same order as the synchronous
    one (pinned by tests/test_pipeline.py), it just hides the production
    latency.

    Semantics worth relying on:

    * a producer exception surfaces on the consumer's ``next()`` at the
      position where the stream broke (after already-buffered batches);
    * a finite source ends with ``StopIteration`` as usual;
    * :meth:`close` (or the context manager) stops the thread promptly —
      the producer never blocks forever on a full queue.

    ``produced`` counts batches the producer has materialised so far —
    the observable the overlap test keys on.
    """

    _DONE = object()

    def __init__(self, source: Iterator, depth: int = 2):
        if depth <= 0:
            raise ValueError(f"depth must be positive, got {depth}")
        self.depth = int(depth)
        self.produced = 0
        self._source = source
        self._queue: queue.Queue = queue.Queue(maxsize=self.depth)
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._produce, name="walk-prefetch", daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._queue.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _produce(self):
        try:
            for item in self._source:
                self.produced += 1
                if not self._put(item):
                    return
        except BaseException as exc:  # surfaces on the consumer side
            self._err = exc
        self._put(self._DONE)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._queue.get()
        if item is self._DONE:
            self._queue.put(self._DONE)  # stay terminal if re-polled
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self) -> None:
        """Stop the producer thread and release the buffers."""
        self._stop.set()
        while True:  # unblock a producer stuck on a full queue
            try:
                self._queue.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def walk_corpus_batches_prefetched(corpus, dcfg: DataConfig,
                                   start_step: int = 0,
                                   depth: int = 2) -> PrefetchIterator:
    """`walk_corpus_batches` behind a double buffer: the engine walks the
    next batch while the consumer trains on the current one, yielding the
    exact synchronous stream (batches are pure in ``(seed, step)``)."""
    return PrefetchIterator(walk_corpus_batches(corpus, dcfg, start_step),
                            depth=depth)
