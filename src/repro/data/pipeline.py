"""Deterministic, resumable data pipeline.

Batches are a pure function of (seed, step) — fold_in(step) — so restart
from a checkpoint replays the exact stream with no stored iterator state
(the standard deterministic-dataloader design for fault-tolerant training).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    vocab_size: int = 256
    seed: int = 0


def synthetic_batch(dcfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """Markov-ish synthetic tokens (not uniform — loss can actually drop)."""
    key = jax.random.fold_in(jax.random.key(dcfg.seed), step)
    k1, k2 = jax.random.split(key)
    base = jax.random.randint(k1, (dcfg.batch_size, dcfg.seq_len),
                              0, dcfg.vocab_size, jnp.int32)
    # inject learnable structure: every even position repeats previous token
    shifted = jnp.roll(base, 1, axis=1)
    pos = jnp.arange(dcfg.seq_len) % 2 == 0
    tokens = jnp.where(pos[None, :], shifted, base)
    labels = jnp.concatenate([tokens[:, 1:],
                              jnp.full((dcfg.batch_size, 1), -1, jnp.int32)],
                             axis=1)
    return {"tokens": tokens, "labels": labels}


def synthetic_batches(dcfg: DataConfig, start_step: int = 0
                      ) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield synthetic_batch(dcfg, step)
        step += 1


def walk_corpus_batches(corpus, dcfg: DataConfig, start_step: int = 0
                        ) -> Iterator[Dict[str, jax.Array]]:
    """LM batches over walk sequences (vocab = num_nodes + 1)."""
    step = start_step
    while True:
        seqs = corpus.lm_sequences(dcfg.batch_size, dcfg.seq_len + 1,
                                   seed=dcfg.seed + step)
        tokens = jnp.asarray(seqs[:, :-1])
        labels = jnp.asarray(seqs[:, 1:])
        yield {"tokens": tokens, "labels": labels}
        step += 1
