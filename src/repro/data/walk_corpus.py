"""Walk → token-stream bridge: FlexiWalker as the data engine for training.

This is the actual downstream use of dynamic random walks (DeepWalk /
Node2Vec / metapath2vec): walk sequences become token sequences for
embedding or LM training.  ``WalkCorpus`` runs the engine over a graph and
exposes (a) LM-style next-token sequences (node ids as tokens) and (b)
skip-gram (center, context) pairs for the Node2Vec embedding example.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
import numpy as np

from repro.core import EngineConfig, WalkEngine
from repro.core.types import WalkProgram
from repro.graphs.csr import CSRGraph


@dataclasses.dataclass
class WalkCorpus:
    graph: CSRGraph
    # any WalkProgram (legacy Workload objects still adapt transparently
    # inside WalkEngine via from_workload)
    workload: WalkProgram
    walk_len: int = 40
    engine_config: Optional[EngineConfig] = None

    def __post_init__(self):
        self.engine = WalkEngine(self.graph, self.workload,
                                 self.engine_config or EngineConfig())

    def walks(self, starts: np.ndarray, seed: int = 0) -> np.ndarray:
        """[Q, walk_len+1] node-id paths (-1 padded after dead ends)."""
        res = self.engine.run(starts, num_steps=self.walk_len,
                              key=jax.random.key(seed))
        return res.paths

    def lm_sequences(self, num_seqs: int, seq_len: int,
                     seed: int = 0) -> np.ndarray:
        """Concatenate walks into fixed-length token sequences.  Token id =
        node id (+1; 0 is BOS/pad) — vocab = num_nodes + 1."""
        rng = np.random.default_rng(seed)
        V = self.graph.num_nodes
        toks = []
        need = num_seqs * seq_len
        batch = max(256, need // max(self.walk_len, 1) + 1)
        starts = rng.integers(0, V, size=batch)
        paths = self.walks(starts, seed=seed)
        stream = paths[paths >= 0] + 1  # shift; 0 reserved
        while stream.size < need:
            starts = rng.integers(0, V, size=batch)
            paths = self.walks(starts, seed=seed + len(toks) + 1)
            stream = np.concatenate([stream, paths[paths >= 0] + 1])
        return stream[:need].reshape(num_seqs, seq_len).astype(np.int32)


def skipgram_pairs(paths: np.ndarray, window: int = 5,
                   max_pairs: Optional[int] = None,
                   seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """(center, context) node-id pairs from walk paths (word2vec-style)."""
    rng = np.random.default_rng(seed)
    centers, contexts = [], []
    Q, L = paths.shape
    for off in range(1, window + 1):
        a = paths[:, :-off].reshape(-1)
        b = paths[:, off:].reshape(-1)
        ok = (a >= 0) & (b >= 0)
        centers.append(a[ok])
        contexts.append(b[ok])
        centers.append(b[ok])
        contexts.append(a[ok])
    c = np.concatenate(centers)
    x = np.concatenate(contexts)
    perm = rng.permutation(c.shape[0])
    c, x = c[perm], x[perm]
    if max_pairs is not None:
        c, x = c[:max_pairs], x[:max_pairs]
    return c.astype(np.int32), x.astype(np.int32)
