from repro.data.pipeline import (DataConfig, PrefetchIterator,
                                 synthetic_batches, walk_corpus_batches,
                                 walk_corpus_batches_prefetched)
from repro.data.walk_corpus import WalkCorpus, skipgram_pairs

__all__ = ["DataConfig", "PrefetchIterator", "synthetic_batches",
           "walk_corpus_batches", "walk_corpus_batches_prefetched",
           "WalkCorpus", "skipgram_pairs"]
