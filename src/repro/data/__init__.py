from repro.data.pipeline import DataConfig, synthetic_batches, walk_corpus_batches
from repro.data.walk_corpus import WalkCorpus, skipgram_pairs

__all__ = ["DataConfig", "synthetic_batches", "walk_corpus_batches",
           "WalkCorpus", "skipgram_pairs"]
