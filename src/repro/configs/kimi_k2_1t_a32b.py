"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]  61L d_model=7168 64H (GQA kv=8)
expert d_ff=2048, vocab=163840, MoE 384e top-8, 1 dense lead-in layer,
1 shared expert (DeepSeek-V3 lineage)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, vocab_size=163_840,
    num_heads=64, num_kv_heads=8, head_dim=112,
    d_ff=18432,               # the dense lead-in layer's FFN
    num_experts=384, experts_per_token=8, moe_d_ff=2048,
    shared_experts=1, num_dense_layers=1,
    capacity_factor=1.25,
    rope_theta=50_000.0,
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke", family="moe",
    num_layers=3, d_model=64, vocab_size=256,
    num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=160,
    num_experts=8, experts_per_token=2, moe_d_ff=32,
    shared_experts=1, num_dense_layers=1,
)
