"""chameleon-34b [vlm] — early-fusion, VQ image tokens.
[arXiv:2405.09818; unverified]  48L d_model=8192 64H (GQA kv=8)
d_ff=22016 vocab=65536 (text + VQ image codes in ONE vocabulary —
early fusion means the modality frontend reduces to the shared token
embedding; the VQ tokenizer itself is the stub, input_specs provides
token ids).  Chameleon uses qk-norm for stability."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, vocab_size=65536,
    num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22016, qk_norm=True,
)

SMOKE = ModelConfig(
    name="chameleon-34b-smoke", family="vlm",
    num_layers=2, d_model=64, vocab_size=256,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=160, qk_norm=True,
)
