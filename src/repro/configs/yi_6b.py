"""yi-6b [dense] — llama-arch GQA.  [arXiv:2403.04652; hf]
32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, vocab_size=64000,
    num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=11008,
    rope_theta=5_000_000.0,   # yi long-base rope
)

SMOKE = ModelConfig(
    name="yi-6b-smoke", family="dense",
    num_layers=2, d_model=64, vocab_size=256,
    num_heads=8, num_kv_heads=2, head_dim=8, d_ff=160,
)
