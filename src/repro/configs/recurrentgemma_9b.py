"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn per 2 rec.
[arXiv:2402.19427; unverified]  38L d_model=4096 16H (GQA kv=1, MQA)
d_ff=12288 vocab=256000, window 2048."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, vocab_size=256_000,
    num_heads=16, num_kv_heads=1, head_dim=256,
    d_ff=12288,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096, conv_width=4, local_window=2048,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    num_layers=5, d_model=64, vocab_size=256,
    num_heads=4, num_kv_heads=1, head_dim=16, d_ff=160,
    block_pattern=("rec", "rec", "attn"),
    lru_width=64, conv_width=4, local_window=32,
    tie_embeddings=True,
)
