"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64e top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (kv=16)
expert d_ff=1408 vocab=163840; DeepSeek-V3 arch: 1 dense lead-in,
2 shared experts."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, vocab_size=163_840,
    num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=11264,               # dense lead-in FFN (moonlight intermediate)
    num_experts=64, experts_per_token=6, moe_d_ff=1408,
    shared_experts=2, num_dense_layers=1,
    capacity_factor=1.25,
    rope_theta=50_000.0,
)

SMOKE = ModelConfig(
    name="moonshot-smoke", family="moe",
    num_layers=3, d_model=64, vocab_size=256,
    num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128,
    num_experts=8, experts_per_token=2, moe_d_ff=32,
    shared_experts=2, num_dense_layers=1,
)
