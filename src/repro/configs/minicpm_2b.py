"""minicpm-2b [dense] — WSD schedule, llama-like, depth-scaled residuals.
[arXiv:2404.06395; hf]  40L d_model=2304 36H (GQA kv=36) d_ff=5760
vocab=122753."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, vocab_size=122753,
    num_heads=36, num_kv_heads=36, head_dim=64,
    d_ff=5760,
    scale_depth=1.4,          # minicpm depth-scaled residuals
    tie_embeddings=True,      # minicpm ties embedding and head
    rope_theta=10_000.0,
)

# training schedule is arch-specific: WSD (the paper's contribution)
TRAIN_SCHEDULE = "wsd"

SMOKE = ModelConfig(
    name="minicpm-2b-smoke", family="dense",
    num_layers=2, d_model=64, vocab_size=256,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=160,
    scale_depth=1.4, tie_embeddings=True,
)
