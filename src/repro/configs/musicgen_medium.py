"""musicgen-medium [audio] — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]  48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.
The EnCodec frontend is a STUB per the assignment: input_specs provides
precomputed frame token ids (one codebook stream of the delay pattern)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, vocab_size=2048,
    num_heads=24, num_kv_heads=24, head_dim=64,
    d_ff=6144,
)

SMOKE = ModelConfig(
    name="musicgen-smoke", family="audio",
    num_layers=2, d_model=64, vocab_size=128,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
)
