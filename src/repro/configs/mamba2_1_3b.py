"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]  48L d_model=2048 vocab=50280
ssm_state=128, d_inner=2·d_model, head_dim 64."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, vocab_size=50280,
    d_inner=4096, ssm_state=128, ssm_head_dim=64, ssm_groups=1,
    ssm_chunk=64, conv_width=4,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    num_layers=3, d_model=64, vocab_size=256,
    d_inner=128, ssm_state=16, ssm_head_dim=16, ssm_groups=1,
    ssm_chunk=16, conv_width=4, tie_embeddings=True,
)
