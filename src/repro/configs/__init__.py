"""Architecture registry + assigned input shapes (the 10×4 dry-run grid).

Every architecture is selectable via ``--arch <id>``; each has a FULL
config (exact published dimensions — exercised only through the dry-run's
ShapeDtypeStructs, never allocated) and a SMOKE config (same family,
reduced) that runs a real forward/train step on CPU in the test suite.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig

_MODULES = {
    "minicpm-2b": "minicpm_2b",
    "yi-6b": "yi_6b",
    "qwen3-8b": "qwen3_8b",
    "qwen3-0.6b": "qwen3_0_6b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "chameleon-34b": "chameleon_34b",
    "mamba2-1.3b": "mamba2_1_3b",
    "musicgen-medium": "musicgen_medium",
}

ARCHS: List[str] = list(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def train_schedule(arch: str) -> str:
    return getattr(_module(arch), "TRAIN_SCHEDULE", "cosine")


def cell_supported(arch: str, shape: str) -> Tuple[bool, str]:
    """Whether (arch × shape) is a valid dry-run cell.

    long_500k needs sub-quadratic attention: run for SSM/hybrid, skip for
    pure full-attention archs (recorded in DESIGN.md §Arch-applicability).
    All 10 archs are decoders, so decode shapes otherwise apply.
    """
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, ("full quadratic attention at 524k context — "
                       "skipped per assignment (sub-quadratic archs only)")
    return True, ""


def all_cells(include_skipped: bool = False):
    for arch in ARCHS:
        for shape in SHAPES:
            ok, why = cell_supported(arch, shape)
            if ok or include_skipped:
                yield arch, shape, ok, why
