from repro.train.optimizer import (
    AdamWState,
    adamw_init,
    adamw_update,
    cosine_schedule,
    wsd_schedule,
)
from repro.train.step import TrainConfig, loss_fn, make_train_step
from repro.train.compress import CompressorState, compress_init, compress_apply

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "wsd_schedule",
    "TrainConfig",
    "loss_fn",
    "make_train_step",
    "CompressorState",
    "compress_init",
    "compress_apply",
]
