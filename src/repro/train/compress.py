"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantised gradients with an error-feedback accumulator
(1-bit-Adam / EF-SGD family): before the optimizer consumes a gradient it
is quantised to int8 with per-block scales; the quantisation residual is
carried into the next step.  On a real deployment the quantised payload is
what crosses the ICI/DCN links (shrinking the collective roofline term
4×); inside a single pjit program XLA owns the all-reduce, so we model the
numerics (quantise→dequantise + EF) and expose ``wire_bytes()`` so the
roofline report can account for the compressed collective volume.  The
EXPERIMENTS.md §Perf log measures the end-to-end effect on the collective
term.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
BLOCK = 256


class CompressorState(NamedTuple):
    error: Any  # residual pytree, same structure as grads


def compress_init(params) -> CompressorState:
    return CompressorState(
        error=jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params))


def _quantize_dequantize(g: jax.Array) -> jax.Array:
    """Per-block symmetric int8 quantise→dequantise (simulated wire)."""
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(F32) * scale
    return deq.reshape(-1)[:n].reshape(g.shape)


def compress_apply(grads, state: CompressorState
                   ) -> Tuple[Any, CompressorState]:
    """grads → (dequantised grads, new error state).  EF: g' = Q(g + e);
    e' = (g + e) - g'."""

    def one(g, e):
        target = g.astype(F32) + e
        deq = _quantize_dequantize(target)
        return deq.astype(g.dtype), target - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(state.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = tdef.unflatten([o[0] for o in outs])
    new_e = tdef.unflatten([o[1] for o in outs])
    return new_g, CompressorState(error=new_e)


def wire_bytes(params) -> Tuple[int, int]:
    """(uncompressed, compressed) bytes a gradient exchange would move."""
    raw = sum(p.size * 4 for p in jax.tree.leaves(params))
    comp = sum(p.size * 1 + (p.size // BLOCK + 1) * 4
               for p in jax.tree.leaves(params))
    return raw, comp
