"""AdamW + LR schedules (WSD for minicpm, cosine default), pure JAX.

Optimizer state pytrees mirror the parameter pytree, so the sharding specs
from distributed.param_specs apply verbatim — on a mesh this is ZeRO-ish
for the TP/EP-sharded dims automatically, and fully sharded when the FSDP
rules shard d_model over "data".  Moments are fp32 regardless of param
dtype (mixed-precision master-moment convention).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


# ------------------------------------------------- 8-bit moment storage
# Blockwise-quantised optimizer moments (8-bit-Adam family): int8 payload +
# fp32 per-block scales along the last dim.  Cuts optimizer-state HBM from
# 8 to ~2.03 bytes/param — what makes the 1T-param kimi-k2 train cell fit
# 16 GB/chip on 512 chips (EXPERIMENTS.md §Perf iteration A2).
QBLOCK = 128


def _quantize_moment(x: jax.Array, signed: bool = True):
    """Blockwise 8-bit quantisation.  The second moment (signed=False) is
    stored in the SQRT domain: q = 255·sqrt(v/vmax) — sqrt compresses the
    dynamic range so small v values keep relative precision (a linear
    scale maps them to 0, and mh/(√0+eps) then explodes — observed)."""
    shape = x.shape
    n = shape[-1] if shape else 1
    pad = (-n) % QBLOCK
    xp = jnp.pad(x, [(0, 0)] * (len(shape) - 1) + [(0, pad)]) if shape else x
    blk = xp.reshape(*shape[:-1], -1, QBLOCK)
    if signed:
        scale = jnp.maximum(jnp.max(jnp.abs(blk), axis=-1) / 127.0, 1e-20)
        q = jnp.clip(jnp.round(blk / scale[..., None]), -127, 127
                     ).astype(jnp.int8)
    else:
        scale = jnp.maximum(jnp.max(blk, axis=-1), 1e-20)
        root = jnp.sqrt(jnp.maximum(blk, 0.0) / scale[..., None])
        q = jnp.clip(jnp.round(root * 254.0) - 127.0, -127, 127
                     ).astype(jnp.int8)
    return {"q": q.reshape(*shape[:-1], -1)[..., :n], "scale": scale}


def _dequantize_moment(m, shape, signed: bool = True):
    q, scale = m["q"], m["scale"]
    n = shape[-1] if shape else 1
    pad = (-n) % QBLOCK
    qp = jnp.pad(q, [(0, 0)] * (len(shape) - 1) + [(0, pad)]) if shape else q
    blk = qp.reshape(*shape[:-1], -1, QBLOCK).astype(F32)
    if signed:
        out = blk * scale[..., None]
    else:
        root = (blk + 127.0) / 254.0
        out = root * root * scale[..., None]
    return out.reshape(*shape[:-1], -1)[..., :n]


def adamw_init(params, moments_dtype: str = "float32") -> AdamWState:
    if moments_dtype == "int8":
        zq = lambda p: _quantize_moment(jnp.zeros(p.shape, F32))
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zq, params),
                          nu=jax.tree.map(zq, params))
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def _is_quantized(m) -> bool:
    return isinstance(m, dict) and set(m) == {"q", "scale"}


def adamw_update(params, grads, state: AdamWState, lr: jax.Array,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, max_grad_norm: float = 1.0):
    """One AdamW step with global-norm clipping.  Returns (params, state,
    metrics dict).  Moments may be fp32 arrays or 8-bit quantised dicts
    (dequantise → update → requantise; the quantisation error enters the
    moment EMA, the standard 8-bit-Adam formulation)."""
    gsq = sum(jnp.sum(jnp.square(g.astype(F32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, max_grad_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(F32)
    c2 = 1.0 - b2 ** step.astype(F32)

    def upd(p, g, m, v):
        quant = _is_quantized(m)
        if quant:
            m = _dequantize_moment(m, p.shape, signed=True)
            v = _dequantize_moment(v, p.shape, signed=False)
        g = g.astype(F32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + eps)
        if quant:
            # update clipping guards residual quantisation error in v
            delta = jnp.clip(delta, -5.0, 5.0)
        # decoupled weight decay on matrix params only (norms/scalars exempt)
        wd = weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(F32) - lr * (delta + wd * p.astype(F32))
        if quant:
            m = _quantize_moment(m, signed=True)
            v = _quantize_moment(v, signed=False)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}


# -------------------------------------------------------------- schedules
def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(F32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)

    return lr


def wsd_schedule(base_lr: float, warmup: int, stable: int, decay: int,
                 final_frac: float = 0.01) -> Callable:
    """Warmup-Stable-Decay (minicpm, arXiv:2404.06395): linear warmup,
    long constant plateau, short steep decay — enables continual
    checkpoint-and-branch training."""

    def lr(step):
        step = step.astype(F32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        in_decay = step > warmup + stable
        t = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = base_lr * (final_frac ** t)  # exponential decay leg
        return jnp.where(step < warmup, warm,
                         jnp.where(in_decay, dec, base_lr))

    return lr
