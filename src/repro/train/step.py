"""Loss + train-step factory: microbatched grad accumulation, remat,
optional gradient compression, schedule-driven AdamW."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import forward  # noqa: F401 (public API re-export)
from repro.models.config import ModelConfig
from repro.train.compress import compress_apply
from repro.train.optimizer import adamw_update, cosine_schedule, wsd_schedule

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    base_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # "cosine" | "wsd"
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    microbatches: int = 1  # grad accumulation
    remat: bool = True
    compress_grads: bool = False
    moments_dtype: str = "float32"  # "float32" | "int8" (8-bit Adam)

    def lr_fn(self) -> Callable:
        if self.schedule == "wsd":
            stable = int(self.total_steps * 0.8) - self.warmup_steps
            decay = self.total_steps - self.warmup_steps - stable
            return wsd_schedule(self.base_lr, self.warmup_steps, stable,
                                max(decay, 1))
        return cosine_schedule(self.base_lr, self.warmup_steps,
                               self.total_steps)


def loss_fn(params, cfg: ModelConfig, tokens: jax.Array, labels: jax.Array,
            remat: bool = True, chunk: int = 512) -> jax.Array:
    """Causal-LM cross entropy; labels == -1 are masked.

    Memory-shape matters more than it looks: materialising [B, S, V] fp32
    logits for a 152k vocab is ~160 GB/device at 32-way DP, and the naive
    ``take_along_axis`` gather on a vocab-sharded tensor forces GSPMD into
    a full all-gather (observed).  So the head matmul + softmax-xent run
    **chunked over the sequence** under jax.checkpoint (logits exist for
    one chunk at a time in fwd AND bwd), and the gold logit is extracted
    with an iota==label masked reduction, which partitions cleanly over
    the vocab-sharded axis (partial-sum + small [B, C] all-reduce).
    """
    from repro.distributed.sharding import shard
    from repro.models import forward_hidden

    x, head = forward_hidden(params, cfg, tokens, remat=remat)  # [B,S,D]
    B, S, D = x.shape
    V = head.shape[1]
    C = min(chunk, S)
    nc = (S + C - 1) // C
    mask_all = labels >= 0

    def chunk_nll(i):
        def f(x, head):
            xc = jax.lax.dynamic_slice_in_dim(x, i * C, C, axis=1)
            lc = jax.lax.dynamic_slice_in_dim(labels, i * C, C, axis=1)
            logits = jnp.einsum("bcd,dv->bcv", xc.astype(F32),
                                head.astype(F32))
            logits = shard(logits, "batch", None, "vocab")
            logz = jax.nn.logsumexp(logits, axis=-1)  # [B, C]
            vio = jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2)
            gold = jnp.sum(jnp.where(vio == lc[..., None], logits, 0.0), -1)
            m = lc >= 0
            return jnp.sum((logz - gold) * m)

        return jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)(x, head)

    def body(acc, i):
        return acc + chunk_nll(i), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), jnp.arange(nc))
    return total / jnp.maximum(jnp.sum(mask_all), 1)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    state = dict(params, opt (AdamWState), comp (CompressorState | ()),
    step int32).  batch = dict(tokens [B,S], labels [B,S]).
    With microbatches > 1 the batch splits on axis 0 and gradients
    accumulate in fp32 across a lax.scan (sequential — the standard
    activation-memory/throughput trade).
    """
    lr_fn = tcfg.lr_fn()

    def grads_of(params, tokens, labels):
        return jax.value_and_grad(loss_fn)(params, cfg, tokens, labels,
                                           remat=tcfg.remat)

    def train_step(state, batch):
        params = state["params"]
        tokens, labels = batch["tokens"], batch["labels"]
        if tcfg.microbatches > 1:
            B = tokens.shape[0]
            mb = tcfg.microbatches
            tk = tokens.reshape(mb, B // mb, *tokens.shape[1:])
            lb = labels.reshape(mb, B // mb, *labels.shape[1:])

            def acc_body(carry, xs):
                loss_acc, g_acc = carry
                t, l = xs
                loss, g = grads_of(params, t, l)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(F32) / mb, g_acc, g)
                return (loss_acc + loss / mb, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (jnp.float32(0.0), g0),
                                            (tk, lb))
        else:
            loss, grads = grads_of(params, tokens, labels)

        comp = state.get("comp", ())
        if tcfg.compress_grads and comp != ():
            grads, comp = compress_apply(grads, comp)

        lr = lr_fn(state["step"])
        params, opt, om = adamw_update(
            params, grads, state["opt"], lr,
            b1=tcfg.b1, b2=tcfg.b2,
            weight_decay=tcfg.weight_decay,
            max_grad_norm=tcfg.max_grad_norm)
        new_state = dict(params=params, opt=opt, comp=comp,
                         step=state["step"] + 1)
        metrics = {"loss": loss, "lr": lr, **om}
        return new_state, metrics

    return train_step
