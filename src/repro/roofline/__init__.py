from repro.roofline.hlo import HloStats, parse_hlo
from repro.roofline.analysis import (
    TPU_V5E,
    HardwareSpec,
    RooflineReport,
    analyze_cell,
    model_flops,
)

__all__ = [
    "HloStats",
    "parse_hlo",
    "TPU_V5E",
    "HardwareSpec",
    "RooflineReport",
    "analyze_cell",
    "model_flops",
]
