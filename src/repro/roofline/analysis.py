"""Roofline terms per (arch × shape × mesh) from the compiled dry-run.

  compute    = FLOPs_per_device   / peak_FLOPs_per_chip
  memory     = HBM_bytes_per_dev  / HBM_bw_per_chip
  collective = coll_bytes_per_dev / ICI_bw_per_chip

All numerators come from the per-device SPMD module via
:mod:`repro.roofline.hlo` (while-trip-scaled).  MODEL_FLOPS is the
analytic useful-compute count (6·N_active·D for training, 2·N_active·D
for inference, + exact attention terms); MODEL_FLOPS / HLO_FLOPs exposes
remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.models.config import ModelConfig
from repro.roofline.hlo import HloStats


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float  # per chip
    hbm_bw: float  # bytes/s per chip
    ici_bw: float  # bytes/s per link per chip


TPU_V5E = HardwareSpec("tpu-v5e", 197e12, 819e9, 50e9)


def _attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for k in cfg.layer_kinds() if k in ("attn", "moe"))


def model_flops(cfg: ModelConfig, seq_len: int, global_batch: int,
                kind: str) -> float:
    """Analytic useful FLOPs for one step of the cell (whole job, not
    per-device).

    train:   6 · N_matmul · tokens  +  3 · attn_fwd
    prefill: 2 · N_matmul · tokens  +  attn_fwd
    decode:  2 · N_matmul · batch   +  4 · B · S_cache · H · hd · L_attn
    attn_fwd = 4 · B · S² · H · hd · L_attn / 2 (causal)
    N_matmul excludes the token-embedding gather (not a matmul) but keeps
    the LM head.
    """
    N = cfg.active_param_count() - cfg.vocab_size * cfg.d_model
    L_attn = _attn_layers(cfg)
    H_hd = cfg.num_heads * cfg.head_dim
    B, S = global_batch, seq_len
    if kind == "train":
        tokens = B * S
        attn = 4 * B * S * S * H_hd * L_attn / 2
        if cfg.local_window and cfg.family == "hybrid":
            attn = 4 * B * S * min(S, cfg.local_window) * H_hd * L_attn
        return 6.0 * N * tokens + 3.0 * attn
    if kind == "prefill":
        tokens = B * S
        attn = 4 * B * S * S * H_hd * L_attn / 2
        if cfg.local_window and cfg.family == "hybrid":
            attn = 4 * B * S * min(S, cfg.local_window) * H_hd * L_attn
        return 2.0 * N * tokens + attn
    if kind == "decode":
        ctx = min(S, cfg.local_window) if (
            cfg.local_window and cfg.family == "hybrid") else S
        attn = 4 * B * ctx * H_hd * L_attn
        return 2.0 * N * B + attn
    raise ValueError(kind)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    kind: str
    # per-device numerators
    hlo_flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_breakdown: Dict[str, float]
    # analytic
    model_flops_total: float
    # memory fit
    argument_bytes: int
    temp_bytes: int
    # terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0

    def finalize(self, hw: HardwareSpec) -> "RooflineReport":
        # compute term anchored on max(parsed-HLO, analytic/chips): the
        # parsed count can undershoot when XLA's loop double-buffering
        # ("wide" whiles) rewrites trip counts; the analytic count is exact
        # for the model's matmuls, so the max is the safe numerator.
        flops = max(self.hlo_flops, self.model_flops_total / self.chips)
        self.t_compute = flops / hw.peak_flops_bf16
        self.t_memory = self.hbm_bytes / hw.hbm_bw
        self.t_collective = self.collective_bytes / hw.ici_bw
        return self

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips) — remat/redundancy waste."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def step_time_bound(self) -> float:
        """Lower bound on step time: max of the three terms (perfect
        overlap assumption)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    # decode steps are bandwidth-bound by construction (one token: every
    # weight + the KV cache must stream once); their "useful work" is bytes
    useful_bytes_total: float = 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-work time / bound — the §Perf score.  For compute cells
        (train/prefill): useful FLOPs at peak vs the three-term bound.
        For decode cells: minimal required bytes (active params + KV once)
        at peak HBM bandwidth vs the bound.  1.0 = the dominant term is
        pure useful work at peak rate."""
        if self.kind == "decode" and self.useful_bytes_total:
            t_useful = (self.useful_bytes_total / self.chips) / TPU_V5E.hbm_bw
        else:
            t_useful = (self.model_flops_total / self.chips) \
                / TPU_V5E.peak_flops_bf16
        b = self.step_time_bound
        return t_useful / b if b else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "kind": self.kind,
            "hlo_flops_per_dev": self.hlo_flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "collective_bytes_per_dev": self.collective_bytes,
            "collective_breakdown": self.collective_breakdown,
            "model_flops_total": self.model_flops_total,
            "argument_bytes": self.argument_bytes,
            "temp_bytes": self.temp_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_fraction": self.useful_flops_fraction,
            "useful_bytes_total": self.useful_bytes_total,
            "roofline_fraction": self.roofline_fraction,
        }


def useful_decode_bytes(cfg: ModelConfig, seq_len: int,
                        global_batch: int) -> float:
    """Minimal HBM traffic for one decode step (whole job): every active
    parameter once + the attention state (KV cache / recurrent state)."""
    params = cfg.active_param_count() * 2  # bf16
    if cfg.family == "ssm":
        H = cfg.d_inner // cfg.ssm_head_dim
        state = global_batch * H * cfg.ssm_head_dim * cfg.ssm_state * 4 \
            * cfg.num_layers
    elif cfg.family == "hybrid":
        n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
        n_rec = cfg.num_layers - n_attn
        state = (global_batch * min(seq_len, cfg.local_window) * cfg.kv_dim
                 * 2 * 2 * n_attn
                 + global_batch * cfg.lru_width * 4 * n_rec)
    else:
        state = (global_batch * seq_len * cfg.kv_dim * 2 * 2
                 * sum(1 for k in cfg.layer_kinds() if k in ("attn", "moe")))
    return float(params + state)


def analyze_cell(arch: str, shape: str, mesh_name: str, chips: int,
                 kind: str, cfg: ModelConfig, seq_len: int,
                 global_batch: int, stats: HloStats,
                 argument_bytes: int, temp_bytes: int,
                 hw: HardwareSpec = TPU_V5E) -> RooflineReport:
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips, kind=kind,
        hlo_flops=stats.flops, hbm_bytes=stats.hbm_bytes,
        collective_bytes=stats.total_collective_bytes,
        collective_breakdown={k: v for k, v in stats.collective_bytes.items()
                              if v},
        model_flops_total=model_flops(cfg, seq_len, global_batch, kind),
        argument_bytes=argument_bytes, temp_bytes=temp_bytes,
        useful_bytes_total=(useful_decode_bytes(cfg, seq_len, global_batch)
                            if kind == "decode" else 0.0),
    )
    return rep.finalize(hw)
