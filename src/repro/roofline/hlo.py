"""Post-compile HLO text analysis with while-loop trip-count scaling.

``compiled.cost_analysis()`` visits every while body ONCE (verified
empirically — a 10-iteration scanned matmul reports 1/10 the FLOPs of its
unrolled twin), which silently under-counts scan-over-layers models by
L×.  This parser walks ``compiled.as_text()`` (the post-SPMD, per-device
module), builds the computation call graph, extracts loop trip counts from
while-condition constants, and accumulates:

  * flops            — dot ops (2·|out|·|contracting|), scaled by trips
  * hbm_bytes        — operand+result sizes of top-level (non-fused-inner)
                       instructions: the buffer traffic at fusion
                       boundaries, scaled by trips
  * collective_bytes — per collective type (all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute),
                       operand sizes × trips — the §Roofline third term.

Everything is PER-DEVICE (the SPMD module is the per-device program), so
terms divide by per-chip peak rates directly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[^\s]+)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    dims = m.group(2)
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    is_fusion_body: bool = False


@dataclasses.dataclass
class HloStats:
    flops: float
    hbm_bytes: float
    collective_bytes: Dict[str, float]
    collective_count: Dict[str, int]
    while_trips: Dict[str, int]
    warnings: List[str]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _split_computations(text: str) -> List[Computation]:
    comps: List[Computation] = []
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(1), [])
            continue
        if line.strip() == "}":
            comps.append(cur)
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2), m.group(3), line,
                                    is_root="ROOT " in line))
    return comps


def _attr(line: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _split_operands(text: str) -> List[str]:
    """Split an operand list on top-level commas (shapes contain commas:
    ``f32[1024,128]{1,0} %a, %b`` must yield two operands, not three)."""
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in text:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


def _operand_shape(operand: str, table: Dict[str, str]):
    """First shape match of one operand: inline type if present, else the
    name resolved through the computation's instruction table."""
    m = _SHAPE_RE.search(operand)
    if m:
        return m
    name = operand.split()[-1].lstrip("%") if operand else ""
    if name in table:
        return _SHAPE_RE.search(table[name])
    return None


def _operand_value_bytes(operand: str, table: Dict[str, str]) -> int:
    """Total bytes of one operand's value (tuple types sum all elements)."""
    b = _shape_bytes(operand)
    if b:
        return b
    name = operand.split()[-1].lstrip("%") if operand else ""
    return _shape_bytes(table.get(name, ""))


def _dot_flops(instr: Instr, table: Dict[str, str]) -> float:
    out_elems = _shape_elems(instr.type_str)
    # contracting dims from the lhs operand shape; the operand list either
    # carries inline types — dot(f32[1024,128]{1,0} %a, …) — or bare names
    # resolved through the computation's table
    m = re.search(r"\(([^)]*)\)", instr.line[instr.line.index(instr.opcode):])
    operands = _split_operands(m.group(1)) if m else []
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    lhs_shape = _operand_shape(operands[0], table) if operands else None
    if not lhs_shape:
        return 2.0 * out_elems  # conservative fallback
    dims = [int(d) for d in lhs_shape.group(2).split(",") if d]
    contract = 1
    if cdims and cdims.group(1):
        for i in cdims.group(1).split(","):
            idx = int(i)
            if idx < len(dims):
                contract *= dims[idx]
    return 2.0 * out_elems * contract


# HBM-traffic model for the TPU target: count ops that move data at fusion
# boundaries.  The CPU backend leaves many singleton elementwise ops
# (convert/copy/transpose/add/…) unfused at top level; on TPU those ride
# along fusions, so counting their operands+results triple-counts every
# value chain.  We therefore count a WHITELIST: fusion boundaries, matmuls,
# reductions, data-movement ops, RNG, and collectives.
_COUNT_BYTES_OPS = {
    "fusion", "dot", "convolution", "reduce", "reduce-window", "sort",
    "gather", "scatter", "dynamic-slice", "dynamic-update-slice",
    "concatenate", "pad", "rng", "rng-bit-generator", "cholesky",
    "triangular-solve", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute", "select-and-scatter",
}

# in-place/update-style ops: traffic = the touched slice, not the buffer
# (XLA aliases the operand; counting the full array per update inflated
# 32k-decode and flash-backward accumulators ~40×)
_INPLACE_OPS = {"dynamic-update-slice", "scatter"}


def _trip_count(cond: Computation) -> Optional[int]:
    """Largest s32 constant in the loop condition ≈ trip count (scan/fori
    conditions are exactly `lt(iv, constant(N))`)."""
    best = None
    for ins in cond.instrs:
        if ins.opcode == "constant" and "s32[]" in ins.type_str:
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                v = int(m.group(1))
                if v > 0 and (best is None or v > best):
                    best = v
    return best


def parse_hlo(text: str) -> HloStats:
    comps = _split_computations(text)
    by_name = {c.name: c for c in comps}
    warnings: List[str] = []

    # mark fusion bodies (referenced by calls=%name on fusion instructions)
    fusion_bodies = set()
    called_bodies = set()
    for c in comps:
        for ins in c.instrs:
            if ins.opcode == "fusion":
                tgt = _attr(ins.line, "calls")
                if tgt:
                    fusion_bodies.add(tgt)
            elif ins.opcode in ("call", "custom-call"):
                tgt = _attr(ins.line, "to_apply") or _attr(ins.line, "calls")
                if tgt:
                    called_bodies.add(tgt)

    # multipliers: start at entry (first ENTRY or largest), propagate
    entry = comps[0].name if comps else ""
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line)
            if m:
                entry = m.group(1)
            break
    mult: Dict[str, float] = {entry: 1.0}
    while_trips: Dict[str, int] = {}

    # BFS over call graph
    stack = [entry]
    seen = set()
    while stack:
        name = stack.pop()
        if name in seen or name not in by_name:
            continue
        seen.add(name)
        m = mult.get(name, 1.0)
        for ins in by_name[name].instrs:
            if ins.opcode == "while":
                body = _attr(ins.line, "body")
                cond = _attr(ins.line, "condition")
                trip = None
                if cond and cond in by_name:
                    trip = _trip_count(by_name[cond])
                if trip is None:
                    trip = 1
                    warnings.append(f"while {ins.name}: trip count unknown, using 1")
                if body:
                    while_trips[body] = trip
                    mult[body] = mult.get(body, 0.0) + m * trip
                    stack.append(body)
                if cond:
                    mult[cond] = mult.get(cond, 0.0) + m * trip
                    stack.append(cond)
            elif ins.opcode == "fusion":
                tgt = _attr(ins.line, "calls")
                if tgt:
                    mult[tgt] = mult.get(tgt, 0.0) + m
                    stack.append(tgt)
            elif ins.opcode in ("call", "conditional", "custom-call"):
                for key in ("to_apply", "calls", "true_computation",
                            "false_computation", "branch_computations"):
                    tgt = _attr(ins.line, key)
                    if tgt and tgt in by_name:
                        mult[tgt] = mult.get(tgt, 0.0) + m
                        stack.append(tgt)

    flops = 0.0
    hbm = 0.0
    coll_bytes: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    coll_count: Dict[str, int] = {k: 0 for k in COLLECTIVES}

    for c in comps:
        m = mult.get(c.name)
        if m is None:
            # unreached computation (dead or referenced in ways we missed)
            continue
        table = {i.name: i.type_str for i in c.instrs}
        in_fusion = c.name in fusion_bodies
        for ins in c.instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, table)
            elif ins.opcode == "convolution":
                # approximation: 2 × |out| × (contraction guessed from lhs)
                flops += m * 2.0 * _shape_elems(ins.type_str)
            op = ins.opcode
            if op.endswith("-start"):
                op = op[:-len("-start")]
            if op in COLLECTIVES and not ins.opcode.endswith("-done"):
                coll_bytes[op] += m * _operand_bytes(ins, table)
                coll_count[op] += int(m)
            if not in_fusion and ins.opcode in _COUNT_BYTES_OPS:
                if ins.opcode in _INPLACE_OPS:
                    # read + write of the update slice only
                    upd = _update_operand_bytes(ins, table)
                    hbm += m * 2 * upd
                elif ins.opcode == "gather":
                    hbm += m * 2 * _shape_bytes(ins.type_str)
                elif ins.opcode == "fusion":
                    hbm += m * _fusion_bytes(ins, table, by_name)
                else:
                    hbm += m * (_shape_bytes(ins.type_str)
                                + _operand_bytes(ins, table))

    return HloStats(flops=flops, hbm_bytes=hbm, collective_bytes=coll_bytes,
                    collective_count=coll_count, while_trips=while_trips,
                    warnings=warnings[:20])


def _fusion_bytes(ins: Instr, table: Dict[str, str],
                  by_name: Dict[str, "Computation"]) -> float:
    """HBM traffic of a fusion op.

    Two systematic overcounts are corrected against the fusion body:
    * a parameter whose only in-body uses are dynamic-slice reads of a
      stacked scan buffer is charged the SLICE bytes, not the buffer;
    * a fusion rooted at dynamic-update-slice writes (and is aliased with)
      the big buffer: charged 2× the update bytes, not result+operand.
    """
    body_name = _attr(ins.line, "calls")
    body = by_name.get(body_name) if body_name else None
    m = re.search(r"\(([^)]*)\)", ins.line[ins.line.index(ins.opcode):])
    operands = [o.strip().lstrip("%") for o in m.group(1).split(",")] if m \
        else []
    op_bytes = [(_shape_bytes(table[o]) if o in table else 0)
                for o in operands]
    result = _shape_bytes(ins.type_str)
    if body is None:
        return result + sum(op_bytes)
    # map parameter index -> param instr name, analyse in-body uses
    params = {}
    for bi in body.instrs:
        if bi.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", bi.line)
            if pm:
                params[int(pm.group(1))] = bi.name
    # update-style fusion: the body updates a result-shaped buffer slice-
    # wise (scan stacking / accumulators).  On TPU the buffer is aliased
    # in place, so the result write is the update slice, not the buffer
    # (the CPU backend may interpose full-buffer converts — host artifact).
    res_dims = _SHAPE_RE.search(ins.type_str)
    res_dims = res_dims.group(2) if res_dims else None
    is_update = any(
        bi.opcode == "dynamic-update-slice"
        and (lambda d: d and d.group(2) == res_dims)(
            _SHAPE_RE.search(bi.type_str))
        for bi in body.instrs)

    def effective_operand_bytes(idx: int) -> float:
        """Slice-consumption analysis: a param whose only in-body uses are
        dynamic-slice reads is charged the slice bytes."""
        b = op_bytes[idx] if idx < len(op_bytes) else 0
        pname = params.get(idx)
        if pname is None or b == 0:
            return float(b)
        slice_bytes = 0
        other_use = False
        for bi in body.instrs:
            if bi.opcode == "parameter" or pname not in bi.line:
                continue
            if not re.search(r"%" + re.escape(pname) + r"\b", bi.line):
                continue
            if bi.opcode == "dynamic-slice":
                slice_bytes += _shape_bytes(bi.type_str)
            else:
                other_use = True
        if slice_bytes and not other_use:
            return float(min(b, slice_bytes))
        return float(b)

    total = 0.0
    for idx in range(len(operands)):
        if is_update and _dims_match(operands[idx], table, res_dims):
            continue  # aliased in-place buffer: no read charge
        total += effective_operand_bytes(idx)
    if is_update:
        return 2.0 * total if total else float(result)
    return float(result) + total


def _dims_match(op_name: str, table: Dict[str, str], dims: Optional[str]) -> bool:
    if op_name not in table or dims is None:
        return False
    m = _SHAPE_RE.search(table[op_name])
    return bool(m and m.group(2) == dims)


def _update_operand_bytes(ins: Instr, table: Dict[str, str]) -> int:
    """Bytes of the update operand: dus(buf, upd, idx...) / scatter(buf,
    idx, upd)."""
    m = re.search(r"\(([^)]*)\)", ins.line[ins.line.index(ins.opcode):])
    if not m:
        return 0
    ops = _split_operands(m.group(1))
    pos = 2 if ins.opcode == "scatter" else 1
    if len(ops) > pos:
        return _operand_value_bytes(ops[pos], table)
    return 0


def _operand_bytes(ins: Instr, table: Dict[str, str]) -> int:
    m = re.search(r"\(([^)]*)\)", ins.line[ins.line.index(ins.opcode):])
    if not m:
        return 0
    # per operand: inline type (op(f32[8,128]{1,0} %a, …)) carries the
    # shape directly; bare names resolve through the table
    return sum(_operand_value_bytes(o, table)
               for o in _split_operands(m.group(1)))
