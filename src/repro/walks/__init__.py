"""Dynamic random walk program definitions (paper §2.1 + extensions).

Each program is ~10–25 lines of user code — exactly the extensibility the
paper advertises, now as the composable ``WalkProgram`` contract: supply
``init`` / ``init_walker_state`` / ``get_weight`` / ``on_step`` /
``should_stop`` and the framework does the rest (Flexi-Compiler derives
the bound/sum estimators with ``wstate`` as a runtime input, Flexi-Runtime
resolves ``EngineConfig.method`` through the sampler registry, threads the
per-walker state through the scheduler, and folds early termination into
the slot alive mask).  ``register_workload`` mirrors
``repro.core.samplers.register_sampler``: both axes of the program ×
strategy matrix are user-extensible by name.  See docs/walk_programs.md
for a write-your-own walkthrough.
"""
from repro.walks.workloads import (
    deepwalk,
    metapath,
    node2vec,
    ppr_nibble,
    second_order_pagerank,
    visited_avoiding,
    WORKLOADS,
    make_workload,
    register_workload,
)

__all__ = [
    "deepwalk",
    "metapath",
    "node2vec",
    "ppr_nibble",
    "second_order_pagerank",
    "visited_avoiding",
    "WORKLOADS",
    "make_workload",
    "register_workload",
]
