"""Dynamic random walk workload definitions (paper §2.1).

Each workload is ~10 lines of user code — exactly the programming model the
paper advertises: supply ``init`` / ``get_weight`` (/ ``update``) and the
framework does the rest (Flexi-Compiler derives the bound/sum estimators,
Flexi-Runtime resolves ``EngineConfig.method`` through the sampler registry
and picks kernels per node per step).  ``register_workload`` mirrors
``repro.core.samplers.register_sampler``: both axes of the workload ×
strategy matrix are user-extensible by name.
"""
from repro.walks.workloads import (
    deepwalk,
    metapath,
    node2vec,
    second_order_pagerank,
    WORKLOADS,
    make_workload,
    register_workload,
)

__all__ = [
    "deepwalk",
    "metapath",
    "node2vec",
    "second_order_pagerank",
    "WORKLOADS",
    "make_workload",
    "register_workload",
]
