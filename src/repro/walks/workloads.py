"""The evaluation walk programs: (un)weighted Node2Vec, (un)weighted
MetaPath, 2nd-order PageRank (paper §2.1, Eqs. 2–3), DeepWalk as the
static-walk reference — plus two programs the bare ``Workload`` protocol
could not express: a visited-set-avoiding second-order walk and an
ε-terminating PPR-Nibble walk.

Every factory returns a :class:`~repro.core.types.WalkProgram`:
``get_weight(ctx, params, wstate)`` receives ONE edge's context, the
hyperparameters and the walker's program state, and returns the transition
weight w̃(v, u) = w(v, u) · h(v, u).  It must be jax-traceable on scalars;
Flexi-Compiler abstract-interprets its jaxpr (``wstate`` leaves enter the
analysis as concrete per-walker runtime inputs).  The paper's five
workloads are stateless: their weight rules ignore ``wstate``, which keeps
their jaxprs — and therefore paths and telemetry — bit-identical to the
deprecated 2-argument ``Workload`` form (``repro.core.from_workload`` is
the adapter; tests/test_programs.py pins the equivalence).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from repro.core.types import EdgeCtx, WalkProgram


# --------------------------------------------------------------- Node2Vec
@dataclasses.dataclass(frozen=True)
class N2VParams:
    a: float = 2.0  # return parameter p (paper calls it a);   w = 1/a at dist 0
    b: float = 0.5  # in-out parameter q (paper calls it b);   w = 1/b at dist 2


def _n2v_rule(ctx: EdgeCtx, p) -> jnp.ndarray:
    """Eq. 2 weight factor: 1/a at dist 0, 1 at dist 1, 1/b at dist 2."""
    return jnp.where(
        ctx.dist == 0,
        1.0 / p.a,
        jnp.where(ctx.dist == 1, 1.0, 1.0 / p.b),
    )


def node2vec(a: float = 2.0, b: float = 0.5,
             weighted: bool = True) -> WalkProgram:
    """Eq. 2: w = 1/a if dist(v',u)=0; 1 if dist=1; 1/b if dist=2."""

    def init():
        return N2VParams(a=a, b=b)

    def get_weight(ctx: EdgeCtx, p: N2VParams, wstate):
        return _n2v_rule(ctx, p) * ctx.h

    return WalkProgram(
        name=f"node2vec[{'w' if weighted else 'u'}]",
        init=init,
        get_weight=get_weight,
        needs_dist=True,
        weighted=weighted,
        walk_len=80,
    )


# --------------------------------------------------------------- MetaPath
@dataclasses.dataclass(frozen=True)
class MetaPathParams:
    schema: Tuple[int, ...] = (0, 1, 2, 3, 4)


def metapath(schema: Tuple[int, ...] = (0, 1, 2, 3, 4),
             weighted: bool = True) -> WalkProgram:
    """Follow the label schema: w = 1 iff label(v,u) == schema[step]."""

    def init():
        return MetaPathParams(schema=tuple(schema))

    def get_weight(ctx: EdgeCtx, p: MetaPathParams, wstate):
        sched = jnp.asarray(p.schema, jnp.int32)
        want = sched[jnp.mod(ctx.step, len(p.schema))]
        w = jnp.where(ctx.label == want, 1.0, 0.0)
        return w * ctx.h

    return WalkProgram(
        name=f"metapath[{'w' if weighted else 'u'}]",
        init=init,
        get_weight=get_weight,
        needs_labels=True,
        num_labels=max(schema) + 1,
        weighted=weighted,
        walk_len=len(schema),
    )


# ------------------------------------------------- Second-Order PageRank
@dataclasses.dataclass(frozen=True)
class SOPRParams:
    gamma: float = 0.2


def second_order_pagerank(gamma: float = 0.2,
                          weighted: bool = True) -> WalkProgram:
    """Eq. 3: w = ((1-γ)/d(v) + γ/d(v')·[dist=1]) · max(d(v), d(v'))."""

    def init():
        return SOPRParams(gamma=gamma)

    def get_weight(ctx: EdgeCtx, p: SOPRParams, wstate):
        dv = jnp.maximum(ctx.deg_cur.astype(jnp.float32), 1.0)
        dp = jnp.maximum(ctx.deg_prev.astype(jnp.float32), 1.0)
        max_d = jnp.maximum(dv, dp)
        base = (1.0 - p.gamma) / dv
        bonus = jnp.where(ctx.dist == 1, p.gamma / dp, 0.0)
        return (base + bonus) * max_d * ctx.h

    return WalkProgram(
        name=f"2ndpr[{'w' if weighted else 'u'}]",
        init=init,
        get_weight=get_weight,
        needs_dist=True,
        weighted=weighted,
        walk_len=80,
    )


# --------------------------------------------------------------- DeepWalk
def deepwalk(weighted: bool = True) -> WalkProgram:
    """Static walk (w ≡ 1): the degenerate case every sampler must also get
    right; useful as the correctness anchor in property tests."""

    def init():
        return ()

    def get_weight(ctx: EdgeCtx, p, wstate):
        return ctx.h * 1.0

    return WalkProgram(
        name=f"deepwalk[{'w' if weighted else 'u'}]",
        init=init,
        get_weight=get_weight,
        weighted=weighted,
        walk_len=80,
    )


# ------------------------------------------- visited-avoiding SecondOrder
@dataclasses.dataclass(frozen=True)
class VisitedAvoidingParams:
    a: float = 2.0
    b: float = 0.5
    window: int = 16  # tabu capacity: nodes stepped on in the last `window`


def visited_avoiding(a: float = 2.0, b: float = 0.5, window: int = 16,
                     weighted: bool = True) -> WalkProgram:
    """Second-order (Node2Vec-weighted) walk that never re-visits a node it
    stepped on within the last ``window`` steps — inexpressible under the
    bare ``Workload`` protocol, which had no per-walker memory.

    ``wstate`` is a tabu ring of the last ``window`` visited node ids
    (int32, -1 = empty slot; with ``window ≥ num_steps`` it is the exact
    visited set).  ``get_weight`` zeroes edges into tabu nodes, so the
    Flexi-Compiler's bound stays the plain Node2Vec bound (the tabu factor
    only shrinks weights — the hull over {0, base} is sound), and
    ``on_step`` pushes the chosen node into slot ``step % window``.  When
    every neighbour is tabu the walk dead-ends (all weights zero), which
    the scheduler already handles.
    """

    def init():
        return VisitedAvoidingParams(a=a, b=b, window=window)

    def init_walker_state(query):
        return jnp.full((window,), -1, jnp.int32)

    def get_weight(ctx: EdgeCtx, p: VisitedAvoidingParams, visited):
        base = _n2v_rule(ctx, p) * ctx.h
        tabu = jnp.any(visited == ctx.nbr)
        return jnp.where(tabu, 0.0, base)

    def on_step(ctx: EdgeCtx, p: VisitedAvoidingParams, visited):
        return visited.at[jnp.mod(ctx.step, p.window)].set(ctx.nbr)

    return WalkProgram(
        name=f"visited[{'w' if weighted else 'u'}]",
        init=init,
        get_weight=get_weight,
        init_walker_state=init_walker_state,
        on_step=on_step,
        needs_dist=True,
        weighted=weighted,
        walk_len=80,
    )


# ------------------------------------------------- ε-terminating PPR-Nibble
@dataclasses.dataclass(frozen=True)
class PPRNibbleParams:
    alpha: float = 0.15  # teleport probability: residual decays by (1-α)
    eps: float = 2e-2  # push threshold: stop when mass < ε·d(v)


def ppr_nibble(alpha: float = 0.15, eps: float = 2e-2,
               weighted: bool = True) -> WalkProgram:
    """PPR-Nibble-style walk with data-dependent early termination —
    inexpressible under the bare ``Workload`` protocol, whose only
    termination was the fixed ``walk_len``.

    A walker carries residual mass (init 1.0) that decays by (1-α) per
    step; after stepping out of node v it stops as soon as
    ``mass < ε·d(v)`` — the ACL push threshold: high-degree regions drain
    a walker's usefulness faster.  Stop times therefore depend on the
    degrees along the *sampled path*, and termination folds into the slot
    ``alive`` mask so finished walkers free scheduler slots mid-run.

    The transition weights are plain edge weights (state-independent), so
    the Flexi-Compiler still proves the workload static and the precomp
    regime serves it from baked tables — static *sampling* composes with
    dynamic *termination*.
    """

    def init():
        return PPRNibbleParams(alpha=alpha, eps=eps)

    def init_walker_state(query):
        return jnp.float32(1.0)  # residual mass

    def get_weight(ctx: EdgeCtx, p: PPRNibbleParams, mass):
        return ctx.h * 1.0

    def on_step(ctx: EdgeCtx, p: PPRNibbleParams, mass):
        return mass * (1.0 - p.alpha)

    def should_stop(ctx: EdgeCtx, p: PPRNibbleParams, mass):
        return mass < p.eps * ctx.deg_cur.astype(jnp.float32)

    return WalkProgram(
        name=f"ppr_nibble[{'w' if weighted else 'u'}]",
        init=init,
        get_weight=get_weight,
        init_walker_state=init_walker_state,
        on_step=on_step,
        should_stop=should_stop,
        weighted=weighted,
        walk_len=80,
    )


def make_workload(name: str, **kw) -> WalkProgram:
    if name not in WORKLOADS:
        raise KeyError(f"unknown workload {name!r}; have {sorted(WORKLOADS)}")
    return WORKLOADS[name](**kw)


def register_workload(name: str, factory, *, overwrite: bool = False):
    """Register a walk-program factory by name (the counterpart of
    ``repro.core.samplers.register_sampler`` on the workload axis: a user
    strategy × user program pair runs with zero framework edits)."""
    if name in WORKLOADS and not overwrite:
        existing = WORKLOADS[name]
        existing_name = getattr(existing, "__name__",
                                type(existing).__name__)
        raise ValueError(
            f"workload {name!r} already registered by {existing_name} "
            f"(pass overwrite=True to replace); registered workloads: "
            f"{', '.join(sorted(WORKLOADS))}")
    WORKLOADS[name] = factory
    return factory


WORKLOADS = {
    "node2vec": node2vec,
    "node2vec_unweighted": lambda **kw: node2vec(weighted=False, **kw),
    "metapath": metapath,
    "metapath_unweighted": lambda **kw: metapath(weighted=False, **kw),
    "2ndpr": second_order_pagerank,
    "deepwalk": deepwalk,
    "visited_avoiding": visited_avoiding,
    "ppr_nibble": ppr_nibble,
}
