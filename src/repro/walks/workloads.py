"""The five evaluation workloads: (un)weighted Node2Vec, (un)weighted
MetaPath, and 2nd-order PageRank (paper §2.1, Eqs. 2–3), plus DeepWalk as
the static-walk reference.

``get_weight`` receives ONE edge's context and the hyperparameters, and
returns the transition weight w̃(v, u) = w(v, u) · h(v, u).  It must be
jax-traceable on scalars; Flexi-Compiler abstract-interprets its jaxpr.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from repro.core.types import EdgeCtx, Workload


# --------------------------------------------------------------- Node2Vec
@dataclasses.dataclass(frozen=True)
class N2VParams:
    a: float = 2.0  # return parameter p (paper calls it a);   w = 1/a at dist 0
    b: float = 0.5  # in-out parameter q (paper calls it b);   w = 1/b at dist 2


def node2vec(a: float = 2.0, b: float = 0.5, weighted: bool = True) -> Workload:
    """Eq. 2: w = 1/a if dist(v',u)=0; 1 if dist=1; 1/b if dist=2."""

    def init():
        return N2VParams(a=a, b=b)

    def get_weight(ctx: EdgeCtx, p: N2VParams):
        w = jnp.where(
            ctx.dist == 0,
            1.0 / p.a,
            jnp.where(ctx.dist == 1, 1.0, 1.0 / p.b),
        )
        return w * ctx.h

    return Workload(
        name=f"node2vec[{'w' if weighted else 'u'}]",
        init=init,
        get_weight=get_weight,
        needs_dist=True,
        weighted=weighted,
        walk_len=80,
    )


# --------------------------------------------------------------- MetaPath
@dataclasses.dataclass(frozen=True)
class MetaPathParams:
    schema: Tuple[int, ...] = (0, 1, 2, 3, 4)


def metapath(schema: Tuple[int, ...] = (0, 1, 2, 3, 4),
             weighted: bool = True) -> Workload:
    """Follow the label schema: w = 1 iff label(v,u) == schema[step]."""

    def init():
        return MetaPathParams(schema=tuple(schema))

    def get_weight(ctx: EdgeCtx, p: MetaPathParams):
        sched = jnp.asarray(p.schema, jnp.int32)
        want = sched[jnp.mod(ctx.step, len(p.schema))]
        w = jnp.where(ctx.label == want, 1.0, 0.0)
        return w * ctx.h

    return Workload(
        name=f"metapath[{'w' if weighted else 'u'}]",
        init=init,
        get_weight=get_weight,
        needs_labels=True,
        num_labels=max(schema) + 1,
        weighted=weighted,
        walk_len=len(schema),
    )


# ------------------------------------------------- Second-Order PageRank
@dataclasses.dataclass(frozen=True)
class SOPRParams:
    gamma: float = 0.2


def second_order_pagerank(gamma: float = 0.2, weighted: bool = True) -> Workload:
    """Eq. 3: w = ((1-γ)/d(v) + γ/d(v')·[dist=1]) · max(d(v), d(v'))."""

    def init():
        return SOPRParams(gamma=gamma)

    def get_weight(ctx: EdgeCtx, p: SOPRParams):
        dv = jnp.maximum(ctx.deg_cur.astype(jnp.float32), 1.0)
        dp = jnp.maximum(ctx.deg_prev.astype(jnp.float32), 1.0)
        max_d = jnp.maximum(dv, dp)
        base = (1.0 - p.gamma) / dv
        bonus = jnp.where(ctx.dist == 1, p.gamma / dp, 0.0)
        return (base + bonus) * max_d * ctx.h

    return Workload(
        name=f"2ndpr[{'w' if weighted else 'u'}]",
        init=init,
        get_weight=get_weight,
        needs_dist=True,
        weighted=weighted,
        walk_len=80,
    )


# --------------------------------------------------------------- DeepWalk
def deepwalk(weighted: bool = True) -> Workload:
    """Static walk (w ≡ 1): the degenerate case every sampler must also get
    right; useful as the correctness anchor in property tests."""

    def init():
        return ()

    def get_weight(ctx: EdgeCtx, p):
        return ctx.h * 1.0

    return Workload(
        name=f"deepwalk[{'w' if weighted else 'u'}]",
        init=init,
        get_weight=get_weight,
        weighted=weighted,
        walk_len=80,
    )


def make_workload(name: str, **kw) -> Workload:
    if name not in WORKLOADS:
        raise KeyError(f"unknown workload {name!r}; have {sorted(WORKLOADS)}")
    return WORKLOADS[name](**kw)


def register_workload(name: str, factory, *, overwrite: bool = False):
    """Register a workload factory by name (the counterpart of
    ``repro.core.samplers.register_sampler`` on the workload axis: a user
    strategy × user workload pair runs with zero framework edits)."""
    if name in WORKLOADS and not overwrite:
        raise ValueError(f"workload {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    WORKLOADS[name] = factory
    return factory


WORKLOADS = {
    "node2vec": node2vec,
    "node2vec_unweighted": lambda **kw: node2vec(weighted=False, **kw),
    "metapath": metapath,
    "metapath_unweighted": lambda **kw: metapath(weighted=False, **kw),
    "2ndpr": second_order_pagerank,
    "deepwalk": deepwalk,
}
