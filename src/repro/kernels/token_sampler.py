"""Token-sampling Pallas TPU kernel — eRVS's key mechanism reused in serving.

Categorical sampling from LM logits is weighted neighbour selection with
w̃_v = exp(logit_v / T): the Efraimidis–Spirakis key argmax_v u_v^{1/w̃_v}
is, in the log domain, argmax_v (logit_v/T + Gumbel_v) — the Gumbel-max
trick.  This kernel streams the vocab in (8, 512) VMEM tiles per batch row,
carrying a running (max-key, argmax) pair across tiles, so sampling needs
no softmax, no normalisation pass, and no [B, V] materialised noise — one
streaming pass, exactly like the walk kernel.  Greedy decoding is the
same kernel with the noise term off.

Used by repro.serving for the decode-step sampler (beyond-paper reuse of
the paper's kernel — DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.prng import uniform_01

NEG_INF = np.float32(-np.inf)
ROWS = 8  # batch rows per block
VTILE = 512  # vocab lanes per block


def _token_kernel(seed_ref, logits_ref, out_ref, best_ref, arg_ref, *,
                  temperature: float, greedy: bool, vocab: int):
    b = pl.program_id(0)
    v = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(v == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, NEG_INF)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    lg = logits_ref[...]  # [ROWS, VTILE]
    col = v * VTILE + jax.lax.broadcasted_iota(jnp.int32, (ROWS, VTILE), 1)
    valid = col < vocab
    if greedy:
        keys = jnp.where(valid, lg, NEG_INF)
    else:
        row = jax.lax.broadcasted_iota(jnp.uint32, (ROWS, VTILE), 0) \
            + jnp.uint32(b * ROWS)
        u = uniform_01(seed_ref[0] + row, seed_ref[1],
                       col.astype(jnp.uint32), jnp.uint32(0x700C0DE))
        g = -jnp.log(-jnp.log(u))
        keys = jnp.where(valid, lg * jnp.float32(1.0 / temperature) + g, NEG_INF)

    tile_arg = jnp.argmax(keys, axis=1).astype(jnp.int32)  # [ROWS]
    tile_best = jnp.max(keys, axis=1)  # [ROWS]
    upd = tile_best > best_ref[:, 0]
    best_ref[:, 0] = jnp.where(upd, tile_best, best_ref[:, 0])
    arg_ref[:, 0] = jnp.where(upd, v * VTILE + tile_arg, arg_ref[:, 0])

    @pl.when(v == nv - 1)
    def _write():
        out_ref[:, 0] = arg_ref[:, 0]


@partial(jax.jit, static_argnames=("temperature", "greedy", "interpret"))
def token_sample(logits: jax.Array, seed: jax.Array,
                 temperature: float = 1.0, greedy: bool = False,
                 interpret: bool = True) -> jax.Array:
    """Sample token ids [B] from logits [B, V] (categorical at temperature
    T via Gumbel-max keys; exact softmax sampling, no normalisation).
    seed: [2] uint32 — per-row streams are derived as (seed0 + row, seed1).
    """
    B, V = logits.shape
    Bp = ((B + ROWS - 1) // ROWS) * ROWS
    Vp = ((V + VTILE - 1) // VTILE) * VTILE
    if (Bp, Vp) != (B, V):
        logits = jnp.pad(logits, ((0, Bp - B), (0, Vp - V)),
                         constant_values=-jnp.inf)

    import functools
    kern = functools.partial(_token_kernel, temperature=float(temperature),
                             greedy=bool(greedy), vocab=V)
    out = pl.pallas_call(
        kern,
        grid=(Bp // ROWS, Vp // VTILE),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # seed
            pl.BlockSpec((ROWS, VTILE), lambda b, v: (b, v)),
        ],
        out_specs=pl.BlockSpec((ROWS, 1), lambda b, v: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((ROWS, 1), jnp.float32),
            pltpu.VMEM((ROWS, 1), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(seed, jnp.uint32), logits)
    return out[:B, 0]
