"""Pure-jnp oracles for every Pallas kernel in this package.

Two kinds of reference per kernel:

* ``*_ref``      — consumes the *same* Threefry counters and performs the
  same float-op composition as the kernel, so outputs match exactly
  (assert_allclose / array_equal in tests).
* ``*_semantic`` — the textbook algorithm with jax.random; used for
  statistical (distribution-level) validation of both.

Layout: the walk kernels use the **tile-aligned CSR** layout produced by
``ops.align_rows`` — each node's weight row starts at a 128-lane boundary in
a [R, 128] stream (a TPU-native adaptation: every DMA is lane-aligned; see
DESIGN.md §3.1).  Row r of walker i lives at rows [row0_i, row0_i + ⌈deg/128⌉).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.prng import uniform_01, uniform_pair_01

NEG_INF = np.float32(-np.inf)
LANES = 128
SUBLANES = 8
TILE = LANES * SUBLANES  # 1024 weights per DMA block


# ----------------------------------------------------------------- eRVS
def ervs_select_ref(w2d: jax.Array, row0: jax.Array, degs: jax.Array,
                    seeds: jax.Array):
    """Block-jump A-ExpJ reservoir selection — the exact kernel oracle.

    w2d:  [R, 128] f32 tile-aligned weight stream (ops.align_rows).
    row0: [W] int32 — first 128-row of each walker's weight row.
    degs: [W] int32; seeds: [W, 2] uint32.
    Returns (offset [W] int32 — selected offset within the row or -1,
             draws [W] int32  — threefry calls consumed,
             jumped [W] int32 — blocks skipped without key generation).
    """
    R = w2d.shape[0]

    def one(r0, deg, k0, k1):
        n_tiles = (deg + TILE - 1) // TILE

        def tile_body(t, st):
            best_lk, best_off, t_rem, draws, jumped = st
            rows = r0 + t * SUBLANES + jnp.arange(SUBLANES, dtype=jnp.int32)
            blk = w2d[jnp.clip(rows, 0, R - 1)]  # [8, 128]
            off = t * TILE + jnp.arange(TILE, dtype=jnp.int32)
            w = jnp.where(off < deg, blk.reshape(TILE), 0.0)
            blocksum = jnp.sum(w)
            crossing = (blocksum >= t_rem) & (blocksum > 0)

            def process(st):
                best_lk, best_off, t_rem, draws, base = st
                cum = jnp.cumsum(w)

                def cross_cond(s):
                    _, _, t_rem, _, base = s
                    return blocksum - base >= t_rem

                def cross_body(s):
                    best_lk, best_off, t_rem, draws, base = s
                    target = base + t_rem
                    hit = (cum >= target) & (w > 0)
                    pos = jnp.argmax(hit).astype(jnp.int32)
                    w_m = w[pos]
                    u1, u2 = uniform_pair_01(k0, k1, jnp.uint32(draws),
                                             jnp.uint32(0x9E3779B9))
                    t_w = jnp.exp(jnp.clip(w_m * best_lk, -80.0, 0.0))
                    is_first = best_lk == NEG_INF
                    uu = jnp.where(is_first, u1, t_w + u1 * (1.0 - t_w))
                    lk_new = jnp.log(jnp.clip(uu, 1e-38, 1.0)) / jnp.maximum(w_m, 1e-30)
                    new_thresh = jnp.log(u2) / jnp.minimum(lk_new, -1e-30)
                    return (lk_new, t * TILE + pos, new_thresh, draws + 1, cum[pos])

                st2 = jax.lax.while_loop(
                    cross_cond, cross_body,
                    (best_lk, best_off, t_rem, draws, jnp.float32(0.0)))
                best_lk, best_off, t_rem, draws, base = st2
                return (best_lk, best_off, t_rem - (blocksum - base), draws)

            def skip(st):
                best_lk, best_off, t_rem, draws, _ = st
                return (best_lk, best_off, t_rem - blocksum, draws)

            best_lk, best_off, t_rem, draws = jax.lax.cond(
                crossing, process, skip,
                (best_lk, best_off, t_rem, draws, jnp.float32(0.0)))
            jumped = jumped + jnp.where(crossing, 0, 1)
            return (best_lk, best_off, t_rem, draws, jumped)

        init = (NEG_INF, jnp.int32(-1), jnp.float32(0.0), jnp.int32(0),
                jnp.int32(0))
        best_lk, best_off, _, draws, jumped = jax.lax.fori_loop(
            0, n_tiles, tile_body, init)
        return best_off, draws, jumped

    return jax.vmap(one)(row0, degs, seeds[:, 0], seeds[:, 1])


def ervs_select_semantic(w2d, row0, degs, key, max_deg: int):
    """Textbook Efraimidis–Spirakis (per-item keys, argmax) with jax.random.

    Statistically identical to ervs_select_ref; used as the distribution
    oracle in chi-square tests.
    """
    R = w2d.shape[0]
    flat = w2d.reshape(-1)

    def one(r0, deg, k):
        idx = jnp.arange(max_deg, dtype=jnp.int32)
        valid = idx < deg
        w = jnp.where(valid, flat[jnp.clip(r0 * LANES + idx, 0, R * LANES - 1)], 0.0)
        u = jax.random.uniform(k, (max_deg,), minval=1e-12)
        lk = jnp.where(w > 0, jnp.log(u) / jnp.where(w > 0, w, 1.0), NEG_INF)
        best = jnp.argmax(lk)
        return jnp.where(jnp.max(lk) > NEG_INF, best, -1).astype(jnp.int32)

    keys = jax.random.split(key, row0.shape[0])
    return jax.vmap(one)(row0, degs, keys)


# ----------------------------------------------------------------- eRJS
def erjs_select_ref(w2d, row0, degs, bounds, seeds,
                    trials: int = 8, max_rounds: int = 16):
    """Bound-based rejection — exact oracle (same counters as the kernel).

    Returns (offset [W] int32 — or -1 (fallback/empty), trials_used [W]).
    """
    R = w2d.shape[0]

    def one(r0, deg, bound, k0, k1):
        feasible = (deg > 0) & (bound > 0)
        limit = jnp.int32(trials * max_rounds)

        def cond(st):
            t, off = st
            return (off < 0) & (t < limit) & feasible

        def body(st):
            t, off = st
            u_idx, u_acc = uniform_pair_01(k0, k1, jnp.uint32(t),
                                           jnp.uint32(0x00C0FFEE))
            cand = jnp.minimum((u_idx * deg.astype(jnp.float32)).astype(jnp.int32),
                               deg - 1)
            r = r0 + cand // LANES
            c = cand % LANES
            w = w2d[jnp.clip(r, 0, R - 1), c]
            ok = (u_acc * bound <= w) & (w > 0)
            return (t + 1, jnp.where(ok, cand, off))

        t, off = jax.lax.while_loop(cond, body, (jnp.int32(0), jnp.int32(-1)))
        return off, t

    return jax.vmap(one)(row0, degs, bounds, seeds[:, 0], seeds[:, 1])


# ---------------------------------------------------- precomputed regime
def its_search_ref(cdf2d, row0, degs, totals, seeds):
    """CDF binary search — exact oracle of precomp_kernel.its_search.

    Same Threefry counters/salt and the same comparisons, so offsets match
    the kernel bit-for-bit; only the probe transport differs (direct
    indexing here vs per-probe DMA in the kernel).
    """
    R = cdf2d.shape[0]
    flat = cdf2d.reshape(-1)

    def one(r0, deg, total, k0, k1):
        u = uniform_01(k0, k1, jnp.uint32(0), jnp.uint32(0x175CDF))
        target = u * total

        def body(_, c):
            lo, hi = c
            mid = (lo + hi) // 2
            val = flat[jnp.clip(r0 * LANES + mid, 0, R * LANES - 1)]
            go_right = (val <= target) & (lo < hi)
            return (jnp.where(go_right, mid + 1, lo),
                    jnp.where(go_right | (lo >= hi), hi, mid))

        lo, _ = jax.lax.fori_loop(0, 32, body, (jnp.int32(0), deg))
        sel = jnp.clip(lo, 0, jnp.maximum(deg - 1, 0))
        return jnp.where((deg > 0) & (total > 0), sel, -1)

    return jax.vmap(one)(row0, degs, totals, seeds[:, 0], seeds[:, 1])


def alias_pick_ref(prob2d, alias2d, row0, degs, totals, seeds):
    """Alias accept-or-alias draw — exact oracle of
    precomp_kernel.alias_pick (same counters, same float comparisons)."""
    R = prob2d.shape[0]
    flat_p = prob2d.reshape(-1)
    flat_a = alias2d.reshape(-1)

    def one(r0, deg, total, k0, k1):
        u1, u2 = uniform_pair_01(k0, k1, jnp.uint32(0), jnp.uint32(0xA11A5))
        col = jnp.minimum((u1 * deg.astype(jnp.float32)).astype(jnp.int32),
                          jnp.maximum(deg - 1, 0))
        pos = jnp.clip(r0 * LANES + col, 0, R * LANES - 1)
        sel = jnp.where(u2 < flat_p[pos], col, flat_a[pos].astype(jnp.int32))
        return jnp.where((deg > 0) & (total > 0), sel, -1)

    return jax.vmap(one)(row0, degs, totals, seeds[:, 0], seeds[:, 1])


# --------------------------------------------------------- token sampler
def token_sample_ref(logits: jax.Array, seed: jax.Array,
                     temperature: float = 1.0, greedy: bool = False):
    """Gumbel-max categorical sampling over the vocab — exact oracle.

    The Gumbel-max trick IS eRVS's exponential-key mechanism applied to the
    softmax distribution: argmax(logit/T + g_v), g_v = -ln(-ln u_v), with
    u_v ~ Threefry(key = (seed0 + row, seed1); counter = v).  Matches the
    kernel bit-for-bit.  seed: [2] uint32.  Returns token ids [B] int32.
    """
    B, V = logits.shape
    ctr = jnp.arange(V, dtype=jnp.uint32)

    def row(lg, r):
        if greedy:
            keys = lg
        else:
            u = uniform_01(seed[0] + r, seed[1], ctr, jnp.uint32(0x700C0DE))
            g = -jnp.log(-jnp.log(u))
            keys = lg * jnp.float32(1.0 / temperature) + g
        return jnp.argmax(keys).astype(jnp.int32)

    return jax.vmap(row)(logits, jnp.arange(B, dtype=jnp.uint32))
