"""Precomputed-regime Pallas TPU kernels — CDF binary search + alias pick.

TPU-native form of the ``core/precomp.py`` selectors (DESIGN.md §3.1 layout:
tables live in the tile-aligned [R, 128] stream of ``ops.align_rows``, every
node's row starting on a 128-lane boundary):

* :func:`its_search` — one walker per grid step performs an O(log d)
  binary search of its row's baked inclusive-prefix CDF.  Each probe DMAs
  only the (8, 128) tile holding the probed element HBM→VMEM — ~log₂(d)
  small copies instead of streaming the whole row, which is the entire
  point of the precomputed regime (C-SAW).  Probes of a converged search
  are never issued (while_loop, not a fixed-depth fori).
* :func:`alias_pick` — O(1): two uniforms, one DMA into the prob stream and
  one into the alias stream, then accept-or-alias.

RNG is the same counter-based Threefry-2x32 the other kernels use
(kernels/prng.py), with per-kernel salts so table draws never collide with
the eRVS/eRJS streams.  Both kernels are validated bit-exactly against the
``ref.its_search_ref`` / ``ref.alias_pick_ref`` oracles in interpret mode
(tests/test_kernels.py).

These kernels are the default execution path of the engine's
``its_precomp``/``alias_precomp`` samplers on TPU
(``EngineConfig.precomp_exec``; see ``samplers.precomp_table_select``) —
the jnp selectors in ``core/precomp.py`` consume the same Threefry
(key, counter, salt) triples, so the two paths are bit-identical and the
knob only ever changes throughput.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.prng import uniform_01, uniform_pair_01
from repro.kernels.ref import LANES, SUBLANES, TILE

# fold-in salts (shared with the ref oracles; distinct from eRVS/eRJS)
ITS_SALT = 0x175CDF
ALIAS_SALT = 0xA11A5


def default_interpret() -> bool:
    """Whether ``pallas_call`` should run in interpret mode on the current
    backend: compiled on TPU, interpreted (the semantic reference, bit-
    identical) everywhere else."""
    return jax.default_backend() != "tpu"


def _its_kernel(row0_ref, degs_ref, totals_ref, seeds_ref,  # SMEM scalars
                cdf_hbm,  # ANY (HBM) [R, 128] tile-aligned CDF stream
                off_ref,  # output (1,) block
                buf, sem):  # scratch: VMEM (8, 128), DMA sem
    i = pl.program_id(0)
    r0 = row0_ref[i]
    deg = degs_ref[i]
    total = totals_ref[i]
    k0 = seeds_ref[i, 0]
    k1 = seeds_ref[i, 1]
    u = uniform_01(k0, k1, jnp.uint32(0), jnp.uint32(ITS_SALT))
    target = u * total

    def probe(pos):
        # DMA the (8, 128) tile holding cdf[row0·128 + pos]; align_rows
        # pads the stream with ≥ 2 slack tiles, so the copy never runs
        # off the end even for the last row.
        t = pos // TILE
        cp = pltpu.make_async_copy(
            cdf_hbm.at[pl.ds(r0 + t * SUBLANES, SUBLANES), :], buf, sem)
        cp.start()
        cp.wait()
        return buf[...].reshape(TILE)[pos - t * TILE]

    # first offset in [0, deg) whose inclusive prefix exceeds the target
    def cond(c):
        lo, hi = c
        return lo < hi

    def body(c):
        lo, hi = c
        mid = (lo + hi) // 2
        go_right = probe(mid) <= target
        return (jnp.where(go_right, mid + 1, lo),
                jnp.where(go_right, hi, mid))

    lo, _ = jax.lax.while_loop(cond, body, (jnp.int32(0), deg))
    sel = jnp.clip(lo, 0, jnp.maximum(deg - 1, 0))
    off_ref[0] = jnp.where((deg > 0) & (total > 0), sel, -1)


@partial(jax.jit, static_argnames=("interpret",))
def its_search(cdf2d: jax.Array, row0: jax.Array, degs: jax.Array,
               totals: jax.Array, seeds: jax.Array, interpret: bool = True):
    """Inverse-transform draw via DMA-probed binary search.

    cdf2d [R,128] f32 (aligned row-local inclusive prefixes), row0/degs [W]
    int32, totals [W] f32, seeds [W,2] uint32.
    Returns offset [W] int32 within each row (-1 for empty/zero rows).
    """
    W = row0.shape[0]
    return pl.pallas_call(
        _its_kernel,
        grid=(W,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # row0
            pl.BlockSpec(memory_space=pltpu.SMEM),  # degs
            pl.BlockSpec(memory_space=pltpu.SMEM),  # totals
            pl.BlockSpec(memory_space=pltpu.SMEM),  # seeds
            pl.BlockSpec(memory_space=pl.ANY),  # CDF stays in HBM
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((W,), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((SUBLANES, LANES), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(row0, degs, totals, seeds, cdf2d)


def _alias_kernel(row0_ref, degs_ref, totals_ref, seeds_ref,  # SMEM
                  prob_hbm, alias_hbm,  # ANY (HBM) [R, 128] streams
                  off_ref,  # output (1,) block
                  buf_p, buf_a, sem_p, sem_a):  # scratch
    i = pl.program_id(0)
    r0 = row0_ref[i]
    deg = degs_ref[i]
    total = totals_ref[i]
    k0 = seeds_ref[i, 0]
    k1 = seeds_ref[i, 1]
    u1, u2 = uniform_pair_01(k0, k1, jnp.uint32(0), jnp.uint32(ALIAS_SALT))
    col = jnp.minimum((u1 * deg.astype(jnp.float32)).astype(jnp.int32),
                      jnp.maximum(deg - 1, 0))
    t = col // TILE
    cp_p = pltpu.make_async_copy(
        prob_hbm.at[pl.ds(r0 + t * SUBLANES, SUBLANES), :], buf_p, sem_p)
    cp_a = pltpu.make_async_copy(
        alias_hbm.at[pl.ds(r0 + t * SUBLANES, SUBLANES), :], buf_a, sem_a)
    cp_p.start()
    cp_a.start()
    cp_p.wait()
    cp_a.wait()
    within = col - t * TILE
    p_col = buf_p[...].reshape(TILE)[within]
    a_col = buf_a[...].reshape(TILE)[within].astype(jnp.int32)
    sel = jnp.where(u2 < p_col, col, a_col)
    off_ref[0] = jnp.where((deg > 0) & (total > 0), sel, -1)


@partial(jax.jit, static_argnames=("interpret",))
def alias_pick(prob2d: jax.Array, alias2d: jax.Array, row0: jax.Array,
               degs: jax.Array, totals: jax.Array, seeds: jax.Array,
               interpret: bool = True):
    """O(1) alias draw: column = ⌊u₁·d⌋, keep iff u₂ < prob else alias.

    prob2d/alias2d [R,128] f32 aligned Vose tables (alias offsets stored
    as float32 — exact for rows up to 2²⁴ neighbours, asserted by the
    table builder), row0/degs [W] int32, totals [W] f32, seeds [W,2].
    Returns offset [W] int32 within each row (-1 for empty/zero rows).
    """
    W = row0.shape[0]
    return pl.pallas_call(
        _alias_kernel,
        grid=(W,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # row0
            pl.BlockSpec(memory_space=pltpu.SMEM),  # degs
            pl.BlockSpec(memory_space=pltpu.SMEM),  # totals
            pl.BlockSpec(memory_space=pltpu.SMEM),  # seeds
            pl.BlockSpec(memory_space=pl.ANY),  # prob stream in HBM
            pl.BlockSpec(memory_space=pl.ANY),  # alias stream in HBM
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((W,), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((SUBLANES, LANES), jnp.float32),
            pltpu.VMEM((SUBLANES, LANES), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(row0, degs, totals, seeds, prob2d, alias2d)
