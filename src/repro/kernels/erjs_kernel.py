"""eRJS Pallas TPU kernel — bound-based rejection sampling.

The point of eRJS (§3.3) is to touch only O(expected-trials) single weights
instead of streaming the whole row.  On TPU that access pattern is a
sequence of tiny latency-bound DMAs — which is exactly the cost the
Eq. 10/11 cost model charges it for (EdgeCost_RJS ≫ EdgeCost_RVS).  The
kernel:

* per walker (sequential grid), loops rejection rounds in a while_loop;
* each trial draws (index, accept) uniforms from Threefry counters and
  DMAs ONE 128-lane row slice of the tile-aligned weight stream, reading
  the candidate's lane — a single-beat HBM transaction, the TPU analogue
  of the paper's per-thread random access;
* stops at acceptance or after trials×max_rounds (the engine falls back
  to eRVS — §7.1 safe mode / straggler bound).

Bit-exact against ref.erjs_select_ref.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.prng import uniform_pair_01
from repro.kernels.ref import LANES


def _erjs_kernel(row0_ref, degs_ref, bounds_ref, seeds_ref, limit_ref,
                 w_hbm,
                 off_ref, trials_ref,
                 buf, sem):
    i = pl.program_id(0)
    r0 = row0_ref[i]
    deg = degs_ref[i]
    bound = bounds_ref[i]
    k0 = seeds_ref[i, 0]
    k1 = seeds_ref[i, 1]
    limit = limit_ref[0]
    feasible = (deg > 0) & (bound > 0)

    def cond(st):
        t, off = st
        return (off < 0) & (t < limit) & feasible

    def body(st):
        t, off = st
        u_idx, u_acc = uniform_pair_01(k0, k1, jnp.uint32(t),
                                       jnp.uint32(0x00C0FFEE))
        cand = jnp.minimum((u_idx * deg.astype(jnp.float32)).astype(jnp.int32),
                           deg - 1)
        r = r0 + cand // LANES
        c = cand % LANES
        # one 128-lane beat: the smallest aligned HBM→VMEM transaction
        cp = pltpu.make_async_copy(w_hbm.at[pl.ds(r, 1), :], buf, sem)
        cp.start()
        cp.wait()
        w = jnp.sum(jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1) == c,
            buf[...], 0.0))
        ok = (u_acc * bound <= w) & (w > 0)
        return (t + 1, jnp.where(ok, cand, off))

    t, off = jax.lax.while_loop(cond, body, (jnp.int32(0), jnp.int32(-1)))
    off_ref[0] = off
    trials_ref[0] = t


@partial(jax.jit, static_argnames=("interpret",))
def erjs_select(w2d: jax.Array, row0: jax.Array, degs: jax.Array,
                bounds: jax.Array, seeds: jax.Array, limit: jax.Array,
                interpret: bool = True):
    """Rejection-sample one offset per walker.  limit = trials×max_rounds.

    Returns (offset [W] i32 — -1 means fallback-to-eRVS, trials [W] i32).
    """
    W = row0.shape[0]
    out = pl.pallas_call(
        _erjs_kernel,
        grid=(W,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((W,), jnp.int32),
            jax.ShapeDtypeStruct((W,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, LANES), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(row0, degs, bounds, seeds, limit, w2d)
    return out
