"""Fused mega-step Pallas kernel — one kernel per scheduler epoch.

One grid step per walker *lane* runs the ENTIRE per-step chain for
``epoch_len`` consecutive walk steps without returning to XLA between
stages (ThunderRW's gather-move-update interleaving; C-SAW's
warp-per-walker structure, with warps → grid lanes):

  neighbour-tile DMA from the tile-aligned CSR stream
    → WalkProgram weight evaluation (programs the Flexi-Compiler proves
      fusable: ``fc.fuse_report``)
    → per-lane regime pick (reservoir / rejection / precomp table draw)
    → ``on_step`` wstate commit + ``should_stop`` alive fold
    → StepStats flag accumulation.

Bit-identity contract (tests/test_megastep.py, tests/test_conformance.py)
-------------------------------------------------------------------------
The kernel consumes the SAME counter-based Threefry triples as the staged
scan (``kernels/prng.py``; per-step key = ``threefry2x32(rng, 0, step)``
= ``WalkerState.stream_keys()``), replicates the staged float maps
exactly (``jax.random.uniform(minval=1e-12)`` bit pattern for the
eRVS/eRJS draws, the top-24-bit map of ``prng.uniform_01`` for table
draws with the shared ITS/ALIAS salts), and applies the same masks in
the same order — so for every fusable (sampler × program) cell
``step_exec=fused`` produces byte-identical paths AND telemetry to
``step_exec=staged``.  That makes the staged scan a true fallback, not a
different estimator.

Per-step telemetry is accumulated as a per-(lane, step) int32 flag word
(bit positions = ``StepStats.LIVE`` …) and reduced to ``StepStats``
outside the kernel — integer sums, so the reduction is order-free exact.

Layout: edge streams are ``ops.align_rows`` [R, 128] tiles (every row
starts on a lane boundary; ≥2 slack sublane-rows so a trailing DMA never
reads out of bounds); per-node scalars ride ``pack_node_stream`` [V→pad,
128] streams so in-kernel degree/row0/bound/total lookups are one (8,
128) DMA each.  ``default_interpret()`` gates compiled vs interpret mode
exactly like the precomp kernels.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.types import EdgeCtx, StepStats, WalkerState
from repro.graphs.delta import host_row_layout
from repro.kernels.ops import align_rows_layout
from repro.kernels.precomp_kernel import (ALIAS_SALT, ITS_SALT,
                                          default_interpret)
from repro.kernels.prng import threefry2x32, uniform_01, uniform_pair_01
from repro.kernels.ref import LANES, SUBLANES, TILE

#: regime kinds a sampler may declare fusable (``Sampler.fused_kind``)
FUSED_KINDS = ("reservoir", "rejection", "precomp_its", "precomp_alias")

# np scalar (not a jnp array: Pallas kernels may not capture device-array
# constants) — same float32 -inf bits as ervs.NEG_INF
_NEG_INF = np.float32(-np.inf)


def _log_keys(u, w):
    """Bit-exact replica of ``ervs._log_keys`` (ln(u)/w̃, -inf for w̃≤0)."""
    safe_w = jnp.where(w > 0, w, 1.0)
    lk = jnp.log(u) / safe_w
    return jnp.where(w > 0, lk, _NEG_INF)

# extra edge streams each kind consumes beyond (deg, row0, nbr, h)
_EXTRA_STREAMS = {"reservoir": 0, "rejection": 1,
                  "precomp_its": 3, "precomp_alias": 4}


def pack_node_stream(x) -> jnp.ndarray:
    """Pack a per-node [V] vector into a DMA-able [pad/128, 128] stream.

    Padded to a whole number of (8, 128) tiles, so the element read at
    any v < V touches rows that exist — no slack needed (works for both
    host-side numpy constants and traced per-epoch jnp arrays)."""
    x = jnp.asarray(x)
    V = max(int(x.shape[0]), 1)
    pad = -(-V // TILE) * TILE
    flat = jnp.zeros((pad,), x.dtype).at[:x.shape[0]].set(x)
    return flat.reshape(pad // LANES, LANES)


def _iota(n: int, dtype=jnp.int32):
    # ≥2D iota only (TPU restriction); squeeze back to the vector
    return jax.lax.broadcasted_iota(dtype, (n, 1), 0)[:, 0]


# --------------------------------------------------------------- DMA reads
def _dma_block(hbm, buf, sem, row):
    """Copy the (8, 128) tile starting at sublane-row ``row`` into VMEM
    and return it flattened to [TILE]."""
    cp = pltpu.make_async_copy(hbm.at[pl.ds(row, SUBLANES), :], buf, sem)
    cp.start()
    cp.wait()
    return buf[...].reshape(TILE)


def _read_elem(hbm, buf, sem, r0, pos):
    """Element ``pos`` of the row starting at sublane-row ``r0``."""
    blk = pos // TILE
    return _dma_block(hbm, buf, sem, r0 + blk * SUBLANES)[pos - blk * TILE]


def _read_span(hbm, buf, sem, r0, start, n: int):
    """``n`` consecutive elements from offset ``start`` (static ``n``
    dividing TILE, ``start`` a multiple of ``n`` — the span never crosses
    a TILE boundary)."""
    blk = start // TILE
    flat = _dma_block(hbm, buf, sem, r0 + blk * SUBLANES)
    return jax.lax.dynamic_slice(flat, (start - blk * TILE,), (n,))


# ----------------------------------------------------- staged-RNG replicas
def _tile_uniforms_lane(sk0, sk1, t, tile: int):
    """Bit-exact per-lane replica of ``ervs._tile_uniforms(rng, t)[lane]``:
    fold the tile counter into the per-step key, then the jax threefry
    even-size counter split + (1e-12, 1.0) float map."""
    fk0, fk1 = threefry2x32(sk0, sk1, jnp.uint32(0), t)
    half = tile // 2
    c0 = _iota(half, jnp.uint32)
    r0, r1 = threefry2x32(fk0, fk1, c0, c0 + jnp.uint32(half))
    bits = jnp.concatenate([r0, r1])
    return _uniform_map(bits)


def _uniform_scalar_lane(sk0, sk1, c):
    """Bit-exact per-lane replica of ``erjs._fold_uniform(rng, c)[lane]``
    (jax's shape-() draw odd-pads the counter to (0, 0) and keeps r0)."""
    gk0, gk1 = threefry2x32(sk0, sk1, jnp.uint32(0), c)
    bits, _ = threefry2x32(gk0, gk1, jnp.uint32(0), jnp.uint32(0))
    return _uniform_map(bits)


def _uniform_map(bits):
    """jax.random.uniform's bits→float map with (minval, maxval) =
    (1e-12, 1.0), replicated operation by operation."""
    f = jax.lax.bitcast_convert_type(
        (bits >> jnp.uint32(9)) | jnp.uint32(0x3F800000), jnp.float32) - 1.0
    eps = jnp.float32(1e-12)
    return jnp.maximum(eps, f * (jnp.float32(1.0) - eps) + eps)


# ------------------------------------------------------------------ kernel
def _make_kernel(program, params, *, kind: str, tile: int, max_tiles: int,
                 rjs_trials: int, rjs_max_rounds: int, epoch_len: int,
                 num_steps: int, n_streams: int, n_ws: int, ws_treedef):
    """Build the mega-step kernel body (refs sliced positionally)."""
    K, R = rjs_trials, rjs_max_rounds
    LIVE, RJS = StepStats.LIVE, StepStats.RJS
    FALLBACK, PRECOMP, STALE = (StepStats.FALLBACK, StepStats.PRECOMP,
                                StepStats.STALE)

    def kernel(*refs):
        cur_s, prev_s, step_s, alive_s, seed_s = refs[:5]
        streams = refs[5:5 + n_streams]
        ws_refs = refs[5 + n_streams:5 + n_streams + n_ws]
        k = 5 + n_streams + n_ws
        em_ref, fl_ref, ocur, oprev, ostep, oalive = refs[k:k + 6]
        ws_out = refs[k + 6:k + 6 + n_ws]
        ibuf, fbuf, isem, fsem = refs[k + 6 + n_ws:]
        deg_nd, row0_nd, nbr_hbm, h_hbm = streams[:4]

        i = pl.program_id(0)
        s0 = seed_s[i, 0]
        s1 = seed_s[i, 1]

        def node_read_i32(nd, v):
            return _read_elem(nd, ibuf, isem, jnp.int32(0), v)

        def node_read_f32(nd, v):
            return _read_elem(nd, fbuf, fsem, jnp.int32(0), v)

        def deg_of(v):
            # degrees_of() semantics: 0 for the -1 sentinel
            d = node_read_i32(deg_nd, jnp.maximum(v, 0))
            return jnp.where(v >= 0, d, 0).astype(jnp.int32)

        # ---------------------------------------------- per-lane regimes
        def reservoir_lane(cur, deg, sk0, sk1, prev, stepc, ws_tree, act):
            """ervs_step for one lane; per-lane trip count ≡ the staged
            cross-lane max (masked tiles are all-NEG_INF no-ops under the
            strict > update)."""
            r0row = node_read_i32(row0_nd, jnp.maximum(cur, 0))
            dprev = deg_of(prev)
            ntiles = jnp.where(
                act, jnp.minimum((deg + tile - 1) // tile, max_tiles), 0)

            def body(t, carry):
                best_lk, best_nbr = carry
                tstart = t * tile
                nbr_raw = _read_span(nbr_hbm, ibuf, isem, r0row, tstart, tile)
                h_raw = _read_span(h_hbm, fbuf, fsem, r0row, tstart, tile)
                offs = tstart + _iota(tile)
                mask = offs < deg
                nbr = jnp.where(mask, nbr_raw, -1)
                h = jnp.where(mask, h_raw, jnp.float32(0.0))
                ctx = EdgeCtx(
                    h=h, label=jnp.zeros_like(nbr), dist=jnp.ones_like(nbr),
                    nbr=nbr,
                    deg_cur=jnp.broadcast_to(deg, (tile,)),
                    deg_prev=jnp.broadcast_to(dprev, (tile,)),
                    cur=jnp.broadcast_to(cur, (tile,)),
                    prev=jnp.broadcast_to(prev, (tile,)),
                    step=jnp.broadcast_to(stepc, (tile,)))
                w_raw = jax.vmap(program.edge_weight,
                                 in_axes=(0, None, None))(ctx, params, ws_tree)
                w = jnp.where(mask, jnp.maximum(w_raw, 0.0), 0.0)
                u = _tile_uniforms_lane(sk0, sk1, t, tile)
                lk = jnp.where(mask, _log_keys(u, w), _NEG_INF)
                b = jnp.argmax(lk)
                upd = lk[b] > best_lk
                return (jnp.where(upd, lk[b], best_lk),
                        jnp.where(upd, nbr[b], best_nbr))

            _, best_nbr = jax.lax.fori_loop(
                0, ntiles, body, (_NEG_INF, jnp.int32(-1)))
            return best_nbr

        def rejection_lane(cur, deg, sk0, sk1, prev, stepc, ws_tree, act):
            """erjs_step + reservoir fallback for one lane (the staged
            round×trial grid flattened: trial t ↔ (r, k) = divmod(t, K),
            counters 2t/2t+1 ≡ r·2K+2k / +1)."""
            bound = node_read_f32(streams[4], jnp.maximum(cur, 0))
            r0row = node_read_i32(row0_nd, jnp.maximum(cur, 0))
            dprev = deg_of(prev)
            feasible = act & (deg > 0) & (bound > 0)

            def cond(c):
                t, done, _ = c
                return (t < K * R) & ~done

            def body(c):
                t, done, chosen = c
                u_idx = _uniform_scalar_lane(sk0, sk1, 2 * t)
                u_acc = _uniform_scalar_lane(sk0, sk1, 2 * t + 1)
                offset = jnp.minimum(
                    (u_idx * deg.astype(jnp.float32)).astype(jnp.int32),
                    jnp.maximum(deg - 1, 0))
                valid = offset < deg
                nbr_c = jnp.where(
                    valid, _read_elem(nbr_hbm, ibuf, isem, r0row, offset), -1)
                h_c = jnp.where(
                    valid, _read_elem(h_hbm, fbuf, fsem, r0row, offset),
                    jnp.float32(0.0))
                ctx = EdgeCtx(
                    h=h_c, label=jnp.zeros_like(nbr_c),
                    dist=jnp.ones_like(nbr_c), nbr=nbr_c, deg_cur=deg,
                    deg_prev=dprev, cur=cur, prev=prev, step=stepc)
                flat = program.edge_weight(ctx, params, ws_tree)
                w = jnp.where(valid, jnp.maximum(flat, 0.0), 0.0)
                accept = feasible & ~done & (u_acc * bound <= w) & (w > 0)
                return (t + 1, done | accept,
                        jnp.where(accept, nbr_c, chosen))

            _, done, chosen = jax.lax.while_loop(
                cond, body, (jnp.int32(0), ~feasible, jnp.int32(-1)))
            fb = feasible & ~done
            res = reservoir_lane(cur, deg, sk0, sk1, prev, stepc, ws_tree, fb)
            nxt = jnp.where(fb, res, chosen)
            extra = (jnp.where(~fb & (chosen >= 0), 1 << RJS, 0)
                     | jnp.where(fb, 1 << FALLBACK, 0))
            return nxt, extra.astype(jnp.int32)

        def precomp_lane(cur, deg, sk0, sk1, prev, stepc, ws_tree, act):
            """_PrecompBase.select for one lane: table draw on valid rows,
            reservoir on stale ones."""
            if kind == "precomp_its":
                cdf_hbm, total_nd, inval_nd = streams[4:7]
            else:
                prob_hbm, alias_hbm, total_nd, inval_nd = streams[4:8]
            vpos = jnp.maximum(cur, 0)
            ok = act & (cur >= 0) & (node_read_i32(inval_nd, vpos) == 0)
            total = node_read_f32(total_nd, vpos)
            r0row = node_read_i32(row0_nd, vpos)
            if kind == "precomp_its":
                u = uniform_01(sk0, sk1, jnp.uint32(0), jnp.uint32(ITS_SALT))
                target = u * total

                def scond(c):
                    lo, hi = c
                    return lo < hi

                def sbody(c):
                    lo, hi = c
                    mid = (lo + hi) // 2
                    go = _read_elem(cdf_hbm, fbuf, fsem, r0row, mid) <= target
                    return (jnp.where(go, mid + 1, lo),
                            jnp.where(go, hi, mid))

                lo, _ = jax.lax.while_loop(
                    scond, sbody,
                    (jnp.int32(0), jnp.where(ok, deg, 0)))
                sel = jnp.clip(lo, 0, jnp.maximum(deg - 1, 0))
            else:
                u1, u2 = uniform_pair_01(sk0, sk1, jnp.uint32(0),
                                         jnp.uint32(ALIAS_SALT))
                col = jnp.minimum(
                    (u1 * deg.astype(jnp.float32)).astype(jnp.int32),
                    jnp.maximum(deg - 1, 0))
                p_c = _read_elem(prob_hbm, fbuf, fsem, r0row, col)
                a_c = _read_elem(alias_hbm, fbuf, fsem, r0row,
                                 col).astype(jnp.int32)
                sel = jnp.where(u2 < p_c, col, a_c)
            nbr_c = _read_elem(nbr_hbm, ibuf, isem, r0row, sel)
            nxt_pre = jnp.where(ok & (deg > 0) & (total > 0), nbr_c, -1)
            stale = act & ~ok
            dyn = reservoir_lane(cur, deg, sk0, sk1, prev, stepc, ws_tree,
                                 stale)
            nxt = jnp.where(ok, nxt_pre, jnp.where(stale, dyn, -1))
            extra = (jnp.where(ok & (nxt_pre >= 0), 1 << PRECOMP, 0)
                     | jnp.where(stale & (dyn >= 0), 1 << STALE, 0))
            return nxt, extra.astype(jnp.int32)

        # ------------------------------------------------- epoch step loop
        def step_body(t, c):
            cur, prev, stepc, alive, ws_leaves, emitted_v, flags_v = c
            ws_tree = jax.tree_util.tree_unflatten(ws_treedef,
                                                   list(ws_leaves))
            deg = deg_of(cur)
            wants = alive & (stepc < num_steps)
            live = wants & (deg > 0)
            # per-step key: stream_keys() folds the step counter
            sk0, sk1 = threefry2x32(s0, s1, jnp.uint32(0), stepc)
            if kind == "reservoir":
                nxt = reservoir_lane(cur, deg, sk0, sk1, prev, stepc,
                                     ws_tree, live)
                extra = jnp.int32(0)
            elif kind == "rejection":
                nxt, extra = rejection_lane(cur, deg, sk0, sk1, prev, stepc,
                                            ws_tree, live)
            else:
                nxt, extra = precomp_lane(cur, deg, sk0, sk1, prev, stepc,
                                          ws_tree, live)
            nxt = jnp.where(live, nxt, -1)
            stepped = live & (nxt >= 0)
            flagw = jnp.where(live, jnp.int32(1 << LIVE) | extra,
                              jnp.int32(0))
            # --- WalkProgram hooks, exactly as the staged step orders them
            new_leaves = ws_leaves
            stop = jnp.zeros_like(stepped)
            if program.has_hooks:
                tctx = EdgeCtx(
                    h=jnp.float32(1.0), label=jnp.int32(-1),
                    dist=jnp.int32(-1), nbr=nxt, deg_cur=deg,
                    deg_prev=deg_of(prev), cur=cur, prev=prev, step=stepc)
                new_ws = ws_tree
                if program.on_step is not None:
                    cand = program.on_step(tctx, params, ws_tree)
                    new_leaves = tuple(
                        jnp.where(stepped, n, o) for n, o in
                        zip(jax.tree_util.tree_leaves(cand), ws_leaves))
                    new_ws = jax.tree_util.tree_unflatten(ws_treedef,
                                                          list(new_leaves))
                if program.should_stop is not None:
                    stop = stepped & program.should_stop(tctx, params, new_ws)
            return (jnp.where(stepped, nxt, cur),
                    jnp.where(stepped, cur, prev),
                    stepc + stepped.astype(jnp.int32),
                    alive & ~(wants & ~stepped) & ~stop,
                    new_leaves,
                    emitted_v.at[t].set(jnp.where(stepped, nxt, -1)),
                    flags_v.at[t].set(flagw))

        init = (cur_s[i], prev_s[i], step_s[i], alive_s[i] != 0,
                tuple(r[...][0] for r in ws_refs),
                jnp.full((epoch_len,), -1, jnp.int32),
                jnp.zeros((epoch_len,), jnp.int32))
        cur, prev, stepc, alive, ws_leaves, emitted_v, flags_v = \
            jax.lax.fori_loop(0, epoch_len, step_body, init)
        em_ref[...] = emitted_v[None]
        fl_ref[...] = flags_v[None]
        ocur[0] = cur
        oprev[0] = prev
        ostep[0] = stepc
        oalive[0] = alive.astype(jnp.int32)
        for r, v in zip(ws_out, ws_leaves):
            r[...] = v[None]

    return kernel


# ----------------------------------------------------------------- wrapper
def fused_streams(graph, program, *, bmax=None, bucket_rows: bool = False):
    """Host-side tile-aligned edge streams for the mega-step kernel:
    ``(deg_nd, row0_nd, nbr2d, h2d[, bmax_nd])``.

    Works on a contiguous ``CSRGraph`` AND a delta-overlay
    ``OverlayGraph`` — the kernel body is layout-agnostic (it reads
    per-node ``deg``/``row0`` streams and never assumes contiguity), so
    aligning the overlay's ``row_start``/``row_deg`` layout produces
    exactly the streams a compacted graph would: dead patch space is
    never gathered, and the within-row order (the RNG key) is identical.

    ``bucket_rows=True`` pow2-pads the aligned row count so a mutation
    burst produces O(log K) distinct stream shapes (→ O(log K) retraces
    of the jitted fused epoch, matching the staged path's shape
    bucketing).  Pass ``bmax`` (per-node weight bound table) for the
    rejection regime.
    """
    starts, degs_h = host_row_layout(graph)
    indices = np.asarray(graph.indices)
    nbr2d, row0, degs = align_rows_layout(indices, starts, degs_h,
                                          dtype=np.int32,
                                          bucket_rows=bucket_rows)
    if program.weighted:
        h_vals = np.asarray(graph.h)
    else:  # unweighted programs see ctx.h == 1 on every real edge
        h_vals = np.ones(int(indices.shape[0]), np.float32)
    h2d, _, _ = align_rows_layout(h_vals, starts, degs_h,
                                  bucket_rows=bucket_rows)
    streams = [pack_node_stream(degs), pack_node_stream(row0), nbr2d, h2d]
    if bmax is not None:
        streams.append(pack_node_stream(jnp.asarray(bmax, jnp.float32)))
    return tuple(streams)


def make_streamed_epoch(program, params, *, kind: str, tile: int,
                        rjs_trials: int = 8, rjs_max_rounds: int = 16,
                        interpret: Optional[bool] = None):
    """Build ``epoch(state, precomp, streams, epoch_len, num_steps,
    max_tiles)`` running the fused mega-step kernel.

    The edge streams (:func:`fused_streams`) are an *argument*, not a
    closure: the engine rebuilds them host-side after a structural
    mutation and the jitted epoch retraces only when their shapes change
    (pow2-bucketed → O(log K) variants per burst), exactly like the
    staged epoch treats the graph.  ``max_tiles`` rides along the same
    way (a static arg at the jit boundary) so pad-bucket growth retraces
    instead of requiring a rebuild.  Precomp kinds read the aligned
    table streams off the ``precomp`` argument at call time, so
    between-epoch rebuild drains swap in re-baked rows with no retrace.
    """
    if kind not in FUSED_KINDS:
        raise ValueError(f"kind {kind!r} not one of {FUSED_KINDS}")
    if tile < 2 or tile % 2 or TILE % tile:
        raise ValueError(
            f"fused step needs an even tile dividing {TILE}, got {tile}")
    interpret = default_interpret() if interpret is None else bool(interpret)

    def epoch(state: WalkerState, precomp, in_streams, epoch_len: int,
              num_steps: int, max_tiles: int):
        want = 5 if kind == "rejection" else 4
        if len(in_streams) != want:
            raise ValueError(
                f"kind={kind!r} expects {want} edge streams "
                f"(fused_streams{' with bmax' if want == 5 else ''}), "
                f"got {len(in_streams)}")
        W = int(state.cur.shape[0])
        seeds = jnp.asarray(state.rng, jnp.uint32).reshape(W, -1)[:, :2]
        streams = list(in_streams)
        if kind in ("precomp_its", "precomp_alias"):
            if precomp is None or precomp.cdf2d is None:
                raise ValueError(
                    f"kind={kind!r} needs aligned precomp tables "
                    f"(build_tables(..., aligned=True))")
            if kind == "precomp_its":
                streams.append(precomp.cdf2d)
            else:
                streams.extend([precomp.prob2d, precomp.alias2d])
            streams.append(pack_node_stream(
                jnp.asarray(precomp.total, jnp.float32)))
            streams.append(pack_node_stream(
                jnp.asarray(precomp.invalid, jnp.int32)))
        ws_leaves, ws_treedef = jax.tree_util.tree_flatten(state.wstate)
        n_ws = len(ws_leaves)
        kernel = _make_kernel(
            program, params, kind=kind, tile=tile, max_tiles=int(max_tiles),
            rjs_trials=rjs_trials, rjs_max_rounds=rjs_max_rounds,
            epoch_len=int(epoch_len), num_steps=int(num_steps),
            n_streams=len(streams), n_ws=n_ws, ws_treedef=ws_treedef)

        def lane_block(leaf):
            extra = leaf.ndim - 1
            return pl.BlockSpec((1,) + leaf.shape[1:],
                                lambda i, n=extra: (i,) + (0,) * n)

        in_specs = ([pl.BlockSpec(memory_space=pltpu.SMEM)] * 5
                    + [pl.BlockSpec(memory_space=pl.ANY)] * len(streams)
                    + [lane_block(l) for l in ws_leaves])
        out_specs = ([pl.BlockSpec((1, int(epoch_len)), lambda i: (i, 0))] * 2
                     + [pl.BlockSpec((1,), lambda i: (i,))] * 4
                     + [lane_block(l) for l in ws_leaves])
        out_shape = ([jax.ShapeDtypeStruct((W, int(epoch_len)), jnp.int32)]
                     * 2
                     + [jax.ShapeDtypeStruct((W,), jnp.int32)] * 4
                     + [jax.ShapeDtypeStruct(l.shape, l.dtype)
                        for l in ws_leaves])
        outs = pl.pallas_call(
            kernel, grid=(W,), in_specs=in_specs, out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=[
                pltpu.VMEM((SUBLANES, LANES), jnp.int32),
                pltpu.VMEM((SUBLANES, LANES), jnp.float32),
                pltpu.SemaphoreType.DMA,
                pltpu.SemaphoreType.DMA,
            ],
            interpret=interpret,
        )(state.cur.astype(jnp.int32), state.prev.astype(jnp.int32),
          state.step.astype(jnp.int32), state.alive.astype(jnp.int32),
          seeds, *streams, *ws_leaves)
        emitted, flags, cur, prev, stepc, alive = outs[:6]
        new_state = WalkerState(
            cur=cur, prev=prev, step=stepc, alive=alive.astype(bool),
            rng=state.rng, carry=state.carry,
            wstate=jax.tree_util.tree_unflatten(ws_treedef, list(outs[6:])))
        return new_state, emitted.T, StepStats.from_flag_bits(flags)

    return epoch


def make_fused_epoch(graph, program, params, *, kind: str, tile: int,
                     max_tiles: int, rjs_trials: int = 8,
                     rjs_max_rounds: int = 16, bmax=None,
                     interpret: Optional[bool] = None):
    """Build ``epoch(state, precomp, epoch_len, num_steps)`` with the edge
    streams baked from ``graph`` at build time — the fixed-graph
    convenience over :func:`make_streamed_epoch` (same kernel, same
    bit-identity contract).  ``graph`` may be a contiguous ``CSRGraph``
    or a delta-overlay ``OverlayGraph`` (see :func:`fused_streams`)."""
    if kind == "rejection" and bmax is None:
        raise ValueError("kind='rejection' requires the baked bmax table")
    streams = fused_streams(graph, program,
                            bmax=bmax if kind == "rejection" else None)
    inner = make_streamed_epoch(program, params, kind=kind, tile=tile,
                                rjs_trials=rjs_trials,
                                rjs_max_rounds=rjs_max_rounds,
                                interpret=interpret)

    def epoch(state: WalkerState, precomp, epoch_len: int, num_steps: int):
        return inner(state, precomp, streams, epoch_len, num_steps,
                     max_tiles)

    return epoch
