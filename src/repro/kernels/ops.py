"""jit'd public wrappers around the Pallas kernels + layout utilities.

``align_rows`` builds the **tile-aligned CSR** layout the walk kernels
consume: every node's weight row starts on a 128-lane boundary of a
[R, 128] stream, so each kernel DMA is lane-aligned (DESIGN.md §3.1).
The ≤127-element per-row padding is the price of alignment — worst case
+127·V floats, measured and reported by the benchmark harness.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.csr import CSRGraph
from repro.kernels.ref import LANES, SUBLANES, TILE
from repro.kernels import (ervs_kernel, erjs_kernel, precomp_kernel,
                           token_sampler)


def align_rows_layout(values: np.ndarray, row_start, row_deg,
                      dtype=np.float32, bucket_rows: bool = False
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """:func:`align_rows` for an explicit (row_start, row_deg) layout.

    Row ``v``'s values are gathered from ``values[row_start[v] :
    row_start[v] + row_deg[v]]`` — the layout a delta-overlay graph
    exposes (`host_row_layout`), of which contiguous CSR is the special
    case ``row_start == indptr[:-1]``.  Dead space between overlay spans
    is never read, so the aligned stream of an overlay row is identical
    to what a compacted graph would produce.

    ``bucket_rows=True`` pads the aligned row count R up to a power of
    two, so a burst of mutations produces O(log K) distinct stream
    shapes instead of one per apply — the jitted fused epoch keys its
    trace cache on these shapes.  Extra rows are zero (lane masks ignore
    them) and cost padding only.
    """
    values = np.asarray(values, dtype)
    starts = np.asarray(row_start, np.int64)
    degs = np.asarray(row_deg, np.int64)
    rows_per_node = np.maximum((degs + LANES - 1) // LANES, 0)
    row0 = np.zeros(degs.shape[0], np.int64)
    np.cumsum(rows_per_node[:-1], out=row0[1:])
    # pad total rows to a multiple of SUBLANES (+1 tile of slack so a DMA
    # that runs past the last row never reads out of bounds)
    R = int(rows_per_node.sum()) + SUBLANES * 2
    R = ((R + SUBLANES - 1) // SUBLANES) * SUBLANES
    if bucket_rows:
        R = max(SUBLANES, 1 << max(R - 1, 0).bit_length())
    flat = np.zeros(R * LANES, dtype)
    # scatter each row into its aligned position
    E = int(degs.sum())
    node_of_edge = np.repeat(np.arange(degs.shape[0]), degs)
    bounds = np.zeros(degs.shape[0] + 1, np.int64)
    np.cumsum(degs, out=bounds[1:])
    within = np.arange(E, dtype=np.int64) - bounds[node_of_edge]
    src = starts[node_of_edge] + within
    dst = row0[node_of_edge] * LANES + within
    flat[dst] = values[src]
    return (jnp.asarray(flat.reshape(R, LANES)),
            jnp.asarray(row0, jnp.int32),
            jnp.asarray(degs, jnp.int32))


def align_rows(values: np.ndarray, indptr: np.ndarray,
               dtype=np.float32
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Repack a flat CSR value stream into the tile-aligned [R, 128] layout.

    Returns (w2d [R,128] of ``dtype``, row0 [V] int32 — first 128-row per
    node, degs [V] int32).  ``dtype`` defaults to float32 (weight/CDF
    streams); the mega-step kernel passes int32 for the neighbour-id
    stream.
    """
    indptr = np.asarray(indptr, np.int64)
    return align_rows_layout(values, indptr[:-1], np.diff(indptr),
                             dtype=dtype)


def graph_aligned_weights(graph: CSRGraph):
    """Aligned layout of the *property* weights h (static-walk hot path)."""
    return align_rows(np.asarray(graph.h), np.asarray(graph.indptr))


# ------------------------------------------------------------ public ops
def ervs_select(w2d, row0, degs, seeds, interpret: bool = True):
    """Block-jump A-ExpJ reservoir selection (see ervs_kernel.py)."""
    return ervs_kernel.ervs_select(w2d, row0, degs, seeds, interpret=interpret)


def erjs_select(w2d, row0, degs, bounds, seeds,
                trials: int = 8, max_rounds: int = 16, interpret: bool = True):
    """Bound-based rejection selection (see erjs_kernel.py)."""
    limit = jnp.asarray([trials * max_rounds], jnp.int32)
    return erjs_kernel.erjs_select(w2d, row0, degs, bounds, seeds, limit,
                                   interpret=interpret)


def its_search(cdf2d, row0, degs, totals, seeds, interpret: bool = True):
    """DMA-probed CDF binary search (see precomp_kernel.py)."""
    return precomp_kernel.its_search(cdf2d, row0, degs, totals, seeds,
                                     interpret=interpret)


def alias_pick(prob2d, alias2d, row0, degs, totals, seeds,
               interpret: bool = True):
    """O(1) alias-table pick (see precomp_kernel.py)."""
    return precomp_kernel.alias_pick(prob2d, alias2d, row0, degs, totals,
                                     seeds, interpret=interpret)


def aligned_precomp_tables(tables, indptr):
    """Repack PrecompTables' flat [E] arrays into the tile-aligned [R, 128]
    layout the Pallas kernels consume.  Alias offsets ride the float32
    stream (exact below 2²⁴; guaranteed by build_tables' degree bound).
    Returns (cdf2d, prob2d, alias2d, row0, degs)."""
    indptr = np.asarray(indptr)
    cdf2d, row0, degs = align_rows(np.asarray(tables.cdf), indptr)
    prob2d, _, _ = align_rows(np.asarray(tables.alias_prob), indptr)
    alias2d, _, _ = align_rows(
        np.asarray(tables.alias_off, np.float32), indptr)
    return cdf2d, prob2d, alias2d, row0, degs


def token_sample(logits, seed, temperature: float = 1.0,
                 greedy: bool = False, interpret: bool = True):
    """Gumbel-max categorical token sampling (see token_sampler.py)."""
    return token_sampler.token_sample(logits, seed, temperature=temperature,
                                      greedy=greedy, interpret=interpret)


def make_seeds(key: jax.Array, n: int) -> jnp.ndarray:
    """Derive [n, 2] uint32 Threefry seeds from a jax PRNG key."""
    data = jax.random.key_data(jax.random.split(key, n))
    return jnp.asarray(data, jnp.uint32).reshape(n, -1)[:, :2]
