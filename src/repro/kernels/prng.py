"""Counter-based RNG usable *inside* Pallas TPU kernels.

Threefry-2x32 (Salmon et al., SC'11) in plain 32-bit jnp ops — add/xor/rotl
only — so the same code path runs (a) inside a Pallas kernel body on TPU,
(b) in interpret mode on CPU, and (c) in the pure-jnp ref oracles.  Being
counter-based is what makes the paper's jump technique *actually free*: a
skipped (walker, block) simply never evaluates its counter (no stream to
advance).  On real TPU deployments this can be swapped for the native
``pltpu.prng_random_bits`` (hardware PRNG); the kernels take the generator
as a parameter.  Statistical quality: full 20-round Threefry, the same
generator family JAX's host PRNG uses.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_ROTATIONS = (13, 15, 26, 6, 17, 29, 16, 24)
_PARITY = np.uint32(0x1BD11BDA)


def _rotl(x, r):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(k0, k1, x0, x1):
    """20-round Threefry-2x32: (key0, key1, ctr0, ctr1) -> (r0, r1), uint32."""
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    x0 = jnp.asarray(x0, jnp.uint32)
    x1 = jnp.asarray(x1, jnp.uint32)
    ks = (k0, k1, k0 ^ k1 ^ _PARITY)
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for block in range(5):  # 5 blocks of 4 rounds = 20 rounds
        for r in range(4):
            rot = _ROTATIONS[(block % 2) * 4 + r]
            x0 = x0 + x1
            x1 = _rotl(x1, rot) ^ x0
        inj = block + 1
        x0 = x0 + ks[inj % 3]
        x1 = x1 + ks[(inj + 1) % 3] + jnp.uint32(inj)
    return x0, x1


def uniform_01(k0, k1, c0, c1):
    """U(0,1) floats (never exactly 0) from two 32-bit counters.

    Uses the top 24 bits → uniform on [2^-25, 1 - 2^-25] after the half-ulp
    shift; safe for log().
    """
    r0, _ = threefry2x32(k0, k1, c0, c1)
    f = (r0 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    return f + jnp.float32(0.5 / (1 << 24))


def uniform_pair_01(k0, k1, c0, c1):
    """Two independent U(0,1) streams from one threefry call."""
    r0, r1 = threefry2x32(k0, k1, c0, c1)
    scale = jnp.float32(1.0 / (1 << 24))
    half = jnp.float32(0.5 / (1 << 24))
    f0 = (r0 >> jnp.uint32(8)).astype(jnp.float32) * scale + half
    f1 = (r1 >> jnp.uint32(8)).astype(jnp.float32) * scale + half
    return f0, f1
