"""eRVS Pallas TPU kernel — block-jump exponential-key reservoir sampling.

The paper's eRVS (§3.2) on a GPU assigns a warp per node and gives each
lane a strided slice.  The TPU-native shape of the same idea (DESIGN.md
§3.1):

* the walker's weight row streams HBM→VMEM in (8, 128) tiles via explicit
  async DMA (tile-aligned CSR layout, every copy lane-aligned);
* one *sequential* A-ExpJ reservoir per walker is carried across tiles —
  legal because the TPU Pallas grid executes sequentially per core;
* **block-level jump**: a tile whose weight-sum stays below the carried
  threshold is retired with ONE vector sum — no RNG, no logs, no cumsum.
  E[#updates] = O(log d), so for d ≫ 1024 almost every tile is jumped —
  the paper's RNG-elimination claim at the granularity a VPU can exploit;
* RNG is counter-based Threefry-2x32 (kernels/prng.py), seeded per walker,
  with the draw counter as the Threefry counter — skipped blocks consume
  literally nothing.

Validated bit-exactly against ref.ervs_select_ref (same counters, same
float composition) in interpret mode; see tests/test_kernels.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.prng import uniform_pair_01
from repro.kernels.ref import LANES, SUBLANES, TILE

NEG_INF = np.float32(-np.inf)


def _ervs_kernel(row0_ref, degs_ref, seeds_ref,  # SMEM scalars
                 w_hbm,  # ANY (HBM) [R, 128] tile-aligned weights
                 off_ref, draws_ref, jumped_ref,  # outputs (1,) blocks
                 buf, sem):  # scratch: VMEM (8,128), DMA sem
    i = pl.program_id(0)
    r0 = row0_ref[i]
    deg = degs_ref[i]
    k0 = seeds_ref[i, 0]
    k1 = seeds_ref[i, 1]
    n_tiles = (deg + TILE - 1) // TILE
    offsets = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 0) * LANES \
        + jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 1)

    def tile_body(t, st):
        best_lk, best_off, t_rem, draws, jumped = st
        cp = pltpu.make_async_copy(
            w_hbm.at[pl.ds(r0 + t * SUBLANES, SUBLANES), :], buf, sem)
        cp.start()
        cp.wait()
        off = t * TILE + offsets.reshape(TILE)
        w = jnp.where(off < deg, buf[...].reshape(TILE), 0.0)
        blocksum = jnp.sum(w)
        crossing = (blocksum >= t_rem) & (blocksum > 0)

        def process(st):
            best_lk, best_off, t_rem, draws, base = st
            cum = jnp.cumsum(w)

            def cross_cond(s):
                _, _, t_rem, _, base = s
                return blocksum - base >= t_rem

            def cross_body(s):
                best_lk, best_off, t_rem, draws, base = s
                target = base + t_rem
                hit = (cum >= target) & (w > 0)
                pos = jnp.argmax(hit).astype(jnp.int32)
                w_m = w[pos]
                u1, u2 = uniform_pair_01(k0, k1, jnp.uint32(draws),
                                         jnp.uint32(0x9E3779B9))
                t_w = jnp.exp(jnp.clip(w_m * best_lk, -80.0, 0.0))
                is_first = best_lk == NEG_INF
                uu = jnp.where(is_first, u1, t_w + u1 * (1.0 - t_w))
                lk_new = jnp.log(jnp.clip(uu, 1e-38, 1.0)) / jnp.maximum(w_m, 1e-30)
                new_thresh = jnp.log(u2) / jnp.minimum(lk_new, -1e-30)
                return (lk_new, t * TILE + pos, new_thresh, draws + 1, cum[pos])

            st2 = jax.lax.while_loop(
                cross_cond, cross_body,
                (best_lk, best_off, t_rem, draws, jnp.float32(0.0)))
            best_lk, best_off, t_rem, draws, base = st2
            return (best_lk, best_off, t_rem - (blocksum - base), draws)

        def skip(st):
            best_lk, best_off, t_rem, draws, _ = st
            return (best_lk, best_off, t_rem - blocksum, draws)

        best_lk, best_off, t_rem, draws = jax.lax.cond(
            crossing, process, skip,
            (best_lk, best_off, t_rem, draws, jnp.float32(0.0)))
        jumped = jumped + jnp.where(crossing, 0, 1)
        return (best_lk, best_off, t_rem, draws, jumped)

    init = (NEG_INF, jnp.int32(-1), jnp.float32(0.0), jnp.int32(0), jnp.int32(0))
    _, best_off, _, draws, jumped = jax.lax.fori_loop(0, n_tiles, tile_body, init)
    off_ref[0] = best_off
    draws_ref[0] = draws
    jumped_ref[0] = jumped


@partial(jax.jit, static_argnames=("interpret",))
def ervs_select(w2d: jax.Array, row0: jax.Array, degs: jax.Array,
                seeds: jax.Array, interpret: bool = True):
    """Select one neighbour offset per walker via block-jump A-ExpJ.

    w2d [R,128] f32, row0/degs [W] int32, seeds [W,2] uint32.
    Returns (offset [W] i32 or -1, draws [W] i32, jumped-blocks [W] i32).
    """
    W = row0.shape[0]
    grid = (W,)
    out = pl.pallas_call(
        _ervs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # row0
            pl.BlockSpec(memory_space=pltpu.SMEM),  # degs
            pl.BlockSpec(memory_space=pltpu.SMEM),  # seeds
            pl.BlockSpec(memory_space=pl.ANY),  # weights stay in HBM
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((W,), jnp.int32),
            jax.ShapeDtypeStruct((W,), jnp.int32),
            jax.ShapeDtypeStruct((W,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((SUBLANES, LANES), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(row0, degs, seeds, w2d)
    return out
