"""Network front-end: framing, loopback integration, fairness.

Three layers under test, all deterministic:

* ``serving.transport``   — frame encode/decode round-trips at any
                            byte split, request validation, wire
                            (de)serialization of ServedWalk (nan-safe).
* ``serving.frontend``    — the loopback integration suite: real TCP
                            sockets, but the driver in ``manual`` mode
                            and the service on a SimClock, so every
                            event interleaving is pinned and served
                            paths must be *bit-identical* to offline
                            ``WalkEngine.run`` — multi-client, mixed
                            priorities, cancel, overload, slow-client
                            backpressure (both policies), malformed
                            frames, graceful drain with partial-path
                            flush.
* ``DeficitRoundRobin``   — hypothesis property tests over random cost
                            schedules: work conservation, weighted
                            shares within the quantum/cost bound, and
                            the starvation bound.
"""
import math

import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st
from repro.core import EngineConfig, WalkEngine
from repro.graphs import random_graph
from repro.launch.walk_client import WalkRejected, WalkServiceClient
from repro.serving import (CANCELLED, COMPLETED, DeficitRoundRobin,
                           FrontendConfig, ServedWalk, ServiceConfig,
                           SimClock, WalkFrontend, WalkService)
from repro.serving import transport as tp
from repro.walks import make_workload

STEPS = 6
KEYSEED = 2


@pytest.fixture(scope="module")
def graph():
    return random_graph(60, 6, weight_dist="uniform", seed=3)


def make_service(graph, *, slots=4, epoch_len=2, max_pending=1024,
                 fairness="drr", weights=None):
    return WalkService(
        graph,
        ServiceConfig(slots=slots, epoch_len=epoch_len, num_steps=STEPS,
                      max_pending=max_pending, seed=KEYSEED,
                      fairness=fairness, weights=weights),
        EngineConfig(method="ervs", tile=32),
        clock=SimClock())


def offline_paths(graph, program_name, starts):
    eng = WalkEngine(graph, make_workload(program_name),
                     EngineConfig(method="ervs", tile=32))
    res = eng.run(np.asarray(starts), num_steps=STEPS,
                  key=jax.random.key(KEYSEED))
    return res.paths


@pytest.fixture
def frontend_factory(graph):
    """Yields a function building (frontend, service) pairs in manual-
    driver mode; every frontend is stopped at teardown."""
    frontends = []

    def build(service=None, **cfg):
        service = service or make_service(graph)
        fe = WalkFrontend(service, FrontendConfig(**cfg), driver="manual")
        fe.start()
        frontends.append(fe)
        return fe

    yield build
    for fe in frontends:
        fe.stop()


def connect(fe: WalkFrontend) -> WalkServiceClient:
    host, port = fe.address
    return WalkServiceClient(host=host, port=port, timeout=30.0)


def pump_all(fe: WalkFrontend, limit: int = 10_000) -> None:
    """Drive the service to idle deterministically."""
    for _ in range(limit):
        if not fe.pump():
            return
    raise AssertionError("service still busy after pump limit")


# --------------------------------------------------------------------------
# transport framing
# --------------------------------------------------------------------------
class TestFraming:
    def test_roundtrip_single_frame(self):
        frame = {"op": "stats", "id": 7}
        out = tp.FrameDecoder().feed(tp.encode_frame(frame))
        assert out == [frame]

    def test_roundtrip_many_frames_any_split(self):
        frames = [{"op": "poll", "id": i, "max": i + 1} for i in range(5)]
        blob = b"".join(tp.encode_frame(f) for f in frames)
        # worst case: the stream arrives one byte at a time
        dec = tp.FrameDecoder()
        got = []
        for i in range(len(blob)):
            got.extend(dec.feed(blob[i:i + 1]))
        assert got == frames

    def test_oversize_frame_rejected_on_decode(self):
        dec = tp.FrameDecoder(max_frame=16)
        blob = tp.encode_frame({"op": "stats", "id": "x" * 64})
        with pytest.raises(tp.ProtocolError) as ei:
            dec.feed(blob)
        assert ei.value.code == tp.ERR_BAD_FRAME and ei.value.fatal

    def test_oversize_frame_rejected_on_encode(self):
        with pytest.raises(tp.ProtocolError):
            tp.encode_frame({"id": "x" * 64}, max_frame=16)

    def test_invalid_json_body_is_fatal(self):
        import struct
        body = b"not json"
        with pytest.raises(tp.ProtocolError) as ei:
            tp.FrameDecoder().feed(struct.pack(">I", len(body)) + body)
        assert ei.value.fatal

    def test_non_object_body_is_fatal(self):
        import struct
        body = b"[1,2,3]"
        with pytest.raises(tp.ProtocolError) as ei:
            tp.FrameDecoder().feed(struct.pack(">I", len(body)) + body)
        assert ei.value.fatal

    def test_walk_wire_roundtrip_exact(self):
        walk = ServedWalk(ticket=3, program="deepwalk", status=COMPLETED,
                          path=np.array([1, 2, 3, -1], np.int32), steps=2,
                          submit_time=0.5, admit_time=0.75,
                          finish_time=1.25, wait=0.25, latency=0.75)
        back = tp.walk_from_wire(tp.walk_to_wire(walk))
        assert back.ticket == walk.ticket and back.status == walk.status
        assert back.path.dtype == np.int32
        np.testing.assert_array_equal(back.path, walk.path)
        assert (back.wait, back.latency) == (walk.wait, walk.latency)

    def test_walk_wire_roundtrip_nan_and_none(self):
        walk = ServedWalk(ticket=9, program="deepwalk", status="expired",
                          path=None, steps=0, submit_time=1.0,
                          admit_time=None, finish_time=2.0,
                          wait=float("nan"), latency=1.0)
        wire = tp.walk_to_wire(walk)
        assert wire["wait"] is None and wire["path"] is None
        back = tp.walk_from_wire(wire)
        assert back.path is None and back.admit_time is None
        assert math.isnan(back.wait)

    @pytest.mark.parametrize("bad", [
        {"op": "noop", "id": 1},
        {"id": 1},
        {"op": "submit", "id": 1},                      # missing start
        {"op": "submit", "id": 1, "start": -1},
        {"op": "submit", "id": 1, "start": "zero"},
        {"op": "submit", "id": 1, "start": 0, "priority": "high"},
        {"op": "poll", "id": 1, "max": 0},
        {"op": "cancel", "id": 1},                      # missing ticket
        {"op": "stats", "id": [1]},                     # non-scalar id
    ])
    def test_bad_requests_rejected_nonfatal(self, bad):
        with pytest.raises(tp.ProtocolError) as ei:
            tp.parse_request(bad)
        assert ei.value.code == tp.ERR_BAD_REQUEST and not ei.value.fatal

    def test_parse_submit_defaults(self):
        op, rid, kw = tp.parse_request({"op": "submit", "id": 4,
                                        "start": 11})
        assert (op, rid) == ("submit", 4)
        assert kw == {"start": 11, "program": "deepwalk", "priority": 0,
                      "deadline": None}


# --------------------------------------------------------------------------
# loopback integration (manual driver + SimClock: pinned interleavings)
# --------------------------------------------------------------------------
class TestLoopback:
    def test_single_client_bit_identical(self, graph, frontend_factory):
        fe = frontend_factory()
        starts = np.arange(9) % graph.num_nodes
        with connect(fe) as client:
            walks = client.walk(starts, pump=fe.pump)
        assert [w.status for w in walks] == [COMPLETED] * 9
        np.testing.assert_array_equal(
            np.stack([w.path for w in walks]),
            offline_paths(graph, "deepwalk", starts))

    def test_multi_client_interleaved_bit_identical(self, graph,
                                                    frontend_factory):
        """3 clients submit in a pinned round-robin with mixed
        priorities; every client's walks match the offline run of the
        global submission order (priorities reorder *admission*, never
        the per-query stream)."""
        fe = frontend_factory()
        clients = [connect(fe) for _ in range(3)]
        try:
            starts = (np.arange(12) * 7) % graph.num_nodes
            tickets = {}  # ticket -> (client idx, start)
            for i, s in enumerate(starts.tolist()):
                c = clients[i % 3]
                t = c.submit(s, priority=i % 2)
                tickets[t] = (i % 3, s)
            # submission order == ticket order: offline ground truth
            ref = offline_paths(graph, "deepwalk", starts)
            pump_all(fe)
            got = {}
            for c in clients:
                for w in c.poll(max_walks=64):
                    got[w.ticket] = w
            assert len(got) == 12
            for i, t in enumerate(sorted(tickets)):
                np.testing.assert_array_equal(got[t].path, ref[i])
                assert got[t].status == COMPLETED
        finally:
            for c in clients:
                c.close()

    def test_replay_is_bit_and_telemetry_identical(self, graph,
                                                   frontend_factory):
        """The headline determinism contract: the same pinned loopback
        scenario (two clients, mixed priorities, a slow client parked
        on backpressure credit) served twice gives identical paths AND
        identical telemetry counters."""
        def run_once():
            svc = make_service(graph, slots=2, epoch_len=1)
            fe = frontend_factory(service=svc, client_buffer=2,
                                  slow_client="suspend")
            fast, slow = connect(fe), connect(fe)
            try:
                srids = [slow.send(slow.submit_frame(s))
                         for s in (3, 9, 27)]  # 3rd parks on credit
                # fence: a round-trip on slow's connection proves the
                # server processed all three sends (per-connection
                # dispatch is in-order), pinning the cross-client
                # submission interleaving
                slow.request({"op": tp.OP_STATS})
                for s in (5, 15):
                    fast.submit(s)
                pump_all(fe)
                walks = {}
                for _ in range(8):
                    for w in slow.poll():
                        walks[w.ticket] = w
                    for w in fast.poll():
                        walks[w.ticket] = w
                    pump_all(fe)
                    if len(walks) == 5:
                        break
                for r in srids:  # every parked submit was admitted
                    assert slow.result(r)["op"] == tp.OP_SUBMIT_OK
                stats = fast.stats()
                # ticket order == service submission order; the parked
                # start 27 entered the service only after slow's first
                # poll, i.e. last
                paths = np.stack([walks[t].path for t in sorted(walks)])
                return paths, stats
            finally:
                fast.close()
                slow.close()

        paths1, stats1 = run_once()
        paths2, stats2 = run_once()
        np.testing.assert_array_equal(paths1, paths2)
        for k in ("completed", "epochs", "live_steps", "frac_rjs",
                  "frac_precomp", "peak_occupancy"):
            assert stats1[k] == stats2[k], k
        # and bit-identical to the offline run of the admission order
        ref = offline_paths(graph, "deepwalk", [3, 9, 5, 15, 27])
        np.testing.assert_array_equal(paths1, ref)

    def test_cancel_pending_and_inflight(self, graph, frontend_factory):
        svc = make_service(graph, slots=2, epoch_len=1)
        fe = frontend_factory(service=svc)
        with connect(fe) as client:
            tickets = [client.submit(s) for s in (1, 2, 3, 4, 5)]
            # nothing admitted yet: a pending cancel has no path
            assert client.cancel(tickets[4]) == CANCELLED
            fe.pump()  # admits 2, runs one 1-step epoch: in flight now
            assert client.cancel(tickets[0]) == CANCELLED
            pump_all(fe)
            walks = {w.ticket: w for w in client.poll(max_walks=16)}
            assert len(walks) == 5
            assert walks[tickets[4]].path is None
            inflight = walks[tickets[0]]
            assert inflight.status == CANCELLED
            assert inflight.path is not None and 0 < inflight.steps < STEPS
            # cancelled partial = prefix of the offline full walk
            ref = offline_paths(graph, "deepwalk", [1, 2, 3, 4, 5])
            k = inflight.steps + 1
            np.testing.assert_array_equal(inflight.path[:k], ref[0][:k])
            assert (inflight.path[k:] == -1).all()
            st_ = client.stats()
            assert st_["cancelled"] == 2 and st_["completed"] == 3
            # double-cancel of a finished ticket: not-found, no recount
            assert client.cancel(tickets[0]) == "not-found"
            assert client.stats()["cancelled"] == 2

    def test_cancel_other_clients_ticket_refused(self, graph,
                                                 frontend_factory):
        fe = frontend_factory()
        a, b = connect(fe), connect(fe)
        try:
            t = a.submit(3)
            assert b.cancel(t) == "not-found"  # cross-client: refused
            assert a.cancel(t) == CANCELLED
        finally:
            a.close()
            b.close()

    def test_overload_rejects_as_typed_error_frames(self, graph,
                                                    frontend_factory):
        svc = make_service(graph, max_pending=3)
        fe = frontend_factory(service=svc, client_buffer=64)
        with connect(fe) as client:
            for s in (1, 2, 3):
                client.submit(s)
            with pytest.raises(WalkRejected) as ei:
                client.submit(4)
            assert ei.value.code == "queue-full"
            with pytest.raises(WalkRejected) as ei:
                client.submit(0, program="no-such-walk")
            assert ei.value.code == "unknown-program"
            pump_all(fe)
            assert len(client.poll(max_walks=16)) == 3

    def test_backpressure_reject_policy(self, graph, frontend_factory):
        fe = frontend_factory(client_buffer=2, slow_client="reject")
        with connect(fe) as client:
            client.submit(1)
            client.submit(2)
            with pytest.raises(WalkRejected) as ei:
                client.submit(3)  # 2 outstanding = at the credit bound
            assert ei.value.code == tp.ERR_BACKPRESSURE
            pump_all(fe)
            assert len(client.poll(max_walks=8)) == 2  # credit freed
            client.submit(3)  # accepted now

    def test_backpressure_suspend_policy(self, graph, frontend_factory):
        fe = frontend_factory(client_buffer=2, slow_client="suspend")
        with connect(fe) as client:
            r1 = client.send(client.submit_frame(1))
            r2 = client.send(client.submit_frame(2))
            r3 = client.send(client.submit_frame(3))  # parked
            t1 = client.result(r1)["ticket"]
            t2 = client.result(r2)["ticket"]
            pump_all(fe)  # first two complete into the buffer
            # the service never saw query 3: backpressure suspends
            # *admission*, upstream of the service queue
            assert fe.service.stats().submitted == 2
            got = {w.ticket for w in client.poll(max_walks=1)}
            assert got == {t1}
            # that poll freed one credit: the parked submit went through
            r3_resp = client.result(r3)
            assert r3_resp["op"] == tp.OP_SUBMIT_OK
            pump_all(fe)
            rest = {w.ticket for w in client.poll(max_walks=8)}
            assert rest == {t2, r3_resp["ticket"]}
            # the stall list is bounded too: buffer full + stash full
            # degrades to a hard reject
            rids = [client.send(client.submit_frame(s))
                    for s in range(2 + 2 + 1)]
            errs = [client.result(r) for r in rids[-1:]]
            assert errs[0]["op"] == tp.OP_ERROR
            assert errs[0]["code"] == tp.ERR_BACKPRESSURE

    def test_stalled_client_never_reduces_others_throughput(
            self, graph, frontend_factory):
        """Acceptance: a client that fills its credit and never polls
        must not reduce another client's completions — and the driver
        keeps running epochs for it."""
        svc = make_service(graph, slots=4, epoch_len=2)
        fe = frontend_factory(service=svc, client_buffer=8,
                              slow_client="suspend")
        slow, fast = connect(fe), connect(fe)
        try:
            slow_starts = list(range(1, 9))
            for s in slow_starts:
                slow.submit(s)  # fills slow's credit; slow never polls
            slow.send(slow.submit_frame(9))  # parked forever
            slow.request({"op": tp.OP_STATS})  # fence: park processed
            fast_starts = (np.arange(16) * 5) % graph.num_nodes
            walks = fast.walk(fast_starts, pump=fe.pump)
            # every fast walk completed, bit-identical to the offline
            # run of the full admission order (slow's 8 went first):
            # zero throughput or determinism loss from the stall
            assert len(walks) == 16
            ref = offline_paths(graph, "deepwalk",
                                slow_starts + fast_starts.tolist())
            np.testing.assert_array_equal(
                np.stack([w.path for w in walks]), ref[8:])
            # slow's finished walks are buffered, bounded by its credit
            st_ = fast.stats()
            assert st_["frontend"]["buffered"] <= 8
            assert st_["frontend"]["stalled"] == 1
            # and they were never lost: slow can still poll them out
            assert len(slow.poll(max_walks=16)) == 8
        finally:
            slow.close()
            fast.close()

    def test_malformed_frame_closes_oversize_connection(self, graph,
                                                        frontend_factory):
        import socket
        import struct
        fe = frontend_factory(max_frame=1024)
        host, port = fe.address
        with socket.create_connection((host, port), timeout=10) as raw:
            raw.sendall(struct.pack(">I", 1 << 30))  # absurd length
            frame = tp.recv_frame(raw)
            assert frame["op"] == tp.OP_ERROR
            assert frame["code"] == tp.ERR_BAD_FRAME
            raw.settimeout(10)
            assert raw.recv(1) == b""  # server hung up

    def test_bad_request_keeps_connection_alive(self, graph,
                                                frontend_factory):
        fe = frontend_factory()
        with connect(fe) as client:
            r = client.request({"op": "warp-core-breach"})
            assert r["op"] == tp.OP_ERROR and r["code"] == tp.ERR_BAD_REQUEST
            # the connection survives a malformed *request* (unlike a
            # malformed *frame*): subsequent ops run fine
            assert client.stats()["submitted"] == 0

    def test_graceful_drain_flushes_partial_paths(self, graph,
                                                  frontend_factory):
        svc = make_service(graph, slots=2, epoch_len=1)
        fe = frontend_factory(service=svc)
        with connect(fe) as client:
            tickets = [client.submit(s) for s in (1, 2, 3, 4)]
            fe.pump()  # 2 in flight, 1 step walked; 2 still queued
            summary = fe.drain(timeout=0.0, flush=True)
            assert summary["flushed"] == 4
            assert summary["pending"] == 0 and summary["in_flight"] == 0
            # draining server refuses new work with a typed error
            with pytest.raises(WalkRejected) as ei:
                client.submit(9)
            assert ei.value.code == tp.ERR_DRAINING
            walks = {w.ticket: w for w in client.poll(max_walks=16)}
            assert set(walks) == set(tickets)
            statuses = {t: walks[t].status for t in tickets}
            assert all(s == CANCELLED for s in statuses.values())
            # the two in-flight lanes carry their partial paths
            partial = [w for w in walks.values() if w.path is not None]
            queued = [w for w in walks.values() if w.path is None]
            assert len(partial) == 2 and len(queued) == 2
            for w in partial:
                assert 0 < w.steps < STEPS
            assert fe.drained

    def test_drain_runs_to_idle_in_manual_mode(self, graph,
                                               frontend_factory):
        fe = frontend_factory()
        with connect(fe) as client:
            for s in (1, 2, 3):
                client.submit(s)
            fe.drain(timeout=30.0, flush=True)  # manual: pumps to idle
            walks = client.poll(max_walks=8)
            assert [w.status for w in walks] == [COMPLETED] * 3

    def test_drain_frame_over_the_wire(self, graph, frontend_factory):
        fe = frontend_factory()
        with connect(fe) as client:
            client.submit(1)
            r = client.drain()
            assert r["op"] == tp.OP_DRAIN_OK and r["pending"] == 1
            assert fe.draining
            pump_all(fe)
            assert len(client.poll()) == 1
            assert fe.drained

    def test_disconnect_cancels_outstanding(self, graph,
                                            frontend_factory):
        fe = frontend_factory()
        c1 = connect(fe)
        c1.submit(3)
        c1.submit(4)
        c1.close()
        with connect(fe) as c2:
            # the close is asynchronous; wait for the server to see it
            for _ in range(100):
                if c2.stats()["frontend"]["clients"] == 1:
                    break
                import time
                time.sleep(0.01)
            st_ = c2.stats()
            assert st_["frontend"]["clients"] == 1
            assert st_["cancelled"] == 2
            assert st_["pending"] == 0 and st_["in_flight"] == 0


# --------------------------------------------------------------------------
# service-level conservation with cancel in the ledger
# --------------------------------------------------------------------------
class TestCancelLedger:
    def test_conserves_through_mixed_outcomes(self, graph):
        from repro.serving import WalkQuery
        svc = make_service(graph, slots=2, epoch_len=1)
        tickets = [svc.submit(WalkQuery(start=s)).ticket
                   for s in (1, 2, 3, 4, 5, 6)]
        svc.step()
        assert svc.cancel(tickets[0]) is not None  # in flight
        assert svc.cancel(tickets[5]) is not None  # pending
        assert svc.cancel(tickets[5]) is None      # already gone
        svc.drain()
        st_ = svc.stats()
        assert st_.conserves(), st_
        assert st_.cancelled == 2 and st_.completed == 4


# --------------------------------------------------------------------------
# DeficitRoundRobin — property tests over random schedules
# --------------------------------------------------------------------------
def drive_drr(quantum, weights, costs, rounds):
    """Simulate `rounds` all-busy DRR rounds; per-epoch costs drawn from
    the `costs` list (cycled).  Returns (drr, served steps per tenant,
    per-round service map)."""
    drr = DeficitRoundRobin(quantum=quantum)
    names = [f"t{i}" for i in range(len(weights))]
    for n, w in zip(names, weights):
        drr.register(n, w)
    ci = 0
    history = []
    for _ in range(rounds):
        drr.begin_round(names)
        ran = set()
        for n in names:
            while drr.runnable(n):
                cost = costs[ci % len(costs)]
                ci += 1
                drr.charge(n, cost)
                ran.add(n)
        if not ran:  # the service's work-conservation backstop
            n = drr.pick(names)
            cost = costs[ci % len(costs)]
            ci += 1
            drr.charge(n, cost)
            ran.add(n)
        history.append(ran)
    return drr, {n: drr.charged(n) for n in names}, history


class TestDRRProperties:
    @given(st.lists(st.integers(min_value=1, max_value=40), min_size=1,
                    max_size=24),
           st.integers(min_value=1, max_value=32),
           st.lists(st.floats(min_value=0.25, max_value=8.0,
                              allow_nan=False), min_size=2, max_size=4))
    @settings(max_examples=60, deadline=None)
    def test_work_conservation_and_ledger_exact(self, costs, quantum,
                                                weights):
        drr, served, history = drive_drr(quantum, weights, costs, 50)
        # work conservation: every all-busy round serves someone
        assert all(len(r) > 0 for r in history)
        # the ledger is exact: charges sum to what was served
        total = sum(served.values())
        assert total > 0
        # deficit never overdrawn by more than one epoch's max cost
        for n in served:
            assert drr.deficit(n) > -max(costs) - 1e-9

    @given(st.integers(min_value=1, max_value=6),
           st.integers(min_value=1, max_value=16),
           st.lists(st.floats(min_value=0.5, max_value=4.0,
                              allow_nan=False), min_size=2, max_size=3))
    @settings(max_examples=40, deadline=None)
    def test_weighted_shares_exact_drr_bound(self, max_cost, quantum,
                                             weights):
        """The classic DRR fairness bound, exactly: under saturation a
        tenant's deficit is always in (-max_cost, 0] after its serving
        turn, so after R rounds

            R*quantum*w  <=  served  <  R*quantum*w + max_cost

        — i.e. walker-step shares match the weight ratio to within one
        epoch's cost, independent of R."""
        rounds = 200
        costs = [(i % max_cost) + 1 for i in range(17)]
        _, served, _ = drive_drr(quantum, weights, costs, rounds)
        for n, w in zip(sorted(served), weights):
            credit = rounds * quantum * w
            assert credit - 1e-6 <= served[n] < credit + max_cost + 1e-6

    @given(st.integers(min_value=1, max_value=8),
           st.floats(min_value=0.5, max_value=4.0, allow_nan=False))
    @settings(max_examples=40, deadline=None)
    def test_no_starvation(self, max_cost, min_weight):
        """A busy tenant is served at least once every
        ceil(max_cost / (quantum * weight)) + 1 rounds."""
        quantum = 4
        weights = [min_weight, 4.0]
        costs = [(i * 3) % max_cost + 1 for i in range(13)]
        _, _, history = drive_drr(quantum, weights, costs, 120)
        bound = math.ceil(max_cost / (quantum * min_weight)) + 1
        gap = 0
        for r in history:
            gap = 0 if "t0" in r else gap + 1
            assert gap <= bound, (gap, bound)

    def test_validation(self):
        with pytest.raises(ValueError):
            DeficitRoundRobin(quantum=0)
        with pytest.raises(ValueError):
            DeficitRoundRobin(quantum=4, cap=0.5)
        drr = DeficitRoundRobin(quantum=4)
        with pytest.raises(ValueError):
            drr.register("t", weight=0.0)
        drr.register("t")
        with pytest.raises(ValueError):
            drr.charge("t", -1)

    def test_rollover_capped(self):
        drr = DeficitRoundRobin(quantum=10, cap=2.0)
        drr.register("t", 1.0)
        for _ in range(50):
            drr.begin_round(["t"])
        assert drr.deficit("t") == 20.0  # 2 quanta banked, not 50
