"""Optional-hypothesis shim: property tests skip, everything else runs.

``from _hypothesis_compat import given, settings, st`` behaves exactly
like the real hypothesis when it is installed; without it, ``@given``
turns the decorated test into a skip (instead of the whole module
failing at collection or being skipped wholesale).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(
            reason="property test needs hypothesis "
                   "(pip install -r requirements-dev.txt)")

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """st.floats(...)/st.integers(...) placeholders; never executed."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
