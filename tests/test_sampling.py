"""Statistical correctness of every sampling method + engine behaviour:
sampler-registry resolution, chi-square equivalence of each registered
sampler against the exact transition distribution, and the streaming
epoch scheduler (refill, pad-lane masking, batch invariance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CostModel, EngineConfig, METHODS, Sampler,
                        SamplerCaps, Selection, WalkEngine, WalkerState,
                        analyze, available_samplers, get_sampler,
                        register_sampler, BoundInputs, exact_probs)
from repro.core.baselines import (als_step, its_step, rjs_maxreduce_step,
                                  rvs_prefix_step)
from repro.core.erjs import erjs_step
from repro.core.ervs import ervs_jump_step, ervs_step
from repro.core.ctxutil import degrees_of
from repro.graphs import node_stats, random_graph
from repro.walks import deepwalk, node2vec, second_order_pagerank

N = 3000
PAD = 64


@pytest.fixture(scope="module")
def setup():
    g = random_graph(60, 6, seed=3)
    wl = node2vec()
    params = wl.params()
    v, pv, st = 7, 3, 2
    p, nbr = exact_probs(g, wl, params, v, pv, st, pad=PAD)
    cur = jnp.full((N,), v, jnp.int32)
    prev = jnp.full((N,), pv, jnp.int32)
    step = jnp.full((N,), st, jnp.int32)
    rng = jax.random.split(jax.random.key(0), N)
    return g, wl, params, p, nbr, cur, prev, step, rng


def tvd(samples, p, nbr):
    f = np.zeros_like(p)
    for i, n_ in enumerate(nbr):
        if n_ >= 0:
            f[i] = np.sum(samples == n_)
    f = f / max(len(samples), 1)
    return 0.5 * np.abs(f - p)[nbr >= 0].sum()

# TVD guard: for ~15 categories at N=3000, E[TVD] ≈ 0.02; 0.06 is ~3σ.
TVD_MAX = 0.06


class TestDistributions:
    def test_ervs(self, setup):
        g, wl, params, p, nbr, cur, prev, step, rng = setup
        out = np.asarray(ervs_step(g, wl, params, cur, prev, step, rng,
                                   tile=32, max_tiles=4))
        assert tvd(out, p, nbr) < TVD_MAX

    def test_ervs_jump(self, setup):
        g, wl, params, p, nbr, cur, prev, step, rng = setup
        out, _ = ervs_jump_step(g, wl, params, cur, prev, step, rng,
                                tile=32, max_tiles=4)
        assert tvd(np.asarray(out), p, nbr) < TVD_MAX

    def test_erjs_with_compiler_bound(self, setup):
        g, wl, params, p, nbr, cur, prev, step, rng = setup
        stats = node_stats(g)
        comp = analyze(wl)
        bi = BoundInputs(h_min=stats.h_min[cur], h_max=stats.h_max[cur],
                         h_mean=stats.h_mean[cur],
                         deg_cur=degrees_of(g, cur),
                         deg_prev=degrees_of(g, prev),
                         cur=cur, prev=prev, step=step)
        _, bmax = jax.vmap(comp.bound_fn)(bi)
        nxt, fb, _ = erjs_step(g, wl, params, cur, prev, step, rng, bmax,
                               max_rounds=32)
        out = np.asarray(nxt)[~np.asarray(fb)]
        assert len(out) > 0.9 * N  # bound tight enough to mostly accept
        assert tvd(out, p, nbr) < TVD_MAX

    @pytest.mark.parametrize("fn", [its_step, als_step, rvs_prefix_step,
                                    rjs_maxreduce_step])
    def test_baselines(self, setup, fn):
        g, wl, params, p, nbr, cur, prev, step, rng = setup
        out = np.asarray(fn(g, wl, params, cur, prev, step, rng, pad=PAD))
        assert tvd(out, p, nbr) < TVD_MAX


class TestEngine:
    @pytest.mark.parametrize("method", ["adaptive", "ervs", "erjs", "its",
                                        "als", "rvs_prefix",
                                        "rjs_maxreduce", "random", "degree",
                                        "its_precomp", "alias_precomp",
                                        "interleaved"])
    def test_walks_stay_on_graph(self, method):
        g = random_graph(200, 8, seed=1)
        eng = WalkEngine(g, node2vec(), EngineConfig(method=method, tile=64))
        res = eng.run(np.arange(48), num_steps=6)
        paths = res.paths
        assert paths.shape == (48, 7)
        indptr = np.asarray(g.indptr)
        indices = np.asarray(g.indices)
        for q in range(0, 48, 7):
            for t in range(6):
                a, b = paths[q, t], paths[q, t + 1]
                if b < 0:
                    break
                assert b in indices[indptr[a]:indptr[a + 1]], \
                    f"{method}: {a}->{b} is not an edge"

    def test_all_methods_agree_statistically(self):
        """End-to-end: step-1 visit distribution similar across methods."""
        g = random_graph(100, 8, seed=5)
        dists = {}
        for method in ["ervs", "its", "adaptive"]:
            eng = WalkEngine(g, deepwalk(),
                             EngineConfig(method=method, tile=64))
            res = eng.run(np.zeros(2000, np.int32), num_steps=1,
                          key=jax.random.key(7))
            dists[method] = np.bincount(res.paths[:, 1], minlength=100) / 2000
        for m in ["its", "adaptive"]:
            d = 0.5 * np.abs(dists[m] - dists["ervs"]).sum()
            assert d < 0.08, f"{m} vs ervs TVD={d}"

    def test_2ndpr_and_metapath_run(self):
        from repro.walks import metapath
        g = random_graph(150, 6, seed=2)
        for wl in [second_order_pagerank(), metapath()]:
            eng = WalkEngine(g, wl, EngineConfig(method="adaptive", tile=64))
            res = eng.run(np.arange(32), num_steps=5)
            assert res.paths.shape == (32, 6)

    def test_cost_model_prefers_rvs_under_skew(self):
        cm = CostModel(edge_cost_ratio=4.0)
        deg = jnp.full((4,), 100, jnp.int32)
        # uniform-ish weights: sum ≈ deg·mean ≫ ratio·max ⇒ RJS
        assert bool(cm.prefer_rjs(jnp.float32(5.0)[None],
                                  jnp.float32(300.0)[None], deg[:1])[0])
        # heavy skew: ratio·max > sum ⇒ RVS
        assert not bool(cm.prefer_rjs(jnp.float32(100.0)[None],
                                      jnp.float32(300.0)[None], deg[:1])[0])


# ---------------------------------------------------------------- registry
def chi2_critical(df: int, z: float = 3.7) -> float:
    """Wilson–Hilferty upper-tail chi-square quantile (z=3.7 ≈ p 1e-4)."""
    a = 2.0 / (9.0 * df)
    return df * (1.0 - a + z * np.sqrt(a)) ** 3


class _UniformTestSampler(Sampler):
    """Degree-uniform proposal — a minimal user-defined strategy."""

    name = "test_uniform"
    caps = SamplerCaps(supports_partition=True)

    def select(self, ctx, state, rng, *, active):
        from repro.core.ctxutil import degrees_of
        deg = degrees_of(ctx.graph, state.cur)
        u = jax.vmap(lambda k: jax.random.uniform(k, ()))(rng)
        off = jnp.minimum((u * deg).astype(jnp.int32),
                          jnp.maximum(deg - 1, 0))
        pos = jnp.clip(ctx.graph.indptr[state.cur] + off, 0,
                       ctx.graph.num_edges - 1)
        nxt = jnp.where(deg > 0, ctx.graph.indices[pos], -1)
        zero = jnp.int32(0)
        return Selection(next_nodes=jnp.where(active, nxt, -1),
                         rjs_served=zero, fallbacks=zero)


class TestSamplerRegistry:
    def test_methods_snapshot_matches_registry(self):
        """METHODS is a sorted snapshot of the built-in registry; the
        registry (also sorted) may only grow around it."""
        assert METHODS == tuple(sorted(METHODS))
        assert set(METHODS) <= set(available_samplers())
        for name in METHODS:
            assert get_sampler(name).name == name

    def test_available_samplers_deterministic(self):
        assert available_samplers() == tuple(sorted(available_samplers()))
        assert available_samplers() == available_samplers()

    def test_new_strategies_registered(self):
        for name in ["its_precomp", "alias_precomp", "interleaved"]:
            assert name in available_samplers()

    def test_unknown_method_rejected(self):
        # EngineConfig itself validates, naming the known samplers
        with pytest.raises(ValueError, match="registered"):
            EngineConfig(method="nope")
        with pytest.raises(ValueError, match="adaptive"):
            EngineConfig(method="nope")
        with pytest.raises(KeyError):
            get_sampler("nope")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_sampler(get_sampler("ervs"))

    def test_custom_sampler_end_to_end(self):
        """A user-registered sampler runs via EngineConfig(method=name)."""
        from repro.core import samplers as samplers_mod
        register_sampler(_UniformTestSampler(), overwrite=True)
        try:
            g = random_graph(150, 8, seed=4)
            eng = WalkEngine(g, deepwalk(),
                             EngineConfig(method="test_uniform", tile=64))
            res = eng.run(np.arange(24), num_steps=5, batch=7)
        finally:
            del samplers_mod._REGISTRY["test_uniform"]
        assert res.paths.shape == (24, 6)
        indptr, indices = np.asarray(g.indptr), np.asarray(g.indices)
        for q in range(24):
            for t in range(5):
                a, b = res.paths[q, t], res.paths[q, t + 1]
                if b < 0:
                    break
                assert b in indices[indptr[a]:indptr[a + 1]]

    @pytest.mark.parametrize("name", METHODS)
    def test_chi_square_equivalence(self, name, setup):
        """Each registered sampler's one-step draw matches exact_probs."""
        g, wl, params, p, nbr, cur, prev, step, rng = setup
        eng = WalkEngine(g, wl, EngineConfig(method=name, tile=32))
        state = WalkerState(
            cur=cur, prev=prev, step=step,
            alive=jnp.ones((N,), bool),
            rng=jax.random.key_data(rng),
        )
        sel = eng.sampler.select(eng.sampler_ctx, state, rng,
                                 active=jnp.ones((N,), bool))
        out = np.asarray(sel.next_nodes)
        support = nbr[(nbr >= 0) & (p > 0)]
        probs = p[(nbr >= 0) & (p > 0)]
        assert np.isin(out, support).all(), \
            f"{name}: sampled outside the support: {set(out) - set(support)}"
        counts = np.array([(out == v).sum() for v in support])
        expected = probs * N
        chi2 = float(((counts - expected) ** 2 / expected).sum())
        crit = chi2_critical(len(support) - 1)
        assert chi2 < crit, f"{name}: chi2={chi2:.1f} ≥ crit={crit:.1f}"


# ------------------------------------------------- streaming epoch scheduler
class TestStreamingScheduler:
    def test_batch_invariance_non_multiple(self):
        """13 queries through 4 slots ≡ 13 queries at once, bit-for-bit —
        streams are keyed per query, refills happen at epoch boundaries,
        and pad/dead lanes never contribute to paths or telemetry."""
        g = random_graph(200, 8, seed=1)
        eng = WalkEngine(g, node2vec(), EngineConfig(method="adaptive",
                                                     tile=64))
        full = eng.run(np.arange(13), num_steps=9, key=jax.random.key(3))
        slotted = eng.run(np.arange(13), num_steps=9, key=jax.random.key(3),
                          batch=4, epoch_len=2)
        np.testing.assert_array_equal(full.paths, slotted.paths)
        assert full.live_steps == slotted.live_steps == 13 * 9
        assert full.frac_rjs == slotted.frac_rjs
        assert full.rjs_fallbacks == slotted.rjs_fallbacks

    def test_tail_epoch_telemetry_unskewed(self):
        """5 queries through 2 slots leaves a 1-walker tail epoch; the
        idle slot must not dilute frac_rjs (the old pad-the-tail chunking
        averaged node-0 pad walkers into it)."""
        g = random_graph(120, 8, seed=2)
        eng = WalkEngine(g, node2vec(), EngineConfig(method="erjs", tile=64))
        full = eng.run(np.arange(5), num_steps=6, key=jax.random.key(1))
        slotted = eng.run(np.arange(5), num_steps=6, key=jax.random.key(1),
                          batch=2)
        assert slotted.live_steps == full.live_steps == 5 * 6
        assert slotted.frac_rjs == full.frac_rjs > 0.5
        # all live steps are accounted for by emitted path entries
        assert (slotted.paths[:, 1:] >= 0).sum() == slotted.live_steps

    def test_early_death_slots_are_refilled(self):
        """metapath walks can dead-end early; their slots must be handed
        to queued queries and dead lanes must stop counting."""
        from repro.walks import metapath
        g = random_graph(150, 6, seed=2)
        eng = WalkEngine(g, metapath(), EngineConfig(method="adaptive",
                                                     tile=64))
        full = eng.run(np.arange(31), num_steps=5, key=jax.random.key(2))
        slotted = eng.run(np.arange(31), num_steps=5,
                          key=jax.random.key(2), batch=8, epoch_len=1)
        np.testing.assert_array_equal(full.paths, slotted.paths)
        assert full.live_steps == slotted.live_steps
        # dead lanes excluded: live steps == emitted entries + dead-end
        # attempts, both bounded by Q × L and < Q × L when walks die early
        assert slotted.live_steps <= 31 * 5
        assert (slotted.paths[:, 1:] >= 0).sum() <= slotted.live_steps

    def test_zero_queries(self):
        g = random_graph(50, 4, seed=0)
        eng = WalkEngine(g, deepwalk(), EngineConfig(method="ervs", tile=64))
        res = eng.run(np.zeros((0,), np.int32), num_steps=4)
        assert res.paths.shape == (0, 5)
        assert res.live_steps == 0 and res.frac_rjs == 0.0

    def test_walk_batch_matches_run(self):
        """walk_batch (the sharded entry point) agrees with run() when
        query order equals slot order."""
        g = random_graph(100, 8, seed=5)
        eng = WalkEngine(g, deepwalk(), EngineConfig(method="ervs", tile=64))
        starts = np.arange(16, dtype=np.int32)
        key = jax.random.key(9)
        paths_b, stats = eng.walk_batch(starts, key, 6)
        res = eng.run(starts, num_steps=6, key=key)
        np.testing.assert_array_equal(np.asarray(paths_b), res.paths[:, 1:])
        assert int(np.asarray(stats.live).sum()) == res.live_steps
