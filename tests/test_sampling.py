"""Statistical correctness of every sampling method + engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CostModel, EngineConfig, WalkEngine, analyze,
                        BoundInputs, exact_probs)
from repro.core.baselines import (als_step, its_step, rjs_maxreduce_step,
                                  rvs_prefix_step)
from repro.core.erjs import erjs_step
from repro.core.ervs import ervs_jump_step, ervs_step
from repro.core.ctxutil import degrees_of
from repro.graphs import node_stats, random_graph
from repro.walks import deepwalk, node2vec, second_order_pagerank

N = 3000
PAD = 64


@pytest.fixture(scope="module")
def setup():
    g = random_graph(60, 6, seed=3)
    wl = node2vec()
    params = wl.params()
    v, pv, st = 7, 3, 2
    p, nbr = exact_probs(g, wl, params, v, pv, st, pad=PAD)
    cur = jnp.full((N,), v, jnp.int32)
    prev = jnp.full((N,), pv, jnp.int32)
    step = jnp.full((N,), st, jnp.int32)
    rng = jax.random.split(jax.random.key(0), N)
    return g, wl, params, p, nbr, cur, prev, step, rng


def tvd(samples, p, nbr):
    f = np.zeros_like(p)
    for i, n_ in enumerate(nbr):
        if n_ >= 0:
            f[i] = np.sum(samples == n_)
    f = f / max(len(samples), 1)
    return 0.5 * np.abs(f - p)[nbr >= 0].sum()

# TVD guard: for ~15 categories at N=3000, E[TVD] ≈ 0.02; 0.06 is ~3σ.
TVD_MAX = 0.06


class TestDistributions:
    def test_ervs(self, setup):
        g, wl, params, p, nbr, cur, prev, step, rng = setup
        out = np.asarray(ervs_step(g, wl, params, cur, prev, step, rng,
                                   tile=32, max_tiles=4))
        assert tvd(out, p, nbr) < TVD_MAX

    def test_ervs_jump(self, setup):
        g, wl, params, p, nbr, cur, prev, step, rng = setup
        out, _ = ervs_jump_step(g, wl, params, cur, prev, step, rng,
                                tile=32, max_tiles=4)
        assert tvd(np.asarray(out), p, nbr) < TVD_MAX

    def test_erjs_with_compiler_bound(self, setup):
        g, wl, params, p, nbr, cur, prev, step, rng = setup
        stats = node_stats(g)
        comp = analyze(wl)
        bi = BoundInputs(h_min=stats.h_min[cur], h_max=stats.h_max[cur],
                         h_mean=stats.h_mean[cur],
                         deg_cur=degrees_of(g, cur),
                         deg_prev=degrees_of(g, prev),
                         cur=cur, prev=prev, step=step)
        _, bmax = jax.vmap(comp.bound_fn)(bi)
        nxt, fb, _ = erjs_step(g, wl, params, cur, prev, step, rng, bmax,
                               max_rounds=32)
        out = np.asarray(nxt)[~np.asarray(fb)]
        assert len(out) > 0.9 * N  # bound tight enough to mostly accept
        assert tvd(out, p, nbr) < TVD_MAX

    @pytest.mark.parametrize("fn", [its_step, als_step, rvs_prefix_step,
                                    rjs_maxreduce_step])
    def test_baselines(self, setup, fn):
        g, wl, params, p, nbr, cur, prev, step, rng = setup
        out = np.asarray(fn(g, wl, params, cur, prev, step, rng, pad=PAD))
        assert tvd(out, p, nbr) < TVD_MAX


class TestEngine:
    @pytest.mark.parametrize("method", ["adaptive", "ervs", "erjs", "its",
                                        "als", "rvs_prefix",
                                        "rjs_maxreduce", "random", "degree"])
    def test_walks_stay_on_graph(self, method):
        g = random_graph(200, 8, seed=1)
        eng = WalkEngine(g, node2vec(), EngineConfig(method=method, tile=64))
        res = eng.run(np.arange(48), num_steps=6)
        paths = res.paths
        assert paths.shape == (48, 7)
        indptr = np.asarray(g.indptr)
        indices = np.asarray(g.indices)
        for q in range(0, 48, 7):
            for t in range(6):
                a, b = paths[q, t], paths[q, t + 1]
                if b < 0:
                    break
                assert b in indices[indptr[a]:indptr[a + 1]], \
                    f"{method}: {a}->{b} is not an edge"

    def test_all_methods_agree_statistically(self):
        """End-to-end: step-1 visit distribution similar across methods."""
        g = random_graph(100, 8, seed=5)
        dists = {}
        for method in ["ervs", "its", "adaptive"]:
            eng = WalkEngine(g, deepwalk(),
                             EngineConfig(method=method, tile=64))
            res = eng.run(np.zeros(2000, np.int32), num_steps=1,
                          key=jax.random.key(7))
            dists[method] = np.bincount(res.paths[:, 1], minlength=100) / 2000
        for m in ["its", "adaptive"]:
            d = 0.5 * np.abs(dists[m] - dists["ervs"]).sum()
            assert d < 0.08, f"{m} vs ervs TVD={d}"

    def test_2ndpr_and_metapath_run(self):
        from repro.walks import metapath
        g = random_graph(150, 6, seed=2)
        for wl in [second_order_pagerank(), metapath()]:
            eng = WalkEngine(g, wl, EngineConfig(method="adaptive", tile=64))
            res = eng.run(np.arange(32), num_steps=5)
            assert res.paths.shape == (32, 6)

    def test_cost_model_prefers_rvs_under_skew(self):
        cm = CostModel(edge_cost_ratio=4.0)
        deg = jnp.full((4,), 100, jnp.int32)
        # uniform-ish weights: sum ≈ deg·mean ≫ ratio·max ⇒ RJS
        assert bool(cm.prefer_rjs(jnp.float32(5.0)[None],
                                  jnp.float32(300.0)[None], deg[:1])[0])
        # heavy skew: ratio·max > sum ⇒ RVS
        assert not bool(cm.prefer_rjs(jnp.float32(100.0)[None],
                                      jnp.float32(300.0)[None], deg[:1])[0])
