"""Mega-step kernel suite: the fused Pallas epoch vs the staged scan.

Three layers, mirroring how the feature is built:

* the Flexi-Compiler's ``fuse_report`` classifies every registered
  workload (which cells MAY fuse, and why the others may not);
* ``Sampler.fused_kind`` maps samplers onto kernel regimes;
* the fused epoch itself is bit-identical to the staged epoch — paths,
  end state, per-walker program state and every StepStats counter — for
  each regime (reservoir / rejection / ITS / alias), including stale
  table rows (in-kernel reservoir fallback) and WalkProgram hooks.

Everything runs in Pallas interpret mode on CPU (``default_interpret``),
which is the same code path the TPU build compiles.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, WalkEngine
from repro.core import flexi_compiler as fc
from repro.core.samplers import get_sampler
from repro.core.types import StepStats
from repro.graphs import random_graph
from repro.walks import WORKLOADS, deepwalk, make_workload, ppr_nibble

TILE = 32
STEPS = 8


@pytest.fixture(scope="module")
def graph():
    return random_graph(60, 6, weight_dist="uniform", seed=3)


def run_both(graph, wl, method, key=0, steps=STEPS, mutate=None):
    """(staged result, fused result) of identical runs; asserts the fused
    engine genuinely resolved the fused path."""
    st = WalkEngine(graph, wl,
                    EngineConfig(method=method, tile=TILE,
                                 step_exec="staged"))
    fu = WalkEngine(graph, wl,
                    EngineConfig(method=method, tile=TILE,
                                 step_exec="fused"))
    assert fu.step_exec_resolved == "fused", fu.fuse.reasons
    if mutate is not None:
        mutate(st)
        mutate(fu)
    starts = np.arange(11) % graph.num_nodes
    a = st.run(starts, num_steps=steps, key=jax.random.key(key))
    b = fu.run(starts, num_steps=steps, key=jax.random.key(key))
    return a, b


def assert_identical(a, b):
    np.testing.assert_array_equal(a.paths, b.paths)
    for f in ("frac_rjs", "frac_precomp", "frac_stale", "rjs_fallbacks",
              "live_steps", "rebuilt_rows"):
        assert getattr(a, f) == getattr(b, f), f


# ------------------------------------------------------- fusability report
FUSABLE = {"deepwalk", "ppr_nibble"}


class TestFuseReport:
    @pytest.mark.parametrize("name", sorted(WORKLOADS))
    def test_registered_workloads_classified(self, name):
        rep = fc.fuse_report(make_workload(name))
        assert rep.fusable == (name in FUSABLE)
        if not rep.fusable:
            # rejection reasons are actionable strings, not bare flags
            assert rep.reasons and all(isinstance(r, str) and r
                                       for r in rep.reasons)

    def test_node_local_bound_certified_for_static_program(self):
        rep = fc.fuse_report(deepwalk())
        assert rep.weight_fusable and rep.hooks_fusable
        assert rep.bound_node_local

    def test_dist_tainted_bound_not_node_local(self):
        rep = fc.fuse_report(make_workload("node2vec"))
        assert not rep.bound_node_local


class TestFusedKindMapping:
    def test_reservoir_and_precomp_kinds(self):
        assert get_sampler("ervs").fused_kind(
            usable=True, has_precomp=False) == "reservoir"
        assert get_sampler("its_precomp").fused_kind(
            usable=True, has_precomp=True) == "precomp_its"
        assert get_sampler("alias_precomp").fused_kind(
            usable=True, has_precomp=True) == "precomp_alias"
        # no tables baked (non-static program): permanently eRVS = reservoir
        assert get_sampler("its_precomp").fused_kind(
            usable=True, has_precomp=False) == "reservoir"

    def test_rejection_needs_usable_bound(self):
        assert get_sampler("erjs").fused_kind(
            usable=True, has_precomp=False) == "rejection"
        # no usable bound: always_policy routes every lane to eRVS
        assert get_sampler("erjs").fused_kind(
            usable=False, has_precomp=False) == "reservoir"

    @pytest.mark.parametrize("name", ["adaptive", "ervs_jump", "interleaved",
                                      "random", "degree"])
    def test_unfusable_samplers_stay_staged(self, name):
        assert get_sampler(name).fused_kind(
            usable=True, has_precomp=True) is None


# ----------------------------------------------------- regime bit-identity
class TestFusedBitIdentity:
    @pytest.mark.parametrize("method", ["ervs", "erjs", "its_precomp",
                                        "alias_precomp"])
    def test_fused_matches_staged(self, method, graph):
        a, b = run_both(graph, deepwalk(), method)
        assert_identical(a, b)

    @pytest.mark.parametrize("method", ["its_precomp", "alias_precomp"])
    def test_stale_rows_fall_back_in_kernel(self, method, graph):
        """Invalidated table rows take the kernel's reservoir fallback —
        same draw the staged eRVS fallback makes, counted as stale."""
        h2 = jnp.asarray(np.asarray(graph.h) * 1.7)
        g2 = dataclasses.replace(graph, h=h2)
        bad = np.arange(0, graph.num_nodes, 3)

        def mutate(eng):
            eng.update_graph(g2, invalidated=bad)

        a, b = run_both(graph, deepwalk(), method, mutate=mutate)
        assert_identical(a, b)
        assert a.frac_stale > 0  # the fallback actually exercised
        assert a.rebuilt_rows > 0  # ... and the drains ran under fused too

    def test_hooks_and_wstate(self, graph):
        """on_step commits + should_stop terminations inside the kernel
        match the staged hook machinery (ppr_nibble stops walkers early)."""
        a, b = run_both(graph, ppr_nibble(), "ervs", steps=12)
        assert_identical(a, b)
        lens = (a.paths[:, 1:] >= 0).sum(axis=1)
        assert (lens < 12).any(), "fixture never stopped a walker early"

    def test_walk_batch_parity(self, graph):
        wl = deepwalk()
        st = WalkEngine(graph, wl, EngineConfig(method="ervs", tile=TILE,
                                                step_exec="staged"))
        fu = WalkEngine(graph, wl, EngineConfig(method="ervs", tile=TILE,
                                                step_exec="fused"))
        starts = np.arange(8) % graph.num_nodes
        pa, sa = st.walk_batch(starts, jax.random.key(4), num_steps=6)
        pb, sb = fu.walk_batch(starts, jax.random.key(4), num_steps=6)
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
        for f in ("live", "rjs_served", "fallbacks", "precomp_served",
                  "stale_served"):
            np.testing.assert_array_equal(np.asarray(getattr(sa, f)),
                                          np.asarray(getattr(sb, f)))


# -------------------------------------------------------------- resolution
class TestStepExecResolution:
    def test_staged_never_builds_the_kernel(self, graph):
        eng = WalkEngine(graph, deepwalk(),
                         EngineConfig(method="ervs", tile=TILE,
                                      step_exec="staged"))
        assert eng.step_exec_resolved == "staged"
        assert eng._fused_epoch_fn is None

    def test_non_fusable_program_falls_back_cleanly(self, graph):
        """step_exec='fused' on a non-fusable cell keeps the staged scan
        (no error) and produces the staged results."""
        wl = make_workload("node2vec")
        fb = WalkEngine(graph, wl, EngineConfig(method="ervs", tile=TILE,
                                                step_exec="fused"))
        assert fb.step_exec_resolved == "staged"
        st = WalkEngine(graph, wl, EngineConfig(method="ervs", tile=TILE,
                                                step_exec="staged"))
        starts = np.arange(9) % graph.num_nodes
        a = st.run(starts, num_steps=5, key=jax.random.key(1))
        b = fb.run(starts, num_steps=5, key=jax.random.key(1))
        assert_identical(a, b)

    def test_non_node_local_bound_keeps_rejection_staged(self, graph):
        # visited_avoiding's bound needs wstate → no baked per-node table;
        # the plan must not silently downgrade rejection to reservoir
        wl = make_workload("visited_avoiding")
        eng = WalkEngine(graph, wl, EngineConfig(method="erjs", tile=TILE,
                                                 step_exec="fused"))
        assert eng.step_exec_resolved == "staged"

    def test_auto_is_staged_off_tpu(self, graph):
        if jax.default_backend() == "tpu":
            pytest.skip("auto resolves fused on TPU by design")
        eng = WalkEngine(graph, deepwalk(),
                         EngineConfig(method="ervs", tile=TILE))
        assert eng.step_exec_resolved == "staged"

    def test_odd_tile_geometry_keeps_staged(self, graph):
        eng = WalkEngine(graph, deepwalk(),
                         EngineConfig(method="ervs", tile=17,
                                      step_exec="fused"))
        assert eng.step_exec_resolved == "staged"

    def test_config_validation(self):
        with pytest.raises(ValueError, match="step_exec"):
            EngineConfig(step_exec="warp")
        with pytest.raises(ValueError, match="rebuild_interval"):
            EngineConfig(rebuild_interval=0)


# ------------------------------------------------------- kernel-level API
class TestKernelValidation:
    def test_bad_kind_rejected(self, graph):
        from repro.kernels.megastep_kernel import make_fused_epoch
        with pytest.raises(ValueError, match="kind"):
            make_fused_epoch(graph, deepwalk(), deepwalk().params(),
                             kind="gibbs", tile=TILE, max_tiles=4)

    def test_bad_tile_rejected(self, graph):
        from repro.kernels.megastep_kernel import make_fused_epoch
        with pytest.raises(ValueError, match="tile"):
            make_fused_epoch(graph, deepwalk(), deepwalk().params(),
                             kind="reservoir", tile=17, max_tiles=4)

    def test_rejection_requires_bmax(self, graph):
        from repro.kernels.megastep_kernel import make_fused_epoch
        with pytest.raises(ValueError, match="bmax"):
            make_fused_epoch(graph, deepwalk(), deepwalk().params(),
                             kind="rejection", tile=TILE, max_tiles=4)

    def test_precomp_kind_requires_aligned_tables(self, graph):
        from repro.core import precomp as precomp_mod
        from repro.core.types import WalkerState
        from repro.kernels.megastep_kernel import make_fused_epoch
        wl = deepwalk()
        tables = precomp_mod.build_tables(graph, wl, wl.params(),
                                          aligned=False)
        epoch = make_fused_epoch(graph, wl, wl.params(), kind="precomp_its",
                                 tile=TILE, max_tiles=4)
        state = WalkerState.create(jnp.arange(4, dtype=jnp.int32),
                                   jax.random.key(0),
                                   wstate=wl.init_wstate_batch(
                                       jnp.arange(4, dtype=jnp.int32)))
        with pytest.raises(ValueError, match="aligned"):
            epoch(state, tables, epoch_len=2, num_steps=2)

    def test_flag_bits_reduce_to_stats(self):
        flags = jnp.asarray([[0b00001, 0b00011],
                             [0b00000, 0b01001],
                             [0b10001, 0b00101]], jnp.int32)  # [W=3, T=2]
        s = StepStats.from_flag_bits(flags)
        np.testing.assert_array_equal(np.asarray(s.live), [2, 3])
        np.testing.assert_array_equal(np.asarray(s.rjs_served), [0, 1])
        np.testing.assert_array_equal(np.asarray(s.fallbacks), [0, 1])
        np.testing.assert_array_equal(np.asarray(s.precomp_served), [0, 1])
        np.testing.assert_array_equal(np.asarray(s.stale_served), [1, 0])
