"""Data-pipeline ↔ engine wiring: the double-buffered walk producer.

``PrefetchIterator`` must be invisible in the stream (bit-identical to
the synchronous iterator — batches are pure functions of (seed, step))
while actually overlapping walk production with consumption, surfacing
producer errors at the right position, and shutting down cleanly.
"""
import itertools
import threading
import time

import numpy as np
import pytest

from repro.core import EngineConfig
from repro.data import (DataConfig, PrefetchIterator, WalkCorpus,
                        walk_corpus_batches, walk_corpus_batches_prefetched)
from repro.graphs import random_graph
from repro.walks import deepwalk


@pytest.fixture(scope="module")
def corpus():
    g = random_graph(80, 6, weight_dist="uniform", seed=5)
    return WalkCorpus(g, deepwalk(), walk_len=8,
                      engine_config=EngineConfig(tile=32))


class TestPrefetchEqualsSynchronous:
    def test_walk_batches_bit_identical(self, corpus):
        """The headline wiring contract: producer epochs overlapping
        consumer steps change nothing — the prefetched stream equals the
        synchronous one exactly, batch for batch."""
        dcfg = DataConfig(batch_size=4, seq_len=16, seed=3)
        sync = list(itertools.islice(
            walk_corpus_batches(corpus, dcfg), 5))
        with walk_corpus_batches_prefetched(corpus, dcfg) as pre:
            fetched = list(itertools.islice(pre, 5))
        assert len(fetched) == len(sync)
        for a, b in zip(sync, fetched):
            np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                          np.asarray(b["tokens"]))
            np.testing.assert_array_equal(np.asarray(a["labels"]),
                                          np.asarray(b["labels"]))

    def test_resume_from_start_step(self, corpus):
        """Restart replays: start_step=k yields the synchronous stream's
        k-th batch first (the checkpoint-resume path)."""
        dcfg = DataConfig(batch_size=2, seq_len=8, seed=1)
        sync = list(itertools.islice(
            walk_corpus_batches(corpus, dcfg), 4))
        with walk_corpus_batches_prefetched(corpus, dcfg,
                                            start_step=2) as pre:
            got = next(pre)
        np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                      np.asarray(sync[2]["tokens"]))


class TestPrefetchOverlap:
    def wait_for(self, cond, timeout=5.0):
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if cond():
                return True
            time.sleep(0.005)
        return False

    def test_producer_runs_ahead_of_consumer(self):
        """Double buffering means the producer materialises batch k+1
        (and fills the buffer) while the consumer still holds batch k —
        ``produced`` outruns consumption by up to depth + 1."""
        events = []

        def slow_source():
            for i in itertools.count():
                events.append(("produce", i))
                yield i

        pre = PrefetchIterator(slow_source(), depth=2)
        try:
            # before ANY consumption, the buffer fills to depth + 1 in
            # hand: production genuinely overlapped the consumer's idle
            assert self.wait_for(lambda: pre.produced >= 3)
            first = next(pre)
            assert first == 0
            # consuming one frees a slot; the producer immediately tops
            # the buffer back up without waiting to be asked
            assert self.wait_for(lambda: pre.produced >= 4)
            assert [e for e in events[:3]] == [("produce", 0),
                                               ("produce", 1),
                                               ("produce", 2)]
        finally:
            pre.close()

    def test_overlap_with_real_walk_corpus(self, corpus):
        """With the actual engine as producer: by the time the consumer
        finishes batch 0, batch 1 is already walked."""
        dcfg = DataConfig(batch_size=2, seq_len=8, seed=7)
        with walk_corpus_batches_prefetched(corpus, dcfg, depth=2) as pre:
            next(pre)
            assert self.wait_for(lambda: pre.produced >= 2)


class TestPrefetchLifecycle:
    def test_finite_source_stops_iteration(self):
        pre = PrefetchIterator(iter(range(3)), depth=2)
        assert list(pre) == [0, 1, 2]
        with pytest.raises(StopIteration):
            next(pre)  # terminal state is sticky

    def test_producer_error_surfaces_in_order(self):
        def broken():
            yield 0
            yield 1
            raise RuntimeError("walk engine fell over")

        pre = PrefetchIterator(broken(), depth=4)
        assert next(pre) == 0 and next(pre) == 1
        with pytest.raises(RuntimeError, match="fell over"):
            next(pre)

    def test_close_stops_blocked_producer(self):
        pre = PrefetchIterator(itertools.count(), depth=1)
        time.sleep(0.05)  # let the producer block on the full queue
        pre.close()
        assert not pre._thread.is_alive()

    def test_depth_validation(self):
        with pytest.raises(ValueError, match="depth"):
            PrefetchIterator(iter(()), depth=0)

    def test_threads_do_not_leak(self, corpus):
        before = threading.active_count()
        dcfg = DataConfig(batch_size=2, seq_len=8)
        with walk_corpus_batches_prefetched(corpus, dcfg) as pre:
            next(pre)
        assert threading.active_count() <= before
