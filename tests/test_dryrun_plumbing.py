"""Dry-run plumbing on a small forced-device mesh (subprocess): proves the
lower→compile→analyze pipeline works end to end without the 512-device
sweep (which is exercised by launch/dryrun.py itself)."""
import os
import subprocess
import sys

import pytest

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from repro.launch import dryrun

def small(*, multi_pod=False):
    if multi_pod:
        return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    return jax.make_mesh((2, 2), ("data", "model"))

dryrun.make_production_mesh = small
recs = []
for arch, shape, mp in [
    ("qwen3-0.6b", "train_4k", True),
    ("qwen3-0.6b", "decode_32k", False),
    ("mamba2-1.3b", "long_500k", False),
    ("qwen3-0.6b", "long_500k", False),  # must SKIP
]:
    r = dryrun.run_cell(arch, shape, multi_pod=mp, out_dir=None,
                        verbose=False)
    recs.append({k: r.get(k) for k in ("cell", "status", "dominant",
                                       "roofline_fraction")})
print("JSON:" + json.dumps(recs))
"""


@pytest.mark.slow
def test_dryrun_cells_on_toy_mesh():
    out = subprocess.run([sys.executable, "-c", CHILD],
                         capture_output=True, text=True, timeout=1200,
                         env={**os.environ, "PYTHONPATH": "src"})
    line = [l for l in out.stdout.splitlines() if l.startswith("JSON:")]
    assert line, out.stderr[-1000:]
    import json
    recs = json.loads(line[0][5:])
    by_cell = {r["cell"]: r for r in recs}
    assert by_cell["qwen3-0.6b__train_4k__2x16x16"]["status"] == "OK"
    assert by_cell["qwen3-0.6b__decode_32k__16x16"]["status"] == "OK"
    assert by_cell["mamba2-1.3b__long_500k__16x16"]["status"] == "OK"
    assert by_cell["qwen3-0.6b__long_500k__16x16"]["status"] == "SKIPPED"
    ok = [r for r in recs if r["status"] == "OK"]
    assert all(r["roofline_fraction"] >= 0 for r in ok)
