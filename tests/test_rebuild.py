"""Amortized rebuild-queue correctness.

Property tests (hypothesis, via the optional shim) over arbitrary
interleavings of ``update_graph``-style invalidations and budgeted drain
steps: the validity bitmap is never inconsistent with the table contents
(a row is pending in the queue iff its bit is stale), and a fully drained
queue restores sampling that is bit-identical to a fresh-build table.
Deterministic companion cases cover the same invariants when hypothesis
is not installed, plus the engine-level transient-fallback contract:
after ``update_graph`` invalidates rows, a bounded number of scheduler
epochs restores ``frac_stale`` to 0 — no permanent dynamic fallback.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (CostModel, EngineConfig, RebuildQueue, WalkEngine,
                        WalkerState, build_tables, exact_probs)
from repro.core.precomp import alias_select, its_select
from repro.graphs import random_graph
from repro.walks import deepwalk

V = 50
TABLE_FIELDS = ("cdf", "total", "alias_off", "alias_prob", "invalid",
                "cdf2d", "prob2d", "alias2d", "arow0")


def mutate_row(graph, node, salt):
    """New graph with node's edge weights rescaled (topology unchanged)."""
    indptr = np.asarray(graph.indptr)
    h = np.asarray(graph.h).copy()
    s, e = int(indptr[node]), int(indptr[node + 1])
    factors = np.random.default_rng(salt).uniform(0.2, 3.0, e - s)
    h[s:e] = h[s:e] * factors.astype(np.float32)
    return dataclasses.replace(graph, h=jnp.asarray(h))


def assert_tables_equal(a, b):
    for f in TABLE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"PrecompTables.{f} differs")


def run_schedule(ops):
    """Drive a (invalidate | drain) schedule through the queue, asserting
    the bitmap/queue invariant after every operation; returns the final
    (graph, tables, queue)."""
    wl = deepwalk()
    params = wl.params()
    g = random_graph(V, 5, weight_dist="uniform", seed=7)
    tables = build_tables(g, wl, params)
    queue = RebuildQueue()
    for i, (is_inval, node, budget) in enumerate(ops):
        if is_inval:
            g = mutate_row(g, node, salt=i)
            tables = tables.invalidate([node])
            queue.push([node])
        else:
            tables, done = queue.drain(tables, g, wl, params, budget=budget)
            assert len(done) <= budget
        # the invariant: a row is queued iff its validity bit is stale —
        # no drain order or interleaving may break it
        stale = set(np.nonzero(np.asarray(tables.invalid))[0].tolist())
        assert set(queue.pending()) == stale, \
            f"after op {i}: queue {sorted(queue.pending())} != " \
            f"stale bits {sorted(stale)}"
    return g, tables, queue, wl, params


def check_fully_drained(g, tables, queue, wl, params):
    """Drain everything: tables must be bit-identical to a fresh build of
    the final graph, in every array AND in actual sampling output."""
    tables, _ = queue.drain(tables, g, wl, params, budget=None)
    assert len(queue) == 0
    assert not np.asarray(tables.invalid).any()
    fresh = build_tables(g, wl, params)
    assert_tables_equal(tables, fresh)
    cur = jnp.asarray(np.arange(32) % V, jnp.int32)
    rng = jax.random.split(jax.random.key(3), 32)
    act = jnp.ones((32,), bool)
    for select in (its_select, alias_select):
        np.testing.assert_array_equal(
            np.asarray(select(g, tables, cur, rng, active=act)),
            np.asarray(select(g, fresh, cur, rng, active=act)))


class TestRebuildQueueProperties:
    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.tuples(st.booleans(), st.integers(0, V - 1),
                              st.integers(0, 4)), max_size=10))
    def test_interleavings_keep_bitmap_consistent(self, ops):
        g, tables, queue, wl, params = run_schedule(ops)
        check_fully_drained(g, tables, queue, wl, params)

    # deterministic companions: the same invariants on hand-picked
    # schedules, run even without hypothesis installed
    @pytest.mark.parametrize("ops", [
        [],
        [(True, 3, 0)],
        [(True, 3, 0), (False, 0, 0)],  # zero-budget drain is a no-op
        [(True, 3, 0), (True, 3, 0)],  # re-invalidate while pending
        [(True, 3, 0), (False, 0, 1), (True, 3, 0)],  # again after rebuild
        [(True, 1, 0), (True, 4, 0), (True, 9, 0), (False, 0, 2),
         (True, 4, 0), (False, 0, 1), (False, 0, 4)],
        [(True, i, 0) for i in range(12)] + [(False, 0, 3)] * 3,
    ])
    def test_deterministic_schedules(self, ops):
        g, tables, queue, wl, params = run_schedule(ops)
        check_fully_drained(g, tables, queue, wl, params)

    def test_dedup_and_counts(self):
        q = RebuildQueue()
        assert q.push([1, 2, 2, 3]) == 3
        assert q.push([2, 4]) == 1
        assert len(q) == 4 and q.pending() == (1, 2, 3, 4)


class TestEngineAmortizedRebuild:
    def make_engine(self, budget, method="its_precomp"):
        g = random_graph(150, 8, weight_dist="uniform", seed=4)
        eng = WalkEngine(g, deepwalk(), EngineConfig(
            method=method, tile=32, rebuild_budget=budget))
        return g, eng

    def invalidate(self, g, eng, nodes):
        g2 = g
        for i, v in enumerate(nodes):
            g2 = mutate_row(g2, v, salt=100 + i)
        eng.update_graph(g2, invalidated=nodes)
        return g2

    def test_budgeted_drains_restore_precomp(self):
        """After update_graph invalidates rows, a bounded number of epoch
        drains flips them back: frac_stale returns to 0, frac_precomp to
        full — the fallback is transient, never permanent."""
        g, eng = self.make_engine(budget=2)
        bad = [3, 5, 9, 11, 20]
        g2 = self.invalidate(g, eng, bad)
        starts = np.asarray(bad * 4, np.int32)
        res = eng.run(starts, num_steps=8, key=jax.random.key(1),
                      batch=4, epoch_len=2)
        assert res.frac_stale > 0  # some lanes hit stale rows early on
        assert res.rebuilt_rows == len(bad)  # ceil(5/2)=3 epochs sufficed
        assert len(eng.rebuild_queue) == 0
        assert not np.asarray(eng.precomp.invalid).any()
        res2 = eng.run(starts, num_steps=8, key=jax.random.key(2))
        assert res2.frac_stale == 0.0
        assert res2.frac_precomp == 1.0
        # and the re-baked row serves the NEW weights
        v = bad[0]
        p, nbr = exact_probs(g2, deepwalk(), deepwalk().params(),
                             v, -1, 0, pad=64)
        NN = 2000
        rng = jax.random.split(jax.random.key(5), NN)
        state = WalkerState(cur=jnp.full((NN,), v, jnp.int32),
                            prev=jnp.full((NN,), -1, jnp.int32),
                            step=jnp.zeros((NN,), jnp.int32),
                            alive=jnp.ones((NN,), bool),
                            rng=jax.random.key_data(rng))
        sel = eng.sampler.select(eng.sampler_ctx, state, rng,
                                 active=jnp.ones((NN,), bool))
        assert int(sel.precomp_served) == NN
        out = np.asarray(sel.next_nodes)
        support = nbr[(nbr >= 0) & (p > 0)]
        counts = np.array([(out == u).sum() for u in support])
        expected = p[(nbr >= 0) & (p > 0)] * NN
        chi2 = float(((counts - expected) ** 2 / expected).sum())
        df = len(support) - 1
        assert chi2 < df * (1 - 2 / (9 * df)
                            + 3.7 * np.sqrt(2 / (9 * df))) ** 3

    def test_zero_budget_keeps_fallback_until_explicit_drain(self):
        """rebuild_budget=0 disables the background path: stale rows keep
        the dynamic fallback (still correct, reading new weights) until
        drain_rebuilds() repairs them synchronously."""
        g, eng = self.make_engine(budget=0)
        bad = [3, 5, 9]
        self.invalidate(g, eng, bad)
        starts = np.asarray(bad * 4, np.int32)
        res = eng.run(starts, num_steps=6, key=jax.random.key(1))
        assert res.rebuilt_rows == 0
        assert res.frac_stale > 0
        assert len(eng.rebuild_queue) == len(bad)
        assert eng.drain_rebuilds() == len(bad)
        res2 = eng.run(starts, num_steps=6, key=jax.random.key(1))
        assert res2.frac_stale == 0.0 and res2.frac_precomp == 1.0

    def test_adaptive_counts_stale_and_recovers(self):
        """The adaptive third regime reports its own stale bounces and the
        run-level telemetry conserves mass throughout the transient."""
        g, eng = self.make_engine(budget=1, method="adaptive")
        bad = [3, 5]
        self.invalidate(g, eng, bad)
        res = eng.run(np.asarray(bad * 6, np.int32), num_steps=8,
                      key=jax.random.key(0), batch=4, epoch_len=2)
        assert res.rebuilt_rows == len(bad)
        assert 0.0 <= res.frac_stale <= 1.0
        assert res.frac_rjs + res.frac_precomp + res.frac_stale <= 1.0 + 1e-9
        res2 = eng.run(np.asarray(bad * 6, np.int32), num_steps=8,
                       key=jax.random.key(0))
        assert res2.frac_stale == 0.0

    def test_batch_invariance_holds_while_rebuild_in_flight(self):
        """The scheduler contract with the carve-out closed: paths AND
        the frac_* telemetry are independent of slot count / epoch length
        even while a budgeted rebuild is actively draining mid-run.

        Every epoch serves from the table view pinned when the run's
        scheduler was created (background drains repair the engine-side
        tables only), and the drain cadence keys off the engine-absolute
        epoch clock — so which steps see a stale row depends only on the
        queue state when the run started, never on the epoch cadence.
        ``rebuilt_rows`` legitimately differs (more epochs, more drain
        opportunities); everything observable must not."""
        bad = [3, 5, 9, 11, 20, 31, 40]
        outs = []
        for batch, epoch_len in [(None, None), (4, 2), (6, 1), (3, 4)]:
            g, eng = self.make_engine(budget=1)
            self.invalidate(g, eng, bad)
            res = eng.run(np.asarray(bad * 4, np.int32), num_steps=8,
                          key=jax.random.key(1), batch=batch,
                          epoch_len=epoch_len)
            # the transient is real: stale rows were served mid-run and
            # the background drain genuinely ran
            assert res.frac_stale > 0
            assert res.rebuilt_rows > 0
            outs.append((res, eng))
        ref, _ = outs[0]
        for res, _ in outs[1:]:
            np.testing.assert_array_equal(ref.paths, res.paths)
            assert ref.frac_stale == res.frac_stale
            assert ref.frac_precomp == res.frac_precomp
            assert ref.frac_rjs == res.frac_rjs
            assert ref.live_steps == res.live_steps
        # repairs become visible to the NEXT run: finish the drain and
        # the stale fraction collapses to zero on every engine
        for _, eng in outs:
            eng.drain_rebuilds()
            res2 = eng.run(np.asarray(bad * 4, np.int32), num_steps=8,
                           key=jax.random.key(2))
            assert res2.frac_stale == 0.0 and res2.frac_precomp == 1.0

    def test_prefer_precomp_discounts_by_stale_fraction(self):
        """CostModel.prefer_precomp prices the regime out as staleness
        grows: full tables route, fully stale tables never do."""
        cm = CostModel()
        deg = jnp.asarray([16, 256, 4096])
        assert all(bool(x) for x in cm.prefer_precomp(deg))
        assert all(bool(x) for x in cm.prefer_precomp(deg, frac_stale=0.0))
        assert not any(bool(x)
                       for x in cm.prefer_precomp(deg, frac_stale=1.0))
