"""Roofline tooling: HLO parser trip-count scaling, byte model, analysis
terms, and the 8-bit optimizer used by the §Perf iterations."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.models.config import ModelConfig
from repro.roofline import TPU_V5E, model_flops
from repro.roofline.analysis import RooflineReport
from repro.train.optimizer import _dequantize_moment, _quantize_moment


class TestHloParser:
    def test_scan_trip_scaling_and_collectives(self):
        """Ground truth: a 10-iteration scanned matmul sharded 8 ways.
        parse_hlo must recover 10× the per-iteration flops (cost_analysis
        reports 1× — the motivating bug) and 10 all-reduces."""
        child = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline.hlo import parse_hlo
mesh = jax.make_mesh((8,), ("model",))
def scanned(x, w):
    def body(c, _):
        return c @ w, None
    out, _ = jax.lax.scan(body, x, None, length=10)
    return out
x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
w = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
c = jax.jit(scanned,
            in_shardings=(NamedSharding(mesh, P(None, "model")),
                          NamedSharding(mesh, P("model", None))),
            out_shardings=NamedSharding(mesh, P())).lower(x, w).compile()
st = parse_hlo(c.as_text())
expect = 10 * 2 * 1024 * 1024 * (1024 // 8)
assert abs(st.flops - expect) / expect < 0.01, (st.flops, expect)
assert st.collective_count["all-reduce"] == 10, st.collective_count
ca = c.cost_analysis()  # list of per-program dicts on newer jax
if isinstance(ca, (list, tuple)):
    ca = ca[0]
assert st.flops > ca["flops"] * 5  # raw undercounts scans
print("OK")
"""
        out = subprocess.run([sys.executable, "-c", child],
                             capture_output=True, text=True,
                             env={**os.environ, "PYTHONPATH": "src"})
        assert "OK" in out.stdout, out.stderr[-800:]


class TestAnalysis:
    CFG = ModelConfig(name="t", family="dense", num_layers=4, d_model=256,
                      vocab_size=1000, num_heads=4, num_kv_heads=4,
                      head_dim=64, d_ff=1024)

    def test_model_flops_ordering(self):
        train = model_flops(self.CFG, 1024, 8, "train")
        prefill = model_flops(self.CFG, 1024, 8, "prefill")
        decode = model_flops(self.CFG, 1024, 8, "decode")
        assert train > prefill > decode > 0
        assert train == pytest.approx(3 * prefill)  # fwd vs fwd+bwd

    def test_dominant_and_fraction(self):
        rep = RooflineReport(
            arch="a", shape="s", mesh="m", chips=256, kind="train",
            hlo_flops=1e12, hbm_bytes=1e12, collective_bytes=1e9,
            collective_breakdown={}, model_flops_total=2.5e14,
            argument_bytes=0, temp_bytes=0).finalize(TPU_V5E)
        assert rep.dominant == "memory"  # 1e12/819e9 > 1e12/197e12
        assert 0 < rep.roofline_fraction <= 1.01


class TestInt8Moments:
    @settings(max_examples=30, deadline=None)
    @given(scale=st.floats(1e-6, 1e3), n=st.integers(3, 400))
    def test_signed_roundtrip_error_bounded(self, scale, n):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, scale, n).astype(np.float32))
        q = _quantize_moment(x, signed=True)
        y = _dequantize_moment(q, x.shape, signed=True)
        blockmax = float(jnp.max(jnp.abs(x)))
        assert float(jnp.max(jnp.abs(y - x))) <= blockmax / 127 + 1e-12

    @settings(max_examples=30, deadline=None)
    @given(scale=st.floats(1e-8, 1e3), n=st.integers(3, 400))
    def test_sqrt_domain_preserves_small_values(self, scale, n):
        rng = np.random.default_rng(1)
        x = jnp.asarray((rng.uniform(0, 1, n) ** 4 * scale
                         ).astype(np.float32))
        q = _quantize_moment(x, signed=False)
        y = _dequantize_moment(q, x.shape, signed=False)
        # sqrt-domain: relative error of sqrt ≤ 1/254 of block sqrt-max
        err = np.abs(np.sqrt(np.asarray(y)) - np.sqrt(np.asarray(x)))
        assert float(err.max()) <= np.sqrt(float(x.max())) / 127 + 1e-12
        assert float(jnp.min(y)) >= 0.0

    def test_nonneg_and_shapes(self):
        x = jnp.abs(jax.random.normal(jax.random.key(0), (7, 300)))
        q = _quantize_moment(x, signed=False)
        assert q["q"].shape == x.shape and q["q"].dtype == jnp.int8
        y = _dequantize_moment(q, x.shape, signed=False)
        assert y.shape == x.shape and bool((y >= 0).all())
