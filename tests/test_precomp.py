"""Precomputed-regime correctness: the is_static analysis, chi-square
equivalence of the table samplers against exact_probs, invalidation-bitmap
fallback after a weight mutation, the three-regime adaptive routing, and
bit-identity of the step-interleaved pipeline vs plain eRVS."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EngineConfig, WalkEngine, WalkerState, build_tables,
                        exact_probs, is_static)
from repro.core.precomp import alias_select, its_select
from repro.graphs import random_graph
from repro.walks import (deepwalk, metapath, node2vec,
                         second_order_pagerank)

N = 3000
PAD = 64


def chi2_critical(df: int, z: float = 3.7) -> float:
    """Wilson–Hilferty upper-tail chi-square quantile (z=3.7 ≈ p 1e-4)."""
    a = 2.0 / (9.0 * df)
    return df * (1.0 - a + z * np.sqrt(a)) ** 3


def chi2_vs_exact(out, p, nbr):
    support = nbr[(nbr >= 0) & (p > 0)]
    probs = p[(nbr >= 0) & (p > 0)]
    assert np.isin(out, support).all(), \
        f"sampled outside the support: {set(out) - set(support)}"
    counts = np.array([(out == v).sum() for v in support])
    expected = probs * len(out)
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    return chi2, chi2_critical(len(support) - 1)


@pytest.fixture(scope="module")
def static_setup():
    """A weighted graph + DeepWalk (static: w̃ = h) and one node's exact
    transition distribution."""
    g = random_graph(60, 6, weight_dist="uniform", seed=3)
    wl = deepwalk()
    params = wl.params()
    v, pv, st = 7, 3, 2
    p, nbr = exact_probs(g, wl, params, v, pv, st, pad=PAD)
    cur = jnp.full((N,), v, jnp.int32)
    prev = jnp.full((N,), pv, jnp.int32)
    step = jnp.full((N,), st, jnp.int32)
    rng = jax.random.split(jax.random.key(0), N)
    return g, wl, params, v, p, nbr, cur, prev, step, rng


class TestIsStatic:
    def test_truth_table(self):
        assert is_static(deepwalk())
        assert is_static(deepwalk(weighted=False))
        assert not is_static(node2vec())  # dist → prev-dependent
        assert not is_static(metapath())  # schema position → step-dependent
        assert not is_static(second_order_pagerank())  # dist + deg_prev

    def test_untraceable_is_conservative(self):
        from repro.core.types import Workload
        with pytest.warns(DeprecationWarning):  # legacy Workload protocol
            bad = Workload(name="bad", init=lambda: (),
                           get_weight=lambda ctx, p: (_ for _ in ()).throw(
                               RuntimeError("nope")))
        assert not is_static(bad)


class TestTableDistributions:
    @pytest.mark.parametrize("method", ["its_precomp", "alias_precomp"])
    def test_chi_square_vs_exact(self, method, static_setup):
        g, wl, params, v, p, nbr, cur, prev, step, rng = static_setup
        eng = WalkEngine(g, wl, EngineConfig(method=method, tile=32))
        assert eng.precomp is not None  # static workload ⇒ tables built
        state = WalkerState(cur=cur, prev=prev, step=step,
                            alive=jnp.ones((N,), bool),
                            rng=jax.random.key_data(rng))
        sel = eng.sampler.select(eng.sampler_ctx, state, rng,
                                 active=jnp.ones((N,), bool))
        # every lane must have been table-served, none dynamic
        assert int(sel.precomp_served) == N
        chi2, crit = chi2_vs_exact(np.asarray(sel.next_nodes), p, nbr)
        assert chi2 < crit, f"{method}: chi2={chi2:.1f} ≥ crit={crit:.1f}"

    @pytest.mark.parametrize("select_fn", [its_select, alias_select])
    def test_raw_selectors_zero_total_row(self, select_fn):
        """A row whose weights are all zero must dead-end (-1), never
        emit a neighbour."""
        g = random_graph(30, 5, seed=1)
        g = dataclasses.replace(g, h=jnp.zeros_like(g.h))
        wl = deepwalk()
        tables = build_tables(g, wl, wl.params())
        cur = jnp.arange(8, dtype=jnp.int32)
        rng = jax.random.split(jax.random.key(1), 8)
        out = select_fn(g, tables, cur, rng,
                        active=jnp.ones((8,), bool))
        assert (np.asarray(out) == -1).all()


class TestInvalidation:
    def test_mutated_row_falls_back_to_dynamic(self, static_setup):
        """update_graph: the invalidated node samples from the NEW weights
        (dynamic path over the live graph), untouched nodes keep serving
        from their still-valid tables."""
        g, wl, params, v, p, nbr, cur, prev, step, rng = static_setup
        eng = WalkEngine(g, wl, EngineConfig(method="its_precomp", tile=32))
        # mutate node v's row: reverse its edge weights (same topology)
        indptr = np.asarray(g.indptr)
        h2 = np.asarray(g.h).copy()
        s, e = indptr[v], indptr[v + 1]
        h2[s:e] = h2[s:e][::-1]
        g2 = dataclasses.replace(g, h=jnp.asarray(h2))
        eng.update_graph(g2, invalidated=[v])
        p_new, nbr_new = exact_probs(g2, wl, params, v, int(prev[0]),
                                     int(step[0]), pad=PAD)
        state = WalkerState(cur=cur, prev=prev, step=step,
                            alive=jnp.ones((N,), bool),
                            rng=jax.random.key_data(rng))
        sel = eng.sampler.select(eng.sampler_ctx, state, rng,
                                 active=jnp.ones((N,), bool))
        # the whole batch sits on the invalidated node ⇒ zero table serves
        assert int(sel.precomp_served) == 0
        chi2, crit = chi2_vs_exact(np.asarray(sel.next_nodes), p_new, nbr_new)
        assert chi2 < crit, f"post-mutation chi2={chi2:.1f} ≥ {crit:.1f}"
        # an untouched node still serves from its (unchanged) table row
        u = 11
        state_u = WalkerState(cur=jnp.full((N,), u, jnp.int32), prev=prev,
                              step=step, alive=jnp.ones((N,), bool),
                              rng=jax.random.key_data(rng))
        sel_u = eng.sampler.select(eng.sampler_ctx, state_u, rng,
                                   active=jnp.ones((N,), bool))
        assert int(sel_u.precomp_served) == N
        p_u, nbr_u = exact_probs(g2, wl, params, u, int(prev[0]),
                                 int(step[0]), pad=PAD)
        chi2, crit = chi2_vs_exact(np.asarray(sel_u.next_nodes), p_u, nbr_u)
        assert chi2 < crit

    def test_update_graph_rejects_topology_change(self):
        g = random_graph(30, 5, seed=1)
        eng = WalkEngine(g, deepwalk(), EngineConfig(method="its_precomp",
                                                     tile=32))
        g_other = random_graph(40, 5, seed=1)
        with pytest.raises(ValueError, match="topology"):
            eng.update_graph(g_other)

    def test_corrupted_invalid_rows_never_read(self):
        """Adversarial: scribble garbage over an invalidated row's tables —
        the walk must stay on the graph (proof the bitmap truly gates every
        table read)."""
        g = random_graph(80, 6, seed=2)
        eng = WalkEngine(g, deepwalk(), EngineConfig(method="alias_precomp",
                                                     tile=32))
        bad = 5
        indptr = np.asarray(g.indptr)
        s, e = indptr[bad], indptr[bad + 1]
        alias = np.asarray(eng.precomp.alias_off).copy()
        alias[s:e] = 9_999_999
        eng.precomp = dataclasses.replace(
            eng.precomp.invalidate([bad]),
            alias_off=jnp.asarray(alias))
        eng.sampler_ctx = dataclasses.replace(eng.sampler_ctx,
                                              precomp=eng.precomp)
        # no epoch rebuild needed: the once-jitted epoch takes precomp
        # as an argument, so the corrupted tables flow in on the next run
        res = eng.run(np.full(32, bad, np.int32), num_steps=4)
        indices = np.asarray(g.indices)
        for q in range(32):
            for t in range(4):
                a, b = res.paths[q, t], res.paths[q, t + 1]
                if b < 0:
                    break
                assert b in indices[indptr[a]:indptr[a + 1]]


class TestAdaptiveThirdRegime:
    def test_static_nodes_route_to_precomp(self):
        g = random_graph(150, 8, seed=4)
        eng = WalkEngine(g, deepwalk(), EngineConfig(method="adaptive",
                                                     tile=64))
        res = eng.run(np.arange(64), num_steps=8)
        # the cost model routes table-eligible nodes to the precomp regime
        assert res.frac_precomp > 0.5
        assert res.frac_precomp + res.frac_rjs <= 1.0 + 1e-9

    def test_dynamic_workload_has_no_precomp(self):
        g = random_graph(150, 8, seed=4)
        eng = WalkEngine(g, node2vec(), EngineConfig(method="adaptive",
                                                     tile=64))
        assert eng.precomp is None
        res = eng.run(np.arange(32), num_steps=6)
        assert res.frac_precomp == 0.0

    def test_batch_invariance_with_precomp(self):
        """The streaming-scheduler contract holds for the new regime too."""
        g = random_graph(150, 8, seed=6)
        eng = WalkEngine(g, deepwalk(), EngineConfig(method="adaptive",
                                                     tile=64))
        full = eng.run(np.arange(13), num_steps=9, key=jax.random.key(3))
        slotted = eng.run(np.arange(13), num_steps=9, key=jax.random.key(3),
                          batch=4, epoch_len=2)
        np.testing.assert_array_equal(full.paths, slotted.paths)
        assert full.frac_precomp == slotted.frac_precomp > 0


class TestInterleaved:
    @pytest.mark.parametrize("wl_fn", [node2vec, deepwalk])
    @pytest.mark.parametrize("tile", [64, 8])
    def test_bit_identical_to_ervs(self, wl_fn, tile):
        """Same RNG streams ⇒ the pipelined sampler must reproduce plain
        eRVS exactly — the prefetch may only change HOW data is fetched.
        tile=8 forces rows past the prefetched tile, exercising the
        multi-tile streaming half of the pipeline too."""
        g = random_graph(200, 8, seed=1)
        a = WalkEngine(g, wl_fn(), EngineConfig(method="ervs", tile=tile))
        b = WalkEngine(g, wl_fn(), EngineConfig(method="interleaved",
                                                tile=tile))
        ra = a.run(np.arange(48), num_steps=9, key=jax.random.key(3))
        rb = b.run(np.arange(48), num_steps=9, key=jax.random.key(3))
        np.testing.assert_array_equal(ra.paths, rb.paths)

    def test_bit_identical_through_streaming_refills(self):
        """Refilled slots inherit a stale prefetch tile; the per-lane node
        tag must force a re-fetch, keeping batch invariance intact."""
        g = random_graph(200, 8, seed=1)
        a = WalkEngine(g, node2vec(), EngineConfig(method="ervs", tile=64))
        b = WalkEngine(g, node2vec(), EngineConfig(method="interleaved",
                                                   tile=64))
        ra = a.run(np.arange(13), num_steps=9, key=jax.random.key(5))
        rb = b.run(np.arange(13), num_steps=9, key=jax.random.key(5),
                   batch=4, epoch_len=2)
        np.testing.assert_array_equal(ra.paths, rb.paths)

    def test_walk_batch_carries_prefetch(self):
        """walk_batch (the sharded entry point) initialises the carry."""
        g = random_graph(100, 8, seed=5)
        a = WalkEngine(g, deepwalk(), EngineConfig(method="ervs", tile=64))
        b = WalkEngine(g, deepwalk(), EngineConfig(method="interleaved",
                                                   tile=64))
        starts = np.arange(16, dtype=np.int32)
        key = jax.random.key(9)
        pa, _ = a.walk_batch(starts, key, 6)
        pb, _ = b.walk_batch(starts, key, 6)
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
