"""Topology invariance of the sharded streaming scheduler (docs/scaling.md):
walks on a forced 2-device host mesh must be bit-identical to single-device
execution — same paths, same telemetry — for the reservoir (`ervs`),
three-regime (`adaptive`) and pipelined (`interleaved`) samplers, including
mid-epoch refills from the host queue.  XLA device-count forcing must
happen before jax is imported, so the mesh cases run in a subprocess (the
same pattern as TestShardingRules in test_system.py)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, WalkEngine
from repro.distributed import walker_mesh, walker_spec
from repro.graphs import random_graph
from repro.walks import deepwalk

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import EngineConfig, WalkEngine
from repro.distributed import shard_walker_state, walker_mesh, walker_spec
from repro.graphs import random_graph
from repro.walks import node2vec

assert len(jax.devices()) == 2, jax.devices()
g = random_graph(200, 8, seed=1)
key = jax.random.key(3)
for method in ["ervs", "adaptive", "interleaved"]:
    eng = WalkEngine(g, node2vec(), EngineConfig(method=method, tile=64))
    # 13 queries through 4 slots with 2-step epochs: forces several
    # mid-walk refills, and 13 % 4 != 0 leaves a partial tail epoch.
    one = eng.run(np.arange(13), num_steps=9, key=key,
                  batch=4, epoch_len=2, devices=1)
    two = eng.run(np.arange(13), num_steps=9, key=key,
                  batch=4, epoch_len=2, devices=2)
    full = eng.run(np.arange(13), num_steps=9, key=key)
    np.testing.assert_array_equal(one.paths, two.paths, err_msg=method)
    np.testing.assert_array_equal(full.paths, two.paths, err_msg=method)
    assert one.frac_rjs == two.frac_rjs, method
    assert one.frac_precomp == two.frac_precomp, method
    assert one.live_steps == two.live_steps == 13 * 9, method
    assert one.rjs_fallbacks == two.rjs_fallbacks, method
    # per-device telemetry: present only when sharded, covers all queries,
    # and the round-robin refill kept both devices fed (13 -> 7/6 split)
    assert one.per_device is None, method
    assert [d["device"] for d in two.per_device] == [0, 1], method
    assert sum(d["queries"] for d in two.per_device) == 13, method
    assert min(d["queries"] for d in two.per_device) >= 6, method
    assert sum(d["emitted_steps"] for d in two.per_device) == 13 * 9, method
    # walk_batch: the no-scheduler entry point under an explicit mesh
    p1, s1 = eng.walk_batch(np.arange(8, dtype=np.int32), key, 6)
    p2, s2 = eng.walk_batch(np.arange(8, dtype=np.int32), key, 6, devices=2)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2),
                                  err_msg=method)
    assert int(np.asarray(s1.live).sum()) == int(np.asarray(s2.live).sum())

# spec machinery on a real 2-device mesh: slot dims shard, indivisible
# pools fall back to replication instead of mis-sharding
mesh = walker_mesh(2)
assert walker_spec(jnp.zeros((4, 3)), 4, mesh) == P("walkers", None)
assert walker_spec(jnp.zeros((3, 4)), 3, mesh) == P(None, None)
assert walker_spec(jnp.zeros((7,)), 4, mesh) == P()
assert walker_spec(jnp.float32(0), 4, mesh) == P()
print("MULTIDEVICE-OK")
"""


def test_two_device_scheduler_bit_identical():
    out = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src",
             # the child forces its own device count
             "XLA_FLAGS": ""})
    assert "MULTIDEVICE-OK" in out.stdout, out.stderr[-2000:]


_PROGRAM_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
import numpy as np
from repro.core import EngineConfig, WalkEngine
from repro.graphs import random_graph
from repro.walks import ppr_nibble, visited_avoiding

assert len(jax.devices()) == 2, jax.devices()
g = random_graph(200, 8, seed=1)
key = jax.random.key(3)
for prog in [visited_avoiding(window=12), ppr_nibble(alpha=0.3, eps=2e-2)]:
    for method in ["ervs", "adaptive"]:
        eng = WalkEngine(g, prog, EngineConfig(method=method, tile=64))
        # 13 queries through 4 slots, 2-step epochs: stateful refills and
        # (for ppr_nibble) should_stop-freed slots handed to new queries,
        # sharded over 2 devices — must stay bit-identical throughout.
        one = eng.run(np.arange(13), num_steps=9, key=key,
                      batch=4, epoch_len=2, devices=1)
        two = eng.run(np.arange(13), num_steps=9, key=key,
                      batch=4, epoch_len=2, devices=2)
        full = eng.run(np.arange(13), num_steps=9, key=key)
        tag = f"{prog.name}/{method}"
        np.testing.assert_array_equal(one.paths, two.paths, err_msg=tag)
        np.testing.assert_array_equal(full.paths, two.paths, err_msg=tag)
        assert one.frac_rjs == two.frac_rjs == full.frac_rjs, tag
        assert one.frac_precomp == two.frac_precomp == full.frac_precomp, tag
        assert one.live_steps == two.live_steps == full.live_steps, tag
        assert one.rjs_fallbacks == two.rjs_fallbacks, tag
        # stopped/dead walkers never count: every live step emitted a node
        # or was a dead-end attempt (at most one per query)
        emitted = int((two.paths[:, 1:] >= 0).sum())
        assert emitted <= two.live_steps <= emitted + 13, tag
print("PROGRAMS-MULTIDEVICE-OK")
"""


def test_two_device_walk_programs_bit_identical():
    """WalkProgram state (wstate refills) and should_stop slot-freeing
    under the forced 2-device mesh: paths and live-lane telemetry must be
    bit-identical to single-device execution."""
    out = subprocess.run(
        [sys.executable, "-c", _PROGRAM_CHILD], capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src", "XLA_FLAGS": ""})
    assert "PROGRAMS-MULTIDEVICE-OK" in out.stdout, out.stderr[-2000:]


class TestShardedSchedulerArgs:
    """Validation paths that hold on any host (no forced devices)."""

    def _engine(self):
        g = random_graph(60, 6, seed=0)
        return WalkEngine(g, deepwalk(), EngineConfig(method="ervs", tile=64))

    def test_run_rejects_nonpositive_devices(self):
        eng = self._engine()
        with pytest.raises(ValueError, match="devices"):
            eng.run(np.arange(4), num_steps=3, devices=0)

    def test_mesh_rejects_more_devices_than_available(self):
        with pytest.raises(ValueError, match="num_devices"):
            walker_mesh(len(jax.devices()) + 1)

    def test_walk_batch_rejects_indivisible_batch(self):
        eng = self._engine()
        with pytest.raises(ValueError, match="divide"):
            eng.walk_batch(np.arange(7, dtype=np.int32), jax.random.key(0),
                           3, devices=2)

    def test_devices_one_is_the_plain_scheduler(self):
        eng = self._engine()
        a = eng.run(np.arange(6), num_steps=4, key=jax.random.key(1))
        b = eng.run(np.arange(6), num_steps=4, key=jax.random.key(1),
                    devices=1)
        np.testing.assert_array_equal(a.paths, b.paths)
        assert b.per_device is None

    def test_walker_spec_single_device_mesh(self):
        mesh = walker_mesh(1)
        from jax.sharding import PartitionSpec as P
        assert walker_spec(jnp.zeros((4, 2)), 4, mesh) == P("walkers", None)
        assert walker_spec(jnp.zeros((2, 4)), 4, mesh) == P()
