"""Docs stay honest: intra-repo links resolve, the README quickstart
actually runs, documented CLI flags exist, and the README sampler table
matches the registry (the same checks the CI docs job enforces via
tools/check_docs.py)."""
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_docs  # noqa: E402


def test_docs_exist():
    assert (ROOT / "README.md").exists()
    assert (ROOT / "docs" / "architecture.md").exists()
    assert (ROOT / "docs" / "scaling.md").exists()
    assert (ROOT / "docs" / "cost_model.md").exists()
    assert (ROOT / "docs" / "walk_programs.md").exists()
    assert (ROOT / "docs" / "serving.md").exists()


def test_no_broken_intra_repo_links():
    problems = []
    for f in check_docs.doc_files(ROOT):
        problems.extend(check_docs.check_links(f, ROOT))
    assert not problems, "\n".join(problems)


def test_link_checker_catches_breakage(tmp_path):
    """The gate itself must not be vacuous."""
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](does/not/exist.py) and "
                   "[escape](../../outside.md)")
    problems = check_docs.check_links(bad, tmp_path)
    assert len(problems) == 2


class TestCliFlagCrossCheck:
    def test_documented_walk_flags_are_accepted(self):
        """Every ``--flag`` shown in a fenced repro.launch.walk command in
        README.md/docs/ must exist on the launcher's parser."""
        known = check_docs.walk_cli_flags()
        problems = []
        for f in check_docs.doc_files(ROOT):
            problems.extend(check_docs.check_cli_flags(f, known))
        assert not problems, "\n".join(problems)

    def test_documented_serve_walks_flags_are_accepted(self):
        """The same audit for the serving launcher: every ``--flag``
        shown in a fenced repro.launch.serve_walks command must exist on
        its ``build_parser()``."""
        known = {"repro.launch.serve_walks":
                 check_docs.cli_flags("repro.launch.serve_walks")}
        problems = []
        for f in check_docs.doc_files(ROOT):
            problems.extend(check_docs.check_cli_flags(f, known))
        assert not problems, "\n".join(problems)

    def test_documented_walk_client_flags_are_accepted(self):
        """And for the TCP client: every ``--flag`` shown in a fenced
        repro.launch.walk_client command must exist on its
        ``build_parser()``."""
        known = {"repro.launch.walk_client":
                 check_docs.cli_flags("repro.launch.walk_client")}
        problems = []
        for f in check_docs.doc_files(ROOT):
            problems.extend(check_docs.check_cli_flags(f, known))
        assert not problems, "\n".join(problems)

    def test_checker_separates_launchers(self, tmp_path):
        """A dict of per-module flag sets audits each command line
        against ITS OWN parser: a serve_walks-only flag on a walk
        command trips the gate, and vice versa."""
        bad = tmp_path / "bad.md"
        bad.write_text(
            "```\npython -m repro.launch.walk --trace overload\n"
            "python -m repro.launch.serve_walks --workload node2vec\n"
            "```\n")
        problems = check_docs.check_cli_flags(bad, {
            "repro.launch.walk": {"--workload"},
            "repro.launch.serve_walks": {"--trace"},
        })
        assert len(problems) == 2
        assert any("--trace" in p and "repro.launch.walk" in p
                   for p in problems)
        assert any("--workload" in p and "repro.launch.serve_walks" in p
                   for p in problems)

    def test_checker_catches_unknown_flag(self, tmp_path):
        """The gate itself must not be vacuous."""
        bad = tmp_path / "bad.md"
        bad.write_text("```\npython -m repro.launch.walk --no-such-flag 3\n"
                       "```\n")
        problems = check_docs.check_cli_flags(bad, {"--method"})
        assert len(problems) == 1 and "--no-such-flag" in problems[0]

    def test_checker_skips_non_walk_blocks_and_xla_flags(self, tmp_path):
        ok = tmp_path / "ok.md"
        ok.write_text(
            "```\nsome-other-tool --whatever\n```\n"
            "```\nXLA_FLAGS=--xla_force_host_platform_device_count=2 \\\n"
            "    python -m repro.launch.walk --method adaptive\n```\n")
        assert check_docs.check_cli_flags(ok, {"--method"}) == []

    def test_checker_ignores_other_commands_in_same_block(self, tmp_path):
        """Only the logical lines invoking repro.launch.walk are checked —
        a sibling command's flags in the same fenced block must not trip
        the gate."""
        mixed = tmp_path / "mixed.md"
        mixed.write_text(
            "```\npip install --upgrade jax\n"
            "python -m repro.launch.walk \\\n    --method adaptive\n"
            "python -m benchmarks.fig15_scaling --quick\n```\n")
        assert check_docs.check_cli_flags(mixed, {"--method"}) == []
        bad = tmp_path / "bad.md"
        bad.write_text(
            "```\npip install --upgrade jax\n"
            "python -m repro.launch.walk --gone\n```\n")
        problems = check_docs.check_cli_flags(bad, {"--method"})
        assert len(problems) == 1 and "--gone" in problems[0]


def test_readme_workload_table_matches_registry():
    """The hand-written workload table in README.md must list exactly
    ``sorted(WORKLOADS)`` — a newly registered walk program cannot ship
    undocumented, and rows for removed ones must go (the same gate the
    sampler table has; check_docs.check_registry_tables enforces both in
    the docs CI job)."""
    from repro.walks import WORKLOADS
    text = (ROOT / "README.md").read_text(encoding="utf-8")
    rows = check_docs.readme_table_rows(text, "Workloads")
    assert rows, "workload table not found under '## Workloads'"
    assert rows == sorted(rows), "table must be sorted like the registry"
    assert rows == sorted(WORKLOADS), (
        f"README workload table out of sync with WORKLOADS:\n"
        f"  missing rows: {set(WORKLOADS) - set(rows)}\n"
        f"  stale rows:   {set(rows) - set(WORKLOADS)}")


def test_check_docs_registry_tables_gate():
    """check_docs.check_registry_tables passes on the real README and
    catches a desynced table (the gate itself must not be vacuous)."""
    assert check_docs.check_registry_tables(ROOT) == []
    assert check_docs.readme_table_rows("## Workloads\nno table here",
                                        "Workloads") == []


def test_readme_sampler_table_matches_registry():
    """The hand-written sampler table in README.md must list exactly
    ``available_samplers()`` — a newly registered sampler cannot ship
    undocumented, and rows for removed samplers must go."""
    from repro.core import available_samplers
    text = (ROOT / "README.md").read_text(encoding="utf-8")
    rows = check_docs.readme_table_rows(text, "Sampler registry")
    assert rows, "sampler table not found under '## Sampler registry'"
    assert rows == sorted(rows), "table must be sorted like the registry"
    assert tuple(rows) == available_samplers(), (
        f"README sampler table out of sync with the registry:\n"
        f"  missing rows: {set(available_samplers()) - set(rows)}\n"
        f"  stale rows:   {set(rows) - set(available_samplers())}")


@pytest.mark.slow
def test_readme_quickstart_doctests():
    """Runs the fenced `>>>` quickstart in README.md end-to-end."""
    problems = check_docs.run_doctests(ROOT / "README.md")
    assert not problems, "\n".join(problems)


@pytest.mark.slow
def test_scaling_and_cost_model_doctests():
    """The docs-gate doctests for the two PR-3 pages, runnable directly."""
    for name in ["scaling.md", "cost_model.md"]:
        problems = check_docs.run_doctests(ROOT / "docs" / name)
        assert not problems, "\n".join(problems)


@pytest.mark.slow
def test_walk_programs_doctests():
    """The write-your-own-program walkthrough must actually run."""
    problems = check_docs.run_doctests(ROOT / "docs" / "walk_programs.md")
    assert not problems, "\n".join(problems)
