"""Docs stay honest: intra-repo links resolve and the README quickstart
actually runs (the same checks the CI docs job enforces via
tools/check_docs.py)."""
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_docs  # noqa: E402


def test_docs_exist():
    assert (ROOT / "README.md").exists()
    assert (ROOT / "docs" / "architecture.md").exists()


def test_no_broken_intra_repo_links():
    problems = []
    for f in check_docs.doc_files(ROOT):
        problems.extend(check_docs.check_links(f, ROOT))
    assert not problems, "\n".join(problems)


def test_link_checker_catches_breakage(tmp_path):
    """The gate itself must not be vacuous."""
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](does/not/exist.py) and "
                   "[escape](../../outside.md)")
    problems = check_docs.check_links(bad, tmp_path)
    assert len(problems) == 2


@pytest.mark.slow
def test_readme_quickstart_doctests():
    """Runs the fenced `>>>` quickstart in README.md end-to-end."""
    problems = check_docs.run_doctests(ROOT / "README.md")
    assert not problems, "\n".join(problems)
