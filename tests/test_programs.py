"""The WalkProgram contract: legacy-Workload bit-identity through the
deprecation adapter, per-walker state (visited-avoiding walks), early
termination (ε-terminating PPR-Nibble) with exact oracles, telemetry
exclusion of stopped walkers, registry collision diagnostics, and the
wstate-aware Flexi-Compiler analysis."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (EngineConfig, WalkEngine, WalkerState, WalkProgram,
                        Workload, analyze, exact_probs, from_workload,
                        get_sampler, is_static, register_sampler,
                        available_samplers, FALLBACK, PER_STEP)
from repro.core.flexi_compiler import BoundInputs, static_taint
from repro.graphs import random_graph
from repro.walks import (WORKLOADS, make_workload, ppr_nibble,
                         register_workload, visited_avoiding)

N = 3000
PAD = 64


def chi2_critical(df: int, z: float = 3.7) -> float:
    """Wilson–Hilferty upper-tail chi-square quantile (z=3.7 ≈ p 1e-4)."""
    a = 2.0 / (9.0 * df)
    return df * (1.0 - a + z * np.sqrt(a)) ** 3


def chi2_vs_exact(out, p, nbr):
    support = nbr[(nbr >= 0) & (p > 0)]
    probs = p[(nbr >= 0) & (p > 0)]
    assert np.isin(out, support).all(), \
        f"sampled outside the support: {set(out) - set(support)}"
    counts = np.array([(out == v).sum() for v in support])
    expected = probs * len(out)
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    return chi2, chi2_critical(len(support) - 1)


def legacy_clone(program: WalkProgram) -> Workload:
    """The stateless program as a genuine legacy ``Workload`` (2-argument
    ``get_weight``), sharing the same weight math."""
    gw3 = program.get_weight
    with pytest.warns(DeprecationWarning):
        return Workload(
            name=program.name, init=program.init,
            get_weight=lambda ctx, params: gw3(ctx, params, None),
            needs_dist=program.needs_dist,
            needs_labels=program.needs_labels,
            num_labels=program.num_labels,
            weighted=program.weighted,
            walk_len=program.walk_len,
        )


# ------------------------------------------------ backward compatibility
class TestLegacyWorkloadAdapter:
    LEGACY_NAMES = ["node2vec", "node2vec_unweighted", "metapath",
                    "metapath_unweighted", "2ndpr", "deepwalk"]

    def test_workload_constructor_warns(self):
        with pytest.warns(DeprecationWarning, match="WalkProgram"):
            Workload(name="w", init=lambda: (),
                     get_weight=lambda c, p: c.h)

    @pytest.mark.parametrize("method", ["ervs", "adaptive", "interleaved"])
    @pytest.mark.parametrize("name", LEGACY_NAMES)
    def test_bit_identity_through_adapter(self, name, method):
        """Every registered legacy workload must produce identical paths
        AND telemetry whether expressed natively, as a deprecated
        ``Workload``, or through ``from_workload``."""
        g = random_graph(150, 6, seed=2)
        native = make_workload(name)
        legacy = legacy_clone(native)
        key = jax.random.key(7)
        results = []
        for wl in [native, legacy, from_workload(legacy)]:
            eng = WalkEngine(g, wl, EngineConfig(method=method, tile=64))
            results.append(eng.run(np.arange(16), num_steps=5, key=key,
                                   batch=5, epoch_len=2))
        ref = results[0]
        for res in results[1:]:
            np.testing.assert_array_equal(ref.paths, res.paths,
                                          err_msg=f"{name}/{method}")
            assert ref.live_steps == res.live_steps, (name, method)
            assert ref.frac_rjs == res.frac_rjs, (name, method)
            assert ref.frac_precomp == res.frac_precomp, (name, method)
            assert ref.rjs_fallbacks == res.rjs_fallbacks, (name, method)

    def test_from_workload_is_identity_for_programs(self):
        prog = make_workload("deepwalk")
        assert from_workload(prog) is prog

    def test_duck_typed_legacy_object_accepted(self):
        """WalkEngine adapts anything with the legacy attributes."""
        class Legacy:
            name = "duck"
            needs_dist = needs_labels = False
            num_labels = 1
            weighted = True
            walk_len = 10

            @staticmethod
            def init():
                return ()

            @staticmethod
            def get_weight(ctx, params):
                return ctx.h

        g = random_graph(80, 6, seed=0)
        eng = WalkEngine(g, Legacy(), EngineConfig(method="ervs", tile=64))
        res = eng.run(np.arange(8), num_steps=4)
        assert res.paths.shape == (8, 5)


# ------------------------------------------------ registry diagnostics
class TestRegistryCollisions:
    def test_workload_collision_names_factory_and_registry(self):
        with pytest.raises(ValueError) as ei:
            register_workload("deepwalk", lambda **kw: None)
        msg = str(ei.value)
        assert "'deepwalk'" in msg
        assert "already registered by deepwalk" in msg  # the factory name
        assert "overwrite=True" in msg
        for name in sorted(WORKLOADS):
            assert name in msg  # available names, sorted

    def test_sampler_collision_names_sampler_and_registry(self):
        with pytest.raises(ValueError) as ei:
            register_sampler(get_sampler("ervs"))
        msg = str(ei.value)
        assert "'ervs'" in msg
        assert "ERVSSampler" in msg  # the colliding object's type
        assert "overwrite=True" in msg
        for name in available_samplers():
            assert name in msg

    def test_overwrite_still_works(self):
        factory = WORKLOADS["deepwalk"]
        assert register_workload("deepwalk", factory,
                                 overwrite=True) is factory


# ------------------------------------------- visited-avoiding SecondOrder
class TestVisitedAvoiding:
    @pytest.mark.parametrize("method", ["ervs", "adaptive"])
    def test_chi_square_vs_exact_oracle(self, method):
        """One-step draw with a non-empty visited set matches the exact
        renormalised distribution (tabu neighbours excluded)."""
        g = random_graph(60, 6, seed=3)
        wl = visited_avoiding(window=4)
        params = wl.params()
        v, pv, st_ = 7, 3, 2
        indptr, indices = np.asarray(g.indptr), np.asarray(g.indices)
        nbrs = indices[indptr[v]:indptr[v + 1]]
        assert len(nbrs) >= 3, "fixture node needs ≥3 neighbours"
        forbidden = nbrs[:2]
        tabu = jnp.asarray([forbidden[0], forbidden[1], -1, -1], jnp.int32)
        p, nbr = exact_probs(g, wl, params, v, pv, st_, pad=PAD,
                             wstate=tabu)
        assert p[np.isin(nbr, forbidden)].sum() == 0.0
        assert p.sum() > 0
        eng = WalkEngine(g, wl, EngineConfig(method=method, tile=32))
        rng = jax.random.split(jax.random.key(0), N)
        state = WalkerState(
            cur=jnp.full((N,), v, jnp.int32),
            prev=jnp.full((N,), pv, jnp.int32),
            step=jnp.full((N,), st_, jnp.int32),
            alive=jnp.ones((N,), bool),
            rng=jax.random.key_data(rng),
            wstate=jnp.broadcast_to(tabu, (N, 4)),
        )
        sel = eng.sampler.select(eng.sampler_ctx, state, rng,
                                 active=jnp.ones((N,), bool))
        out = np.asarray(sel.next_nodes)
        assert not np.isin(out, forbidden).any(), \
            f"{method} sampled a tabu neighbour"
        chi2, crit = chi2_vs_exact(out, p, nbr)
        assert chi2 < crit, f"{method}: chi2={chi2:.1f} ≥ crit={crit:.1f}"

    @pytest.mark.parametrize("method", ["ervs", "adaptive"])
    def test_no_revisits_end_to_end(self, method):
        g = random_graph(200, 8, seed=1)
        wl = visited_avoiding(window=16)
        eng = WalkEngine(g, wl, EngineConfig(method=method, tile=64))
        res = eng.run(np.arange(24), num_steps=9, key=jax.random.key(0))
        for q in range(24):
            stepped = [x for x in res.paths[q, 1:] if x >= 0]
            assert len(set(stepped)) == len(stepped), \
                f"{method} q={q}: revisit in {res.paths[q]}"

    def test_interleaved_bit_identical_to_ervs_with_state(self):
        """The pipelined sampler must stay bit-identical to eRVS for
        state-dependent weights too (the prefetch only changes HOW data
        is fetched, never what wstate the weights see)."""
        g = random_graph(200, 8, seed=1)
        key = jax.random.key(5)
        runs = {}
        for method in ["ervs", "interleaved"]:
            eng = WalkEngine(g, visited_avoiding(window=16),
                             EngineConfig(method=method, tile=64))
            runs[method] = eng.run(np.arange(16), num_steps=9, key=key)
        np.testing.assert_array_equal(runs["ervs"].paths,
                                      runs["interleaved"].paths)

    def test_batch_invariance_with_state(self):
        """Refills must reset wstate per QUERY: 13 queries through 4 slots
        ≡ 13 at once, bit-for-bit, including the visited sets."""
        g = random_graph(200, 8, seed=1)
        eng = WalkEngine(g, visited_avoiding(window=16),
                         EngineConfig(method="adaptive", tile=64))
        full = eng.run(np.arange(13), num_steps=9, key=jax.random.key(3))
        slotted = eng.run(np.arange(13), num_steps=9,
                          key=jax.random.key(3), batch=4, epoch_len=2)
        np.testing.assert_array_equal(full.paths, slotted.paths)
        assert full.live_steps == slotted.live_steps
        assert full.frac_rjs == slotted.frac_rjs

    def test_compiler_analysis(self):
        wl = visited_avoiding()
        cw = analyze(wl)
        assert cw.flag == PER_STEP and cw.usable
        assert not is_static(wl)
        assert "wstate" in static_taint(wl)

    def test_bound_stays_sound_and_tight_with_state(self):
        """The tabu factor only shrinks weights, so the synthesized bound
        must equal the plain Node2Vec bound max(1/a, 1, 1/b)·h_max."""
        wl = visited_avoiding(a=2.0, b=0.5, window=4)
        cw = analyze(wl)
        bi = BoundInputs(
            h_min=jnp.float32(1.0), h_max=jnp.float32(5.0),
            h_mean=jnp.float32(2.0), deg_cur=jnp.int32(10),
            deg_prev=jnp.int32(10), cur=jnp.int32(0), prev=jnp.int32(1),
            step=jnp.int32(0),
            wstate=jnp.asarray([3, 9, -1, -1], jnp.int32))
        _, hi = cw.bound_fn(bi)
        assert float(hi) == pytest.approx(10.0)  # 1/b · h_max = 2 · 5


# ------------------------------------------------ ε-terminating PPR-Nibble
def ppr_stop_oracle(paths, degrees, alpha, eps, num_steps):
    """Recompute the mass recursion along each emitted path and check the
    walk stopped exactly when ``mass < ε·d(v)`` first held — not a step
    earlier, not a step later (dead-ends at zero-degree nodes excepted)."""
    for q in range(paths.shape[0]):
        mass, stopped = 1.0, False
        for t in range(num_steps):
            v, nxt = paths[q, t], paths[q, t + 1]
            if nxt < 0:
                assert stopped or degrees[v] == 0, \
                    (q, t, paths[q], mass, degrees[v])
                break
            assert not stopped, (q, t, paths[q])
            mass *= 1.0 - alpha
            stopped = mass < eps * degrees[v]


class TestPPRNibble:
    ALPHA, EPS = 0.3, 2e-2

    def _program(self):
        return ppr_nibble(alpha=self.ALPHA, eps=self.EPS)

    @pytest.mark.parametrize("method", ["ervs", "adaptive"])
    def test_termination_matches_exact_recursion(self, method):
        g = random_graph(200, 8, seed=1)
        eng = WalkEngine(g, self._program(),
                         EngineConfig(method=method, tile=64))
        res = eng.run(np.arange(48), num_steps=40, key=jax.random.key(1))
        assert (res.paths[:, 1:] >= 0).sum() < 48 * 40  # it DOES stop early
        ppr_stop_oracle(res.paths, np.asarray(g.degrees()),
                        self.ALPHA, self.EPS, 40)

    @pytest.mark.parametrize("method", ["ervs", "adaptive"])
    def test_chi_square_vs_exact(self, method):
        """Transition distribution is untouched by the termination logic."""
        g = random_graph(60, 6, seed=3)
        wl = self._program()
        params = wl.params()
        v, pv, st_ = 7, 3, 2
        p, nbr = exact_probs(g, wl, params, v, pv, st_, pad=PAD,
                             wstate=jnp.float32(1.0))
        eng = WalkEngine(g, wl, EngineConfig(method=method, tile=32))
        rng = jax.random.split(jax.random.key(0), N)
        state = WalkerState(
            cur=jnp.full((N,), v, jnp.int32),
            prev=jnp.full((N,), pv, jnp.int32),
            step=jnp.full((N,), st_, jnp.int32),
            alive=jnp.ones((N,), bool),
            rng=jax.random.key_data(rng),
            wstate=jnp.ones((N,), jnp.float32),
        )
        sel = eng.sampler.select(eng.sampler_ctx, state, rng,
                                 active=jnp.ones((N,), bool))
        chi2, crit = chi2_vs_exact(np.asarray(sel.next_nodes), p, nbr)
        assert chi2 < crit, f"{method}: chi2={chi2:.1f} ≥ crit={crit:.1f}"

    def test_static_sampling_composes_with_dynamic_termination(self):
        """Weights ignore wstate ⇒ still static-provable ⇒ the precomp
        regime serves terminating walks from baked tables."""
        wl = self._program()
        assert is_static(wl)
        g = random_graph(150, 8, seed=4)
        eng = WalkEngine(g, wl, EngineConfig(method="adaptive", tile=64))
        assert eng.precomp is not None
        res = eng.run(np.arange(32), num_steps=30, key=jax.random.key(2))
        assert res.frac_precomp > 0.5
        ppr_stop_oracle(res.paths, np.asarray(g.degrees()),
                        self.ALPHA, self.EPS, 30)


# -------------------------------------- telemetry under early termination
class TestStoppedWalkerTelemetry:
    """should_stop-terminated walkers must never appear in frac_rjs /
    frac_precomp live-lane telemetry — asserted two ways: the live-step
    count equals the emitted transitions exactly (a stopped lane takes no
    further live steps), and telemetry is invariant across schedules
    (mid-epoch refills into freed slots cannot skew it)."""

    def _graph_all_positive_degree(self):
        g = random_graph(150, 8, seed=6)
        assert int(np.asarray(g.degrees()).min()) > 0
        return g

    def _check(self, method, batch, epoch_len, alpha=0.3, eps=2e-2):
        g = self._graph_all_positive_degree()
        eng = WalkEngine(g, ppr_nibble(alpha=alpha, eps=eps),
                         EngineConfig(method=method, tile=64))
        key = jax.random.key(11)
        full = eng.run(np.arange(21), num_steps=25, key=key)
        slotted = eng.run(np.arange(21), num_steps=25, key=key,
                          batch=batch, epoch_len=epoch_len)
        # stopped lanes take no live steps: every live step emitted a node
        emitted = int((full.paths[:, 1:] >= 0).sum())
        assert emitted < 21 * 25  # early termination actually triggered
        assert full.live_steps == emitted
        # schedule invariance: freed slots + mid-epoch refills don't skew
        np.testing.assert_array_equal(full.paths, slotted.paths)
        assert slotted.live_steps == full.live_steps
        assert slotted.frac_rjs == full.frac_rjs
        assert slotted.frac_precomp == full.frac_precomp
        assert slotted.rjs_fallbacks == full.rjs_fallbacks

    @pytest.mark.parametrize("method,batch,epoch_len",
                             [("adaptive", 4, 2), ("adaptive", 5, 1),
                              ("ervs", 3, 3), ("erjs", 6, 2)])
    def test_deterministic_cases(self, method, batch, epoch_len):
        self._check(method, batch, epoch_len)

    @settings(max_examples=6, deadline=None)
    @given(batch=st.integers(2, 8), epoch_len=st.integers(1, 4),
           alpha=st.sampled_from([0.25, 0.4]))
    def test_property(self, batch, epoch_len, alpha):
        self._check("adaptive", batch, epoch_len, alpha=alpha)


# ------------------------------------------------------- compiler fallback
class TestWstateCompilerEdges:
    def test_nonfactorable_wstate_weight_falls_back(self):
        """wstate feeding get_weight through a primitive outside the
        abstract domain ⇒ FALLBACK (eRVS-only), never an unsound bound."""
        prog = WalkProgram(
            name="sorted-state", init=lambda: (),
            get_weight=lambda ctx, p, ws: ctx.h * jnp.sort(ws)[0],
            init_walker_state=lambda q: jnp.ones((3,), jnp.float32))
        cw = analyze(prog)
        assert cw.flag == FALLBACK and not cw.usable
        # ...and the engine still runs it (eRVS needs no bound)
        g = random_graph(80, 6, seed=0)
        eng = WalkEngine(g, prog, EngineConfig(method="ervs", tile=64))
        res = eng.run(np.arange(8), num_steps=4)
        assert res.paths.shape == (8, 5)

    def test_stateless_program_analysis_unchanged(self):
        from repro.walks import node2vec
        cw = analyze(node2vec())
        assert cw.usable and cw.flag == PER_STEP
