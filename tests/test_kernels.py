"""Per-kernel validation: bit-exact vs ref oracles (shape/dtype sweeps) and
distribution-level chi-square vs the textbook semantics."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.prng import threefry2x32, uniform_01


def make_rows(degs, seed=0, lo=0.1, hi=5.0):
    rng = np.random.default_rng(seed)
    degs = np.asarray(degs, np.int64)
    indptr = np.zeros(len(degs) + 1, np.int64)
    np.cumsum(degs, out=indptr[1:])
    vals = rng.uniform(lo, hi, int(degs.sum())).astype(np.float32)
    return ops.align_rows(vals, indptr), vals, indptr


DEG_SETS = [
    [0, 1, 5, 127, 128, 129],
    [1024, 1025, 3000],
    [7, 63, 64, 65, 2047, 2048, 2049],
]


class TestPRNG:
    def test_threefry_deterministic(self):
        a = threefry2x32(jnp.uint32(1), jnp.uint32(2), jnp.uint32(3), jnp.uint32(4))
        b = threefry2x32(jnp.uint32(1), jnp.uint32(2), jnp.uint32(3), jnp.uint32(4))
        assert int(a[0]) == int(b[0]) and int(a[1]) == int(b[1])

    def test_uniform_range_and_spread(self):
        ctr = jnp.arange(100_000, dtype=jnp.uint32)
        u = np.asarray(uniform_01(jnp.uint32(5), jnp.uint32(9), ctr, jnp.uint32(0)))
        assert (u > 0).all() and (u < 1).all()
        assert abs(u.mean() - 0.5) < 0.005
        assert abs(u.std() - (1 / 12) ** 0.5) < 0.005


class TestErvsKernel:
    @pytest.mark.parametrize("degs", DEG_SETS)
    @pytest.mark.parametrize("seed", [0, 1])
    def test_bit_exact_vs_ref(self, degs, seed):
        (w2d, row0, dg), _, _ = make_rows(degs, seed=seed)
        seeds = ops.make_seeds(jax.random.key(seed), len(degs))
        off_k, dr_k, jm_k = ops.ervs_select(w2d, row0, dg, seeds)
        off_r, dr_r, jm_r = ref.ervs_select_ref(w2d, row0, dg, seeds)
        np.testing.assert_array_equal(np.asarray(off_k), np.asarray(off_r))
        np.testing.assert_array_equal(np.asarray(dr_k), np.asarray(dr_r))
        np.testing.assert_array_equal(np.asarray(jm_k), np.asarray(jm_r))

    def test_empty_row_gives_minus_one(self):
        (w2d, row0, dg), _, _ = make_rows([0, 4])
        seeds = ops.make_seeds(jax.random.key(0), 2)
        off, _, _ = ops.ervs_select(w2d, row0, dg, seeds)
        assert int(off[0]) == -1 and 0 <= int(off[1]) < 4

    def test_selected_offset_in_range(self):
        (w2d, row0, dg), _, _ = make_rows([77, 901, 2500])
        seeds = ops.make_seeds(jax.random.key(3), 3)
        off, _, _ = ops.ervs_select(w2d, row0, dg, seeds)
        assert ((np.asarray(off) >= 0) & (np.asarray(off) < np.asarray(dg))).all()

    def test_rng_draw_reduction(self):
        """The paper's JUMP claim: E[draws] = O(log d) ≪ d."""
        (w2d, row0, dg), _, _ = make_rows([4096])
        N = 200
        seeds = ops.make_seeds(jax.random.key(0), N)
        _, draws, jumped = ref.ervs_select_ref(
            w2d, jnp.tile(row0, N), jnp.tile(dg, N), seeds)
        assert float(np.mean(np.asarray(draws))) < 30  # ~ln(4096)+slack ≪ 4096
        assert float(np.mean(np.asarray(jumped))) >= 1  # blocks actually skipped

    def test_distribution_chi_square(self):
        D, N = 200, 20_000
        (w2d, row0, dg), vals, _ = make_rows([D], seed=5)
        seeds = ops.make_seeds(jax.random.key(11), N)
        off, _, _ = ref.ervs_select_ref(
            w2d, jnp.tile(row0, N), jnp.tile(dg, N), seeds)
        p = vals / vals.sum()
        f = np.bincount(np.asarray(off), minlength=D) / N
        chi2 = float((N * ((f - p) ** 2 / p)).sum())
        # dof = 199; mean 199, std ~20 — 6 sigma guard band
        assert chi2 < 199 + 6 * (2 * 199) ** 0.5


class TestErjsKernel:
    @pytest.mark.parametrize("degs", DEG_SETS)
    def test_bit_exact_vs_ref(self, degs):
        (w2d, row0, dg), _, _ = make_rows(degs)
        seeds = ops.make_seeds(jax.random.key(2), len(degs))
        bounds = jnp.full((len(degs),), 5.0, jnp.float32)
        off_k, tr_k = ops.erjs_select(w2d, row0, dg, bounds, seeds)
        off_r, tr_r = ref.erjs_select_ref(w2d, row0, dg, bounds, seeds)
        np.testing.assert_array_equal(np.asarray(off_k), np.asarray(off_r))
        np.testing.assert_array_equal(np.asarray(tr_k), np.asarray(tr_r))

    def test_bound_invariance_distribution(self):
        """Eqs. 5–8: any c ≥ max w̃ leaves the accepted distribution p."""
        D, N = 64, 20_000
        (w2d, row0, dg), vals, _ = make_rows([D], seed=9)
        p = vals / vals.sum()
        seeds = ops.make_seeds(jax.random.key(1), N)
        freqs = []
        for c in [5.0, 8.0, 20.0]:  # exact-ish, loose, very loose bound
            off, _ = ref.erjs_select_ref(
                w2d, jnp.tile(row0, N), jnp.tile(dg, N),
                jnp.full((N,), c, jnp.float32), seeds, trials=8, max_rounds=64)
            off = np.asarray(off)
            ok = off >= 0
            f = np.bincount(off[ok], minlength=D) / ok.sum()
            chi2 = float((ok.sum() * ((f - p) ** 2 / p)).sum())
            assert chi2 < 63 + 6 * (2 * 63) ** 0.5, f"bound c={c}"
            freqs.append(f)

    def test_loose_bound_needs_more_trials(self):
        """Cost model's premise (Eq. 10): trials scale with bound/mean."""
        D, N = 64, 2000
        (w2d, row0, dg), _, _ = make_rows([D], seed=9)
        seeds = ops.make_seeds(jax.random.key(1), N)
        _, t_tight = ref.erjs_select_ref(
            w2d, jnp.tile(row0, N), jnp.tile(dg, N),
            jnp.full((N,), 5.0, jnp.float32), seeds, max_rounds=64)
        _, t_loose = ref.erjs_select_ref(
            w2d, jnp.tile(row0, N), jnp.tile(dg, N),
            jnp.full((N,), 50.0, jnp.float32), seeds, max_rounds=64)
        assert float(np.mean(np.asarray(t_loose))) > \
            2.0 * float(np.mean(np.asarray(t_tight)))


class TestTokenSampler:
    @pytest.mark.parametrize("shape", [(3, 100), (8, 512), (5, 1000), (16, 2048)])
    @pytest.mark.parametrize("temperature", [1.0, 0.7])
    def test_bit_exact_vs_ref(self, shape, temperature):
        logits = jax.random.normal(jax.random.key(0), shape) * 2.0
        seed = jnp.asarray([11, 22], jnp.uint32)
        out_k = ops.token_sample(logits, seed, temperature=temperature)
        out_r = ref.token_sample_ref(logits, seed, temperature=temperature)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    def test_greedy_is_argmax(self):
        logits = jax.random.normal(jax.random.key(4), (9, 777))
        seed = jnp.asarray([1, 2], jnp.uint32)
        out = ops.token_sample(logits, seed, greedy=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(jnp.argmax(logits, axis=1)))

    def test_distribution_matches_softmax(self):
        V, N = 32, 12_000
        logits_row = jax.random.normal(jax.random.key(2), (V,))
        logits = jnp.tile(logits_row[None, :], (N, 1))
        seed = jnp.asarray([7, 13], jnp.uint32)
        out = np.asarray(ops.token_sample(logits, seed, temperature=1.0))
        p = np.asarray(jax.nn.softmax(logits_row))
        f = np.bincount(out, minlength=V) / N
        chi2 = float((N * ((f - p) ** 2 / p)).sum())
        assert chi2 < 31 + 6 * (2 * 31) ** 0.5


def make_precomp_rows(degs, seed=0):
    """Aligned CDF + Vose tables for random weight rows, repacked through
    the same ops.aligned_precomp_tables the kernel layout is defined by."""
    from repro.core.precomp import PrecompTables, _vose_build

    (w2d, row0, dg), vals, indptr = make_rows(degs, seed=seed)
    cdf = np.zeros_like(vals)
    totals = np.zeros(len(degs), np.float32)
    for i in range(len(degs)):
        s, e = int(indptr[i]), int(indptr[i + 1])
        cdf[s:e] = np.cumsum(vals[s:e])
        if e > s:
            totals[i] = cdf[e - 1]
    alias, prob = _vose_build(vals.astype(np.float64), indptr)
    tables = PrecompTables(
        cdf=jnp.asarray(cdf), total=jnp.asarray(totals),
        alias_off=jnp.asarray(alias), alias_prob=jnp.asarray(prob),
        invalid=jnp.zeros((len(degs),), bool))
    cdf2d, prob2d, alias2d, row0, dg = ops.aligned_precomp_tables(
        tables, indptr)
    return cdf2d, prob2d, alias2d, row0, dg, jnp.asarray(totals), vals, indptr


class TestPrecompKernels:
    @pytest.mark.parametrize("degs", DEG_SETS)
    @pytest.mark.parametrize("seed", [0, 1])
    def test_its_bit_exact_vs_ref(self, degs, seed):
        cdf2d, _, _, row0, dg, totals, _, _ = make_precomp_rows(degs, seed)
        seeds = ops.make_seeds(jax.random.key(seed), len(degs))
        off_k = ops.its_search(cdf2d, row0, dg, totals, seeds)
        off_r = ref.its_search_ref(cdf2d, row0, dg, totals, seeds)
        np.testing.assert_array_equal(np.asarray(off_k), np.asarray(off_r))

    @pytest.mark.parametrize("degs", DEG_SETS)
    def test_alias_bit_exact_vs_ref(self, degs):
        _, prob2d, alias2d, row0, dg, totals, _, _ = make_precomp_rows(degs)
        seeds = ops.make_seeds(jax.random.key(7), len(degs))
        off_k = ops.alias_pick(prob2d, alias2d, row0, dg, totals, seeds)
        off_r = ref.alias_pick_ref(prob2d, alias2d, row0, dg, totals, seeds)
        np.testing.assert_array_equal(np.asarray(off_k), np.asarray(off_r))

    def test_empty_row_gives_minus_one(self):
        cdf2d, prob2d, alias2d, row0, dg, totals, _, _ = \
            make_precomp_rows([0, 4])
        seeds = ops.make_seeds(jax.random.key(0), 2)
        its = np.asarray(ops.its_search(cdf2d, row0, dg, totals, seeds))
        als = np.asarray(ops.alias_pick(prob2d, alias2d, row0, dg, totals,
                                        seeds))
        assert its[0] == -1 and 0 <= its[1] < 4
        assert als[0] == -1 and 0 <= als[1] < 4

    @pytest.mark.parametrize("which", ["its", "alias"])
    def test_distribution_chi_square(self, which):
        D, N = 200, 20_000
        cdf2d, prob2d, alias2d, row0, dg, totals, vals, _ = \
            make_precomp_rows([D], seed=5)
        seeds = ops.make_seeds(jax.random.key(11), N)
        if which == "its":
            off = ref.its_search_ref(cdf2d, jnp.tile(row0, N),
                                     jnp.tile(dg, N), jnp.tile(totals, N),
                                     seeds)
        else:
            off = ref.alias_pick_ref(prob2d, alias2d, jnp.tile(row0, N),
                                     jnp.tile(dg, N), jnp.tile(totals, N),
                                     seeds)
        p = vals / vals.sum()
        f = np.bincount(np.asarray(off), minlength=D) / N
        chi2 = float((N * ((f - p) ** 2 / p)).sum())
        # dof = 199; mean 199, std ~20 — 6 sigma guard band
        assert chi2 < 199 + 6 * (2 * 199) ** 0.5

    def test_selected_offsets_in_range(self):
        cdf2d, prob2d, alias2d, row0, dg, totals, _, _ = \
            make_precomp_rows([77, 901, 2500])
        seeds = ops.make_seeds(jax.random.key(3), 3)
        its = np.asarray(ops.its_search(cdf2d, row0, dg, totals, seeds))
        als = np.asarray(ops.alias_pick(prob2d, alias2d, row0, dg, totals,
                                        seeds))
        dgn = np.asarray(dg)
        assert ((its >= 0) & (its < dgn)).all()
        assert ((als >= 0) & (als < dgn)).all()


class TestWiredPrecompExec:
    """The engine-wired Pallas path vs the jnp selector path — the oracle
    pattern above, extended up through the engine samplers: same Threefry
    (key, counter, salt) triples, so the ``precomp_exec`` knob must never
    change an output bit, on ragged degree distributions."""

    def _graph(self):
        from repro.graphs import power_law_graph
        return power_law_graph(150, 8, weight_dist="uniform", seed=4)

    @pytest.mark.parametrize("method",
                             ["its_precomp", "alias_precomp", "adaptive"])
    def test_engine_paths_bit_identical(self, method):
        from repro.core import EngineConfig, WalkEngine
        from repro.walks import deepwalk

        g = self._graph()
        runs = {}
        for exec_path in ("jnp", "pallas"):
            eng = WalkEngine(g, deepwalk(), EngineConfig(
                method=method, tile=32, precomp_exec=exec_path))
            assert eng.precomp is not None
            runs[exec_path] = eng.run(np.arange(16), num_steps=5,
                                      key=jax.random.key(1))
        np.testing.assert_array_equal(runs["jnp"].paths,
                                      runs["pallas"].paths)
        assert runs["jnp"].frac_precomp == runs["pallas"].frac_precomp > 0
        assert runs["jnp"].frac_rjs == runs["pallas"].frac_rjs

    @pytest.mark.parametrize("kind", ["its", "alias"])
    def test_selector_matches_kernel_bitwise(self, kind):
        """Raw level: the flat-table jnp selectors vs the aligned-stream
        kernels, fed the identical per-walker keys."""
        from repro.core.ctxutil import degrees_of
        from repro.core.precomp import (alias_select, build_tables,
                                        its_select, threefry_seeds)
        from repro.walks import deepwalk

        g = self._graph()
        wl = deepwalk()
        tables = build_tables(g, wl, wl.params())
        W = 64
        cur = jnp.asarray(
            np.random.default_rng(0).integers(0, g.num_nodes, W), jnp.int32)
        rng = jax.random.split(jax.random.key(5), W)
        seeds = threefry_seeds(rng)
        vs = jnp.maximum(cur, 0)
        deg = degrees_of(g, cur)
        if kind == "its":
            off = ops.its_search(tables.cdf2d, tables.arow0[vs], deg,
                                 tables.total[vs], seeds)
            sel = its_select(g, tables, cur, rng,
                             active=jnp.ones((W,), bool))
        else:
            off = ops.alias_pick(tables.prob2d, tables.alias2d,
                                 tables.arow0[vs], deg, tables.total[vs],
                                 seeds)
            sel = alias_select(g, tables, cur, rng,
                               active=jnp.ones((W,), bool))
        start = g.indptr[vs]
        nxt_k = jnp.where(off >= 0, g.indices[jnp.clip(
            start + jnp.maximum(off, 0), 0, g.num_edges - 1)], -1)
        np.testing.assert_array_equal(np.asarray(nxt_k), np.asarray(sel))
        assert (np.asarray(off) >= 0).any()


class TestAlignRows:
    def test_roundtrip_and_alignment(self):
        degs = [3, 0, 200, 128, 1]
        (w2d, row0, dg), vals, indptr = make_rows(degs)
        flat = np.asarray(w2d).reshape(-1)
        for i, d in enumerate(degs):
            got = flat[int(row0[i]) * 128:int(row0[i]) * 128 + d]
            np.testing.assert_allclose(got, vals[indptr[i]:indptr[i] + d])
            assert int(row0[i]) * 128 % 128 == 0
