"""Structural graph mutations under live traffic — the differential
mutation-fuzzing harness.

The tentpole contract this file pins: a ``WalkEngine`` that absorbed an
arbitrary interleaving of structural edits (``apply_updates`` inserts /
deletes), re-weights, overlay compactions, partial rebuild drains and
walks must be *observationally identical* to a fresh engine built from
the equivalently mutated edge list — bit-identical paths, telemetry,
per-walker program state (wstate), node stats, and (once drained)
precomp tables, plus chi-square conformance of one-step draws against
``exact_probs`` on the mutated graph.

Property tests (hypothesis, via the optional shim) drive random op
schedules; deterministic companions drive the same harness on pinned
schedules (so the contract is exercised even without hypothesis
installed) and cover the edge cases a short random schedule rarely
hits: deleting an entire row, inserting into an emptied row,
re-weighting via upsert, compaction cadence (``compact_interval``),
and the ``update_graph`` weight-only fast path staying overlay-free.
The CI ``structural-fuzz`` job runs this file on both legs of the
``JAX_ENABLE_X64`` matrix.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (EngineConfig, WalkEngine, WalkerState, exact_probs)
from repro.graphs import CSRGraph, from_edges, node_stats, random_graph
from repro.graphs.delta import GraphDelta, host_row_layout
from repro.walks import deepwalk, visited_avoiding

V = 40
STEPS = 6
STAT_FIELDS = ("h_min", "h_max", "h_sum", "h_mean", "degree", "label_count")

# the two engine profiles the fuzzer alternates over: the precomp-table
# regime (tables spliced/invalidated/drained across mutations) and a
# stateful program (wstate equality is part of the differential check;
# dynamic weights keep precomp off, exercising the pure-overlay path)
PROFILES = {
    "tables": (lambda: deepwalk(),
               lambda: EngineConfig(method="its_precomp", tile=32,
                                    rebuild_budget=4)),
    "stateful": (lambda: visited_avoiding(window=4),
                 lambda: EngineConfig(method="adaptive", tile=32)),
}

# append-only: pinned schedules index into this tuple by position
OPS = ("insert", "delete", "reweight", "compact", "drain", "walk", "noop")


def edge_dict(graph: CSRGraph) -> dict:
    indptr = np.asarray(graph.indptr, np.int64)
    src = np.repeat(np.arange(graph.num_nodes), np.diff(indptr))
    dst = np.asarray(graph.indices, np.int64)
    h = np.asarray(graph.h)
    return {(int(s), int(d)): float(w) for s, d, w in zip(src, dst, h)}


def graph_of(edges: dict, num_nodes: int) -> CSRGraph:
    """The fresh-build oracle: ``from_edges`` of the mutated edge list."""
    ks = sorted(edges)
    src = np.array([k[0] for k in ks], np.int64)
    dst = np.array([k[1] for k in ks], np.int64)
    h = np.array([edges[k] for k in ks], np.float32)
    return from_edges(src, dst, num_nodes, h=h)


def run_with_state(eng: WalkEngine, starts, key):
    """Walk every query with a slot each (manual scheduler loop, so the
    final per-walker wstate is observable alongside paths/telemetry)."""
    sched = eng.scheduler(num_steps=STEPS, key=key, slots=len(starts),
                          epoch_len=3, capacity=len(starts))
    sched.admit(np.arange(len(starts)), np.asarray(starts, np.int32))
    while sched.busy:
        sched.run_epoch()
    wstate = jax.tree_util.tree_map(np.asarray, sched.state.wstate)
    return sched.paths.copy(), dict(sched.totals), wstate


class Harness:
    """Mutable edge-list ground truth + the live engine under test.

    Every op mutates both; :meth:`check` asserts the cheap invariants
    after each op and the full differential (fresh-build oracle engine)
    on every ``walk`` op."""

    def __init__(self, profile: str, seed: int = 3):
        program, cfg = PROFILES[profile]
        self.program_fn, self.cfg = program, cfg()
        g = random_graph(V, 5, weight_dist="uniform", seed=seed)
        self.edges = edge_dict(g)
        self.eng = WalkEngine(g, self.program_fn(), self.cfg)
        self.walks_run = 0

    # ------------------------------------------------------------- ops
    def op_insert(self, rng):
        n = int(rng.integers(1, 4))
        src = rng.integers(0, V, n)
        dst = rng.integers(0, V, n)
        h = rng.uniform(0.2, 2.0, n).astype(np.float32)
        self.eng.apply_updates(inserts=(src, dst, h))
        for s, d, w in zip(src, dst, h):
            # duplicate (src, dst) within one batch: last payload wins
            self.edges[(int(s), int(d))] = float(w)

    def op_delete(self, rng):
        if not self.edges:
            return
        ks = sorted(self.edges)
        pick = rng.choice(len(ks), size=min(int(rng.integers(1, 4)),
                                            len(ks)), replace=False)
        src = np.array([ks[i][0] for i in pick], np.int64)
        dst = np.array([ks[i][1] for i in pick], np.int64)
        self.eng.apply_updates(deletes=(src, dst))
        for s, d in zip(src, dst):
            self.edges.pop((int(s), int(d)), None)

    def op_reweight(self, rng):
        """Upsert: inserting an existing edge re-weights it in place."""
        if not self.edges:
            return
        ks = sorted(self.edges)
        i = int(rng.integers(0, len(ks)))
        s, d = ks[i]
        w = float(rng.uniform(0.2, 2.0))
        self.eng.apply_updates(inserts=([s], [d], np.float32([w])))
        self.edges[(s, d)] = w

    def op_compact(self, rng):
        self.eng.compact()
        assert not self.eng.overlay_active

    def op_noop(self, rng):
        """An apply_updates whose edit set touches nothing must be
        bit-neutral: no overlay, no mutation-clock bump (live schedulers
        keep their pinned views and prefetch carries)."""
        clock = self.eng.mutation_clock
        overlay = self.eng.overlay_active
        rep = self.eng.apply_updates(
            inserts=(np.zeros(0, np.int64), np.zeros(0, np.int64),
                     np.zeros(0, np.float32)),
            deletes=(np.zeros(0, np.int64), np.zeros(0, np.int64)))
        assert rep.touched == ()
        assert self.eng.mutation_clock == clock
        assert self.eng.overlay_active == overlay

    def op_drain(self, rng):
        self.eng.drain_rebuilds(max_rows=int(rng.integers(1, 4)))

    def op_walk(self, rng):
        """The full differential: drain both engines, walk identical
        queries, compare everything bitwise."""
        if self.walks_run >= 2:  # bound fresh-oracle builds per schedule
            return self.op_drain(rng)
        self.walks_run += 1
        starts = rng.integers(0, V, 9).astype(np.int32)
        key = jax.random.key(int(rng.integers(0, 2 ** 31)))
        oracle = WalkEngine(graph_of(self.edges, V), self.program_fn(),
                            self.cfg)
        if self.eng.overlay_active:
            # the sticky pow2 pad is monotone while the overlay is live
            # (so mutation bursts reuse the jitted epoch); oversizing is
            # bit-neutral — the differential below proves it
            assert self.eng.pad >= oracle.pad
            assert self.eng.max_tiles >= oracle.max_tiles
        else:
            assert self.eng.pad == oracle.pad
            assert self.eng.max_tiles == oracle.max_tiles
        self.eng.drain_rebuilds()
        paths, totals, wstate = run_with_state(self.eng, starts, key)
        opaths, ototals, owstate = run_with_state(oracle, starts, key)
        np.testing.assert_array_equal(paths, opaths)
        assert totals == ototals
        jax.tree_util.tree_map(np.testing.assert_array_equal, wstate,
                               owstate)
        if self.eng.precomp is not None:
            # fully drained: every row's table values match the fresh
            # build's, modulo the overlay's row layout
            assert not np.asarray(self.eng.precomp.invalid).any()
            self._assert_tables_match(oracle)

    def _assert_tables_match(self, oracle):
        es, edg = host_row_layout(self.eng.graph)
        os_, odg = host_row_layout(oracle.graph)
        np.testing.assert_array_equal(edg, odg)
        np.testing.assert_array_equal(np.asarray(self.eng.precomp.total),
                                      np.asarray(oracle.precomp.total))
        for f in ("cdf", "alias_off", "alias_prob"):
            a = np.asarray(getattr(self.eng.precomp, f))
            b = np.asarray(getattr(oracle.precomp, f))
            for v in range(V):
                np.testing.assert_array_equal(
                    a[es[v]:es[v] + edg[v]], b[os_[v]:os_[v] + odg[v]],
                    err_msg=f"{f} row {v}")

    # ------------------------------------------------------ invariants
    def check(self):
        """Cheap invariants after EVERY op."""
        # merged view == mutated edge list, bit for bit
        want = graph_of(self.edges, V)
        got = (self.eng.delta.compact() if self.eng.delta is not None
               else self.eng.graph)
        np.testing.assert_array_equal(np.asarray(got.indptr),
                                      np.asarray(want.indptr))
        np.testing.assert_array_equal(np.asarray(got.indices),
                                      np.asarray(want.indices))
        np.testing.assert_array_equal(np.asarray(got.h),
                                      np.asarray(want.h))
        # patched node stats == full recompute on the mutated graph
        fresh = node_stats(want, num_labels=max(
            self.eng.workload.num_labels, 1))
        for f in STAT_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(self.eng.stats, f)),
                np.asarray(getattr(fresh, f)), err_msg=f"stats.{f}")
        # rebuild-queue membership == stale bitmap bits
        if self.eng.precomp is not None:
            stale = set(np.nonzero(
                np.asarray(self.eng.precomp.invalid))[0].tolist())
            assert stale == set(self.eng.rebuild_queue.pending())

    def run_schedule(self, schedule):
        for kind, seed in schedule:
            getattr(self, f"op_{OPS[kind % len(OPS)]}")(
                np.random.default_rng(seed))
            self.check()
        # every schedule ends with the full differential (lift the
        # walk-op cap so it always runs, even on walk-heavy schedules)
        self.walks_run = 0
        self.op_walk(np.random.default_rng(len(schedule)))


# ------------------------------------------------------------ the fuzzer
class TestMutationFuzzer:
    @pytest.mark.slow
    @given(st.sampled_from(sorted(PROFILES)),
           st.lists(st.tuples(st.integers(0, len(OPS) - 1),
                              st.integers(0, 2 ** 16)),
                    min_size=2, max_size=8))
    @settings(max_examples=8, deadline=None)
    def test_random_schedules(self, profile, schedule):
        h = Harness(profile)
        h.walks_run = 2  # property schedules defer the walk to the end
        h.run_schedule(schedule)

    # pinned schedules through the same harness — run without hypothesis
    SCHEDULES = [
        ("tables", [(0, 11), (1, 12), (4, 13), (2, 14), (5, 15)]),
        ("tables", [(1, 21), (1, 22), (3, 23), (0, 24), (5, 25), (4, 26)]),
        ("tables", [(0, 31), (2, 32), (5, 33), (1, 34), (3, 35), (5, 36)]),
        ("stateful", [(0, 41), (1, 42), (2, 43), (5, 44), (3, 45)]),
        # noop interleavings: bit-neutral both overlay-free and mid-burst
        ("tables", [(6, 51), (0, 52), (6, 53), (5, 54), (3, 55), (6, 56)]),
    ]

    @pytest.mark.structural_smoke
    @pytest.mark.parametrize("profile,schedule", SCHEDULES)
    def test_deterministic_schedules(self, profile, schedule):
        Harness(profile).run_schedule(schedule)


# ----------------------------------------------- deterministic companions
@pytest.fixture(scope="module")
def base_graph():
    return random_graph(V, 5, weight_dist="uniform", seed=3)


def make_engine(graph, **cfg):
    defaults = dict(method="its_precomp", tile=32, rebuild_budget=4)
    defaults.update(cfg)
    return WalkEngine(graph, deepwalk(), EngineConfig(**defaults))


@pytest.mark.structural_smoke
class TestStructuralEdgeCases:
    def test_delete_entire_row_then_reinsert(self, base_graph):
        h = Harness("tables")
        indptr = np.asarray(base_graph.indptr, np.int64)
        v = int(np.argmax(np.diff(indptr) > 0))
        dst = np.asarray(base_graph.indices,
                         np.int64)[indptr[v]:indptr[v + 1]]
        h.eng.apply_updates(deletes=(np.full(dst.size, v), dst))
        for d in dst:
            h.edges.pop((v, int(d)), None)
        h.check()
        assert int(np.asarray(h.eng.stats.degree)[v]) == 0
        # walks starting at the emptied row dead-end immediately, same
        # as the oracle's
        h.op_walk(np.random.default_rng(0))
        h.eng.apply_updates(
            inserts=([v, v], [int(dst[0]), (int(dst[0]) + 1) % V],
                     np.float32([0.5, 1.5])))
        h.edges[(v, int(dst[0]))] = 0.5
        h.edges[(v, (int(dst[0]) + 1) % V)] = 1.5
        h.check()
        h.op_walk(np.random.default_rng(1))

    def test_compact_without_overlay_is_noop(self, base_graph):
        eng = make_engine(base_graph)
        g0 = eng.graph
        assert eng.compact() == 0
        assert eng.graph is g0 and eng.delta is None

    def test_out_of_range_node_rejected(self, base_graph):
        eng = make_engine(base_graph)
        with pytest.raises(ValueError, match="cannot add nodes"):
            eng.apply_updates(inserts=([V], [0], np.float32([1.0])))
        assert eng.delta is None or not len(eng.delta)

    def test_empty_update_is_noop(self, base_graph):
        eng = make_engine(base_graph)
        rep = eng.apply_updates()
        assert rep.touched == () and not eng.overlay_active

    def test_partial_drain_then_walk_matches_oracle(self, base_graph):
        """A budgeted (incomplete) drain between mutation and walk: the
        still-stale rows serve the dynamic fallback, which reads the
        overlay — paths must STILL match the fresh oracle after both
        engines drain the same remaining rows."""
        h = Harness("tables")
        h.op_insert(np.random.default_rng(5))
        h.op_delete(np.random.default_rng(6))
        h.eng.drain_rebuilds(max_rows=1)
        h.check()
        h.op_walk(np.random.default_rng(7))


@pytest.mark.structural_smoke
class TestCompactionCadence:
    def test_compact_interval_validation(self):
        with pytest.raises(ValueError, match="compact_interval"):
            EngineConfig(compact_interval=-1)
        assert EngineConfig(compact_interval=0).compact_interval == 0
        assert EngineConfig(compact_interval=3).compact_interval == 3

    def test_auto_compaction_folds_overlay_mid_run(self, base_graph):
        eng = make_engine(base_graph, compact_interval=1)
        rng = np.random.default_rng(9)
        src = rng.integers(0, V, 3)
        dst = rng.integers(0, V, 3)
        h = rng.uniform(0.2, 2.0, 3).astype(np.float32)
        eng.apply_updates(inserts=(src, dst, h))
        assert eng.overlay_active
        starts = np.arange(9, dtype=np.int32) % V
        res = eng.run(starts, num_steps=STEPS, key=jax.random.key(4))
        # the first scheduler epoch compacted the overlay (interval=1)
        assert not eng.overlay_active
        assert isinstance(eng.graph, CSRGraph)
        # and the run still matches a fresh engine on the mutated list
        edges = edge_dict(base_graph)
        for s, d, w in zip(src, dst, h):
            edges[(int(s), int(d))] = float(w)
        oracle = make_engine(graph_of(edges, V), compact_interval=1)
        oracle.drain_rebuilds()
        eng.drain_rebuilds()
        a = eng.run(starts, num_steps=STEPS, key=jax.random.key(4))
        b = oracle.run(starts, num_steps=STEPS, key=jax.random.key(4))
        np.testing.assert_array_equal(a.paths, b.paths)

    def test_epoch_clock_is_engine_absolute(self, base_graph):
        eng = make_engine(base_graph, compact_interval=4)
        starts = np.arange(5, dtype=np.int32)
        eng.run(starts, num_steps=3, key=jax.random.key(0), epoch_len=1)
        clock0 = eng.epoch_clock
        assert clock0 > 0
        eng.run(starts, num_steps=3, key=jax.random.key(0), epoch_len=1)
        assert eng.epoch_clock > clock0  # runs share one timeline


@pytest.mark.structural_smoke
class TestWeightOnlyFastPath:
    """Satellite: update_graph stays the overlay-free weight path and
    its topology error points at apply_updates."""

    def test_weight_update_stays_overlay_free(self, base_graph):
        eng = make_engine(base_graph)
        g2 = dataclasses.replace(base_graph,
                                 h=base_graph.h * np.float32(1.5))
        eng.update_graph(g2, invalidated=np.arange(4))
        assert eng.delta is None and not eng.overlay_active
        assert isinstance(eng.graph, CSRGraph)
        assert len(eng.rebuild_queue) == 4

    def test_topology_error_names_apply_updates(self, base_graph):
        eng = make_engine(base_graph)
        smaller = graph_of(dict(list(edge_dict(base_graph).items())[:-3]),
                           V)
        with pytest.raises(ValueError, match="apply_updates"):
            eng.update_graph(smaller)

    def test_update_graph_while_overlay_active_raises(self, base_graph):
        eng = make_engine(base_graph)
        eng.apply_updates(inserts=([0], [1], np.float32([1.0])))
        assert eng.overlay_active
        g2 = dataclasses.replace(base_graph,
                                 h=base_graph.h * np.float32(2.0))
        with pytest.raises(ValueError, match="compact"):
            eng.update_graph(g2, invalidated=[0])


@pytest.mark.structural_smoke
class TestChiSquareOnMutatedGraph:
    def test_one_step_draws_match_exact_probs(self, base_graph):
        """Sampled transitions on the overlay conform to the exact
        distribution of the mutated graph (chi-square, p ~ 1e-4)."""
        from test_conformance import chi2_vs_exact

        eng = make_engine(base_graph)
        indptr = np.asarray(base_graph.indptr, np.int64)
        v = int(np.argmax(np.diff(indptr)))  # highest-degree row
        dst = np.asarray(base_graph.indices,
                         np.int64)[indptr[v]:indptr[v + 1]]
        # delete one edge, insert two, re-weight one — then sample at v
        eng.apply_updates(
            inserts=([v, v, v],
                     [int(dst[1]), (v + 1) % V, (v + 2) % V],
                     np.float32([2.5, 0.7, 1.3])),
            deletes=([v], [int(dst[0])]))
        eng.drain_rebuilds()
        wl = eng.workload
        p, nbr = exact_probs(eng.graph, wl, wl.params(), v, -1, 0,
                             pad=eng.pad)
        assert p.sum() > 0
        N = 2500
        rng = jax.random.split(jax.random.key(0), N)
        state = WalkerState(
            cur=jnp.full((N,), v, jnp.int32),
            prev=jnp.full((N,), -1, jnp.int32),
            step=jnp.zeros((N,), jnp.int32),
            alive=jnp.ones((N,), bool),
            rng=jax.random.key_data(rng),
        )
        sel = eng.sampler.select(eng.sampler_ctx, state, rng,
                                 active=jnp.ones((N,), bool))
        out = np.asarray(sel.next_nodes)
        served = out[out >= 0]
        assert len(served) > 0.8 * N
        chi2, crit = chi2_vs_exact(served, p, nbr)
        assert chi2 < crit, f"chi2={chi2:.1f} >= crit={crit:.1f}"


# ------------------------------------------------ retrace-bounded bursts
@pytest.mark.structural_smoke
class TestRetraceBounds:
    """Satellite: K apply_updates bursts inside one pad/capacity bucket
    must reuse the once-jitted epochs — the trace counters (bumped only
    at compile time) stay O(log K), never O(K).  The seed rebuilt the
    jit wrapper on every mutation, recompiling per burst."""

    K = 12

    def test_staged_epoch_traces_log_bounded(self, base_graph):
        eng = make_engine(base_graph)
        starts = np.arange(8, dtype=np.int32)
        key = jax.random.key(0)
        eng.walk_batch(starts, key, num_steps=4)
        t0 = eng.staged_traces
        assert t0 >= 1
        E0 = int(base_graph.num_edges)
        rng = np.random.default_rng(7)
        shapes = set()
        for _ in range(self.K):
            s, d = int(rng.integers(0, V)), int(rng.integers(0, V))
            eng.apply_updates(inserts=([s], [d], np.float32([1.25])))
            shapes.add((int(eng.graph.num_edges), eng.pad))
            eng.walk_batch(starts, key, num_steps=4)
        burst_traces = eng.staged_traces - t0
        # every retrace needs a new (pow2 patch capacity, pow2 pad)
        # bucket, +1 for the CSR→overlay pytree-type switch
        assert burst_traces <= len(shapes) + 1
        cap = int(eng.graph.num_edges) - E0
        assert len(shapes) <= max(cap.bit_length(), 2)
        assert burst_traces < self.K

    def test_fused_epoch_traces_log_bounded(self, base_graph):
        eng = WalkEngine(base_graph, deepwalk(),
                         EngineConfig(method="ervs", tile=32,
                                      step_exec="fused"))
        assert eng.step_exec_resolved == "fused"
        starts = np.arange(8, dtype=np.int32)
        key = jax.random.key(0)
        eng.walk_batch(starts, key, num_steps=4)
        t0 = eng.fused_traces
        assert t0 >= 1
        rng = np.random.default_rng(11)
        shapes = set()
        K = 8
        for _ in range(K):
            s, d = int(rng.integers(0, V)), int(rng.integers(0, V))
            eng.apply_updates(inserts=([s], [d], np.float32([0.8])))
            assert eng.step_exec_resolved == "fused"
            shapes.add(tuple(int(st_.shape[0]) for st_ in eng._fused_streams)
                       + (eng.max_tiles,))
            eng.walk_batch(starts, key, num_steps=4)
        burst_traces = eng.fused_traces - t0
        # pow2 row-bucketed streams: one trace per distinct stream shape
        assert burst_traces <= len(shapes) + 1
        assert burst_traces < K


# ------------------------------------------- compact carries patched stats
@pytest.mark.structural_smoke
class TestCompactKeepsPatchedStats:
    """Satellite: compact() must carry the incrementally-patched node
    stats (bitwise equal to a fresh recompute, pinned by the fuzzer's
    check()) instead of recomputing node_stats(graph) — the recompute
    was the last O(V·deg) step on the compaction path."""

    def test_compact_does_not_recompute_stats(self, base_graph,
                                              monkeypatch):
        import repro.core.runtime as runtime_mod
        eng = make_engine(base_graph)
        rng = np.random.default_rng(3)
        src = rng.integers(0, V, 5)
        dst = rng.integers(0, V, 5)
        eng.apply_updates(inserts=(src, dst,
                                   rng.uniform(0.2, 2.0, 5)
                                   .astype(np.float32)))
        eng.apply_updates(deletes=(src[:2], dst[:2]))
        assert eng.overlay_active

        def _boom(*a, **k):
            raise AssertionError("compact() recomputed node_stats")

        monkeypatch.setattr(runtime_mod, "node_stats", _boom)
        eng.compact()
        monkeypatch.undo()
        fresh = node_stats(eng.graph,
                           num_labels=max(eng.workload.num_labels, 1))
        for f in STAT_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(eng.stats, f)),
                np.asarray(getattr(fresh, f)), err_msg=f"stats.{f}")


# --------------------------------------------- aligned-stream re-attach
@pytest.mark.structural_smoke
class TestAlignedStreamGuard:
    """Satellite: an engine whose precomp draws resolve to the Pallas
    kernels must never reach a kernel DMA with the per-kind aligned
    streams absent — present at init, dropped (with arow0) while the
    overlay holds the tables in the overlay layout, re-attached by
    compact(); a hand-stripped table errors, never a silent wrong
    draw."""

    ALIGNED = ("cdf2d", "prob2d", "alias2d", "arow0")

    def test_overlay_cycle_reattaches_streams(self, base_graph):
        eng = make_engine(base_graph, precomp_exec="pallas")
        for f in self.ALIGNED:
            assert getattr(eng.precomp, f) is not None, f
        eng.apply_updates(inserts=([1], [2], np.float32([1.0])))
        # overlay layout: grow_tables drops the whole aligned set, so
        # the pallas branch (gated on arow0) cleanly stands down to the
        # bit-identical jnp selectors
        for f in self.ALIGNED:
            assert getattr(eng.precomp, f) is None, f
        eng.compact()
        for f in self.ALIGNED:
            assert getattr(eng.precomp, f) is not None, f

    def test_auto_resolution_on_tpu_attaches_streams(self, base_graph,
                                                     monkeypatch):
        monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
        eng = make_engine(base_graph, step_exec="staged")  # precomp_exec
        for f in self.ALIGNED:                             # defaults auto
            assert getattr(eng.precomp, f) is not None, f
        eng.apply_updates(inserts=([1], [2], np.float32([1.0])))
        eng.compact()
        for f in self.ALIGNED:
            assert getattr(eng.precomp, f) is not None, f

    def test_partially_stripped_tables_error_loudly(self, base_graph):
        eng = make_engine(base_graph, precomp_exec="pallas")
        eng.precomp = dataclasses.replace(eng.precomp, cdf2d=None)
        eng.sampler_ctx = dataclasses.replace(eng.sampler_ctx,
                                              precomp=eng.precomp)
        N = 8
        rng = jax.random.split(jax.random.key(0), N)
        state = WalkerState(
            cur=jnp.zeros((N,), jnp.int32),
            prev=jnp.full((N,), -1, jnp.int32),
            step=jnp.zeros((N,), jnp.int32),
            alive=jnp.ones((N,), bool),
            rng=jax.random.key_data(rng),
        )
        with pytest.raises(RuntimeError, match="aligned"):
            eng.sampler.select(eng.sampler_ctx, state, rng,
                               active=jnp.ones((N,), bool))


# ----------------------------------------------- fused over the overlay
@pytest.mark.structural_smoke
class TestFusedOverOverlay:
    """Tentpole leg (c): reservoir/rejection fused engines keep the
    mega-step kernel while a structural overlay is active — bit-identical
    to the staged scan on the same mutated graph — and precomp regimes
    stand down until compact() restores the aligned table streams."""

    @pytest.mark.parametrize("method", ["ervs", "erjs"])
    def test_fused_stays_fused_and_bit_identical(self, base_graph, method):
        cfg = dict(method=method, tile=32)
        fused = WalkEngine(base_graph, deepwalk(),
                           EngineConfig(step_exec="fused", **cfg))
        staged = WalkEngine(base_graph, deepwalk(),
                            EngineConfig(step_exec="staged", **cfg))
        assert fused.step_exec_resolved == "fused"
        rng = np.random.default_rng(13)
        for eng in (fused, staged):
            eng.apply_updates(
                inserts=(np.array([0, 3, 7]), np.array([5, 1, 2]),
                         np.float32([1.5, 0.4, 2.2])),
                deletes=(np.array([1]), np.array([0])))
        assert fused.overlay_active and staged.overlay_active
        assert fused.step_exec_resolved == "fused"
        assert staged.step_exec_resolved == "staged"
        starts = rng.integers(0, V, 8).astype(np.int32)
        key = jax.random.key(21)
        pf, sf = fused.walk_batch(starts, key, num_steps=STEPS)
        ps, ss = staged.walk_batch(starts, key, num_steps=STEPS)
        np.testing.assert_array_equal(np.asarray(pf), np.asarray(ps))
        np.testing.assert_array_equal(np.asarray(sf.live),
                                      np.asarray(ss.live))
        np.testing.assert_array_equal(np.asarray(sf.rjs_served),
                                      np.asarray(ss.rjs_served))
        # compact() folds the overlay; the fused path stays up throughout
        fused.compact()
        staged.compact()
        assert fused.step_exec_resolved == "fused"
        pf2, _ = fused.walk_batch(starts, key, num_steps=STEPS)
        ps2, _ = staged.walk_batch(starts, key, num_steps=STEPS)
        np.testing.assert_array_equal(np.asarray(pf2), np.asarray(ps2))

    def test_precomp_kind_stays_staged_until_compact(self, base_graph):
        eng = make_engine(base_graph, step_exec="fused")
        assert (eng._fused_kind or "").startswith("precomp")
        assert eng.step_exec_resolved == "fused"
        eng.apply_updates(inserts=([2], [4], np.float32([1.1])))
        # overlay-layout tables carry no aligned streams, so the table-
        # regime kernel stands down (staged scan is bit-identical)
        assert eng.step_exec_resolved == "staged"
        eng.compact()
        assert eng.step_exec_resolved == "fused"
