"""Cross-sampler conformance suite — the single harness every registry
sampler must pass.

Parametrized over **every** entry in ``available_samplers()`` × three
program classes (static, dynamic, stateful), asserting the three
contracts the engine relies on:

(a) chi-square agreement of one-step draws with ``exact_probs``,
(b) streaming-refill bit-invariance (``run`` with a small slot pool and
    short epochs reproduces the single-batch run bit for bit), and
(c) telemetry mass conservation (the live-lane regime fractions —
    rjs / precomp / stale, with the reservoir share as the remainder —
    are each in [0, 1] and sum to 1).

Registry-driven: a future ``register_sampler`` entry is tested with zero
new code here (the parametrize list is read from the registry at
collection).  The CI ``conformance-x64`` job runs this file with
``JAX_ENABLE_X64`` toggled both ways, so float64 table builds against
float32 sampling paths are exercised in both global configurations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EngineConfig, WalkEngine, WalkerState,
                        available_samplers, exact_probs)
from repro.graphs import random_graph
from repro.walks import deepwalk, node2vec, visited_avoiding

N = 2500
PAD = 64
TABU_WINDOW = 4

# one program per class the paper's sampler matrix must cover: static
# (precomp-table-provable), dynamic (second-order weights), stateful
# (per-walker wstate feeding get_weight)
PROGRAMS = {
    "static": deepwalk,
    "dynamic": node2vec,
    "stateful": lambda: visited_avoiding(window=TABU_WINDOW),
}


def chi2_critical(df: int, z: float = 3.7) -> float:
    """Wilson–Hilferty upper-tail chi-square quantile (z=3.7 ≈ p 1e-4)."""
    a = 2.0 / (9.0 * df)
    return df * (1.0 - a + z * np.sqrt(a)) ** 3


def chi2_vs_exact(out, p, nbr):
    support = nbr[(nbr >= 0) & (p > 0)]
    probs = p[(nbr >= 0) & (p > 0)]
    assert np.isin(out, support).all(), \
        f"sampled outside the support: {set(out) - set(support)}"
    counts = np.array([(out == v).sum() for v in support])
    expected = probs / probs.sum() * len(out)
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    return chi2, chi2_critical(len(support) - 1)


@pytest.fixture(scope="module")
def graph():
    return random_graph(60, 6, weight_dist="uniform", seed=3)


def one_step_setup(graph, kind):
    """(program, params, fixture wstate) for one-step distribution checks.
    The stateful program gets a non-empty tabu ring, so its exact oracle
    is genuinely renormalised (a tabu neighbour is excluded)."""
    wl = PROGRAMS[kind]()
    params = wl.params()
    wstate = None
    if kind == "stateful":
        indptr, indices = np.asarray(graph.indptr), np.asarray(graph.indices)
        nbrs = indices[indptr[7]:indptr[8]]
        assert len(nbrs) >= 2, "fixture node needs >= 2 neighbours"
        wstate = jnp.asarray([int(nbrs[0])] + [-1] * (TABU_WINDOW - 1),
                             jnp.int32)
    return wl, params, wstate


class TestChiSquareVsExact:
    @pytest.mark.parametrize("kind", sorted(PROGRAMS))
    @pytest.mark.parametrize("method", available_samplers())
    def test_one_step_distribution(self, method, kind, graph):
        wl, params, wstate = one_step_setup(graph, kind)
        v, pv, st_ = 7, 3, 2
        p, nbr = exact_probs(graph, wl, params, v, pv, st_, pad=PAD,
                             wstate=wstate)
        assert p.sum() > 0
        eng = WalkEngine(graph, wl, EngineConfig(method=method, tile=32))
        rng = jax.random.split(jax.random.key(0), N)
        ws_batch = None if wstate is None else jnp.broadcast_to(
            wstate, (N, TABU_WINDOW))
        state = WalkerState(
            cur=jnp.full((N,), v, jnp.int32),
            prev=jnp.full((N,), pv, jnp.int32),
            step=jnp.full((N,), st_, jnp.int32),
            alive=jnp.ones((N,), bool),
            rng=jax.random.key_data(rng),
            wstate=ws_batch,
        )
        sel = eng.sampler.select(eng.sampler_ctx, state, rng,
                                 active=jnp.ones((N,), bool))
        out = np.asarray(sel.next_nodes)
        # rejection-style samplers may leave a few lanes unresolved (-1);
        # unresolved lanes are candidate-independent, so dropping them
        # does not bias the accepted distribution
        served = out[out >= 0]
        assert len(served) > 0.8 * N, \
            f"{method}/{kind}: only {len(served)}/{N} lanes served"
        chi2, crit = chi2_vs_exact(served, p, nbr)
        assert chi2 < crit, \
            f"{method}/{kind}: chi2={chi2:.1f} >= crit={crit:.1f}"


class TestStreamingAndTelemetry:
    @pytest.mark.parametrize("kind", sorted(PROGRAMS))
    @pytest.mark.parametrize("method", available_samplers())
    def test_refill_bit_invariance_and_mass_conservation(self, method, kind,
                                                         graph):
        wl = PROGRAMS[kind]()
        eng = WalkEngine(graph, wl, EngineConfig(method=method, tile=32))
        starts = np.arange(11) % graph.num_nodes
        full = eng.run(starts, num_steps=6, key=jax.random.key(2))
        slotted = eng.run(starts, num_steps=6, key=jax.random.key(2),
                          batch=3, epoch_len=2)
        # (b) the scheduler contract: paths AND telemetry are independent
        # of slot count / epoch length, for every sampler × program class
        np.testing.assert_array_equal(full.paths, slotted.paths)
        assert full.frac_rjs == slotted.frac_rjs
        assert full.frac_precomp == slotted.frac_precomp
        assert full.frac_stale == slotted.frac_stale
        assert full.rjs_fallbacks == slotted.rjs_fallbacks
        # (c) mass conservation over live lanes: each step a live lane is
        # served by exactly one regime, so the fractions are in [0, 1]
        # and sum to 1 with the reservoir share as the remainder
        for res in (full, slotted):
            for frac in (res.frac_rjs, res.frac_precomp, res.frac_stale):
                assert 0.0 <= frac <= 1.0
            reservoir = 1.0 - (res.frac_rjs + res.frac_precomp
                               + res.frac_stale)
            assert -1e-9 <= reservoir <= 1.0
            # emitted transitions never exceed live walker-steps (lanes
            # may be live yet dead-end, never the other way around)
            assert int((res.paths[:, 1:] >= 0).sum()) <= res.live_steps
            assert res.rebuilt_rows == 0  # nothing was invalidated


class TestFusedStepExec:
    """step_exec matrix over the FULL registry × program classes.

    For every cell, an engine forced to ``step_exec="fused"`` must produce
    byte-identical paths and telemetry to the staged engine — either
    because the cell genuinely runs the mega-step kernel (``FUSED_CELLS``)
    or because the resolver correctly fell back to the staged scan.  The
    fused engine is also held to the streaming-refill contract (small slot
    pool, short epochs)."""

    # (method, program class) cells the resolver must ACTUALLY fuse:
    # a fusable static program × a sampler with a fused regime.  Every
    # other cell must resolve staged (never error, never diverge).
    FUSED_CELLS = {
        ("ervs", "static"), ("erjs", "static"),
        ("its_precomp", "static"), ("alias_precomp", "static"),
    }

    @pytest.mark.parametrize("kind", sorted(PROGRAMS))
    @pytest.mark.parametrize("method", available_samplers())
    def test_fused_bit_identical_or_clean_fallback(self, method, kind,
                                                   graph):
        wl = PROGRAMS[kind]()
        staged = WalkEngine(graph, wl, EngineConfig(
            method=method, tile=32, step_exec="staged"))
        fused = WalkEngine(graph, wl, EngineConfig(
            method=method, tile=32, step_exec="fused"))
        expected = ("fused" if (method, kind) in self.FUSED_CELLS
                    else "staged")
        assert fused.step_exec_resolved == expected
        starts = np.arange(11) % graph.num_nodes
        a = staged.run(starts, num_steps=6, key=jax.random.key(2))
        b = fused.run(starts, num_steps=6, key=jax.random.key(2))
        c = fused.run(starts, num_steps=6, key=jax.random.key(2),
                      batch=3, epoch_len=2)
        for res in (b, c):
            np.testing.assert_array_equal(a.paths, res.paths)
            assert a.frac_rjs == res.frac_rjs
            assert a.frac_precomp == res.frac_precomp
            assert a.frac_stale == res.frac_stale
            assert a.rjs_fallbacks == res.rjs_fallbacks
            assert a.live_steps == res.live_steps


class TestServiceConformance:
    """Walk-as-a-service over the FULL registry × program classes.

    The serving loop (repro/serving/walk_service.py) is the batch engine
    wearing a queue: for every ``available_samplers()`` entry × program
    class, queries served through ``WalkService`` — admitted into slots
    at epoch boundaries, streamed back as they finish — must match the
    batch-mode ``run`` bit for bit, paths AND telemetry.  Registry-driven
    like the rest of this file: a future ``register_sampler`` entry is
    held to the serving contract with zero new code here.  The CI
    ``service`` job runs these cells on both legs of the
    ``JAX_ENABLE_X64`` matrix.
    """

    @pytest.mark.parametrize("kind", sorted(PROGRAMS))
    @pytest.mark.parametrize("method", available_samplers())
    def test_served_paths_and_telemetry_match_batch_run(self, method, kind,
                                                        graph):
        from repro.serving import (ServiceConfig, SimClock, WalkQuery,
                                   WalkService)
        wl = PROGRAMS[kind]()
        svc = WalkService(
            graph,
            ServiceConfig(slots=3, epoch_len=2, num_steps=6, seed=2),
            EngineConfig(method=method, tile=32),
            programs={"prog": wl}, clock=SimClock())
        starts = np.arange(11) % graph.num_nodes
        receipts = [svc.submit(WalkQuery(start=int(s), program="prog"))
                    for s in starts]
        served = {s.ticket: s for s in svc.drain()}
        st_ = svc.stats()
        assert st_.conserves() and st_.completed == len(starts)
        # the tenant's own engine replays the same queries batch-mode —
        # identical tables, identical streams, so equality is exact
        eng = svc.tenant("prog").engine
        ref = eng.run(starts, num_steps=6, key=jax.random.key(2))
        got = np.stack([served[r.ticket].path for r in receipts])
        np.testing.assert_array_equal(got, ref.paths)
        # telemetry bit-for-bit: same regime served every live step
        assert st_.live_steps == ref.live_steps
        assert st_.frac_rjs == ref.frac_rjs
        assert st_.frac_precomp == ref.frac_precomp
        assert st_.frac_stale == ref.frac_stale
        assert st_.rebuilt_rows == ref.rebuilt_rows == 0


def structural_burst(graph, seed=11):
    """A deterministic insert/delete burst for mid-stream mutation cells:
    8 existing edges deleted, 12 random edges inserted (an insert hitting
    a surviving edge re-weights it — upsert semantics)."""
    rng = np.random.default_rng(seed)
    V = graph.num_nodes
    indptr = np.asarray(graph.indptr, np.int64)
    indices = np.asarray(graph.indices, np.int64)
    src_all = np.repeat(np.arange(V), np.diff(indptr))
    pick = rng.choice(indices.size, size=8, replace=False)
    deletes = (src_all[pick], indices[pick])
    inserts = (rng.integers(0, V, 12), rng.integers(0, V, 12),
               rng.uniform(0.5, 1.5, 12).astype(np.float32))
    return deletes, inserts


class TestStructuralConformance:
    """Structural edits under live traffic over the FULL registry ×
    program classes.

    For every ``available_samplers()`` entry × program class: an engine
    absorbs a mid-stream insert/delete burst through
    ``WalkEngine.apply_updates`` — walks keep running over the overlay
    while the touched precomp rows are stale — and, once the rebuild
    queue drains, must match a fresh engine built from the mutated edge
    list bit for bit, paths AND telemetry.  Registry-driven like the
    rest of this file; the deeper op-interleaving coverage lives in
    ``tests/test_structural.py`` (the differential mutation fuzzer).
    """

    @pytest.mark.parametrize("kind", sorted(PROGRAMS))
    @pytest.mark.parametrize("method", available_samplers())
    def test_mutated_engine_matches_fresh_build(self, method, kind, graph):
        from test_structural import edge_dict, graph_of
        wl = PROGRAMS[kind]()
        eng = WalkEngine(graph, wl, EngineConfig(method=method, tile=32))
        starts = np.arange(11) % graph.num_nodes
        # traffic before the burst, so the mutation lands on a warm engine
        pre = eng.run(starts, num_steps=4, key=jax.random.key(1))
        assert int((pre.paths >= 0).sum()) > 0
        deletes, inserts = structural_burst(graph)
        edges = edge_dict(graph)
        eng.apply_updates(deletes=deletes)
        for s, d in zip(*deletes):
            edges.pop((int(s), int(d)), None)
        eng.apply_updates(inserts=inserts)
        for s, d, w in zip(*inserts):
            edges[(int(s), int(d))] = float(w)
        # live traffic over the overlay: stale rows serve the dynamic
        # fallback; the run completes and telemetry conserves mass
        mid = eng.run(starts, num_steps=6, key=jax.random.key(2))
        total = mid.frac_rjs + mid.frac_precomp + mid.frac_stale
        assert -1e-9 <= 1.0 - total <= 1.0
        # drained, the mutated engine IS the fresh build: identical
        # paths, telemetry, and streaming-refill behaviour
        eng.drain_rebuilds()
        fresh = WalkEngine(graph_of(edges, graph.num_nodes), wl,
                           EngineConfig(method=method, tile=32))
        assert eng.pad == fresh.pad
        a = eng.run(starts, num_steps=6, key=jax.random.key(2))
        b = fresh.run(starts, num_steps=6, key=jax.random.key(2))
        c = eng.run(starts, num_steps=6, key=jax.random.key(2),
                    batch=3, epoch_len=2)
        for res in (b, c):
            np.testing.assert_array_equal(a.paths, res.paths)
            assert a.frac_rjs == res.frac_rjs
            assert a.frac_precomp == res.frac_precomp
            assert a.frac_stale == res.frac_stale
            assert a.live_steps == res.live_steps

    def test_service_absorbs_structural_burst_mid_serve(self, graph):
        """The service path: a structural burst lands while queries are
        in flight; every query still completes and the ledger conserves."""
        from repro.serving import (ServiceConfig, SimClock, WalkQuery,
                                   WalkService)
        svc = WalkService(
            graph,
            ServiceConfig(slots=3, epoch_len=2, num_steps=6, seed=2),
            EngineConfig(method="its_precomp", tile=32, rebuild_budget=4),
            programs={"prog": deepwalk()}, clock=SimClock())
        starts = np.arange(11) % graph.num_nodes
        receipts = [svc.submit(WalkQuery(start=int(s), program="prog"))
                    for s in starts]
        served = list(svc.step())  # some walkers are now mid-walk
        deletes, inserts = structural_burst(graph)
        reports = svc.apply_updates(inserts=inserts, deletes=deletes)
        assert reports["prog"].touched
        served += list(svc.drain())
        st_ = svc.stats()
        assert st_.conserves() and st_.completed == len(receipts)
        # every path is a walk on SOME consistent graph view: each
        # transition's endpoint was a neighbour before or after the burst
        assert all(s.status == "completed" for s in served)
        # the service's admission-graph view compacted eagerly; the
        # tenant engine's merged overlay view is the same graph
        eng = svc.tenant("prog").engine
        merged = (eng.delta.compact() if eng.delta is not None
                  else eng.graph)
        np.testing.assert_array_equal(np.asarray(merged.indptr),
                                      np.asarray(svc.graph.indptr))
        np.testing.assert_array_equal(np.asarray(merged.indices),
                                      np.asarray(svc.graph.indices))
        np.testing.assert_array_equal(np.asarray(merged.h),
                                      np.asarray(svc.graph.h))


class TestEngineConfigValidation:
    """The __post_init__ guards for the new knobs mirror the existing
    unknown-sampler error: fail fast, name the valid choices."""

    def test_unknown_method_names_known_samplers(self):
        with pytest.raises(ValueError) as ei:
            EngineConfig(method="definitely_not_registered")
        for name in available_samplers():
            assert name in str(ei.value)

    def test_unknown_precomp_exec_names_choices(self):
        with pytest.raises(ValueError) as ei:
            EngineConfig(precomp_exec="cuda")
        msg = str(ei.value)
        for choice in ("auto", "jnp", "pallas"):
            assert choice in msg

    @pytest.mark.parametrize("choice", ["auto", "jnp", "pallas"])
    def test_valid_precomp_exec_accepted(self, choice):
        assert EngineConfig(precomp_exec=choice).precomp_exec == choice

    def test_negative_rebuild_budget_rejected(self):
        with pytest.raises(ValueError, match="rebuild_budget"):
            EngineConfig(rebuild_budget=-1)

    @pytest.mark.parametrize("budget", [0, 1, 64])
    def test_nonnegative_rebuild_budget_accepted(self, budget):
        assert EngineConfig(rebuild_budget=budget).rebuild_budget == budget

    def test_unknown_step_exec_names_choices(self):
        with pytest.raises(ValueError) as ei:
            EngineConfig(step_exec="warp")
        msg = str(ei.value)
        for choice in ("auto", "fused", "staged"):
            assert choice in msg

    @pytest.mark.parametrize("choice", ["auto", "fused", "staged"])
    def test_valid_step_exec_accepted(self, choice):
        assert EngineConfig(step_exec=choice).step_exec == choice

    def test_rebuild_interval_must_be_positive(self):
        with pytest.raises(ValueError, match="rebuild_interval"):
            EngineConfig(rebuild_interval=0)

    @pytest.mark.parametrize("interval", [1, 4])
    def test_valid_rebuild_interval_accepted(self, interval):
        assert EngineConfig(
            rebuild_interval=interval).rebuild_interval == interval

    def test_negative_compact_interval_rejected(self):
        with pytest.raises(ValueError, match="compact_interval"):
            EngineConfig(compact_interval=-1)

    @pytest.mark.parametrize("interval", [0, 1, 8])
    def test_nonnegative_compact_interval_accepted(self, interval):
        assert EngineConfig(
            compact_interval=interval).compact_interval == interval
