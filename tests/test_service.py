"""Walk-as-a-service harness: deterministic simulated-clock trace tests.

The serving loop (repro/serving/walk_service.py) must be *provably* the
batch engine wearing a queue: every served path bit-identical to the
equivalent offline ``WalkEngine.run``, every counter conserved after
every scripted event, every admission decision replayable.  A
:class:`~repro.serving.SimClock` plus pinned seeds make whole traces —
bursts, overload, deadline storms, mid-serve graph mutation — exact
replays, so these tests assert equality, not tolerances.

Layers under test here:
* ``serving.stats``      — exact percentiles vs numpy on edge cases
* ``AdmissionQueue``     — priority/FIFO/aging/expiry ordering, plus
                           hypothesis property tests over random
                           admit/complete/expire interleavings
* ``WalkService``        — bit-identity vs offline runs, counter
                           conservation, deadline + rejection semantics
* ``launch.serve_walks`` — the CLI sustains a scripted overload trace
                           without deadlock and reports the SLO counters
"""
import dataclasses
import math
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st
from repro.core import EngineConfig, WalkEngine
from repro.graphs import random_graph
from repro.launch import serve_walks
from repro.serving import (REJECT_DEADLINE, REJECT_QUEUE_FULL,
                           REJECT_UNKNOWN_PROGRAM, AdmissionQueue,
                           LatencyWindow, ServiceConfig, SimClock,
                           WalkQuery, WalkService, percentile)
from repro.walks import make_workload

STEPS = 6
KEYSEED = 2


@pytest.fixture(scope="module")
def graph():
    return random_graph(60, 6, weight_dist="uniform", seed=3)


def make_service(graph, clock, *, slots=4, epoch_len=2, max_pending=1024,
                 min_service_time=0.0, aging_interval=0.0,
                 method="ervs", rebuild_budget=0, programs=None,
                 fairness="drr", quantum=None, weights=None):
    return WalkService(
        graph,
        ServiceConfig(slots=slots, epoch_len=epoch_len, num_steps=STEPS,
                      max_pending=max_pending, aging_interval=aging_interval,
                      min_service_time=min_service_time, seed=KEYSEED,
                      fairness=fairness, quantum=quantum, weights=weights),
        EngineConfig(method=method, tile=32, rebuild_budget=rebuild_budget),
        programs=programs, clock=clock)


def offline_paths(graph, program_name, starts, *, method="ervs",
                  batch=None, epoch_len=None):
    """The ground truth: a plain batch run over the same queries."""
    eng = WalkEngine(graph, make_workload(program_name),
                     EngineConfig(method=method, tile=32))
    res = eng.run(np.asarray(starts), num_steps=STEPS,
                  key=jax.random.key(KEYSEED), batch=batch,
                  epoch_len=epoch_len)
    return res.paths


def check_conserved(svc):
    st_ = svc.stats()
    assert st_.conserves(), st_
    assert st_.occupancy <= st_.slots
    return st_


# --------------------------------------------------------------------------
# serving.stats — exact percentiles (satellite 3)
# --------------------------------------------------------------------------
class TestLatencyStats:
    def test_empty_window_is_nan(self):
        w = LatencyWindow(8)
        assert math.isnan(w.p50) and math.isnan(w.p99)
        assert math.isnan(percentile([], 50.0))

    def test_single_sample_is_every_percentile(self):
        w = LatencyWindow(8)
        w.add(3.25)
        for q in (0.0, 1.0, 50.0, 99.0, 100.0):
            assert w.percentile(q) == 3.25

    def test_ties_match_numpy(self):
        vals = [2.0, 2.0, 2.0, 5.0, 5.0, 1.0, 1.0]
        for q in (0, 10, 25, 50, 75, 90, 99, 100):
            assert percentile(vals, q) == pytest.approx(
                float(np.percentile(vals, q)), abs=0, rel=0)

    def test_random_windows_match_numpy_exactly(self):
        rng = np.random.default_rng(0)
        for n in (1, 2, 3, 7, 64, 257):
            vals = rng.normal(size=n)
            for q in (0, 13.7, 50, 86.5, 99, 100):
                assert percentile(vals, q) == float(np.percentile(vals, q))

    def test_ring_wraparound_keeps_most_recent(self):
        w = LatencyWindow(4)
        for v in range(10):
            w.add(float(v))
        assert len(w) == 4 and w.total == 10
        assert list(w.values()) == [6.0, 7.0, 8.0, 9.0]
        assert w.p50 == float(np.percentile([6, 7, 8, 9], 50))

    def test_validation(self):
        with pytest.raises(ValueError):
            LatencyWindow(0)
        with pytest.raises(ValueError):
            percentile([1.0], 101.0)
        with pytest.raises(ValueError):
            percentile([1.0], -0.5)


# --------------------------------------------------------------------------
# AdmissionQueue — ordering semantics
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Item:
    priority: int
    submit_time: float
    deadline: float = None
    tag: int = 0


class TestAdmissionQueue:
    def test_fifo_within_priority(self):
        q = AdmissionQueue()
        for i in range(6):
            q.push(Item(priority=1, submit_time=0.0, tag=i))
        out = q.pop_batch(6, now=0.0)
        assert [it.tag for it in out] == [0, 1, 2, 3, 4, 5]

    def test_priority_order_then_fifo(self):
        q = AdmissionQueue()
        for i, p in enumerate([0, 2, 1, 2, 0, 1]):
            q.push(Item(priority=p, submit_time=0.0, tag=i))
        out = q.pop_batch(6, now=0.0)
        assert [it.tag for it in out] == [1, 3, 2, 5, 0, 4]

    def test_bounded_push(self):
        q = AdmissionQueue(max_pending=2)
        assert q.push(Item(0, 0.0)) and q.push(Item(0, 0.0))
        assert not q.push(Item(9, 0.0))  # full rejects even high priority
        assert len(q) == 2

    def test_expire_removes_only_lapsed(self):
        q = AdmissionQueue()
        q.push(Item(0, 0.0, deadline=1.0, tag=0))
        q.push(Item(0, 0.0, deadline=5.0, tag=1))
        q.push(Item(0, 0.0, deadline=None, tag=2))
        gone = q.expire(now=2.0)
        assert [it.tag for it in gone] == [0]
        assert [it.tag for it in q.items()] == [1, 2]

    def test_aging_promotes_the_starved(self):
        """A waiting priority-0 item outranks fresh priority-2 arrivals
        once it has aged past (2 - 0) * aging_interval."""
        q = AdmissionQueue(aging_interval=1.0)
        q.push(Item(priority=0, submit_time=0.0, tag=99))
        # a high-priority arrival while the victim is still young wins…
        q.push(Item(priority=2, submit_time=1.0, tag=0))
        assert q.pop_batch(1, now=1.0)[0].tag == 0  # eff 2 beats eff 1
        # …but once the victim ages to the arrival's level, its earlier
        # sequence number breaks the tie: the next fresh burst loses
        q.push(Item(priority=2, submit_time=2.5, tag=1))
        assert q.pop_batch(1, now=2.5)[0].tag == 99

    def test_no_starvation_under_sustained_load(self):
        """Under an endless stream of fresh max-priority arrivals, every
        item is served within (P - p) * aging_interval of queue wait."""
        q = AdmissionQueue(aging_interval=0.5)
        q.push(Item(priority=0, submit_time=0.0, tag=-1))
        now, served_victim = 0.0, None
        for round_ in range(20):
            now = round_ * 0.25
            q.push(Item(priority=3, submit_time=now, tag=round_))
            got = q.pop_batch(1, now=now)[0]
            if got.tag == -1:
                served_victim = now
                break
        assert served_victim is not None
        assert served_victim - 0.0 <= (3 - 0) * 0.5 + 0.25

    def test_validation(self):
        with pytest.raises(ValueError):
            AdmissionQueue(max_pending=-1)
        with pytest.raises(ValueError):
            AdmissionQueue(aging_interval=-0.1)


# --------------------------------------------------------------------------
# AdmissionQueue — hypothesis property tests over random interleavings
# --------------------------------------------------------------------------
OPS = st.lists(
    st.one_of(
        st.tuples(st.just("push"), st.integers(0, 3),
                  st.one_of(st.none(), st.floats(0.1, 3.0))),
        st.tuples(st.just("pop"), st.integers(1, 4)),
        st.tuples(st.just("expire")),
        st.tuples(st.just("tick"), st.floats(0.1, 1.0)),
    ),
    min_size=1, max_size=40,
)


class TestAdmissionQueueProperties:
    @given(ops=OPS, aging=st.sampled_from([0.0, 0.5, 1.0]))
    @settings(max_examples=80, deadline=None)
    def test_interleavings_conserve_and_order(self, ops, aging):
        """Random admit/complete/expire interleavings: no item is ever
        lost or duplicated, expiry only removes lapsed deadlines, and
        pops come out FIFO within each base priority level."""
        q = AdmissionQueue(aging_interval=aging)
        now, tag = 0.0, 0
        pushed, popped, expired = [], [], []
        for op in ops:
            if op[0] == "push":
                it = Item(priority=op[1], submit_time=now,
                          deadline=None if op[2] is None else now + op[2],
                          tag=tag)
                tag += 1
                assert q.push(it)
                pushed.append(it)
            elif op[0] == "pop":
                out = q.pop_batch(op[1], now=now)
                assert len(out) <= op[1]
                popped.extend(out)
            elif op[0] == "expire":
                gone = q.expire(now=now)
                for it in gone:
                    assert it.deadline is not None and it.deadline <= now
                expired.extend(gone)
            else:
                now += op[1]
            # conservation after EVERY event
            assert len(pushed) == len(popped) + len(expired) + len(q)
            assert len({it.tag for it in popped}) == len(popped)
        # FIFO within each base priority: among same-priority items the
        # pop sequence follows arrival order (aging moves levels in
        # lockstep, so it can never reorder equals)
        for p in range(4):
            tags = [it.tag for it in popped if it.priority == p]
            assert tags == sorted(tags)

    @given(ops=OPS)
    @settings(max_examples=40, deadline=None)
    def test_bounded_queue_never_overfills(self, ops):
        q = AdmissionQueue(max_pending=3)
        now = 0.0
        for op in ops:
            if op[0] == "push":
                ok = q.push(Item(priority=op[1], submit_time=now))
                assert ok == (len(q) <= 3)
            elif op[0] == "pop":
                q.pop_batch(op[1], now=now)
            elif op[0] == "tick":
                now += op[1]
            assert len(q) <= 3


# --------------------------------------------------------------------------
# WalkService — bit-identity vs offline runs (the headline assertion)
# --------------------------------------------------------------------------
class TestServiceBitIdentity:
    def drive(self, svc, clock, arrivals, tick=0.01):
        """Replay a scripted trace: (time, WalkQuery) pairs on a sim
        clock, conservation checked after every single event."""
        receipts, served, i = [], [], 0
        arrivals = sorted(arrivals, key=lambda a: a[0])
        while i < len(arrivals) or not svc.idle:
            while i < len(arrivals) and arrivals[i][0] <= clock():
                receipts.append(svc.submit(arrivals[i][1]))
                check_conserved(svc)
                i += 1
            served.extend(svc.step())
            check_conserved(svc)
            clock.advance(tick)
        return receipts, served

    def test_steady_trace_matches_offline_run(self, graph):
        clock = SimClock()
        svc = make_service(graph, clock)
        starts = np.arange(11) % graph.num_nodes
        arrivals = [(i * 0.015, WalkQuery(start=int(s), program="deepwalk"))
                    for i, s in enumerate(starts)]
        receipts, served = self.drive(svc, clock, arrivals)
        assert all(r.accepted for r in receipts)
        ref = offline_paths(graph, "deepwalk", starts)
        by_ticket = {s.ticket: s for s in served}
        for i, r in enumerate(receipts):
            np.testing.assert_array_equal(by_ticket[r.ticket].path, ref[i])

    def test_burst_with_priorities_still_matches_submission_order(
            self, graph):
        """Priorities reorder *admission*, never results: RNG streams key
        off the submission-order query id, so row i of the offline run
        matches the i-th submitted query no matter when it got a slot."""
        clock = SimClock()
        svc = make_service(graph, clock, slots=3)
        rng = np.random.default_rng(7)
        starts = rng.integers(0, graph.num_nodes, size=10)
        arrivals = [(0.0, WalkQuery(start=int(s), program="deepwalk",
                                    priority=int(rng.integers(0, 3))))
                    for s in starts]
        receipts, served = self.drive(svc, clock, arrivals)
        ref = offline_paths(graph, "deepwalk", starts)
        by_ticket = {s.ticket: s for s in served}
        for i, r in enumerate(receipts):
            np.testing.assert_array_equal(by_ticket[r.ticket].path, ref[i])

    def test_results_independent_of_slots_and_epoch_len(self, graph):
        """The serving cadence is invisible in the results: 2 slots ×
        epoch 1 serves bit-identically to 8 slots × epoch 3."""
        starts = np.arange(9) % graph.num_nodes
        outs = []
        for slots, epoch_len in ((2, 1), (8, 3)):
            clock = SimClock()
            svc = make_service(graph, clock, slots=slots,
                               epoch_len=epoch_len)
            arrivals = [(0.0, WalkQuery(start=int(s))) for s in starts]
            receipts, served = self.drive(svc, clock, arrivals)
            by_ticket = {s.ticket: s for s in served}
            outs.append(np.stack([by_ticket[r.ticket].path
                                  for r in receipts]))
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_multi_tenant_each_program_matches_its_own_offline_run(
            self, graph):
        """Interleaved node2vec + deepwalk queries: each tenant's paths
        equal a batch run of just that tenant's queries, in per-tenant
        submission order."""
        clock = SimClock()
        svc = make_service(graph, clock, slots=3)
        rng = np.random.default_rng(5)
        progs = ["deepwalk", "node2vec"]
        arrivals, per_prog = [], {p: [] for p in progs}
        for i in range(12):
            p = progs[int(rng.integers(0, 2))]
            s = int(rng.integers(0, graph.num_nodes))
            per_prog[p].append(s)
            arrivals.append((i * 0.01, WalkQuery(start=s, program=p)))
        receipts, served = self.drive(svc, clock, arrivals)
        by_ticket = {s.ticket: s for s in served}
        for p in progs:
            ref = offline_paths(graph, p, per_prog[p])
            got = [by_ticket[r.ticket].path
                   for (_, q), r in zip(arrivals, receipts)
                   if q.program == p]
            np.testing.assert_array_equal(np.stack(got), ref)

    def test_mid_serve_update_graph_matches_before_and_after_runs(
            self, graph):
        """Mid-serve ``update_graph``: queries finished before the swap
        match an offline run on the OLD graph; queries submitted after
        it match an offline run on the NEW graph (with their service
        query ids), while counters keep conserving throughout."""
        from test_rebuild import mutate_row
        clock = SimClock()
        svc = make_service(graph, clock)
        starts = np.arange(12) % graph.num_nodes
        # phase 1: six queries served to completion on the old graph
        r1, s1 = self.drive(svc, clock, [
            (0.0, WalkQuery(start=int(s))) for s in starts[:6]])
        g2 = mutate_row(mutate_row(graph, 3, salt=11), 17, salt=12)
        svc.update_graph(g2, invalidated=[3, 17])
        check_conserved(svc)
        # phase 2: six more, served on the new graph with qids 6..11
        r2, s2 = self.drive(svc, clock, [
            (clock(), WalkQuery(start=int(s))) for s in starts[6:]])
        by_ticket = {s.ticket: s for s in s1 + s2}
        ref_old = offline_paths(graph, "deepwalk", starts[:6])
        for i, r in enumerate(r1):
            np.testing.assert_array_equal(by_ticket[r.ticket].path,
                                          ref_old[i])
        # offline equivalent of phase 2: same streams = qids 6..11, i.e.
        # rows 6..11 of a 12-query batch run on the new graph
        ref_new = offline_paths(g2, "deepwalk", starts)[6:]
        for i, r in enumerate(r2):
            np.testing.assert_array_equal(by_ticket[r.ticket].path,
                                          ref_new[i])

    def test_update_graph_under_in_flight_walkers_is_deterministic(
            self, graph):
        """Walkers crossing the swap epoch (the documented offline
        carve-out) still replay bit-identically: two services driven
        through the same scripted mutation trace agree exactly."""
        from test_rebuild import mutate_row
        g2 = mutate_row(graph, 5, salt=21)

        def run_once():
            clock = SimClock()
            svc = make_service(graph, clock, slots=4, epoch_len=1,
                               method="its_precomp", rebuild_budget=2)
            starts = np.arange(10) % graph.num_nodes
            receipts = [svc.submit(WalkQuery(start=int(s)))
                        for s in starts]
            served = []
            for step in range(200):
                if step == 2:  # mid-serve, walkers still in flight
                    svc.update_graph(g2, invalidated=[5])
                served.extend(svc.step())
                check_conserved(svc)
                clock.advance(0.01)
                if svc.idle:
                    break
            assert svc.idle
            by_ticket = {s.ticket: s for s in served}
            return np.stack([by_ticket[r.ticket].path for r in receipts])

        np.testing.assert_array_equal(run_once(), run_once())


# --------------------------------------------------------------------------
# WalkService — admission control, deadlines, counter conservation
# --------------------------------------------------------------------------
class TestServiceAdmission:
    def test_queue_full_rejects_with_reason(self, graph):
        clock = SimClock()
        svc = make_service(graph, clock, slots=2, max_pending=3)
        receipts = [svc.submit(WalkQuery(start=i)) for i in range(6)]
        assert [r.accepted for r in receipts] == [True] * 3 + [False] * 3
        assert all(r.reason == REJECT_QUEUE_FULL for r in receipts[3:])
        st_ = check_conserved(svc)
        assert st_.rejected_full == 3 and st_.pending == 3
        svc.drain()
        assert check_conserved(svc).completed == 3

    def test_infeasible_deadline_rejected_not_expired(self, graph):
        clock = SimClock(start=10.0)
        svc = make_service(graph, clock, min_service_time=0.5)
        r = svc.submit(WalkQuery(start=0, deadline=10.2))
        assert not r.accepted and r.reason == REJECT_DEADLINE
        r = svc.submit(WalkQuery(start=0, deadline=12.0))
        assert r.accepted
        st_ = check_conserved(svc)
        assert st_.rejected_deadline == 1 and st_.admitted == 1

    def test_unknown_program_rejected_without_building_tenant(self, graph):
        clock = SimClock()
        svc = make_service(graph, clock)
        r = svc.submit(WalkQuery(start=0, program="nope"))
        assert not r.accepted and r.reason == REJECT_UNKNOWN_PROGRAM
        assert "nope" in r.detail
        assert svc._tenants == {}
        assert check_conserved(svc).rejected_unknown == 1

    def test_pending_deadline_expires_in_queue(self, graph):
        clock = SimClock()
        svc = make_service(graph, clock, slots=2)
        # 2 fill the slots; the 3rd waits with a deadline that lapses
        receipts = [svc.submit(WalkQuery(start=i, deadline=None))
                    for i in range(2)]
        receipts.append(svc.submit(WalkQuery(start=2, deadline=0.02)))
        svc.step()
        check_conserved(svc)
        clock.advance(0.05)  # past the pending query's deadline
        served = svc.step()
        expired = [s for s in served if s.status == "expired"]
        assert [e.ticket for e in expired] == [receipts[2].ticket]
        assert expired[0].path is None and math.isnan(expired[0].wait)
        svc.drain()
        st_ = check_conserved(svc)
        assert st_.expired == 1 and st_.completed == 2

    def test_in_flight_deadline_killed_with_partial_path(self, graph):
        clock = SimClock()
        svc = make_service(graph, clock, slots=2, epoch_len=1)
        r = svc.submit(WalkQuery(start=1, deadline=0.025))
        svc.step()  # admitted, walked 1 of 6 steps
        check_conserved(svc)
        assert svc.in_flight == 1
        clock.advance(0.05)
        served = svc.step()
        assert [s.status for s in served] == ["expired"]
        got = served[0]
        assert got.ticket == r.ticket and got.path is not None
        assert 0 < got.steps < STEPS  # a partial walk came back
        assert got.path[0] == 1 and (got.path[got.steps + 1:] == -1).all()
        st_ = check_conserved(svc)
        assert st_.expired == 1 and st_.in_flight == 0
        # the freed slot is reusable: a fresh query completes
        assert svc.submit(WalkQuery(start=0)).accepted
        done = svc.drain()
        assert [s.status for s in done] == ["completed"]
        check_conserved(svc)

    def test_deadline_storm_counters_conserve_after_every_event(
            self, graph):
        """A storm of tight/loose deadlines under overload: after every
        submit and every step the ledger balances and occupancy stays
        within the slot pool."""
        clock = SimClock()
        svc = make_service(graph, clock, slots=3, epoch_len=1,
                           max_pending=6, min_service_time=0.005)
        rng = np.random.default_rng(9)
        for i in range(24):
            dl = clock() + float(rng.choice([0.001, 0.04, 2.0]))
            svc.submit(WalkQuery(start=int(rng.integers(0, 60)),
                                 priority=int(rng.integers(0, 2)),
                                 deadline=dl))
            check_conserved(svc)
            if i % 3 == 2:
                svc.step()
                check_conserved(svc)
                clock.advance(0.015)
        while not svc.idle:
            svc.step()
            check_conserved(svc)
            clock.advance(0.015)
        st_ = check_conserved(svc)
        assert st_.submitted == 24
        assert st_.rejected > 0 and st_.expired > 0 and st_.completed > 0
        assert st_.peak_occupancy <= st_.slots == 3
        assert st_.pending == 0 and st_.in_flight == 0
        # the latency telemetry saw every completed + admitted-expired
        assert math.isfinite(st_.latency_p50)
        assert math.isfinite(st_.queue_wait_p99)

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=8, deadline=None)
    def test_property_random_interleavings_conserve(self, graph, seed):
        """Hypothesis drives random submit/step/advance/expire
        interleavings against a live service: the slot accounting
        invariant holds after every event, and the service always
        drains to idle (no starvation, no leaked slots)."""
        clock = SimClock()
        svc = make_service(graph, clock, slots=2, epoch_len=1,
                           max_pending=4, aging_interval=0.02)
        rng = np.random.default_rng(seed)
        for _ in range(18):
            op = rng.integers(0, 3)
            if op == 0:
                dl = None if rng.random() < 0.5 else \
                    clock() + float(rng.choice([0.01, 0.5]))
                svc.submit(WalkQuery(start=int(rng.integers(0, 60)),
                                     priority=int(rng.integers(0, 3)),
                                     deadline=dl))
            elif op == 1:
                svc.step()
            else:
                clock.advance(float(rng.choice([0.005, 0.03])))
            check_conserved(svc)
        while not svc.idle:
            svc.step()
            clock.advance(0.01)
            check_conserved(svc)
        st_ = svc.stats()
        assert st_.admitted == st_.completed + st_.expired


# --------------------------------------------------------------------------
# launch.serve_walks — the CLI sustains scripted traces (satellite CLI)
# --------------------------------------------------------------------------
class TestServeWalksCLI:
    def run_cli(self, capsys, monkeypatch, *flags):
        monkeypatch.setattr(sys, "argv", [
            "serve_walks", "--sim-clock", "--nodes", "200",
            "--avg-degree", "6", "--steps", "6", "--slots", "8",
            "--epoch-len", "2", "--graph", "random", *flags])
        serve_walks.main()
        return capsys.readouterr().out

    def test_overload_trace_reports_rejections(self, capsys, monkeypatch):
        out = self.run_cli(capsys, monkeypatch, "--trace", "overload",
                           "--queries", "48", "--seed", "1")
        assert "queue-full" in out and "p99=" in out
        # the overload trace must actually reject (bounded queue) and
        # still finish every admitted query
        assert " 48 submitted -> " in out
        admitted = int(out.split(" submitted -> ")[1].split(" admitted")[0])
        assert admitted < 48

    def test_deadline_storm_trace_reports_expiries(self, capsys,
                                                   monkeypatch):
        out = self.run_cli(capsys, monkeypatch, "--trace",
                           "deadline-storm", "--queries", "24",
                           "--tick", "0.01", "--seed", "2")
        assert "expired" in out
        expired = int(out.split(" completed + ")[1].split(" expired")[0])
        assert expired > 0

    def test_burst_trace_with_mid_serve_mutation(self, capsys,
                                                 monkeypatch):
        out = self.run_cli(capsys, monkeypatch, "--trace", "burst",
                           "--queries", "24", "--interarrival", "0.05",
                           "--mutate-at", "0.06", "--method",
                           "its_precomp", "--seed", "3")
        assert "rebuilt_rows=" in out
        rebuilt = int(out.split("rebuilt_rows=")[1].split()[0])
        assert rebuilt > 0


# --------------------------------------------------------------------------
# Cross-tenant fairness (DRR) + sharded-slot tenants (satellites 1, 3)
# --------------------------------------------------------------------------
class TestFairness:
    """Deficit round robin replaces one-epoch-per-busy-tenant: weighted
    walker-step shares under overload, with the legacy ``epoch`` mode
    kept as a config escape hatch and bit-identical paths either way."""

    WEIGHTS = {"deepwalk": 3.0, "node2vec": 1.0}

    def _flood(self, svc, per_tenant=40, seed=7):
        rng = np.random.default_rng(seed)
        for _ in range(per_tenant):
            for prog in self.WEIGHTS:
                r = svc.submit(WalkQuery(start=int(rng.integers(0, 60)),
                                         program=prog))
                assert r.accepted

    def test_weighted_shares_within_10pct_under_overload(self, graph):
        """Two tenants at 3:1 weights, both backlogged throughout: the
        cumulative walker-step split stays within 10% of 3:1 (ISSUE
        acceptance).  The exact DRR bound is one epoch of overdraft per
        round, so with enough rounds the measured share pins down."""
        clock = SimClock()
        svc = make_service(graph, clock, slots=2, epoch_len=2,
                           weights=self.WEIGHTS)
        self._flood(svc, per_tenant=40)
        for _ in range(12):  # both tenants stay backlogged for all rounds
            svc.step()
            check_conserved(svc)
        st_ = check_conserved(svc)
        steps = {n: t["walker_steps"] for n, t in st_.per_tenant.items()}
        assert st_.pending > 0  # still overloaded: shares were contested
        total = sum(steps.values())
        share = steps["deepwalk"] / total
        assert abs(share - 0.75) <= 0.10 * 0.75, steps
        # per-tenant ledger: epochs and steps sum to the service totals
        assert sum(t["epochs_run"] for t in st_.per_tenant.values()) \
            == st_.epochs
        assert st_.per_tenant["deepwalk"]["weight"] == 3.0
        while not svc.idle:
            svc.step()
        check_conserved(svc)

    def test_equal_weights_split_evenly(self, graph):
        clock = SimClock()
        svc = make_service(graph, clock, slots=2, epoch_len=2)
        self._flood(svc, per_tenant=30)
        for _ in range(10):
            svc.step()
        st_ = check_conserved(svc)
        steps = {n: t["walker_steps"] for n, t in st_.per_tenant.items()}
        assert st_.pending > 0
        share = steps["deepwalk"] / sum(steps.values())
        assert abs(share - 0.5) <= 0.10 * 0.5, steps
        while not svc.idle:
            svc.step()

    def test_paths_identical_across_fairness_modes(self, graph):
        """The determinism contract survives the scheduler swap: drr
        and legacy epoch mode serve bit-identical paths (streams are
        keyed per tenant-local qid, not by service timing)."""
        outs = {}
        for mode in ("drr", "epoch"):
            clock = SimClock()
            svc = make_service(graph, clock, slots=3,
                               fairness=mode, weights=self.WEIGHTS)
            rng = np.random.default_rng(11)
            tickets = []
            for _ in range(14):
                prog = ("deepwalk", "node2vec")[int(rng.integers(0, 2))]
                r = svc.submit(WalkQuery(start=int(rng.integers(0, 60)),
                                         program=prog))
                tickets.append(r.ticket)
            done = {}
            while not svc.idle:
                for w in svc.step():
                    done[w.ticket] = w
                check_conserved(svc)
            outs[mode] = [done[t].path for t in tickets]
        for a, b in zip(outs["drr"], outs["epoch"]):
            np.testing.assert_array_equal(a, b)

    def test_legacy_epoch_mode_matches_offline(self, graph):
        """fairness="epoch" (the pre-DRR loop) still serves paths
        bit-identical to the offline batch run and keeps the ledger."""
        clock = SimClock()
        svc = make_service(graph, clock, slots=4, fairness="epoch")
        starts = list(range(0, 36, 3))
        tickets = [svc.submit(WalkQuery(start=s)).ticket for s in starts]
        done = {}
        while not svc.idle:
            for w in svc.step():
                done[w.ticket] = w
            check_conserved(svc)
        got = np.stack([done[t].path for t in tickets])
        np.testing.assert_array_equal(
            got, offline_paths(graph, "deepwalk", starts))

    def test_config_validation(self, graph):
        with pytest.raises(ValueError):
            make_service(graph, SimClock(), fairness="lottery")
        with pytest.raises(ValueError):
            make_service(graph, SimClock(), quantum=0)
        with pytest.raises(ValueError):
            make_service(graph, SimClock(),
                         weights={"deepwalk": 0.0})


_SHARDED_TENANT_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
import numpy as np
from repro.core import EngineConfig, WalkEngine
from repro.graphs import random_graph
from repro.serving import ServiceConfig, SimClock, WalkQuery, WalkService
from repro.walks import make_workload

assert len(jax.devices()) == 2, jax.devices()
g = random_graph(60, 6, weight_dist="uniform", seed=3)
starts = [int(s) for s in np.random.default_rng(0).integers(0, 60, 13)]

def serve(devices):
    svc = WalkService(
        g, ServiceConfig(slots=4, epoch_len=2, num_steps=6, seed=2,
                         devices=devices),
        EngineConfig(method="ervs", tile=32), clock=SimClock())
    tickets = [svc.submit(WalkQuery(start=s)).ticket for s in starts]
    done = {}
    while not svc.idle:
        for w in svc.step():
            done[w.ticket] = w
    st = svc.stats()
    assert st.conserves(), st
    assert st.completed == len(starts)
    return [done[t].path for t in tickets]

one = serve(1)
two = serve(2)
eng = WalkEngine(g, make_workload("deepwalk"),
                 EngineConfig(method="ervs", tile=32))
full = eng.run(np.asarray(starts), num_steps=6,
               key=jax.random.key(2)).paths
for a, b, c in zip(one, two, full):
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)
print("SHARDED-TENANT-OK")
"""


def test_sharded_tenant_bit_identical_to_single_device():
    """ServiceConfig(devices=2) on a forced 2-device host mesh: served
    paths bit-identical to devices=1 and to the offline batch run
    (XLA device-count forcing must precede the jax import, so the mesh
    leg runs in a subprocess — same pattern as test_multidevice.py)."""
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_TENANT_CHILD], capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src",
             # the child forces its own device count
             "XLA_FLAGS": ""})
    assert "SHARDED-TENANT-OK" in out.stdout, out.stderr[-2000:]
