"""End-to-end behaviour tests for the whole system: walks→training bridge,
serving, checkpoint/restart fault tolerance, elastic restore, sharding
rules, data determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.core import EngineConfig, WalkEngine
from repro.data import DataConfig, WalkCorpus, skipgram_pairs
from repro.data.pipeline import synthetic_batch, walk_corpus_batches
from repro.graphs import random_graph
from repro.models import ModelConfig, init_params, init_cache
from repro.serving import GenerateConfig, generate
from repro.train import (TrainConfig, adamw_init, compress_init,
                         make_train_step)
from repro.walks import deepwalk, node2vec

SMALL = ModelConfig(name="sys-t", family="dense", num_layers=2, d_model=64,
                    vocab_size=256, num_heads=4, num_kv_heads=2, head_dim=16,
                    d_ff=128)


class TestWalkToTraining:
    def test_walk_corpus_sequences(self):
        g = random_graph(120, 6, seed=0)
        corpus = WalkCorpus(g, deepwalk(), walk_len=12)
        seqs = corpus.lm_sequences(8, 33, seed=0)
        assert seqs.shape == (8, 33)
        assert seqs.min() >= 0 and seqs.max() <= g.num_nodes

    def test_walk_corpus_is_deprecation_free(self):
        # the corpus speaks WalkProgram natively: constructing and running
        # it must not touch the deprecated Workload protocol
        import warnings

        g = random_graph(60, 5, seed=2)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            corpus = WalkCorpus(g, deepwalk(), walk_len=6)
            paths = corpus.walks(np.arange(4), seed=0)
        assert paths.shape == (4, 7)

    def test_skipgram_pairs(self):
        g = random_graph(80, 6, seed=1)
        corpus = WalkCorpus(g, node2vec(), walk_len=10)
        paths = corpus.walks(np.arange(16), seed=0)
        c, x = skipgram_pairs(paths, window=3, max_pairs=500)
        assert c.shape == x.shape and len(c) > 0
        assert c.min() >= 0 and x.max() < g.num_nodes

    def test_train_on_walk_corpus_loss_drops(self):
        g = random_graph(120, 6, seed=0)
        cfg = ModelConfig(name="walklm", family="dense", num_layers=2,
                          d_model=64, vocab_size=g.num_nodes + 1,
                          num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128)
        corpus = WalkCorpus(g, deepwalk(), walk_len=16)
        params = init_params(cfg, jax.random.key(0))
        tcfg = TrainConfig(base_lr=5e-3, warmup_steps=2, total_steps=40)
        step = jax.jit(make_train_step(cfg, tcfg))
        state = dict(params=params, opt=adamw_init(params), comp=(),
                     step=jnp.int32(0))
        it = walk_corpus_batches(corpus, DataConfig(batch_size=8, seq_len=32))
        losses = []
        for i, batch in zip(range(10), it):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses


class TestServing:
    def test_generate_shapes_and_determinism(self):
        params = init_params(SMALL, jax.random.key(0))
        prompt = jnp.asarray([[5, 6, 7], [9, 10, 11]], jnp.int32)
        gcfg = GenerateConfig(max_new_tokens=5, greedy=True,
                              use_pallas_sampler=False)
        out1 = generate(params, SMALL, prompt, gcfg)
        out2 = generate(params, SMALL, prompt, gcfg)
        assert out1.shape == (2, 8)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        np.testing.assert_array_equal(np.asarray(out1[:, :3]),
                                      np.asarray(prompt))

    def test_pallas_and_ref_sampler_agree(self):
        from repro.kernels import ops, ref
        logits = jax.random.normal(jax.random.key(1), (4, 300))
        seed = jnp.asarray([3, 4], jnp.uint32)
        a = ops.token_sample(logits, seed, temperature=0.9)
        b = ref.token_sample_ref(logits, seed, temperature=0.9)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestFaultTolerance:
    def test_checkpoint_restart_resumes_identically(self):
        """Train 6 steps; compare vs train 3 + save + restore + 3 (the
        deterministic data pipeline replays from the step counter)."""
        tcfg = TrainConfig(base_lr=1e-3, warmup_steps=2, total_steps=20)
        dcfg = DataConfig(batch_size=4, seq_len=16, vocab_size=256)
        step = jax.jit(make_train_step(SMALL, tcfg))

        def fresh():
            p = init_params(SMALL, jax.random.key(0))
            return dict(params=p, opt=adamw_init(p), comp=(),
                        step=jnp.int32(0))

        sA = fresh()
        for i in range(6):
            sA, _ = step(sA, synthetic_batch(dcfg, i))

        sB = fresh()
        for i in range(3):
            sB, _ = step(sB, synthetic_batch(dcfg, i))
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 3, sB)
            sB2, at = load_checkpoint(d, sB)
            assert at == 3
            for i in range(3, 6):
                sB2, _ = step(sB2, synthetic_batch(dcfg, i))
        for a, b in zip(jax.tree.leaves(sA), jax.tree.leaves(sB2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-2)

    def test_manager_retention_and_async(self):
        p = init_params(SMALL, jax.random.key(0))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, save_every=1, keep=2, async_save=True)
            for s in [1, 2, 3, 4]:
                mgr.maybe_save(s, {"p": p}, force=True)
            mgr.wait()
            from repro.checkpoint.manager import available_steps
            assert available_steps(d) == [3, 4]

    def test_corrupt_structure_rejected(self):
        p = init_params(SMALL, jax.random.key(0))
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, {"p": p})
            with pytest.raises((ValueError, Exception)):
                load_checkpoint(d, {"p": p, "extra": jnp.zeros(3)})

    def test_elastic_restore_with_shardings(self):
        """Save, then restore with explicit target shardings (elastic)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        p = {"w": jnp.arange(16.0).reshape(4, 4)}
        mesh = jax.make_mesh((1,), ("data",))
        sh = {"w": NamedSharding(mesh, P(None, None))}
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 7, p)
            restored, _ = load_checkpoint(d, p, shardings=sh)
            np.testing.assert_allclose(np.asarray(restored["w"]),
                                       np.asarray(p["w"]))


class TestShardingRules:
    def test_param_specs_structure_matches(self):
        from repro.distributed.sharding import param_specs
        p = init_params(SMALL, jax.random.key(0))
        specs = param_specs(p, rules=None)
        n_p = len(jax.tree.leaves(p))
        n_s = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec)))
        assert n_p == n_s

    def test_divisibility_fallback_drops_axis(self):
        import os
        import subprocess
        import sys
        # needs >1 devices: run in a subprocess with 4 forced host devices
        child = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
from jax.sharding import PartitionSpec as P
from repro.distributed.sharding import MeshRules, logical_to_spec
mesh = jax.make_mesh((2, 2), ("data", "model"))
rules = MeshRules(mesh=mesh, logical={"kv_heads": ("model",), "batch": ("data",)})
spec = logical_to_spec(("batch", None, "kv_heads", None), (8, 128, 3, 64), rules)
assert spec == P("data", None, None, None), spec  # 3 % 2 != 0 -> dropped
spec2 = logical_to_spec(("batch", None, "kv_heads", None), (8, 128, 4, 64), rules)
assert spec2 == P("data", None, "model", None), spec2
print("OK")
"""
        out = subprocess.run([sys.executable, "-c", child],
                             capture_output=True, text=True,
                             env={**os.environ, "PYTHONPATH": "src"})
        assert "OK" in out.stdout, out.stderr[-500:]


class TestDataPipeline:
    def test_deterministic_replay(self):
        dcfg = DataConfig(batch_size=4, seq_len=16)
        b1 = synthetic_batch(dcfg, 5)
        b2 = synthetic_batch(dcfg, 5)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))

    def test_gradient_compression_error_feedback(self):
        from repro.train.compress import compress_apply, compress_init
        p = {"w": jnp.ones((64, 64))}
        st = compress_init(p)
        g = {"w": jax.random.normal(jax.random.key(0), (64, 64)) * 1e-3}
        total = jnp.zeros((64, 64))
        for _ in range(8):
            dq, st = compress_apply(g, st)
            total = total + dq["w"]
        # error feedback: accumulated dequantised grads track the true
        # accumulated gradient within one quantisation step
        np.testing.assert_allclose(np.asarray(total), np.asarray(g["w"] * 8),
                                   atol=5e-4)
