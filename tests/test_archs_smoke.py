"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts shapes and no NaNs (assignment §f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke, get_config, SHAPES, cell_supported
from repro.models import init_params, forward, init_cache, decode_step
from repro.train import TrainConfig, make_train_step, adamw_init


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train(arch):
    cfg = get_smoke(arch)
    B, S = 2, 24
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    logits = forward(params, cfg, toks, remat=False)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    tcfg = TrainConfig(base_lr=1e-3, warmup_steps=2, total_steps=10,
                       remat=True)
    step = jax.jit(make_train_step(cfg, tcfg))
    state = dict(params=params, opt=adamw_init(params), comp=(),
                 step=jnp.int32(0))
    labels = jnp.roll(toks, -1, axis=1)
    state, metrics = step(state, {"tokens": toks, "labels": labels})
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    assert float(metrics["loss"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch):
    cfg = get_smoke(arch)
    B = 2
    params = init_params(cfg, jax.random.key(0))
    caches = init_cache(cfg, B, max_len=16)
    tok = jnp.zeros((B, 1), jnp.int32)
    for i in range(3):
        logits, caches = decode_step(params, cfg, tok, caches, jnp.int32(i))
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact published dimensions."""
    expect = {
        "minicpm-2b": dict(num_layers=40, d_model=2304, num_heads=36,
                           num_kv_heads=36, d_ff=5760, vocab_size=122753),
        "yi-6b": dict(num_layers=32, d_model=4096, num_heads=32,
                      num_kv_heads=4, d_ff=11008, vocab_size=64000),
        "qwen3-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12288, vocab_size=151936,
                         qk_norm=True),
        "qwen3-0.6b": dict(num_layers=28, d_model=1024, num_heads=16,
                           num_kv_heads=8, d_ff=3072, vocab_size=151936,
                           qk_norm=True),
        "recurrentgemma-9b": dict(num_layers=38, d_model=4096, num_heads=16,
                                  num_kv_heads=1, d_ff=12288,
                                  vocab_size=256000),
        "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                                num_kv_heads=8, moe_d_ff=2048,
                                vocab_size=163840, num_experts=384,
                                experts_per_token=8),
        "moonshot-v1-16b-a3b": dict(num_layers=48, d_model=2048,
                                    num_heads=16, num_kv_heads=16,
                                    moe_d_ff=1408, vocab_size=163840,
                                    num_experts=64, experts_per_token=6),
        "chameleon-34b": dict(num_layers=48, d_model=8192, num_heads=64,
                              num_kv_heads=8, d_ff=22016, vocab_size=65536),
        "mamba2-1.3b": dict(num_layers=48, d_model=2048, vocab_size=50280,
                            ssm_state=128),
        "musicgen-medium": dict(num_layers=48, d_model=1536, num_heads=24,
                                num_kv_heads=24, d_ff=6144, vocab_size=2048),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    assert 0.9e12 < cfg.param_count() < 1.15e12      # ~1T total
    assert 30e9 < cfg.active_param_count() < 36e9    # ~32B active


def test_long_context_cells():
    ok_long = [a for a in ARCHS if cell_supported(a, "long_500k")[0]]
    assert sorted(ok_long) == ["mamba2-1.3b", "recurrentgemma-9b"]
    for a in ARCHS:
        assert cell_supported(a, "decode_32k")[0]  # all are decoders
