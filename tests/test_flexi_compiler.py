"""Flexi-Compiler: interval soundness (hypothesis property tests), flag
lattice, fallback behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import analyze, BoundInputs, FALLBACK, PER_KERNEL, PER_STEP
from repro.core.types import EdgeCtx, Workload
from repro.walks import deepwalk, metapath, node2vec, second_order_pagerank


def make_bi(h_min, h_max, h_mean, deg_cur, deg_prev, step=0):
    return BoundInputs(
        h_min=jnp.float32(h_min), h_max=jnp.float32(h_max),
        h_mean=jnp.float32(h_mean), deg_cur=jnp.int32(deg_cur),
        deg_prev=jnp.int32(deg_prev), cur=jnp.int32(0), prev=jnp.int32(1),
        step=jnp.int32(step))


ALL_WORKLOADS = [node2vec(), node2vec(weighted=False), metapath(),
                 second_order_pagerank(), deepwalk()]


class TestFlags:
    def test_flag_lattice(self):
        assert analyze(node2vec(weighted=False)).flag == PER_KERNEL
        assert analyze(node2vec()).flag == PER_STEP
        assert analyze(second_order_pagerank()).flag == PER_STEP

    def test_fallback_on_unsupported(self):
        with pytest.warns(DeprecationWarning):  # legacy Workload protocol
            bad = Workload(name="bad", init=lambda: (),
                           get_weight=lambda c, p: jnp.sort(
                               jnp.stack([c.h, c.h * 2]))[0])
        cw = analyze(bad)
        assert cw.flag == FALLBACK and not cw.usable
        assert any("unsupported" in w for w in cw.warnings)

    def test_fallback_on_untraceable(self):
        def gw(c, p):
            if c.h > 1:  # python branching on tracer
                return c.h
            return c.h * 2

        with pytest.warns(DeprecationWarning):  # legacy Workload protocol
            wl = Workload(name="untraceable", init=lambda: (),
                          get_weight=gw)
        cw = analyze(wl)
        assert cw.flag == FALLBACK


class TestBoundSoundness:
    """Property: for any concrete edge ctx within the declared domains,
    get_weight(ctx) ≤ bound_fn(bi).hi — the Eqs. 5–8 requirement."""

    @settings(max_examples=60, deadline=None)
    @given(
        h=st.floats(0.1, 100.0), h_lo=st.floats(0.0, 1.0),
        dist=st.integers(0, 2), label=st.integers(0, 4),
        deg_cur=st.integers(1, 10_000), deg_prev=st.integers(1, 10_000),
        step=st.integers(0, 100), wl_idx=st.integers(0, len(ALL_WORKLOADS) - 1),
    )
    def test_bound_dominates(self, h, h_lo, dist, label, deg_cur, deg_prev,
                             step, wl_idx):
        wl = ALL_WORKLOADS[wl_idx]
        params = wl.params()
        h_min = h * h_lo
        bi = make_bi(h_min, h, (h_min + h) / 2, deg_cur, deg_prev, step)
        cw = analyze(wl)
        assert cw.usable
        _, hi = cw.bound_fn(bi)
        ctx = EdgeCtx(h=jnp.float32(h if wl.weighted else 1.0),
                      label=jnp.int32(label), dist=jnp.int32(dist),
                      nbr=jnp.int32(0), deg_cur=jnp.int32(deg_cur),
                      deg_prev=jnp.int32(deg_prev), cur=jnp.int32(0),
                      prev=jnp.int32(1), step=jnp.int32(step))
        w = float(wl.edge_weight(ctx, params, wl.wstate_template()))
        assert w <= float(hi) * (1 + 1e-5) + 1e-6, \
            f"{wl.name}: w={w} > bound={float(hi)}"

    @settings(max_examples=30, deadline=None)
    @given(h=st.floats(0.5, 10.0), deg=st.integers(1, 1000))
    def test_sum_estimate_scales_with_degree(self, h, deg):
        wl = node2vec()
        cw = analyze(wl)
        bi1 = make_bi(h, h, h, deg, 4)
        bi2 = make_bi(h, h, h, deg * 2, 4)
        s1, s2 = float(cw.sum_fn(bi1)), float(cw.sum_fn(bi2))
        assert s2 == pytest.approx(2 * s1, rel=1e-5)

    def test_node2vec_bound_matches_paper_factorization(self):
        """max(w)·max(h) of §3.3: a=2, b=0.5 ⇒ max(w)=2; h_max=5 ⇒ 10."""
        cw = analyze(node2vec(a=2.0, b=0.5))
        _, hi = cw.bound_fn(make_bi(1.0, 5.0, 2.0, 10, 10))
        assert float(hi) == pytest.approx(10.0)

    def test_2ndpr_bound_matches_eq3(self):
        cw = analyze(second_order_pagerank(gamma=0.2))
        _, hi = cw.bound_fn(make_bi(1.0, 5.0, 2.0, 10, 4))
        # ((1-γ)/dv + γ/dp)·max_d·h_max = (0.08+0.05)·10·5
        assert float(hi) == pytest.approx(6.5, rel=1e-5)


class TestBoundUnderJit:
    def test_bound_fn_jits_and_vmaps(self):
        cw = analyze(node2vec())
        bis = BoundInputs(
            h_min=jnp.ones(8), h_max=jnp.full(8, 3.0), h_mean=jnp.full(8, 2.0),
            deg_cur=jnp.arange(1, 9, dtype=jnp.int32),
            deg_prev=jnp.ones(8, jnp.int32), cur=jnp.zeros(8, jnp.int32),
            prev=jnp.zeros(8, jnp.int32), step=jnp.zeros(8, jnp.int32))
        lo, hi = jax.jit(jax.vmap(cw.bound_fn))(bis)
        assert hi.shape == (8,) and bool((hi >= lo).all())
