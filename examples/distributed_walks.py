"""Distributed walks: queries hash-partitioned over devices (paper §6.6),
graph replicated per device, engine running under a data mesh.

Forces 8 host devices (run as a separate process — this script must be the
first thing to touch jax in the process).

    PYTHONPATH=src python examples/distributed_walks.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import EngineConfig, WalkEngine  # noqa: E402
from repro.graphs import power_law_graph  # noqa: E402
from repro.walks import node2vec  # noqa: E402


def main():
    devs = jax.devices()
    print(f"devices: {len(devs)} × {devs[0].platform}")
    graph = power_law_graph(10_000, 12, weight_dist="uniform", seed=0)
    engine = WalkEngine(graph, node2vec(), EngineConfig(method="adaptive"))

    Q = 1024
    starts = np.arange(Q, dtype=np.int32)
    # hash-partition queries over devices (paper's scheme — range mapping
    # scales worse because node ids correlate with degree)
    dev_of = starts % len(devs)
    order = np.argsort(dev_of, kind="stable")
    mesh = jax.make_mesh((len(devs),), ("data",))
    sharded = jax.device_put(jnp.asarray(starts[order]),
                             NamedSharding(mesh, P("data")))

    t0 = time.time()
    paths, _ = engine.walk_batch(sharded, jax.random.key(0), 20)
    jax.block_until_ready(paths)
    print(f"{Q} walks × 20 steps on {len(devs)} devices: "
          f"{time.time() - t0:.2f}s (single-core host; on real hardware "
          f"this is embarrassingly parallel)")
    paths = np.asarray(paths)
    print("per-device query counts:",
          np.bincount(dev_of, minlength=len(devs)).tolist())
    print("all walks valid:", bool((paths >= 0).all()))


if __name__ == "__main__":
    main()
