"""End-to-end driver: FlexiWalker as the data engine for representation
learning — Node2Vec walks → token sequences → train a ~100M-parameter
decoder LM over node-id tokens for a few hundred steps.

This is the paper's actual downstream use (Node2Vec/DeepWalk feed
embedding training), scaled to this host.  Checkpointing + resume are
exercised along the way.

    PYTHONPATH=src python examples/node2vec_embeddings.py [--steps 200]
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import EngineConfig
from repro.data import DataConfig, WalkCorpus
from repro.data.pipeline import walk_corpus_batches
from repro.graphs import power_law_graph
from repro.models import ModelConfig, init_params
from repro.train import TrainConfig, adamw_init, make_train_step
from repro.walks import node2vec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()

    graph = power_law_graph(20_000, 12, weight_dist="uniform", seed=0)
    corpus = WalkCorpus(graph, node2vec(), walk_len=40,
                        engine_config=EngineConfig(method="adaptive"))
    vocab = graph.num_nodes + 1

    # ~100M params at the default size (vocab 20k, d 512, 8 layers)
    cfg = ModelConfig(name="n2v-lm", family="dense",
                      num_layers=args.layers, d_model=args.d_model,
                      vocab_size=vocab, num_heads=8, num_kv_heads=4,
                      head_dim=args.d_model // 8, d_ff=4 * args.d_model)
    n = cfg.param_count()
    print(f"model: {n/1e6:.1f}M params over node-token vocab {vocab}")

    params = init_params(cfg, jax.random.key(0))
    tcfg = TrainConfig(base_lr=3e-4, warmup_steps=20,
                       total_steps=args.steps, schedule="wsd")
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    state = dict(params=params, opt=adamw_init(params), comp=(),
                 step=jnp.int32(0))
    dcfg = DataConfig(batch_size=8, seq_len=128)
    data = walk_corpus_batches(corpus, dcfg)

    with tempfile.TemporaryDirectory() as ckdir:
        mgr = CheckpointManager(ckdir, save_every=50, keep=2)
        t0 = time.time()
        for i, batch in zip(range(args.steps), data):
            state, m = step_fn(state, batch)
            mgr.maybe_save(int(state["step"]), state)
            if i % 20 == 0 or i == args.steps - 1:
                tok_s = dcfg.batch_size * dcfg.seq_len * (i + 1) / \
                    (time.time() - t0)
                print(f"step {i:4d} loss={float(m['loss']):.3f} "
                      f"lr={float(m['lr']):.2e} tok/s={tok_s:.0f}")
        mgr.wait()

        # node embeddings = input embedding table; nearest-neighbour sanity
        emb = np.asarray(state["params"]["embed"], np.float32)[1:]
        emb = emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-9)
        node = 17
        sims = emb @ emb[node]
        top = np.argsort(-sims)[1:6]
        nbrs = set(np.asarray(graph.indices)[
            int(graph.indptr[node]):int(graph.indptr[node + 1])].tolist())
        print(f"\nnode {node}: top-5 embedding neighbours {top.tolist()}")
        print(f"graph neighbours overlap: "
              f"{len(set(top.tolist()) & nbrs)}/5")


if __name__ == "__main__":
    main()
