"""Serve a small LM with batched requests: prefill + decode with the eRVS
exponential-key (Gumbel-max) token sampler — the paper's kernel reused as
the serving sampler.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, init_params
from repro.serving import GenerateConfig, generate

CFG = ModelConfig(name="serve-demo", family="dense", num_layers=4,
                  d_model=256, vocab_size=1024, num_heads=8, num_kv_heads=4,
                  head_dim=32, d_ff=1024, qk_norm=True)


def main():
    params = init_params(CFG, jax.random.key(0))
    batch = 4
    prompts = jax.random.randint(jax.random.key(1), (batch, 8), 0,
                                 CFG.vocab_size, jnp.int32)
    print(f"model {CFG.param_count()/1e6:.1f}M; serving batch={batch}, "
          f"prompt len 8")

    for label, gcfg in [
        ("greedy", GenerateConfig(max_new_tokens=16, greedy=True,
                                  use_pallas_sampler=True)),
        ("sampled T=0.8 (eRVS keys, Pallas interpret)",
         GenerateConfig(max_new_tokens=16, temperature=0.8,
                        use_pallas_sampler=True)),
    ]:
        t0 = time.time()
        out = generate(params, CFG, prompts, gcfg, key=jax.random.key(2))
        dt = time.time() - t0
        print(f"\n[{label}] {dt:.1f}s "
              f"({batch * gcfg.max_new_tokens / dt:.1f} tok/s)")
        for b in range(batch):
            print("  req", b, np.asarray(out[b]).tolist())
    # determinism: same key ⇒ same samples
    g = GenerateConfig(max_new_tokens=8, temperature=0.8,
                       use_pallas_sampler=True)
    a = generate(params, CFG, prompts, g, key=jax.random.key(5))
    b = generate(params, CFG, prompts, g, key=jax.random.key(5))
    print("\ndeterministic sampling:", bool(jnp.array_equal(a, b)))


if __name__ == "__main__":
    main()
