"""Serve a small LM whose prompts are random walks fetched over the
walk-service TCP front-end — the two serving stacks composed end to end:

1. a :class:`repro.serving.WalkFrontend` serves a ``WalkService`` on a
   loopback socket (length-prefixed JSON frames);
2. a :class:`repro.launch.walk_client.WalkServiceClient` submits start
   nodes and polls the walks back — node ids become prompt token ids
   (the walk-as-data-engine pattern: graph context feeding an LM);
3. the LM decodes with the eRVS exponential-key (Gumbel-max) token
   sampler — the paper's kernel reused as the serving sampler.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig
from repro.graphs import random_graph
from repro.launch.walk_client import WalkServiceClient
from repro.models import ModelConfig, init_params
from repro.serving import (FrontendConfig, GenerateConfig, ServiceConfig,
                           WalkFrontend, WalkService, generate)

CFG = ModelConfig(name="serve-demo", family="dense", num_layers=4,
                  d_model=256, vocab_size=1024, num_heads=8, num_kv_heads=4,
                  head_dim=32, d_ff=1024, qk_norm=True)

BATCH = 4
PROMPT_LEN = 8


def fetch_walk_prompts() -> jnp.ndarray:
    """Walk the graph over the wire: serve a loopback front-end, submit
    BATCH start nodes through the stock client, and pack the returned
    paths into [BATCH, PROMPT_LEN] prompt token ids."""
    graph = random_graph(CFG.vocab_size, 8, seed=0)
    service = WalkService(
        graph,
        ServiceConfig(slots=BATCH, epoch_len=4, num_steps=PROMPT_LEN - 1,
                      seed=0),
        EngineConfig(method="ervs", tile=64))
    frontend = WalkFrontend(service, FrontendConfig())
    host, port = frontend.start()
    try:
        with WalkServiceClient(host=host, port=port) as client:
            walks = client.walk(np.arange(BATCH) * 17 % CFG.vocab_size)
            stats = client.stats()
    finally:
        frontend.drain()
        frontend.stop()
    print(f"[walks] {stats['completed']} served over {host}:{port} in "
          f"{stats['epochs']} epochs "
          f"(live walker-steps {stats['live_steps']})")
    prompts = np.zeros((BATCH, PROMPT_LEN), np.int32)
    for b, w in enumerate(walks):
        path = w.path[w.path >= 0]
        prompts[b, :len(path)] = path[:PROMPT_LEN]
    return jnp.asarray(prompts)


def main():
    params = init_params(CFG, jax.random.key(0))
    prompts = fetch_walk_prompts()
    print(f"model {CFG.param_count()/1e6:.1f}M; serving batch={BATCH}, "
          f"walk-derived prompt len {PROMPT_LEN}")

    for label, gcfg in [
        ("greedy", GenerateConfig(max_new_tokens=16, greedy=True,
                                  use_pallas_sampler=True)),
        ("sampled T=0.8 (eRVS keys, Pallas interpret)",
         GenerateConfig(max_new_tokens=16, temperature=0.8,
                        use_pallas_sampler=True)),
    ]:
        t0 = time.time()
        out = generate(params, CFG, prompts, gcfg, key=jax.random.key(2))
        dt = time.time() - t0
        print(f"\n[{label}] {dt:.1f}s "
              f"({BATCH * gcfg.max_new_tokens / dt:.1f} tok/s)")
        for b in range(BATCH):
            print("  req", b, np.asarray(out[b]).tolist())
    # determinism: same key ⇒ same samples
    g = GenerateConfig(max_new_tokens=8, temperature=0.8,
                       use_pallas_sampler=True)
    a = generate(params, CFG, prompts, g, key=jax.random.key(5))
    b = generate(params, CFG, prompts, g, key=jax.random.key(5))
    print("\ndeterministic sampling:", bool(jnp.array_equal(a, b)))


if __name__ == "__main__":
    main()
