"""Quickstart: define a dynamic walk program in ~10 lines, let FlexiWalker
compile, select kernels, and run it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import EngineConfig, WalkEngine, WalkProgram, analyze
from repro.graphs import power_law_graph
from repro.walks import node2vec


def main():
    # a skewed-degree graph with uniform property weights (paper's default)
    graph = power_law_graph(5_000, 12, weight_dist="uniform", seed=0)
    print(f"graph: {graph.num_nodes} nodes, {graph.num_edges} edges, "
          f"max degree {graph.max_degree()}")

    # --- built-in workload ------------------------------------------------
    wl = node2vec(a=2.0, b=0.5)
    compiled = analyze(wl)
    print(f"\n[flexi-compiler] {wl.name}: flag={compiled.flag} "
          f"(bound estimator synthesized from the jaxpr)")

    engine = WalkEngine(graph, wl, EngineConfig(method="adaptive"))
    res = engine.run(np.arange(512), num_steps=20)
    print(f"[flexi-runtime] 512 walks × 20 steps done; "
          f"{res.frac_rjs:.0%} of live steps served by eRJS, "
          f"{res.rjs_fallbacks} fallbacks to eRVS")
    print("first walk:", res.paths[0][:10], "...")

    # --- custom walk program (the paper's extensibility story) ------------
    def get_weight(ctx, params, mass):
        # prefer low-degree neighbours, damped by the property weight
        return ctx.h / jnp.sqrt(ctx.deg_prev.astype(jnp.float32) + 1.0)

    custom = WalkProgram(
        name="degree-damped", init=lambda: (), get_weight=get_weight,
        # per-walker state + early termination — things the legacy bare
        # Workload protocol could not express (docs/walk_programs.md):
        init_walker_state=lambda q: jnp.float32(1.0),
        on_step=lambda ctx, p, mass: mass * 0.85,
        should_stop=lambda ctx, p, mass: mass < 0.25,
        weighted=True)
    cw = analyze(custom)
    print(f"\n[flexi-compiler] custom program: flag={cw.flag}, "
          f"warnings={cw.warnings}")
    engine2 = WalkEngine(graph, custom, EngineConfig(method="adaptive"))
    res2 = engine2.run(np.arange(256), num_steps=10)
    emitted = int((res2.paths[:, 1:] >= 0).sum(axis=1).max())
    print(f"custom program ran: {res2.paths.shape}, "
          f"frac_rjs={res2.frac_rjs:.0%}, "
          f"longest walk before ε-stop: {emitted} steps")


if __name__ == "__main__":
    main()
