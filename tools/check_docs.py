#!/usr/bin/env python
"""Docs gate: broken intra-repo links + doctest of quickstart snippets.

Run from the repo root (the docs CI job does):

    PYTHONPATH=src python tools/check_docs.py

Checks every markdown file in README.md + docs/:

* each relative link ``[text](target)`` must resolve to an existing file
  or directory (anchors are stripped; http(s)/mailto links are skipped);
* every ``>>>`` example in the files (the README quickstart) must pass
  ``doctest``.

Exits non-zero with a per-problem report on failure.
"""
from __future__ import annotations

import doctest
import re
import sys
from pathlib import Path

# [text](target) — excludes images' leading "!" capture; tolerant of
# titles after the URL.  Good enough for the plain links these docs use.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")


def doc_files(root: Path) -> list[Path]:
    files = []
    readme = root / "README.md"
    if readme.exists():
        files.append(readme)
    files.extend(sorted((root / "docs").glob("**/*.md")))
    return files


def check_links(path: Path, root: Path) -> list[str]:
    problems = []
    text = path.read_text(encoding="utf-8")
    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        try:
            resolved.relative_to(root.resolve())
        except ValueError:
            problems.append(f"{path}: link escapes the repo: {target}")
            continue
        if not resolved.exists():
            problems.append(f"{path}: broken link: {target}")
    return problems


def run_doctests(path: Path) -> list[str]:
    # default flags — identical semantics to `python -m doctest <file>`
    results = doctest.testfile(
        str(path), module_relative=False, verbose=False)
    if results.failed:
        return [f"{path}: {results.failed}/{results.attempted} doctest "
                f"example(s) failed (run `python -m doctest {path.name}`)"]
    return []


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    problems: list[str] = []
    files = doc_files(root)
    if not files:
        print("check_docs: no markdown files found", file=sys.stderr)
        return 1
    for f in files:
        problems.extend(check_links(f, root))
    for f in files:
        problems.extend(run_doctests(f))
    if problems:
        for p in problems:
            print(f"FAIL {p}", file=sys.stderr)
        return 1
    print(f"check_docs: {len(files)} file(s) OK "
          f"({', '.join(str(f.relative_to(root)) for f in files)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
