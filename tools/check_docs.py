#!/usr/bin/env python
"""Docs gate: broken intra-repo links + doctest of quickstart snippets.

Run from the repo root (the docs CI job does):

    PYTHONPATH=src python tools/check_docs.py

Checks every markdown file in README.md + docs/:

* each relative link ``[text](target)`` must resolve to an existing file
  or directory (anchors are stripped; http(s)/mailto links are skipped);
* every ``>>>`` example in the files (the README quickstart) must pass
  ``doctest``;
* every ``--flag`` shown in a fenced launcher command (``LAUNCH_MODULES``:
  ``repro.launch.walk``, ``repro.launch.serve_walks``,
  ``repro.launch.walk_client``) must be accepted by that module's
  argparse parser, so removed/renamed CLI flags fail the gate instead
  of rotting in the docs;
* the hand-written README registry tables must list exactly the registered
  names: the sampler table against ``repro.core.available_samplers()`` and
  the workload table against ``repro.walks.WORKLOADS`` — a newly
  registered sampler/workload cannot ship undocumented, and rows for
  removed ones must go.

Exits non-zero with a per-problem report on failure.
"""
from __future__ import annotations

import doctest
import re
import sys
from pathlib import Path

# [text](target) — excludes images' leading "!" capture; tolerant of
# titles after the URL.  Good enough for the plain links these docs use.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

# fenced code blocks (``` ... ```); the flag check only looks inside these
_FENCE_RE = re.compile(r"```[^\n]*\n(.*?)```", re.DOTALL)
# a CLI long option: --word with dashes.  Underscored tokens (e.g. the
# XLA_FLAGS value --xla_force_host_platform_device_count=2) never match:
# the char class stops at "_" and \b cannot fall between word chars.
_FLAG_RE = re.compile(r"(?<![\w-])--([a-z][a-z0-9-]*)\b")


def doc_files(root: Path) -> list[Path]:
    files = []
    readme = root / "README.md"
    if readme.exists():
        files.append(readme)
    files.extend(sorted((root / "docs").glob("**/*.md")))
    return files


def check_links(path: Path, root: Path) -> list[str]:
    problems = []
    text = path.read_text(encoding="utf-8")
    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        try:
            resolved.relative_to(root.resolve())
        except ValueError:
            problems.append(f"{path}: link escapes the repo: {target}")
            continue
        if not resolved.exists():
            problems.append(f"{path}: broken link: {target}")
    return problems


# every audited launcher exposes its surface as ``build_parser()``; add
# new CLI modules here and their documented flags join the gate
LAUNCH_MODULES = ("repro.launch.walk", "repro.launch.serve_walks",
                  "repro.launch.walk_client")


def cli_flags(module: str) -> set[str]:
    """Option strings the module's ``build_parser()`` accepts (requires
    ``PYTHONPATH=src``, like the doctests)."""
    import importlib
    flags: set[str] = set()
    for action in importlib.import_module(module).build_parser()._actions:
        flags.update(action.option_strings)
    return flags


def walk_cli_flags() -> set[str]:
    """Back-compat alias: the ``repro.launch.walk`` flags."""
    return cli_flags("repro.launch.walk")


def check_cli_flags(path: Path,
                    known: set[str] | dict | None = None) -> list[str]:
    """Flag every documented ``<launcher> --option`` the launcher no
    longer accepts.  Only the *logical command lines* (backslash
    continuations joined) that invoke the module inside fenced code blocks
    are scanned, so prose dashes and other commands' flags — even in the
    same block — are ignored.

    ``known`` is a ``{module: flags}`` mapping; a bare set keeps the
    legacy meaning (the ``repro.launch.walk`` flags).  ``None`` audits
    every ``LAUNCH_MODULES`` entry."""
    text = path.read_text(encoding="utf-8")
    if isinstance(known, set):
        known = {"repro.launch.walk": known}
    elif known is None:
        known = {m: cli_flags(m) for m in LAUNCH_MODULES}
    logical = [ln
               for block in _FENCE_RE.findall(text)
               # join continuations even with trailing whitespace after \
               for ln in re.sub(r"\\[ \t]*\n", " ", block).splitlines()]
    problems = []
    for module, flags in known.items():
        # negative lookahead so repro.launch.walk never claims a
        # repro.launch.walk<anything> sibling's command lines
        mod_re = re.compile(re.escape(module) + r"(?![\w.])")
        for line in logical:
            if not mod_re.search(line):
                continue
            for m in _FLAG_RE.finditer(line):
                flag = "--" + m.group(1)
                if flag not in flags:
                    problems.append(
                        f"{path}: documented flag {flag} is not accepted "
                        f"by {module} (see build_parser())")
    return problems


def readme_table_rows(text: str, section: str) -> list[str]:
    """First-column backticked names of the markdown table under the given
    ``## <section>`` header (empty list if the section is missing)."""
    parts = text.split(f"## {section}", 1)
    if len(parts) < 2:
        return []
    body = parts[1].split("\n## ", 1)[0]
    return re.findall(r"^\|\s*`([\w-]+)`\s*\|", body, flags=re.M)


def check_registry_tables(root: Path) -> list[str]:
    """README registry tables vs the live registries (requires
    ``PYTHONPATH=src``, like the doctests)."""
    from repro.core import available_samplers
    from repro.walks import WORKLOADS

    text = (root / "README.md").read_text(encoding="utf-8")
    problems = []
    for section, expected in [("Sampler registry", list(available_samplers())),
                              ("Workloads", sorted(WORKLOADS))]:
        rows = readme_table_rows(text, section)
        if not rows:
            problems.append(f"README.md: no registry table found under "
                            f"'## {section}'")
            continue
        if rows != sorted(rows):
            problems.append(f"README.md: '## {section}' table must be "
                            f"sorted like the registry")
        if rows != expected:
            problems.append(
                f"README.md: '## {section}' table out of sync with the "
                f"registry (missing: {sorted(set(expected) - set(rows))}, "
                f"stale: {sorted(set(rows) - set(expected))})")
    return problems


def run_doctests(path: Path) -> list[str]:
    # default flags — identical semantics to `python -m doctest <file>`
    results = doctest.testfile(
        str(path), module_relative=False, verbose=False)
    if results.failed:
        return [f"{path}: {results.failed}/{results.attempted} doctest "
                f"example(s) failed (run `python -m doctest {path.name}`)"]
    return []


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    problems: list[str] = []
    files = doc_files(root)
    if not files:
        print("check_docs: no markdown files found", file=sys.stderr)
        return 1
    for f in files:
        problems.extend(check_links(f, root))
    known_flags = {m: cli_flags(m) for m in LAUNCH_MODULES}
    for f in files:
        problems.extend(check_cli_flags(f, known_flags))
    problems.extend(check_registry_tables(root))
    for f in files:
        problems.extend(run_doctests(f))
    if problems:
        for p in problems:
            print(f"FAIL {p}", file=sys.stderr)
        return 1
    print(f"check_docs: {len(files)} file(s) OK "
          f"({', '.join(str(f.relative_to(root)) for f in files)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
